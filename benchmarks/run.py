"""Benchmark harness — one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (one per measured quantity)
and writes detailed artifacts (trajectories, tables) to ``results/``.

  PYTHONPATH=src python -m benchmarks.run              # default (quick-ish)
  PYTHONPATH=src python -m benchmarks.run --full       # paper-scale rounds
  PYTHONPATH=src python -m benchmarks.run --only fig1
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

RESULTS = pathlib.Path("results")


def _csv(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------
# Fig. 1: optimality gap vs communication rounds, all methods
# ---------------------------------------------------------------------------

def bench_fig1_convergence(full: bool) -> None:
    from benchmarks.paper_common import build_problem, fig1_methods, run_method

    datasets = ["phishing"] + (["covtype", "susy"] if full else [])
    rounds = 30 if full else 20
    for ds in datasets:
        spec, prob, w0, w_star = build_problem(ds, n_cap=None if full else 20000)
        out = {"dataset": ds, "rounds": rounds, "methods": {}}
        for name, kw in fig1_methods(spec):
            hist = run_method(name, kw, prob, w0, w_star, rounds)
            out["methods"][hist.name] = {
                "gap": hist.gap.tolist(),
                "uplink_floats_per_round": hist.uplink_floats,
                "wall_s": hist.wall_time_s,
            }
            # derived: rounds to reach 1e-6 gap (paper's convergence metric)
            reach = np.argmax(hist.gap < 1e-6) if (hist.gap < 1e-6).any() else -1
            _csv(
                f"fig1/{ds}/{hist.name}",
                hist.wall_time_s / rounds * 1e6,
                f"gap_final={hist.gap[-1]:.3e};rounds_to_1e-6={reach}",
            )
        (RESULTS / "fig1").mkdir(parents=True, exist_ok=True)
        (RESULTS / "fig1" / f"{ds}.json").write_text(json.dumps(out, indent=1))


# ---------------------------------------------------------------------------
# Fig. 2: loss discrepancy vs sketch size
# ---------------------------------------------------------------------------

def bench_fig2_sketch_size(full: bool) -> None:
    from benchmarks.paper_common import build_problem, run_method

    spec, prob, w0, w_star = build_problem("phishing",
                                           n_cap=None if full else 20000)
    rounds = 25 if full else 15
    ks = [4, 8, 16, 32, 64, 128] if full else [8, 16, 32, 64]
    out = {"dataset": "phishing", "rounds": rounds, "gap_vs_k": {}}
    for k in ks:
        hist = run_method("flens", dict(k=k), prob, w0, w_star, rounds)
        out["gap_vs_k"][k] = float(hist.gap[-1])
        _csv(f"fig2/phishing/flens_k{k}", hist.wall_time_s / rounds * 1e6,
             f"gap_final={hist.gap[-1]:.3e}")
    # monotonicity check (paper: larger k -> closer to Newton)
    ks_sorted = sorted(out["gap_vs_k"])
    mono = all(out["gap_vs_k"][a] >= out["gap_vs_k"][b] * 0.5
               for a, b in zip(ks_sorted, ks_sorted[1:]))
    _csv("fig2/monotone_in_k", 0.0, f"monotone={mono}")
    (RESULTS / "fig2.json").write_text(json.dumps(out, indent=1))


# ---------------------------------------------------------------------------
# Fig. 3: computational time vs sketch size (FLeNS vs FedNS/FedNDES)
# ---------------------------------------------------------------------------

def bench_fig3_time_vs_sketch(full: bool) -> None:
    from benchmarks.paper_common import build_problem, run_method

    spec, prob, w0, w_star = build_problem("phishing",
                                           n_cap=None if full else 20000)
    rounds = 10 if full else 6
    ks = [8, 16, 32, 64] if not full else [8, 16, 32, 64, 128]
    out = {}
    for k in ks:
        row = {}
        for name in ("flens", "fedns"):
            hist = run_method(name, dict(k=k), prob, w0, w_star, rounds)
            per_round = hist.wall_time_s / rounds
            row[name] = per_round
            _csv(f"fig3/{name}_k{k}", per_round * 1e6,
                 f"gap_final={hist.gap[-1]:.3e}")
        out[k] = row
    (RESULTS / "fig3.json").write_text(json.dumps(out, indent=1))


# ---------------------------------------------------------------------------
# Table I: per-round communication (measured, floats per client)
# ---------------------------------------------------------------------------

def bench_table1_communication(full: bool) -> None:
    from benchmarks.paper_common import build_problem, fig1_methods
    from repro.core import make_optimizer

    spec, prob, w0, w_star = build_problem("phishing", n_cap=5000)
    m_dim, k = prob.dim, spec.sketch_k
    rows = []
    for name, kw in fig1_methods(spec):
        opt = make_optimizer(name, **kw)
        opt.init(prob, w0)  # fedndes resolves its adaptive k here
        up = opt.uplink_floats(prob)
        down = opt.downlink_floats(prob)
        rows.append((opt.name, up, down))
        _csv(f"table1/{opt.name}", 0.0, f"uplink_floats={up};downlink={down}")
    # the paper's headline claim: FLeNS uplink O(k^2) << FedNS O(kM)
    up = {r[0]: r[1] for r in rows}
    claim = up["flens"] < up["fedns"] and up["flens"] < up["fednewton"]
    _csv("table1/flens_cheapest_newton_type", 0.0, f"claim_holds={claim}")
    (RESULTS / "table1.json").write_text(json.dumps(
        {"M": m_dim, "k": k, "rows": rows}, indent=1))


# ---------------------------------------------------------------------------
# Comm: loss vs transmitted bytes / simulated time through repro.comm
# ---------------------------------------------------------------------------

def bench_comm(full: bool) -> None:
    """Loss-vs-bytes and loss-vs-simulated-time for FLeNS under the
    simulated transport: identity codec vs symmetric-pack + int8 on the
    sketched Hessian, vs a bf16-compressed model BROADCAST (the
    symmetric downlink direction — asserted to strictly lower both
    transport axes at a bounded loss gap), all under a 10%-dropout
    full-participation channel; plus error-feedback on/off curves for a
    top-k-crushed O(M) uplink (fedavg), whose ``ef_gap_shrink`` ratio
    records how much of the compression floor EF21 memory recovers at
    identical byte cost. Also asserts the backward-compat contract:
    identity codec + full participation reproduces the no-comm
    trajectory exactly.

    The sketch-policy axis (``SketchPolicy`` spec per variant) rides in
    every record; its headline is the ``flens_rot_ef`` pair: the same
    top-k-crushed sketch payloads under a fresh per-round basis (EF
    requested but ineligible — cross-round memory is meaningless there)
    vs a rotating ``srht:rotate=8`` basis (EF eligible by
    ``basis_persistent``), asserted strictly lower loss at exactly
    equal bytes — the cross-round sketch closing the sketch-payload
    compression floor the PR-2 ROADMAP item predicted."""
    from benchmarks.paper_common import (
        build_problem, ef_gap_shrink, ef_ratio_label, run_method)
    from repro.comm import ChannelModel, CommConfig, summarize
    from repro.core import make_optimizer, run_rounds

    spec, prob, w0, w_star = build_problem("phishing",
                                           n_cap=None if full else 20000)
    rounds = 25 if full else 12
    k = spec.sketch_k

    # contract check: identity/full-participation == legacy, bit for bit
    base = run_method("flens", dict(k=k), prob, w0, w_star, rounds)
    ident = run_rounds(make_optimizer("flens", k=k), prob, w0, w_star,
                       rounds=rounds, comm=CommConfig())
    exact = bool(np.array_equal(base.loss, ident.loss))
    _csv("comm/identity_reproduces_legacy", 0.0, f"exact={exact}")
    assert exact, "identity-codec comm path diverged from the legacy driver"

    # accounting cross-check: the formula-derived uplink byte curve
    # (History.cumulative_uplink — per-client floats × itemsize × m)
    # must equal the traced per-round uplink bytes on the identity/full
    # path, where every client delivers the raw wire format
    traced_up = sum(float(t.bytes_up.sum()) for t in ident.traces)
    formula_up = float(ident.cumulative_uplink[-1])
    _csv("comm/uplink_formula_matches_traced", 0.0,
         f"formula={formula_up:.0f};traced={traced_up:.0f};"
         f"match={bool(abs(formula_up - traced_up) < 0.5)}")
    assert abs(formula_up - traced_up) < 0.5, (
        f"cumulative_uplink formula ({formula_up}) disagrees with traced "
        f"bytes ({traced_up})")

    channel = ChannelModel(dropout_prob=0.10, straggler_prob=0.10)
    # the sketch-policy pair: identical top-k-crushed sketch payloads,
    # fresh vs rotating basis — only the rotating one can use EF
    sketch_topk = {"h_sk": "topk0.25", "sg": "topk0.5"}
    variants = [
        ("flens_identity", "flens", dict(k=k),
         CommConfig(channel=channel, seed=1)),
        ("flens_sympack_qint8", "flens", dict(k=k), CommConfig(
            codecs={"h_sk": "sympack+qint8", "sg": "qint8"},
            channel=channel, seed=1)),
        # the symmetric direction: compress the server's model broadcast
        # (identity uplink, so the saving is purely downlink)
        ("flens_down_bf16", "flens", dict(k=k), CommConfig(
            downlink_codecs="bf16", channel=channel, seed=1)),
        # the policy axis: EF is requested in BOTH runs; the fresh basis
        # is ineligible (basis_persistent -> False), the rotating basis
        # carries EF21 memory on h_sk/sg across its 8-round epochs
        ("flens_fresh_topk", "flens", dict(k=k, sketch="srht"), CommConfig(
            codecs=sketch_topk, error_feedback=True, channel=channel,
            seed=1)),
        ("flens_rot_ef", "flens", dict(k=k, sketch="srht:rotate=8"),
         CommConfig(codecs=sketch_topk, error_feedback=True,
                    channel=channel, seed=1)),
        # EF on/off under a biased codec that actually bites: fedavg's
        # O(M) model uplink at topk0.05 (5% of coordinates per round)
        ("fedavg_identity", "fedavg", dict(lr=2.0, local_steps=5),
         CommConfig(channel=channel, seed=1)),
        ("fedavg_topk_ef_off", "fedavg", dict(lr=2.0, local_steps=5),
         CommConfig(codecs="topk0.05", channel=channel, seed=1)),
        ("fedavg_topk_ef_on", "fedavg", dict(lr=2.0, local_steps=5),
         CommConfig(codecs="topk0.05", error_feedback=True,
                    channel=channel, seed=1)),
    ]
    out = {"dataset": spec.name, "rounds": rounds, "k": k, "variants": {}}
    finals = {}
    for name, opt_name, opt_kw, comm in variants:
        opt = make_optimizer(opt_name, **opt_kw)
        policy = getattr(opt, "policy", None)
        hist = run_rounds(opt, prob, w0, w_star, rounds=rounds, comm=comm)
        stats = summarize(hist.traces)
        finals[name] = float(hist.loss[-1])
        out["variants"][name] = {
            "policy": policy.spec() if policy is not None else None,
            "gap": hist.gap.tolist(),
            "loss_final": float(hist.loss[-1]),
            "cumulative_bytes": hist.cumulative_bytes.tolist(),
            "sim_time_s": hist.sim_time_s.tolist(),
            "stats": stats,
            "ef_residuals": hist.ef_residuals,
        }
        policy_label = f";policy={policy.spec()}" if policy is not None else ""
        _csv(
            f"comm/{name}",
            hist.wall_time_s / rounds * 1e6,
            f"gap_final={hist.gap[-1]:.3e};"
            f"total_MB={hist.cumulative_bytes[-1] / 1e6:.3f};"
            f"sim_s={hist.sim_time_s[-1]:.2f}" + policy_label,
        )
    ident_b = out["variants"]["flens_identity"]["cumulative_bytes"][-1]
    packed_b = out["variants"]["flens_sympack_qint8"]["cumulative_bytes"][-1]
    _csv("comm/bytes_saved_by_sympack_qint8", 0.0,
         f"ratio={ident_b / max(packed_b, 1):.2f}x")

    # downlink-compression acceptance: the bf16 broadcast must strictly
    # lower BOTH transport axes vs the identity broadcast at a bounded
    # final-loss gap (the guard absorbs the broadcast rounding noise)
    ident_v = out["variants"]["flens_identity"]
    down_v = out["variants"]["flens_down_bf16"]
    gap_id = float(ident_v["gap"][-1])
    gap_dn = float(down_v["gap"][-1])
    out["downlink"] = {
        "bytes_identity": ident_v["cumulative_bytes"][-1],
        "bytes_bf16": down_v["cumulative_bytes"][-1],
        "sim_identity": ident_v["sim_time_s"][-1],
        "sim_bf16": down_v["sim_time_s"][-1],
        "gap_identity": gap_id,
        "gap_bf16": gap_dn,
    }
    saves = (down_v["cumulative_bytes"][-1] < ident_v["cumulative_bytes"][-1]
             and down_v["sim_time_s"][-1] < ident_v["sim_time_s"][-1])
    _csv("comm/downlink_bf16_saves", 0.0,
         f"bytes_ratio={ident_v['cumulative_bytes'][-1] / max(down_v['cumulative_bytes'][-1], 1):.2f}x;"
         f"sim_ratio={ident_v['sim_time_s'][-1] / max(down_v['sim_time_s'][-1], 1e-9):.2f}x;"
         f"gap_identity={gap_id:.3e};gap_bf16={gap_dn:.3e};"
         f"strictly_lower={bool(saves)}")
    assert saves, (
        "bf16 downlink did not strictly lower both cumulative_bytes and "
        f"sim_time_s: {out['downlink']}")
    assert np.isfinite(gap_dn) and gap_dn < max(10.0 * gap_id, 1e-2), (
        f"bf16 broadcast loss gap unbounded: {gap_dn} vs identity {gap_id}")

    # sketch-policy acceptance: rotating-SRHT + EF21 must strictly beat
    # the fresh basis at EXACTLY equal bytes — EF never changes encoded
    # sizes, and both runs crush h_sk/sg with the same top-k codecs, so
    # the whole loss difference is the cross-round basis unlocking EF
    fresh_v = out["variants"]["flens_fresh_topk"]
    rot_v = out["variants"]["flens_rot_ef"]
    bytes_equal = fresh_v["cumulative_bytes"] == rot_v["cumulative_bytes"]
    gap_fresh, gap_rot = float(fresh_v["gap"][-1]), float(rot_v["gap"][-1])
    out["rot_ef"] = {
        "policy_fresh": fresh_v["policy"],
        "policy_rot": rot_v["policy"],
        "gap_fresh": gap_fresh,
        "gap_rot": gap_rot,
        "bytes": rot_v["cumulative_bytes"][-1],
        "bytes_equal": bool(bytes_equal),
        "ef_residuals_rot": rot_v["ef_residuals"],
    }
    _csv("comm/flens_rot_ef_closes_sketch_floor", 0.0,
         f"gap_fresh={gap_fresh:.3e};gap_rot={gap_rot:.3e};"
         f"ratio={gap_fresh / max(gap_rot, 1e-30):.2f}x;"
         f"equal_bytes={bool(bytes_equal)};"
         f"strictly_lower={bool(gap_rot < gap_fresh)}")
    assert bytes_equal, (
        "rotating-basis run must cost exactly the bytes of the fresh-basis "
        "run (EF and the schedule change values, never sizes)")
    assert finals["flens_rot_ef"] < finals["flens_fresh_topk"], (
        f"rotating-SRHT + EF did not beat the fresh basis at equal bytes: "
        f"{finals['flens_rot_ef']} vs {finals['flens_fresh_topk']}")
    # EF's headline number: how much of the loss gap to the
    # no-compression baseline the memory recovers (same encoded bytes)
    shrink = ef_gap_shrink(finals["fedavg_identity"],
                           finals["fedavg_topk_ef_off"],
                           finals["fedavg_topk_ef_on"])
    out["ef_gap_shrink"] = shrink
    off_b = out["variants"]["fedavg_topk_ef_off"]["cumulative_bytes"][-1]
    on_b = out["variants"]["fedavg_topk_ef_on"]["cumulative_bytes"][-1]
    _csv("comm/ef_gap_shrink", 0.0,
         f"ratio={ef_ratio_label(shrink)}x;ef_off_gap={shrink['ef_off']:.3e};"
         f"ef_on_gap={shrink['ef_on']:.3e};"
         f"same_bytes={bool(off_b == on_b)}")

    # population scale: the same seeded gate at m=100 000 with lazy
    # cohort materialization (uniform:1e-3 -> ~100 clients per round;
    # the dense (m, n_shard, M) tensor never exists). Byte accounting
    # stays exact under the gate: cohorts, channel draws, and codec
    # keys are pure functions of CommConfig.seed, and the trace stores
    # cohort-length arrays so the record stays small at this m.
    from repro.core import SyntheticPopulation, newton_solve

    pop_m, pop_q = 100_000, 1e-3
    pop = SyntheticPopulation(m=pop_m, dim=16, seed=1, dirichlet_alpha=0.3)
    w0_pop = np.zeros(pop.dim)
    w_star_pop = newton_solve(pop.eval_problem(), w0_pop)
    pop_comm = CommConfig(
        codecs={"h_sk": "sympack+qint8", "sg": "qint8"},
        channel=ChannelModel(
            uplink_bytes_per_s="loguniform:3e4,3e6",
            downlink_bytes_per_s="loguniform:3e5,3e7",
            latency_s=0.08, straggler_prob=0.20, straggler_slowdown=10.0,
            dropout_prob=0.10),
        scheduler=f"uniform:{pop_q}", seed=1)
    hist = run_rounds(make_optimizer("flens", k=8), pop, w0_pop,
                      w_star_pop, rounds=rounds, comm=pop_comm)
    cohort = max(len(t.ids) for t in hist.traces)
    assert cohort < 4 * pop_q * pop_m, (
        f"population cohorts should stay near q*m={pop_q * pop_m:.0f}, "
        f"got {cohort} — lazy materialization is not bounding the round")
    out["variants"]["flens_population_100k"] = {
        "policy": None,
        "gap": hist.gap.tolist(),
        "loss_final": float(hist.loss[-1]),
        "cumulative_bytes": hist.cumulative_bytes.tolist(),
        "sim_time_s": hist.sim_time_s.tolist(),
        "stats": summarize(hist.traces),
        "ef_residuals": hist.ef_residuals,
        "population": pop_m,
        "q": pop_q,
        "cohort": cohort,
    }
    _csv("comm/flens_population_100k", hist.wall_time_s / rounds * 1e6,
         f"gap_final={hist.gap[-1]:.3e};"
         f"total_MB={hist.cumulative_bytes[-1] / 1e6:.3f};"
         f"sim_s={hist.sim_time_s[-1]:.2f};"
         f"population={pop_m};cohort={cohort}")

    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "comm.json").write_text(json.dumps(out, indent=1))


# ---------------------------------------------------------------------------
# Robust: Byzantine sign-flip coalition, with/without trimmed-mean defense
# ---------------------------------------------------------------------------

def bench_robust(full: bool) -> None:
    """FLeNS vs FedAvg under a seeded 10% sign-flip coalition
    (``DynamicsConfig(threat="signflip:0.1")``), with and without the
    ``trimmed:0.1`` coordinate-wise trimmed mean, on the heterogeneous
    edge channel. Loss-vs-bytes is the axis that matters: the attack
    and the defense never change wire formats, so every variant of one
    optimizer transmits EXACTLY the same bytes and the entire loss
    difference is the coalition vs the aggregator.

    Gates (seeded, deterministic):

      * **fedavg** — the coalition flips the O(M) model uplink, a real
        attack; the trimmed mean must recover at least 2x of the
        final-loss gap it opens (``gap(attacked) >= 2 * gap(defended)``).
      * **flens** — sign-flipping BOTH sketch payloads (``h_sk`` and
        ``sg``) rescales the Hessian estimate and the gradient estimate
        by the same factor, and the Newton step ``H^-1 g``
        self-normalizes: the attack must open a SMALLER gap than it
        does on fedavg, and the defended run must stay within a small
        absolute band of clean (the trimmed mean's own bias bound).

    The records merge into ``results/comm.json`` so
    ``benchmarks/compare.py`` (and ``--update``) gates their bytes
    exactly and losses at rtol alongside the other comm variants.
    """
    from benchmarks.paper_common import build_problem, straggler_edge_channel
    from repro.comm import CommConfig, summarize
    from repro.core import make_optimizer, run_rounds
    from repro.dynamics import DynamicsConfig

    spec, prob, w0, w_star = build_problem("phishing",
                                           n_cap=None if full else 20000)
    rounds = 20 if full else 10
    k = spec.sketch_k
    channel = straggler_edge_channel(prob.m)
    threat, robust = "signflip:0.1", "trimmed:0.1"

    def comm(threat_spec=None, robust_spec=None):
        dyn = None
        if threat_spec or robust_spec:
            dyn = DynamicsConfig(threat=threat_spec, robust=robust_spec,
                                 seed=1)
        return CommConfig(channel=channel, seed=1, dynamics=dyn)

    lineup = [("flens", dict(k=k)),
              ("fedavg", dict(lr=2.0, local_steps=5))]
    arms = [("clean", None, None),
            ("attacked", threat, None),
            ("trimmed", threat, robust)]
    out = {"dataset": spec.name, "rounds": rounds, "m": prob.m, "k": k,
           "threat": threat, "robust": robust, "variants": {}}
    finals: dict = {}
    for opt_name, opt_kw in lineup:
        bytes_by_arm = {}
        for arm, t_spec, r_spec in arms:
            name = f"robust_{opt_name}_{arm}"
            hist = run_rounds(make_optimizer(opt_name, **opt_kw), prob, w0,
                              w_star, rounds=rounds,
                              comm=comm(t_spec, r_spec))
            finals[name] = float(hist.loss[-1])
            bytes_by_arm[arm] = hist.cumulative_bytes.tolist()
            out["variants"][name] = {
                "loss": hist.loss.tolist(),
                "loss_final": float(hist.loss[-1]),
                "cumulative_bytes": bytes_by_arm[arm],
                "stats": summarize(hist.traces),
            }
            _csv(f"robust/{name}", hist.wall_time_s / rounds * 1e6,
                 f"loss_final={hist.loss[-1]:.6f};"
                 f"total_MB={hist.cumulative_bytes[-1] / 1e6:.3f}")
        assert (bytes_by_arm["clean"] == bytes_by_arm["attacked"]
                == bytes_by_arm["trimmed"]), (
            f"{opt_name}: threat/robust changed the byte accounting — "
            "corruption and aggregation must never touch wire formats")
        gap_att = finals[f"robust_{opt_name}_attacked"] - finals[
            f"robust_{opt_name}_clean"]
        gap_def = finals[f"robust_{opt_name}_trimmed"] - finals[
            f"robust_{opt_name}_clean"]
        recovery = gap_att / max(gap_def, 1e-30)
        out.setdefault("robust_gate", {})[opt_name] = {
            "gap_attacked": gap_att,
            "gap_defended": gap_def,
            "recovery": recovery,
        }
        _csv(f"robust/{opt_name}_gate", 0.0,
             f"gap_attacked={gap_att:.3e};gap_defended={gap_def:.3e};"
             f"recovery={recovery:.1f}x")
        assert gap_att > 0, (
            f"{opt_name}: the sign-flip coalition did not hurt — the "
            "threat is not reaching the uplink")

    gates = out["robust_gate"]
    rec = gates["fedavg"]["recovery"]
    assert rec >= 2.0, (
        f"fedavg: trimmed mean recovered only {rec:.2f}x of the attack's "
        f"loss gap ({gates['fedavg']}); gate needs >= 2x")
    # the comparison headline: the Newton step self-normalizes, so the
    # same coalition hurts flens strictly less than fedavg — and the
    # trimmed mean's own bias stays within a small absolute band
    assert gates["flens"]["gap_attacked"] < gates["fedavg"]["gap_attacked"], (
        f"flens should be naturally MORE robust to proportional "
        f"sign-flips than fedavg: {gates}")
    assert abs(gates["flens"]["gap_defended"]) < 1e-2, (
        f"flens trimmed-mean bias left the clean band: {gates['flens']}")
    _csv("robust/gate", 0.0,
         f"fedavg_recovery={rec:.1f}x;"
         f"flens_self_normalizes="
         f"{bool(gates['flens']['gap_attacked'] < gates['fedavg']['gap_attacked'])}")

    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "robust.json").write_text(json.dumps(out, indent=1))
    # ride the comm regression gate: merge the seeded records into
    # results/comm.json so compare.py (and --update) pins their bytes
    # exactly and losses at rtol like every other comm variant
    comm_path = RESULTS / "comm.json"
    if comm_path.exists():
        doc = json.loads(comm_path.read_text())
        doc["variants"].update(out["variants"])
        doc["robust_gate"] = out["robust_gate"]
        comm_path.write_text(json.dumps(doc, indent=1))
        _csv("robust/merged_into_comm_record", 0.0,
             f"variants={len(out['variants'])}")


# ---------------------------------------------------------------------------
# Async: loss vs simulated time, synchronous vs event-driven driver
# ---------------------------------------------------------------------------

def bench_async(full: bool) -> None:
    """Sync vs async round driver under stragglers on heterogeneous edge
    links: the synchronous server waits for the slowest delivering
    client every round, the async server commits once a FedBuff-style
    buffer of K uploads has arrived, weighting stale contributions by
    1/(1+tau). Records loss at the latest common simulated-time point
    (``async_beats_sync``: the headline loss-vs-sim-time comparison) and
    asserts the lock-step anchor: async with a full quorum reproduces
    the synchronous trajectory bit-identically."""
    from benchmarks.paper_common import (
        build_problem,
        check_async_lockstep_anchor,
        hist_record,
        loss_at,
        straggler_edge_channel,
        sync_async_race,
    )
    from repro.core import make_optimizer

    spec, prob, w0, w_star = build_problem("phishing",
                                           n_cap=None if full else 20000)
    rounds = 20 if full else 10
    m = prob.m
    channel = straggler_edge_channel(m)

    def fedavg():
        return make_optimizer("fedavg", lr=2.0, local_steps=5)

    # lock-step anchor: full-quorum async == sync, bit for bit
    exact, _, _ = check_async_lockstep_anchor(fedavg, prob, w0, w_star,
                                              channel, rounds=4)
    _csv("async/full_quorum_reproduces_sync", 0.0, f"exact={exact}")
    assert exact, "full-quorum async diverged from the synchronous driver"

    out = {"dataset": spec.name, "rounds": rounds, "m": m,
           "straggler_prob": channel.straggler_prob, "variants": {}}
    hists = sync_async_race(fedavg, prob, w0, w_star, channel, rounds=rounds)
    for name, hist in hists.items():
        out["variants"][name] = hist_record(hist)
        r = hist.rounds
        _csv(f"async/{name}", hist.wall_time_s / r * 1e6,
             f"gap_final={hist.gap[-1]:.3e};"
             f"sim_s={hist.sim_time_s[-1]:.2f};rounds={r}")

    sync_h = hists["sync"]
    failures = []
    for name in ("async_buf", "async_q50"):
        av = out["variants"][name]
        t_common = min(sync_h.sim_time_s[-1], hists[name].sim_time_s[-1])
        loss_sync = loss_at(sync_h, t_common)
        loss_async = loss_at(hists[name], t_common)
        beats = bool(loss_async < loss_sync)
        av["loss_at_common_sim_time"] = {
            "t": t_common, "sync": loss_sync, "async": loss_async}
        _csv(f"async/{name}_beats_sync_at_t", 0.0,
             f"t={t_common:.1f}s;sync={loss_sync:.6f};"
             f"async={loss_async:.6f};beats={beats}")
        if not beats:
            failures.append(
                f"{name}: async ({loss_async}) did not beat sync "
                f"({loss_sync}) on loss-vs-sim-time at t={t_common}")
    # persist the curves BEFORE asserting: a failed dominance check is
    # exactly when the per-variant diagnostics are needed
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "async.json").write_text(json.dumps(out, indent=1))
    assert not failures, "; ".join(failures)


# ---------------------------------------------------------------------------
# Perf trajectory: per-optimizer compile/exec wall-clock + bytes + loss
# ---------------------------------------------------------------------------

# the representative per-family lineup the perf trajectory tracks (one
# first-order, one exact-Newton, and the three sketched-Newton variants
# the paper headlines); kwargs as in fig1_methods
_ROUND_TIME_OPTS = [
    ("fedavg", lambda k: dict(lr=2.0, local_steps=5)),
    ("fednewton", lambda k: {}),
    ("fedns", lambda k: dict(k=k)),
    ("flens", lambda k: dict(k=k)),
    ("flens_plus", lambda k: dict(k=k)),
]

# committed at the repo root: the tracked perf-trajectory artifact
# (schema-checked by `python -m repro.obs.report --check-schema`, gated
# by `python benchmarks/compare.py --bench`)
BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / (
    "BENCH_round_time.json")


def bench_round_time(full: bool) -> None:
    """The tracked wall-clock axis: run the representative optimizer
    lineup through the instrumented round driver and emit
    ``BENCH_round_time.json`` (repo root) with, per optimizer, the
    compile-vs-execute wall-clock split (from ``repro.obs`` telemetry —
    first jitted-round call billed as compile), the exact transported
    bytes, and the loss reached at a common byte budget (the smallest
    total any optimizer transmitted, so every method is compared at
    bytes it actually reached). Bytes and losses are pure functions of
    ``CommConfig.seed`` — deterministic, gated against the committed
    baseline by ``benchmarks/compare.py --bench``; wall-clock fields are
    machine-dependent and gated only by a generous slowdown factor.

    The full per-round telemetry stream (phase timings, per-round
    records) lands in ``results/telemetry_round_time.jsonl`` — one
    artifact, one run per optimizer label, rendered by
    ``python -m repro.obs.report``. When roofline dry-run artifacts are
    present (``results/dryrun*``), their per-arch dominant-term summary
    is attached under ``"roofline"`` so the accelerator-model axis rides
    the same tracked file.
    """
    from benchmarks.paper_common import build_problem
    from benchmarks.roofline import aggregate
    from repro.comm import CommConfig
    from repro.core import make_optimizer, run_rounds
    from repro.obs import TelemetryConfig
    from repro.obs.report import BENCH_SCHEMA

    spec, prob, w0, w_star = build_problem("phishing",
                                           n_cap=None if full else 20000)
    rounds = 20 if full else 12
    k = spec.sketch_k
    telemetry_path = RESULTS / "telemetry_round_time.jsonl"
    telemetry_path.unlink(missing_ok=True)  # the jsonl sink appends

    opts: dict = {}
    hists: dict = {}
    for name, kw_fn in _ROUND_TIME_OPTS:
        hist = run_rounds(
            make_optimizer(name, **kw_fn(k)), prob, w0, w_star,
            rounds=rounds, comm=CommConfig(seed=1),
            obs=TelemetryConfig(sink=f"jsonl:{telemetry_path}", label=name))
        tel = hist.telemetry
        hists[name] = hist
        opts[name] = {
            # wall-clock (machine-dependent; gated by ratio only)
            "compile_s": tel["compile_s"],
            "exec_s": tel["exec_s"],
            "exec_s_per_round": tel["exec_s_per_round"],
            "wall_time_s": hist.wall_time_s,
            # deterministic (gated exactly / at loss rtol)
            "bytes_total": float(hist.cumulative_bytes[-1]),
            "uplink_floats": int(hist.uplink_floats),
            "loss_final": float(hist.loss[-1]),
        }
        _csv(f"round_time/{name}", tel["exec_s_per_round"] * 1e6,
             f"compile_s={tel['compile_s']:.3f};"
             f"bytes_total={hist.cumulative_bytes[-1]:.0f};"
             f"loss_final={hist.loss[-1]:.6f}")

    # loss at the common byte budget: the smallest total transmitted —
    # every optimizer's curve is interpolated at bytes it reached
    budget = min(row["bytes_total"] for row in opts.values())
    for name, hist in hists.items():
        opts[name]["loss_at_budget"] = float(
            np.interp(budget, hist.cumulative_bytes, hist.loss))

    doc = {
        "schema": BENCH_SCHEMA,
        "dataset": spec.name,
        "rounds": rounds,
        "clients": prob.m,
        "budget_bytes": budget,
        "optimizers": opts,
    }
    dryrun = RESULTS / ("dryrun_opt" if (RESULTS / "dryrun_opt").exists()
                        else "dryrun")
    if dryrun.exists():
        doc["roofline"] = [
            {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
             "status": r["status"],
             **({"dominant": r["roofline"]["dominant"],
                 "compute_s": r["roofline"]["compute_s"],
                 "memory_s": r["roofline"]["memory_s"],
                 "collective_s": r["roofline"]["collective_s"]}
                if r["status"] == "ok" else {})}
            for r in aggregate(dryrun)
        ]
    BENCH_PATH.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    _csv("round_time/artifact", 0.0,
         f"budget_MB={budget / 1e6:.3f};wrote={BENCH_PATH.name}")


# ---------------------------------------------------------------------------
# Kernel micro-benchmarks (CPU timings of the portable paths)
# ---------------------------------------------------------------------------

def bench_kernels(full: bool) -> None:
    from repro.kernels import ops as kops
    from repro.kernels import ref

    # Fused SRHT dispatch (sign-flip -> FWHT -> row-subsample) on the
    # active backend: `kernels/srht_*` rows track the sketch hot loop
    # end-to-end through repro.kernels.ops — the exact code path
    # Sketch.apply runs inside every sketched optimizer. On CPU the
    # resolver picks the reference path; on TPU the same rows time the
    # fused Pallas kernel, so speedups land in this CSV unchanged.
    impl = kops.resolve_impl()
    for n in (1024, 4096):
        k = n // 16
        key = jax.random.PRNGKey(0)
        signs = jax.random.rademacher(key, (n,), jnp.float32)
        rows_idx = jax.random.choice(jax.random.PRNGKey(1), n, (k,),
                                     replace=False)
        x = jax.random.normal(jax.random.PRNGKey(2), (64, n), jnp.float32)
        y = jax.random.normal(jax.random.PRNGKey(3), (64, k), jnp.float32)
        fwd = jax.jit(lambda x: kops.srht_apply(x, signs, rows_idx))
        bwd = jax.jit(lambda y: kops.srht_apply_t(y, signs, rows_idx, n))
        for tag, fn, arg in (("fwd", fwd, x), ("t", bwd, y)):
            fn(arg).block_until_ready()
            t0 = time.perf_counter()
            iters = 20
            for _ in range(iters):
                fn(arg).block_until_ready()
            dt = (time.perf_counter() - t0) / iters
            _csv(f"kernels/srht_{tag}_{impl}_n{n}", dt * 1e6,
                 f"k={k};rows=64")

    # Fused codec inner loops (the transport hot path) through the same
    # dispatch: top-k select+pack and qint8 quantize->dequantize
    size = 4096 * 16
    x = jax.random.normal(jax.random.PRNGKey(4), (size,), jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(5), (size,), jnp.float32)
    kept = size // 20
    codec_fns = (
        (f"topk_{impl}", jax.jit(lambda x: kops.topk_mask(x, kept)), (x,)),
        (f"qint8_{impl}", jax.jit(kops.qint8_roundtrip), (x, u)),
    )
    for tag, fn, args_ in codec_fns:
        fn(*args_).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(20):
            fn(*args_).block_until_ready()
        dt = (time.perf_counter() - t0) / 20
        _csv(f"kernels/{tag}_n{size}", dt * 1e6, f"kept={kept}")

    # FWHT: the SRHT hot loop
    for n in (1024, 4096):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, n), jnp.float32)
        f = jax.jit(lambda x: ref.fwht(x))
        f(x).block_until_ready()
        t0 = time.perf_counter()
        iters = 20
        for _ in range(iters):
            f(x).block_until_ready()
        dt = (time.perf_counter() - t0) / iters
        gbps = x.size * 4 * np.log2(n) / dt / 1e9
        _csv(f"kernels/fwht_ref_n{n}", dt * 1e6, f"effective_GB/s={gbps:.2f}")

    # blocked attention vs naive (the flash structure's win is memory; on
    # CPU we report time parity + the memory ratio it avoids)
    b, t, h, d = 1, 1024, 4, 64
    q = jax.random.normal(jax.random.PRNGKey(1), (b, t, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, t, h, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (b, t, h, d), jnp.float32)
    for name, fn in (
        ("naive", jax.jit(lambda q, k, v: ref.mha(q, k, v))),
        ("blocked", jax.jit(lambda q, k, v: ref.mha_blocked(q, k, v))),
    ):
        fn(q, k, v).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            fn(q, k, v).block_until_ready()
        dt = (time.perf_counter() - t0) / 5
        _csv(f"kernels/attention_{name}_t{t}", dt * 1e6,
             f"logits_bytes_naive={b*h*t*t*4}")


# ---------------------------------------------------------------------------
# Roofline aggregation (from the dry-run artifacts)
# ---------------------------------------------------------------------------

def bench_sketch_types(full: bool) -> None:
    """Paper §VI: SRHT vs sub-Gaussian vs SJLT sketches inside FLeNS."""
    from benchmarks.paper_common import build_problem, run_method

    spec, prob, w0, w_star = build_problem("phishing", n_cap=20000)
    rounds = 12
    for kind in ("srht", "gaussian", "sjlt"):
        hist = run_method("flens", dict(k=spec.sketch_k, sketch=kind),
                          prob, w0, w_star, rounds)
        _csv(f"sketch_types/flens_{kind}", hist.wall_time_s / rounds * 1e6,
             f"gap_final={hist.gap[-1]:.3e}")


def bench_flens_ablation(full: bool) -> None:
    """Ablate the FLeNS design choices (momentum rule, guard, step point)."""
    from benchmarks.paper_common import build_problem, run_method

    spec, prob, w0, w_star = build_problem("phishing", n_cap=20000)
    rounds = 15
    k = spec.sketch_k
    variants = [
        ("beta0", dict(k=k, beta=0.0)),
        ("betaA7_guarded", dict(k=k, beta="paper", restart=True)),
        ("betaA7_unguarded", dict(k=k, beta="paper", restart=False)),
        ("beta_sqrt", dict(k=k, beta="sqrt")),
        ("step_from_w", dict(k=k, beta="paper", step_from="w")),
        ("gauss_sketch", dict(k=k, beta=0.0, sketch="gaussian")),
    ]
    for name, kw in variants:
        hist = run_method("flens", kw, prob, w0, w_star, rounds)
        gap = hist.gap[-1]
        import numpy as _np

        stable = bool(_np.isfinite(hist.gap).all() and gap < hist.gap[0])
        _csv(f"ablation/flens_{name}", hist.wall_time_s / rounds * 1e6,
             f"gap_final={gap:.3e};stable={stable}")


def bench_roofline(full: bool) -> None:
    from benchmarks.roofline import aggregate

    # prefer the post-§Perf artifacts when present (baseline kept alongside)
    src = RESULTS / ("dryrun_opt" if (RESULTS / "dryrun_opt").exists()
                     else "dryrun")
    table = aggregate(src)
    for row in table:
        if row["status"] != "ok":
            _csv(f"roofline/{row['arch']}/{row['shape']}/{row['mesh']}", 0.0,
                 f"status={row['status']}")
            continue
        r = row["roofline"]
        _csv(
            f"roofline/{row['arch']}/{row['shape']}/{row['mesh']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"dominant={r['dominant']};compute_s={r['compute_s']:.3e};"
            f"memory_s={r['memory_s']:.3e};collective_s={r['collective_s']:.3e}",
        )


BENCHES = {
    "fig1": bench_fig1_convergence,
    "fig2": bench_fig2_sketch_size,
    "fig3": bench_fig3_time_vs_sketch,
    "table1": bench_table1_communication,
    "comm": bench_comm,
    "robust": bench_robust,
    "async": bench_async,
    "round_time": bench_round_time,
    "sketch_types": bench_sketch_types,
    "ablation": bench_flens_ablation,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    RESULTS.mkdir(exist_ok=True)
    names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        BENCHES[name](args.full)


if __name__ == "__main__":
    main()
