"""Aggregate dry-run JSONs into the §Roofline table (markdown + rows)."""
from __future__ import annotations

import json
import pathlib


def aggregate(dryrun_dir) -> list[dict]:
    rows = []
    for f in sorted(pathlib.Path(dryrun_dir).glob("*.json")):
        rows.append(json.loads(f.read_text()))
    order = {"single": 0, "multi": 1}
    rows.sort(key=lambda r: (r["arch"], r["shape"], order.get(r["mesh"], 2)))
    return rows


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}us"
    if x < 0.1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.3f}s"


def markdown_table(rows: list[dict], mesh: str = "single") -> str:
    """§Roofline markdown (single-pod by default, per the brief)."""
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "useful-FLOPs ratio | peak mem/chip |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | "
                f"skipped ({r['reason'].split('(')[0].strip()}) | — | — |"
            )
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        rf = r["roofline"]
        ratio = rf.get("useful_flops_ratio")
        peak = r["memory"]["peak_bytes_estimate"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rf['compute_s'])} | "
            f"{_fmt_s(rf['memory_s'])} | {_fmt_s(rf['collective_s'])} | "
            f"**{rf['dominant'].replace('_s','')}** | "
            f"{ratio:.2f} | {peak:.1f} GB |"
        )
    return "\n".join(lines)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = aggregate(args.dir)
    print(markdown_table(rows, args.mesh))


if __name__ == "__main__":
    main()
