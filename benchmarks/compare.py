"""CI benchmark regression gate: compare a fresh comm benchmark run
against the committed baseline.

The comm benchmark (``python -m benchmarks.run --only comm``) is fully
seeded — channel draws, cohorts, and codec randomness are all pure
functions of ``CommConfig.seed`` — so on a pinned environment any drift
in its record is a regression, not noise:

  * ``cumulative_bytes`` is derived from static payload shapes and codec
    wire formats; it must match the baseline EXACTLY (a byte-accounting
    change is either an intentional codec change or a bug);
  * final losses may move by float-level jitter across jax/BLAS builds,
    so they get a small relative tolerance instead of equality.

Usage (exit code 1 on any violation):

  python benchmarks/compare.py results/comm.json results/comm_baseline.json
  python benchmarks/compare.py CURRENT BASELINE --loss-rtol 5e-3

Refreshing the baseline after an INTENTIONAL change (re-runs the seeded
benchmark in-process and writes the result as the new baseline — commit
the file it reports):

  python benchmarks/compare.py --update
"""

from __future__ import annotations

import argparse
import json
import math
import os
import pathlib
import shutil
import sys

# anchor defaults (and --update) to the repo root, not the caller's CWD
_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _rel_err(a: float, b: float) -> float:
    denom = max(abs(a), abs(b), 1e-30)
    return abs(a - b) / denom


def compare(current: dict, baseline: dict, loss_rtol: float) -> list[str]:
    """Return a list of human-readable violations (empty = gate passes)."""
    violations = []
    cur_vars = current.get("variants", {})
    base_vars = baseline.get("variants", {})
    missing = sorted(set(base_vars) - set(cur_vars))
    if missing:
        violations.append(f"variants missing from current run: {missing}")
    added = sorted(set(cur_vars) - set(base_vars))
    if added:
        violations.append(
            f"variants not in the baseline (refresh it to gate them): {added}"
        )
    for name in sorted(set(base_vars) & set(cur_vars)):
        cur, base = cur_vars[name], base_vars[name]
        # --- byte accounting: exact ------------------------------------
        cb, bb = cur["cumulative_bytes"][-1], base["cumulative_bytes"][-1]
        if cb != bb:
            violations.append(
                f"{name}: total bytes drifted {bb} -> {cb} "
                f"(byte accounting must match the baseline exactly)"
            )
        for key in ("total_bytes_up", "total_bytes_down"):
            if cur["stats"][key] != base["stats"][key]:
                violations.append(
                    f"{name}: stats.{key} drifted "
                    f"{base['stats'][key]} -> {cur['stats'][key]}"
                )
        # --- final loss: small relative tolerance ----------------------
        cl, bl = float(cur["loss_final"]), float(base["loss_final"])
        if not (math.isfinite(cl) and math.isfinite(bl)):
            violations.append(f"{name}: non-finite loss (cur={cl} base={bl})")
        elif _rel_err(cl, bl) > loss_rtol:
            violations.append(
                f"{name}: final loss drifted {bl:.9g} -> {cl:.9g} "
                f"(rel err {_rel_err(cl, bl):.2e} > rtol {loss_rtol:.0e})"
            )
    return violations


def update_baseline(baseline: pathlib.Path) -> pathlib.Path:
    """Re-run the seeded comm benchmark in-process and install its
    record as the new baseline. Deterministic: every channel draw,
    cohort, and codec key in the benchmark is a pure function of
    ``CommConfig.seed``, so two --update runs on one environment write
    byte-identical baselines. Runs from the repo root regardless of the
    caller's CWD (the benchmark writes its artifacts relative to it);
    an explicitly-passed relative BASELINE is resolved against the
    caller's CWD first."""
    baseline = baseline.resolve()
    for p in (_ROOT, _ROOT / "src"):  # plain `python benchmarks/compare.py`
        if str(p) not in sys.path:
            sys.path.insert(0, str(p))
    os.chdir(_ROOT)
    from benchmarks.run import RESULTS, bench_comm

    RESULTS.mkdir(exist_ok=True)
    bench_comm(full=False)
    fresh = (RESULTS / "comm.json").resolve()
    shutil.copyfile(fresh, baseline)
    return fresh


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail when the comm benchmark drifts from its baseline."
    )
    ap.add_argument(
        "current",
        type=pathlib.Path,
        nargs="?",
        default=_ROOT / "results" / "comm.json",
    )
    ap.add_argument(
        "baseline",
        type=pathlib.Path,
        nargs="?",
        default=_ROOT / "results" / "comm_baseline.json",
    )
    ap.add_argument(
        "--loss-rtol",
        type=float,
        default=5e-3,
        help="relative tolerance on final losses "
        "(absorbs BLAS/jax build jitter; default 5e-3)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="regenerate the baseline: re-run the seeded comm benchmark "
        "and write its record to BASELINE (commit the result)",
    )
    args = ap.parse_args(argv)

    if args.update:
        fresh = update_baseline(args.baseline)
        n = len(json.loads(args.baseline.read_text()).get("variants", {}))
        print(
            f"baseline refreshed: {fresh} -> {args.baseline} "
            f"({n} variants); commit the new baseline"
        )
        return 0

    current = json.loads(args.current.read_text())
    baseline = json.loads(args.baseline.read_text())
    violations = compare(current, baseline, args.loss_rtol)
    if violations:
        print(f"BENCHMARK REGRESSION GATE FAILED ({len(violations)} violation(s)):")
        for v in violations:
            print(f"  - {v}")
        print(
            "If the change is intentional, refresh the baseline: "
            "python benchmarks/compare.py --update  (and commit it)"
        )
        return 1
    n = len(baseline.get("variants", {}))
    print(
        f"benchmark gate OK: {n} variants match the baseline "
        f"(bytes exact, loss rtol {args.loss_rtol:g})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
