"""CI benchmark regression gates: compare fresh benchmark records
against their committed baselines.

Two gates share one drift-table engine:

**Comm gate** (default). The comm benchmark (``python -m benchmarks.run
--only comm``) is fully seeded — channel draws, cohorts, and codec
randomness are all pure functions of ``CommConfig.seed`` — so on a
pinned environment any drift in its record is a regression, not noise:

  * ``cumulative_bytes`` is derived from static payload shapes and codec
    wire formats; it must match the baseline EXACTLY (a byte-accounting
    change is either an intentional codec change or a bug);
  * final losses may move by float-level jitter across jax/BLAS builds,
    so they get a small relative tolerance instead of equality.

**Bench gate** (``--bench``). Gates the perf-trajectory artifact
``BENCH_round_time.json`` (``python -m benchmarks.run --only
round_time``): structure and byte/loss fields are exact-or-rtol like the
comm gate, while wall-clock fields (``exec_s_per_round``,
``compile_s``) are machine-dependent and only gated against a generous
slowdown factor (``--time-factor``, default 5x — a real perf cliff, not
scheduler jitter). Record-then-gate: when the baseline file does not
exist yet, the current record is INSTALLED as the baseline (exit 0,
commit it); every later run gates against it.

Both gates print a per-record drift table (baseline vs current,
relative delta, pass/fail per field) — every comparison is shown, not
just the first failure.

Usage (exit code 1 on any violation):

  python benchmarks/compare.py results/comm.json results/comm_baseline.json
  python benchmarks/compare.py CURRENT BASELINE --loss-rtol 5e-3
  python benchmarks/compare.py --bench        # BENCH_round_time.json gate

Refreshing a baseline after an INTENTIONAL change (re-runs the seeded
benchmark in-process and writes the result as the new baseline — commit
the file it reports):

  python benchmarks/compare.py --update
  python benchmarks/compare.py --bench --update
"""

from __future__ import annotations

import argparse
import json
import math
import os
import pathlib
import shutil
import sys

# anchor defaults (and --update) to the repo root, not the caller's CWD
_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _rel_err(a: float, b: float) -> float:
    denom = max(abs(a), abs(b), 1e-30)
    return abs(a - b) / denom


# ---------------------------------------------------------------------------
# drift rows + table
# ---------------------------------------------------------------------------


def _row(record: str, field: str, old, new, ok: bool, note: str = "") -> dict:
    """One drift-table entry: a (record, field) comparison outcome."""
    rel = None
    if isinstance(old, (int, float)) and isinstance(new, (int, float)):
        rel = _rel_err(float(new), float(old))
    return {
        "record": record,
        "field": field,
        "old": old,
        "new": new,
        "rel": rel,
        "ok": bool(ok),
        "note": note,
    }


def _fmt_val(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def drift_table(rows: list[dict]) -> str:
    """Render drift rows as a fixed-width per-record table (every
    comparison, not just failures)."""
    if not rows:
        return "(nothing compared)"
    header = ("record", "field", "baseline", "current", "rel-delta", "status")
    body = [
        (
            r["record"],
            r["field"],
            _fmt_val(r["old"]),
            _fmt_val(r["new"]),
            "-" if r["rel"] is None else f"{r['rel']:.2e}",
            ("PASS" if r["ok"] else "FAIL") + (f" ({r['note']})" if r["note"] else ""),
        )
        for r in rows
    ]
    widths = [
        max(len(header[i]), *(len(b[i]) for b in body)) for i in range(len(header))
    ]
    lines = [
        "  ".join(h.ljust(w) for h, w in zip(header, widths)),
        "  ".join("-" * w for w in widths),
    ]
    lines += ["  ".join(c.ljust(w) for c, w in zip(b, widths)) for b in body]
    return "\n".join(lines)


def violations_of(rows: list[dict]) -> list[str]:
    """Human-readable violation lines for the failing rows."""
    out = []
    for r in rows:
        if r["ok"]:
            continue
        msg = (
            f"{r['record']}: {r['field']} drifted "
            f"{_fmt_val(r['old'])} -> {_fmt_val(r['new'])}"
        )
        if r["note"]:
            msg += f" ({r['note']})"
        out.append(msg)
    return out


# ---------------------------------------------------------------------------
# comm gate
# ---------------------------------------------------------------------------


def compare(current: dict, baseline: dict, loss_rtol: float) -> list[dict]:
    """Compare a comm benchmark record against its baseline; returns
    drift rows (``violations_of`` extracts the failures)."""
    rows: list[dict] = []
    cur_vars = current.get("variants", {})
    base_vars = baseline.get("variants", {})
    missing = sorted(set(base_vars) - set(cur_vars))
    if missing:
        rows.append(
            _row(
                "(structure)",
                "variants",
                sorted(base_vars),
                sorted(cur_vars),
                False,
                f"missing from current run: {missing}",
            )
        )
    added = sorted(set(cur_vars) - set(base_vars))
    if added:
        rows.append(
            _row(
                "(structure)",
                "variants",
                sorted(base_vars),
                sorted(cur_vars),
                False,
                f"not in the baseline (refresh it to gate them): {added}",
            )
        )
    for name in sorted(set(base_vars) & set(cur_vars)):
        cur, base = cur_vars[name], base_vars[name]
        # --- byte accounting: exact ------------------------------------
        cb, bb = cur["cumulative_bytes"][-1], base["cumulative_bytes"][-1]
        rows.append(
            _row(
                name,
                "bytes_total",
                bb,
                cb,
                cb == bb,
                "" if cb == bb else "byte accounting must match exactly",
            )
        )
        for key in ("total_bytes_up", "total_bytes_down"):
            cs, bs = cur["stats"][key], base["stats"][key]
            rows.append(_row(name, f"stats.{key}", bs, cs, cs == bs))
        # --- final loss: small relative tolerance ----------------------
        cl, bl = float(cur["loss_final"]), float(base["loss_final"])
        if not (math.isfinite(cl) and math.isfinite(bl)):
            rows.append(_row(name, "loss_final", bl, cl, False, "non-finite"))
        else:
            ok = _rel_err(cl, bl) <= loss_rtol
            rows.append(_row(name, "loss_final", bl, cl, ok, f"rtol {loss_rtol:g}"))
    return rows


# ---------------------------------------------------------------------------
# bench (perf trajectory) gate
# ---------------------------------------------------------------------------

# deterministic per-optimizer fields: exact / loss-rtol gated
_BENCH_EXACT = ("bytes_total", "uplink_floats")
_BENCH_LOSS = ("loss_final", "loss_at_budget")
# machine-dependent wall-clock fields: gated only against a generous
# slowdown RATIO (a relative error is bounded by 1 and cannot express
# "5x slower", hence a factor, not an rtol)
_BENCH_TIME = ("exec_s_per_round", "compile_s")


def compare_bench(
    current: dict, baseline: dict, loss_rtol: float, time_factor: float
) -> list[dict]:
    """Compare a ``BENCH_round_time.json`` record against its baseline;
    structure and byte/loss fields are exact-or-rtol, wall-clock fields
    pass unless they slowed down by more than ``time_factor``x."""
    rows: list[dict] = []
    for key in ("schema", "dataset", "rounds", "clients"):
        cv, bv = current.get(key), baseline.get(key)
        rows.append(_row("(structure)", key, bv, cv, cv == bv))
    cur_opts = current.get("optimizers", {})
    base_opts = baseline.get("optimizers", {})
    if sorted(cur_opts) != sorted(base_opts):
        rows.append(
            _row(
                "(structure)",
                "optimizers",
                sorted(base_opts),
                sorted(cur_opts),
                False,
                "optimizer lineup drifted",
            )
        )
    cb, bb = current.get("budget_bytes"), baseline.get("budget_bytes")
    rows.append(_row("(structure)", "budget_bytes", bb, cb, cb == bb))
    for name in sorted(set(base_opts) & set(cur_opts)):
        cur, base = cur_opts[name], base_opts[name]
        for key in _BENCH_EXACT:
            rows.append(_row(name, key, base[key], cur[key], cur[key] == base[key]))
        for key in _BENCH_LOSS:
            cl, bl = float(cur[key]), float(base[key])
            finite = math.isfinite(cl) and math.isfinite(bl)
            ok = finite and _rel_err(cl, bl) <= loss_rtol
            rows.append(_row(name, key, bl, cl, ok, f"rtol {loss_rtol:g}"))
        for key in _BENCH_TIME:
            ct, bt = float(cur[key]), float(base[key])
            # slowdown-only gate: getting faster always passes
            ok = ct <= time_factor * max(bt, 1e-9)
            rows.append(_row(name, key, bt, ct, ok, f"<= {time_factor:g}x baseline"))
    return rows


# ---------------------------------------------------------------------------
# baseline refresh
# ---------------------------------------------------------------------------


def _chdir_root() -> None:
    for p in (_ROOT, _ROOT / "src"):  # plain `python benchmarks/compare.py`
        if str(p) not in sys.path:
            sys.path.insert(0, str(p))
    os.chdir(_ROOT)


def update_baseline(baseline: pathlib.Path) -> pathlib.Path:
    """Re-run the seeded comm benchmark in-process and install its
    record as the new baseline. Deterministic: every channel draw,
    cohort, and codec key in the benchmark is a pure function of
    ``CommConfig.seed``, so two --update runs on one environment write
    byte-identical baselines. Runs from the repo root regardless of the
    caller's CWD (the benchmark writes its artifacts relative to it);
    an explicitly-passed relative BASELINE is resolved against the
    caller's CWD first."""
    baseline = baseline.resolve()
    _chdir_root()
    from benchmarks.run import RESULTS, bench_comm, bench_robust

    RESULTS.mkdir(exist_ok=True)
    bench_comm(full=False)
    # the Byzantine-robustness records ride the same baseline: bench_robust
    # merges its seeded variants (and the >=2x recovery gate numbers) into
    # results/comm.json before it is installed
    bench_robust(full=False)
    fresh = (RESULTS / "comm.json").resolve()
    shutil.copyfile(fresh, baseline)
    return fresh


def update_bench_baseline(baseline: pathlib.Path) -> pathlib.Path:
    """Re-run the seeded round_time benchmark and install its record as
    the new bench baseline (wall-clock fields come along for the ride —
    they are only ever ratio-gated)."""
    baseline = baseline.resolve()
    _chdir_root()
    from benchmarks.run import BENCH_PATH, RESULTS, bench_round_time

    RESULTS.mkdir(exist_ok=True)
    bench_round_time(full=False)
    shutil.copyfile(BENCH_PATH, baseline)
    return BENCH_PATH


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail when a benchmark record drifts from its baseline."
    )
    ap.add_argument("current", type=pathlib.Path, nargs="?", default=None)
    ap.add_argument("baseline", type=pathlib.Path, nargs="?", default=None)
    ap.add_argument(
        "--bench",
        action="store_true",
        help="gate BENCH_round_time.json (perf trajectory) instead of the "
        "comm record; record-then-gate — a missing baseline is installed "
        "from the current record",
    )
    ap.add_argument(
        "--loss-rtol",
        type=float,
        default=5e-3,
        help="relative tolerance on final losses "
        "(absorbs BLAS/jax build jitter; default 5e-3)",
    )
    ap.add_argument(
        "--time-factor",
        type=float,
        default=5.0,
        help="--bench only: allowed wall-clock slowdown factor vs baseline "
        "(default 5x; speedups always pass)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="regenerate the baseline: re-run the seeded benchmark "
        "and write its record to BASELINE (commit the result)",
    )
    args = ap.parse_args(argv)

    if args.bench:
        current = args.current or (_ROOT / "BENCH_round_time.json")
        baseline = args.baseline or (
            _ROOT / "results" / "bench_round_time_baseline.json"
        )
    else:
        current = args.current or (_ROOT / "results" / "comm.json")
        baseline = args.baseline or (_ROOT / "results" / "comm_baseline.json")

    if args.update:
        if args.bench:
            fresh = update_bench_baseline(baseline)
            n = len(json.loads(baseline.read_text()).get("optimizers", {}))
            what = "optimizers"
        else:
            fresh = update_baseline(baseline)
            n = len(json.loads(baseline.read_text()).get("variants", {}))
            what = "variants"
        print(
            f"baseline refreshed: {fresh} -> {baseline} "
            f"({n} {what}); commit the new baseline"
        )
        return 0

    cur_doc = json.loads(current.read_text())
    if args.bench and not baseline.exists():
        # record-then-gate: first run installs the baseline
        baseline.parent.mkdir(parents=True, exist_ok=True)
        shutil.copyfile(current, baseline)
        n = len(cur_doc.get("optimizers", {}))
        print(
            f"bench baseline recorded: {current} -> {baseline} "
            f"({n} optimizers); commit it — later runs gate against it"
        )
        return 0
    base_doc = json.loads(baseline.read_text())

    if args.bench:
        rows = compare_bench(cur_doc, base_doc, args.loss_rtol, args.time_factor)
        gate = f"bench gate (time factor {args.time_factor:g}x)"
        n = len(base_doc.get("optimizers", {}))
        unit = "optimizers"
    else:
        rows = compare(cur_doc, base_doc, args.loss_rtol)
        gate = "comm gate"
        n = len(base_doc.get("variants", {}))
        unit = "variants"

    print(drift_table(rows))
    violations = violations_of(rows)
    if violations:
        print(f"\nBENCHMARK REGRESSION GATE FAILED ({len(violations)} violation(s)):")
        for v in violations:
            print(f"  - {v}")
        update_cmd = "python benchmarks/compare.py " + (
            "--bench --update" if args.bench else "--update"
        )
        print(
            "If the change is intentional, refresh the baseline: "
            f"{update_cmd}  (and commit it)"
        )
        return 1
    print(
        f"\n{gate} OK: {n} {unit} match the baseline "
        f"(bytes exact, loss rtol {args.loss_rtol:g})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
