"""Shared setup for the paper-figure benchmarks (Fig 1-3, Table I)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import ChannelModel
from repro.core import make_optimizer, make_problem, newton_solve, run_rounds
from repro.core.losses import logistic
from repro.data.libsvm_like import load


def straggler_edge_channel(m: int) -> ChannelModel:
    """The canonical heterogeneous straggler scenario the sync-vs-async
    comparisons share (``--only async`` bench, ``examples/async_edge.py``,
    ``tests/test_async.py``): log-spaced uplinks across two decades, 10x
    faster downlinks, 30% stragglers at 10x slowdown — and NO dropout,
    which keeps the full-quorum async anchor on the lock-step-equivalent
    (bit-identical) path. Tune it here and every consumer moves together.
    """
    rates = np.logspace(np.log10(3e4), np.log10(3e6), m)
    return ChannelModel(
        uplink_bytes_per_s=rates,
        downlink_bytes_per_s=10.0 * rates,
        latency_s=0.05,
        straggler_prob=0.30,
        straggler_slowdown=10.0,
    )


def build_problem(dataset: str, *, seed: int = 0, n_cap: int | None = None,
                  heterogeneity: str = "iid"):
    """Federated logistic-regression problem per paper Table II."""
    spec, X, y = load(dataset, seed=seed)
    if n_cap and X.shape[0] > n_cap:
        X, y = X[:n_cap], y[:n_cap]
    lam = 1e-3  # paper: lambda = 1e-3 everywhere
    prob = make_problem(X, y, m=spec.m_clients, lam=lam, objective=logistic,
                        key=jax.random.PRNGKey(seed),
                        heterogeneity=heterogeneity)
    w0 = jnp.zeros((prob.dim,), jnp.float64)
    w_star = newton_solve(prob, w0, iters=40)
    return spec, prob, w0, w_star


# Methods compared in the paper's Fig. 1 (+ our flens_plus)
def fig1_methods(spec):
    k = spec.sketch_k
    return [
        ("fedavg", dict(lr=2.0, local_steps=5)),
        ("fedprox", dict(lr=2.0, local_steps=5, mu_prox=0.01)),
        ("fednew", dict(rho=spec_rho(spec), alpha=spec_alpha(spec))),
        ("fednl", {}),
        ("fedns", dict(k=k)),
        ("fedndes", {}),
        ("fednewton", {}),
        ("flens", dict(k=k)),
        ("flens_plus", dict(k=k)),
    ]


def spec_rho(spec):
    return {"phishing": 0.1, "covtype": 50.0, "susy": 50.0}.get(spec.name, 0.1)


def spec_alpha(spec):
    return {"phishing": 0.25, "covtype": 1.0, "susy": 1.0}.get(spec.name, 0.25)


def run_method(name, kwargs, prob, w0, w_star, rounds, seed=0):
    opt = make_optimizer(name, **kwargs)
    return run_rounds(opt, prob, w0, w_star, rounds=rounds, seed=seed)


def ef_gap_shrink(loss_base: float, loss_off: float, loss_on: float) -> dict:
    """Error-feedback headline record: final-loss gap to the
    no-compression baseline with EF off vs on. ``ratio`` is ``None``
    (JSON null — json.dumps would otherwise emit the invalid token
    ``Infinity``) when the EF run lands at or below the baseline."""
    d_off = float(loss_off) - float(loss_base)
    d_on = float(loss_on) - float(loss_base)
    ratio = d_off / d_on if d_on > 0 else None
    return {"ef_off": d_off, "ef_on": d_on, "ratio": ratio}


def ef_ratio_label(shrink: dict) -> str:
    """Render ``ef_gap_shrink``'s ratio for reports: ``inf`` only when
    EF-off genuinely had a gap to close; ``n/a`` when both runs already
    sit at or below the baseline."""
    if shrink["ratio"] is not None:
        return f"{shrink['ratio']:.2f}"
    return "inf" if shrink["ef_off"] > 0 else "n/a"
