"""Shared setup for the paper-figure benchmarks (Fig 1-3, Table I) and
the sync-vs-async comparison scaffolding used by both
``benchmarks/run.py --only async`` and ``examples/async_edge.py``."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import ChannelModel, CommConfig, summarize
from repro.core import make_optimizer, make_problem, newton_solve, run_rounds
from repro.core.losses import logistic
from repro.data.libsvm_like import load


def straggler_edge_channel(m: int) -> ChannelModel:
    """The canonical heterogeneous straggler scenario the sync-vs-async
    comparisons share (``--only async`` bench, ``examples/async_edge.py``,
    ``tests/test_async.py``): log-spaced uplinks across two decades, 10x
    faster downlinks, 30% stragglers at 10x slowdown — and NO dropout,
    which keeps the full-quorum async anchor on the lock-step-equivalent
    (bit-identical) path. Tune it here and every consumer moves together.
    """
    rates = np.logspace(np.log10(3e4), np.log10(3e6), m)
    return ChannelModel(
        uplink_bytes_per_s=rates,
        downlink_bytes_per_s=10.0 * rates,
        latency_s=0.05,
        straggler_prob=0.30,
        straggler_slowdown=10.0,
    )


def build_problem(dataset: str, *, seed: int = 0, n_cap: int | None = None,
                  heterogeneity: str = "iid"):
    """Federated logistic-regression problem per paper Table II."""
    spec, X, y = load(dataset, seed=seed)
    if n_cap and X.shape[0] > n_cap:
        X, y = X[:n_cap], y[:n_cap]
    lam = 1e-3  # paper: lambda = 1e-3 everywhere
    prob = make_problem(X, y, m=spec.m_clients, lam=lam, objective=logistic,
                        key=jax.random.PRNGKey(seed),
                        heterogeneity=heterogeneity)
    w0 = jnp.zeros((prob.dim,), jnp.float64)
    w_star = newton_solve(prob, w0, iters=40)
    return spec, prob, w0, w_star


# Methods compared in the paper's Fig. 1 (+ our flens_plus)
def fig1_methods(spec):
    k = spec.sketch_k
    return [
        ("fedavg", dict(lr=2.0, local_steps=5)),
        ("fedprox", dict(lr=2.0, local_steps=5, mu_prox=0.01)),
        ("fednew", dict(rho=spec_rho(spec), alpha=spec_alpha(spec))),
        ("fednl", {}),
        ("fedns", dict(k=k)),
        ("fedndes", {}),
        ("fednewton", {}),
        ("flens", dict(k=k)),
        ("flens_plus", dict(k=k)),
    ]


def spec_rho(spec):
    return {"phishing": 0.1, "covtype": 50.0, "susy": 50.0}.get(spec.name, 0.1)


def spec_alpha(spec):
    return {"phishing": 0.25, "covtype": 1.0, "susy": 1.0}.get(spec.name, 0.25)


def run_method(name, kwargs, prob, w0, w_star, rounds, seed=0):
    opt = make_optimizer(name, **kwargs)
    return run_rounds(opt, prob, w0, w_star, rounds=rounds, seed=seed)


# ---------------------------------------------------------------------------
# sync-vs-async comparison scaffolding (shared by `--only async` and
# examples/async_edge.py — one copy, both consumers move together)
# ---------------------------------------------------------------------------

def loss_at(hist, t: float) -> float:
    """Loss at a simulated-time point (linear interpolation)."""
    return float(np.interp(t, hist.sim_time_s, hist.loss))


def hist_record(hist) -> dict:
    """JSON-able record of one run's transport curves."""
    return {
        "loss": hist.loss.tolist(),
        "gap": hist.gap.tolist(),
        "sim_time_s": hist.sim_time_s.tolist(),
        "cumulative_bytes": hist.cumulative_bytes.tolist(),
        "staleness": (hist.staleness.tolist()
                      if hist.staleness is not None else None),
        "stats": summarize(hist.traces) if hist.traces else None,
    }


def check_async_lockstep_anchor(make_opt, prob, w0, w_star, channel, *,
                                rounds: int = 3, seed: int = 1):
    """The backward-compatibility anchor both consumers assert before
    comparing drivers: full-quorum async must reproduce the synchronous
    ``History`` bit-identically (losses AND byte accounting). Returns
    ``(exact, sync_hist, async_hist)``."""
    sync = run_rounds(make_opt(), prob, w0, w_star, rounds=rounds,
                      comm=CommConfig(channel=channel, seed=seed))
    asy = run_rounds(make_opt(), prob, w0, w_star, rounds=rounds,
                     comm=CommConfig(channel=channel, seed=seed,
                                     async_mode=True))
    exact = bool(
        np.array_equal(sync.loss, asy.loss)
        and np.array_equal(sync.cumulative_bytes, asy.cumulative_bytes))
    return exact, sync, asy


def sync_async_race(make_opt, prob, w0, w_star, channel, *, rounds: int,
                    seed: int = 1, buffer_size: "int | None" = None,
                    obs_for=None) -> dict:
    """The canonical three-driver race on one channel/seed: lock-step
    sync, a FedBuff-style buffer (default K = m/4, 4x the commits), and
    a 50%-quantile quorum (3x the commits), both with inverse staleness
    weighting. Returns ``{name: History}`` in run order (sync first).

    ``obs_for(name) -> TelemetryConfig | None`` opts each driver into
    the ``repro.obs`` telemetry layer (default: uninstrumented)."""
    buf = buffer_size if buffer_size is not None else max(2, prob.m // 4)
    runs = [
        ("sync", rounds, CommConfig(channel=channel, seed=seed)),
        ("async_buf", 4 * rounds, CommConfig(
            channel=channel, seed=seed, async_mode=True, buffer_size=buf,
            staleness="inverse")),
        ("async_q50", 3 * rounds, CommConfig(
            channel=channel, seed=seed, async_mode=True, async_quantile=0.5,
            staleness="inverse")),
    ]
    return {name: run_rounds(make_opt(), prob, w0, w_star, rounds=r,
                             comm=comm,
                             obs=obs_for(name) if obs_for else None)
            for name, r, comm in runs}


def ef_gap_shrink(loss_base: float, loss_off: float, loss_on: float) -> dict:
    """Error-feedback headline record: final-loss gap to the
    no-compression baseline with EF off vs on. ``ratio`` is ``None``
    (JSON null — json.dumps would otherwise emit the invalid token
    ``Infinity``) when the EF run lands at or below the baseline."""
    d_off = float(loss_off) - float(loss_base)
    d_on = float(loss_on) - float(loss_base)
    ratio = d_off / d_on if d_on > 0 else None
    return {"ef_off": d_off, "ef_on": d_on, "ratio": ratio}


def ef_ratio_label(shrink: dict) -> str:
    """Render ``ef_gap_shrink``'s ratio for reports: ``inf`` only when
    EF-off genuinely had a gap to close; ``n/a`` when both runs already
    sit at or below the baseline."""
    if shrink["ratio"] is not None:
        return f"{shrink['ratio']:.2f}"
    return "inf" if shrink["ef_off"] > 0 else "n/a"
