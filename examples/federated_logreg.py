"""End-to-end paper reproduction driver (the paper's own experiment).

Runs every Table-I algorithm on the paper's three datasets (synthetic
twins with matched n/M/m/k — see data/libsvm_like.py), reporting the
optimality gap per round, the per-round uplink, and wall time; writes
JSON trajectories under results/examples/.

  PYTHONPATH=src python examples/federated_logreg.py --dataset phishing
  PYTHONPATH=src python examples/federated_logreg.py --all --rounds 30
"""
import argparse
import json
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import make_optimizer, make_problem, newton_solve, run_rounds
from repro.core.losses import logistic
from repro.data.libsvm_like import PAPER_DATASETS, load


def run_dataset(name: str, rounds: int, n_cap: int | None):
    spec, X, y = load(name)
    if n_cap and X.shape[0] > n_cap:
        X, y = X[:n_cap], y[:n_cap]
    prob = make_problem(X, y, m=spec.m_clients, lam=1e-3, objective=logistic)
    w0 = jnp.zeros((prob.dim,), jnp.float64)
    w_star = newton_solve(prob, w0, iters=40)
    print(f"\n=== {name}: n={X.shape[0]} M={spec.dim} m={spec.m_clients} "
          f"k={spec.sketch_k} ===")
    print(f"{'method':>18} {'uplink':>8} {'wall_s':>7}  gap trajectory")

    methods = [
        ("fedavg", dict(lr=2.0, local_steps=5)),
        ("fedprox", dict(lr=2.0, local_steps=5, mu_prox=0.01)),
        ("local_newton", {}),
        ("distributed_newton", {}),
        ("fednew", {}),
        ("fednl", {}),
        ("fedns", dict(k=spec.sketch_k)),
        ("fedndes", {}),
        ("fednewton", {}),
        ("flens", dict(k=spec.sketch_k)),
        ("flens_plus", dict(k=spec.sketch_k)),
    ]
    out = {}
    for mname, kw in methods:
        hist = run_rounds(make_optimizer(mname, **kw), prob, w0, w_star,
                          rounds=rounds)
        traj = "  ".join(f"{g:.1e}" for g in hist.gap[:: max(1, rounds // 6)])
        print(f"{hist.name:>18} {hist.uplink_floats:>8} "
              f"{hist.wall_time_s:>7.2f}  {traj}")
        out[hist.name] = {"gap": hist.gap.tolist(),
                          "uplink": hist.uplink_floats}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="phishing",
                    choices=list(PAPER_DATASETS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--n-cap", type=int, default=30000,
                    help="cap dataset size for CPU (0 = full)")
    args = ap.parse_args()

    datasets = list(PAPER_DATASETS) if args.all else [args.dataset]
    outdir = pathlib.Path("results/examples")
    outdir.mkdir(parents=True, exist_ok=True)
    for ds in datasets:
        out = run_dataset(ds, args.rounds, args.n_cap or None)
        (outdir / f"logreg_{ds}.json").write_text(json.dumps(out))


if __name__ == "__main__":
    main()
