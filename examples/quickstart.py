"""Quickstart: FLeNS vs FedAvg/FedNewton on a synthetic federated problem.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import make_optimizer, make_problem, newton_solve, run_rounds
from repro.core.losses import logistic
from repro.data import make_classification


def main():
    # 1. a federated logistic-regression problem: 8 clients, 64 features
    X, y = make_classification(jax.random.PRNGKey(0), n=4000, dim=64)
    problem = make_problem(X, y, m=8, lam=1e-3, objective=logistic)
    w0 = jnp.zeros((problem.dim,), jnp.float64)
    w_star = newton_solve(problem, w0)  # reference optimum

    # 2. run three optimizers for 12 communication rounds
    for name, kw in [
        ("fedavg", dict(lr=2.0, local_steps=5)),
        ("flens", dict(k=32)),  # the paper's method, k = M/2 sketch
        ("fednewton", {}),  # exact second-order upper bound
    ]:
        hist = run_rounds(make_optimizer(name, **kw), problem, w0, w_star,
                          rounds=12)
        gaps = "  ".join(f"{g:.1e}" for g in hist.gap[::3])
        print(f"{hist.name:>10}  uplink/round={hist.uplink_floats:>5} floats"
              f"  gap: {gaps}")

    print("\nFLeNS reaches near-Newton convergence at a fraction of the "
          "uplink; FedAvg is still ~1e-2 away after the same rounds.")


if __name__ == "__main__":
    main()
