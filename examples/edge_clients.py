"""FLeNS on bandwidth-limited edge clients — the scenario the paper's
O(k²) uplink is *for*, now actually simulated.

Heterogeneous per-client uplinks (2G-ish to fiber, log-spaced), 20%
stragglers at 10× slowdown, 10% dropout, Dirichlet non-iid shards.
Compares three transports for FLeNS+ (whose O(M) complement gradient is
the payload top-k sparsification targets):

  * raw          — identity codecs, full participation (the old model)
  * compressed   — sympack+int8 sketched Hessian, top-k+int8 gradient
  * comp+sched   — compressed + bandwidth-aware 50% participation

and reports bytes and *simulated wall-clock* to a fixed optimality gap:
on slow links the compressed transport reaches the target in a fraction
of the simulated time, even though per-round convergence is slightly
noisier.

  PYTHONPATH=src python examples/edge_clients.py
  PYTHONPATH=src python examples/edge_clients.py --rounds 30 --gap 1e-4
"""
import argparse
import json
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.paper_common import build_problem
from repro.comm import ChannelModel, CommConfig, summarize
from repro.core import make_optimizer, run_rounds


def edge_channel(m: int) -> ChannelModel:
    """Log-spaced uplinks from 30 kB/s to 3 MB/s, 20% stragglers, 10% drop."""
    rates = np.logspace(np.log10(3e4), np.log10(3e6), m)
    return ChannelModel(
        uplink_bytes_per_s=rates,
        downlink_bytes_per_s=10.0 * rates,
        latency_s=0.08,
        straggler_prob=0.20,
        straggler_slowdown=10.0,
        dropout_prob=0.10,
    )


def rounds_to_gap(hist, target: float) -> int:
    hit = np.nonzero(hist.gap <= target)[0]
    return int(hit[0]) if hit.size else -1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="phishing")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--gap", type=float, default=5e-3)
    ap.add_argument("--n-cap", type=int, default=20000)
    args = ap.parse_args()

    spec, prob, w0, w_star = build_problem(
        args.dataset, n_cap=args.n_cap, heterogeneity="dirichlet")
    k = spec.sketch_k
    chan = edge_channel(prob.m)

    compressed = {
        "h_sk": "sympack+qint8",  # k×k sketched Hessian: triangle + int8
        "sg": "qint8",  # sketched gradient
        "grad": "topk0.1+qint8",  # FLeNS+ complement gradient (the O(M) term)
    }
    transports = [
        ("raw", CommConfig(channel=chan, seed=1)),
        ("compressed", CommConfig(codecs=compressed, channel=chan, seed=1)),
        ("comp+sched", CommConfig(codecs=compressed, channel=chan,
                                  scheduler="bandwidth:0.5", seed=1)),
    ]

    print(f"=== {spec.name}: M={prob.dim} m={prob.m} k={k} | 20% stragglers, "
          f"10% dropout, dirichlet shards ===")
    print(f"{'transport':>12} {'gap_final':>10} {'MB_total':>9} "
          f"{'sim_s':>8} {'rounds<=%.0e' % args.gap:>12} {'sim_s<=gap':>10}")
    out = {}
    for name, comm in transports:
        hist = run_rounds(make_optimizer("flens_plus", k=k), prob, w0, w_star,
                          rounds=args.rounds, comm=comm)
        r_hit = rounds_to_gap(hist, args.gap)
        sim_hit = hist.sim_time_s[r_hit] if r_hit >= 0 else float("nan")
        print(f"{name:>12} {hist.gap[-1]:>10.2e} "
              f"{hist.cumulative_bytes[-1] / 1e6:>9.3f} "
              f"{hist.sim_time_s[-1]:>8.1f} {r_hit:>12d} {sim_hit:>10.1f}")
        out[name] = {
            "gap": hist.gap.tolist(),
            "cumulative_bytes": hist.cumulative_bytes.tolist(),
            "sim_time_s": hist.sim_time_s.tolist(),
            "stats": summarize(hist.traces),
        }

    dest = pathlib.Path("results/examples")
    dest.mkdir(parents=True, exist_ok=True)
    (dest / "edge_clients.json").write_text(json.dumps(out, indent=1))
    print(f"\nwrote results/examples/edge_clients.json")


if __name__ == "__main__":
    main()
