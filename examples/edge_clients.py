"""FLeNS on bandwidth-limited edge clients — the scenario the paper's
O(k²) uplink is *for*, now actually simulated.

Heterogeneous per-client uplinks (2G-ish to fiber, log-spaced), 20%
stragglers at 10× slowdown, 10% dropout, Dirichlet non-iid shards.
Compares five transports for FLeNS+ (whose O(M) complement gradient is
the payload top-k sparsification targets):

  * raw           — identity codecs, full participation (the old model)
  * compressed    — sympack+int8 sketched Hessian, top-k+int8 gradient
  * comp+down     — compressed + a bf16 model broadcast (the symmetric
                    downlink direction of the wire API)
  * comp+sched    — compressed + bandwidth-aware 50% participation
  * comp+sched+ef — comp+sched with EF21 error feedback on the lossy
                    fixed-basis payload (the top-k complement gradient)
  * crush+sched   — the sketch payloads themselves top-k-crushed
                    (biased compression on h_sk/sg); EF is requested but
                    the fresh per-round basis is ineligible, so the
                    sketch bias goes uncorrected
  * crush+rot+ef  — same crushed codecs under a rotating sketch policy
                    (``sketch="srht:rotate=6"``): the basis persists
                    across 6-round epochs, so ``basis_persistent`` makes
                    h_sk/sg EF-eligible and the memory cancels the top-k
                    bias — identical bytes, lower loss. The epoch is
                    longer than the comm benchmark's rotate=8-on-full-
                    participation setting per *memory update*: under the
                    50% scheduler a client's EF memory only advances on
                    the rounds it participates, so each epoch must span
                    several participation cycles for EF21 to contract

and reports bytes and *simulated wall-clock* to a fixed optimality gap:
on slow links the compressed transport reaches the target in a fraction
of the simulated time, even though per-round convergence is slightly
noisier.

A second table isolates what error feedback buys on this channel where
compression bias is the *dominant* error: FedAvg's O(M) model uplink
crushed to topk0.05, EF off vs on, against the no-compression baseline.
Without EF the discarded coordinates never reach the server and the
loss stalls at a compression floor; with EF the floor collapses (the
recorded ``ef_gap_shrink`` ratio is ≳4×).

A final row runs the same edge channel at population scale: a
``SyntheticPopulation`` of m=100 000 clients with ``uniform:1e-3``
sampling, distribution-spec links instead of ``(m,)`` rate arrays, and
lazy cohort materialization — only the ~100 sampled shards per round
ever exist in memory (``--pop-m 0`` skips it).

  PYTHONPATH=src python examples/edge_clients.py
  PYTHONPATH=src python examples/edge_clients.py --rounds 30 --gap 1e-4
"""
import argparse
import json
import pathlib

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.paper_common import (
    build_problem,
    ef_gap_shrink,
    ef_ratio_label,
    hist_record,
)
from repro.comm import ChannelModel, CommConfig
from repro.core import (
    SyntheticPopulation,
    make_optimizer,
    newton_solve,
    run_rounds,
)


def edge_channel(m: int) -> ChannelModel:
    """Log-spaced uplinks from 30 kB/s to 3 MB/s, 20% stragglers, 10% drop."""
    rates = np.logspace(np.log10(3e4), np.log10(3e6), m)
    return ChannelModel(
        uplink_bytes_per_s=rates,
        downlink_bytes_per_s=10.0 * rates,
        latency_s=0.08,
        straggler_prob=0.20,
        straggler_slowdown=10.0,
        dropout_prob=0.10,
    )


def population_edge_channel() -> ChannelModel:
    """The same edge-link statistics without ``(m,)`` storage: per-client
    links are drawn from distribution specs keyed by client id, so the
    channel scales to ``m ~ 10^5`` for free."""
    return ChannelModel(
        uplink_bytes_per_s="loguniform:3e4,3e6",
        downlink_bytes_per_s="loguniform:3e5,3e7",
        latency_s=0.08,
        straggler_prob=0.20,
        straggler_slowdown=10.0,
        dropout_prob=0.10,
    )


def rounds_to_gap(hist, target: float) -> int:
    hit = np.nonzero(hist.gap <= target)[0]
    return int(hit[0]) if hit.size else -1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="phishing")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--gap", type=float, default=5e-3)
    ap.add_argument("--n-cap", type=int, default=20000)
    ap.add_argument("--pop-m", type=int, default=100_000,
                    help="population size for the lazy-cohort row "
                         "(0 disables it)")
    ap.add_argument("--churn", nargs="?", const="poisson:0.05", default=None,
                    metavar="SPEC",
                    help="add a population row with client churn (and a "
                         "diurnal uplink cycle + regional outages); "
                         "default spec poisson:0.05")
    ap.add_argument("--byzantine", nargs="?", const="noise:0.1,5",
                    default=None, metavar="SPEC",
                    help="add population rows under a Byzantine uplink "
                         "coalition, unprotected vs trimmed:0.1; default "
                         "spec noise:0.1,5 (10%% of clients upload "
                         "garbage). Try signflip:0.1 to see FLeNS "
                         "self-normalize a proportional attack")
    args = ap.parse_args()

    spec, prob, w0, w_star = build_problem(
        args.dataset, n_cap=args.n_cap, heterogeneity="dirichlet")
    k = spec.sketch_k
    chan = edge_channel(prob.m)

    compressed = {
        "h_sk": "sympack+qint8",  # k×k sketched Hessian: triangle + int8
        "sg": "qint8",  # sketched gradient
        "grad": "topk0.1+qint8",  # FLeNS+ complement gradient (the O(M) term)
    }
    crushed = {
        "h_sk": "topk0.25",  # BIASED sketch-Hessian compression: only a
        "sg": "topk0.5",  # rotating basis lets EF cancel the bias
        "grad": "topk0.1+qint8",
    }
    transports = [
        ("raw", "srht", CommConfig(channel=chan, seed=1)),
        ("compressed", "srht",
         CommConfig(codecs=compressed, channel=chan, seed=1)),
        # + the symmetric direction: bf16 model broadcast (the downlink
        # is 10x faster here, so bytes drop more than sim time does)
        ("comp+down", "srht",
         CommConfig(codecs=compressed, downlink_codecs="bf16",
                    channel=chan, seed=1)),
        ("comp+sched", "srht", CommConfig(codecs=compressed, channel=chan,
                                          scheduler="bandwidth:0.5", seed=1)),
        ("comp+sched+ef", "srht",
         CommConfig(codecs=compressed, channel=chan,
                    scheduler="bandwidth:0.5", error_feedback=True, seed=1)),
        # + the sketch-policy axis, where it actually bites: BIASED
        # (top-k) compression on the sketch payloads themselves. With a
        # fresh basis the bias is uncorrectable (EF ineligible); a
        # rotating basis keeps h_sk/sg in a stable coordinate system for
        # 6-round epochs, so the same EF request now covers them too —
        # identical bytes, lower loss
        ("crush+sched", "srht",
         CommConfig(codecs=crushed, channel=chan,
                    scheduler="bandwidth:0.5", error_feedback=True, seed=1)),
        ("crush+rot+ef", "srht:rotate=6",
         CommConfig(codecs=crushed, channel=chan,
                    scheduler="bandwidth:0.5", error_feedback=True, seed=1)),
    ]

    print(f"=== {spec.name}: M={prob.dim} m={prob.m} k={k} | 20% stragglers, "
          f"10% dropout, dirichlet shards ===")
    print(f"{'transport':>13} {'policy':>14} {'gap_final':>10} {'MB_total':>9} "
          f"{'sim_s':>8} {'rounds<=%.0e' % args.gap:>12} {'sim_s<=gap':>10}")
    out = {}
    for name, sketch, comm in transports:
        hist = run_rounds(make_optimizer("flens_plus", k=k, sketch=sketch),
                          prob, w0, w_star, rounds=args.rounds, comm=comm)
        r_hit = rounds_to_gap(hist, args.gap)
        sim_hit = hist.sim_time_s[r_hit] if r_hit >= 0 else float("nan")
        print(f"{name:>13} {sketch:>14} {hist.gap[-1]:>10.2e} "
              f"{hist.cumulative_bytes[-1] / 1e6:>9.3f} "
              f"{hist.sim_time_s[-1]:>8.1f} {r_hit:>12d} {sim_hit:>10.1f}")
        out[name] = hist_record(hist)
        out[name]["policy"] = sketch

    # --- error feedback vs the compression floor (FedAvg, O(M) uplink) ---
    # topk0.05 keeps 5% of model coordinates per round; without EF the
    # dropped 95% never reach the server and the loss floors well above
    # the uncompressed run. EF21 memory re-offers the innovation until
    # it lands, collapsing the floor at identical byte cost.
    ef_runs = [
        ("fedavg_raw", CommConfig(channel=chan, seed=1)),
        ("fedavg_topk", CommConfig(codecs="topk0.05", channel=chan, seed=1)),
        ("fedavg_topk_ef", CommConfig(codecs="topk0.05", error_feedback=True,
                                      channel=chan, seed=1)),
    ]
    print("\n--- error feedback on the O(M) uplink (fedavg, topk0.05) ---")
    finals = {}
    for name, comm in ef_runs:
        hist = run_rounds(make_optimizer("fedavg", lr=2.0, local_steps=5),
                          prob, w0, w_star, rounds=args.rounds, comm=comm)
        finals[name] = float(hist.loss[-1])
        print(f"{name:>15} loss_final={hist.loss[-1]:.6f} "
              f"gap_final={hist.gap[-1]:.2e} "
              f"MB_total={hist.cumulative_bytes[-1] / 1e6:.3f}")
        out[name] = hist_record(hist)
    shrink = ef_gap_shrink(finals["fedavg_raw"], finals["fedavg_topk"],
                           finals["fedavg_topk_ef"])
    out["ef_gap_shrink"] = shrink
    print(f"loss gap to no-compression baseline: "
          f"EF off {shrink['ef_off']:.2e}, EF on {shrink['ef_on']:.2e}"
          f"  ->  {ef_ratio_label(shrink)}x smaller with EF")

    # --- population scale: lazy cohorts at m=100 000, q=10^-3 ---
    # The same edge statistics, but the client axis is a population
    # spec: the scheduler samples ~100 client ids per round and ONLY
    # those shards/links are materialized — the dense (m, n_shard, M)
    # tensor (~10^2 GiB at this m for the dense rows above) never
    # exists. Traces store cohort-length arrays, so the JSON record
    # stays small too.
    if args.pop_m > 0:
        q = 1e-3
        pop = SyntheticPopulation(m=args.pop_m, dim=16, seed=1,
                                  dirichlet_alpha=0.3)
        eval_prob = pop.eval_problem()
        w0p = np.zeros(pop.dim)
        w_star_p = newton_solve(eval_prob, w0p)
        comm = CommConfig(codecs={"h_sk": "sympack+qint8", "sg": "qint8",
                                  "grad": "topk0.1+qint8"},
                          channel=population_edge_channel(),
                          scheduler=f"uniform:{q}", seed=1)
        hist = run_rounds(make_optimizer("flens_plus", k=8), pop, w0p,
                          w_star_p, rounds=args.rounds, comm=comm)
        cohort = len(hist.traces[0].ids)
        print(f"\n--- population scale: m={args.pop_m} q={q:g} "
              f"(cohort {cohort}/round, lazy materialization) ---")
        print(f"{'population':>13} {'flens_plus':>14} {hist.gap[-1]:>10.2e} "
              f"{hist.cumulative_bytes[-1] / 1e6:>9.3f} "
              f"{hist.sim_time_s[-1]:>8.1f}")
        out["population_flens_plus"] = {
            **hist_record(hist), "population": args.pop_m, "q": q,
            "cohort": cohort,
        }

        # --- scenario dynamics at population scale (repro.dynamics) ---
        from repro.dynamics import ChannelProcess, DynamicsConfig

        if args.churn:
            # churn shrinks the eligible id pool the uniform:q sampler
            # draws from; the diurnal cycle + regional outages modulate
            # the same per-(client, round) seeded links lazily, so the
            # whole scenario still materializes ~q*m clients per round
            dyn = DynamicsConfig(
                churn=args.churn,
                channel=ChannelProcess(uplink_bytes_per_s="sin:24,0.5",
                                       outage="outage:0.05,3,16", seed=1),
                seed=1)
            hist_c = run_rounds(make_optimizer("flens_plus", k=8), pop, w0p,
                                w_star_p, rounds=args.rounds,
                                comm=CommConfig(
                                    codecs=comm.codecs,
                                    channel=population_edge_channel(),
                                    scheduler=f"uniform:{q}", seed=1,
                                    dynamics=dyn))
            alive = int(dyn.churn.eligible_mask(args.rounds - 1,
                                                args.pop_m).sum())
            print(f"{'churn':>13} {args.churn:>14} {hist_c.gap[-1]:>10.2e} "
                  f"{hist_c.cumulative_bytes[-1] / 1e6:>9.3f} "
                  f"{hist_c.sim_time_s[-1]:>8.1f}"
                  f"   alive@{args.rounds - 1}={alive}/{args.pop_m}")
            out["population_churn"] = {
                **hist_record(hist_c), "churn": args.churn,
                "alive_final": alive,
            }

        if args.byzantine:
            # the coalition corrupts its uplink payloads inside the
            # traced round; the trimmed mean discards the tails
            # coordinate-wise before the participation-weighted average.
            # NOTE the dense codec set: a coordinate-wise trim is
            # destructive on top-k-sparse wire formats (every column is
            # ~90% zeros, so the trim discards the real signal, not the
            # attacker) — robust aggregation wants dense payloads
            dense_codecs = {"h_sk": "sympack+qint8", "sg": "qint8",
                            "grad": "qint8"}
            arms = [("attacked", None), ("trimmed", "trimmed:0.1")]
            gaps = {}
            for arm, robust in arms:
                hist_b = run_rounds(
                    make_optimizer("flens_plus", k=8), pop, w0p, w_star_p,
                    rounds=args.rounds,
                    comm=CommConfig(
                        codecs=dense_codecs,
                        channel=population_edge_channel(),
                        scheduler=f"uniform:{q}", seed=1,
                        dynamics=DynamicsConfig(threat=args.byzantine,
                                                robust=robust, seed=1)))
                gaps[arm] = float(hist_b.gap[-1])
                label = f"byz+{arm}"
                print(f"{label:>13} {args.byzantine:>14} "
                      f"{hist_b.gap[-1]:>10.2e} "
                      f"{hist_b.cumulative_bytes[-1] / 1e6:>9.3f} "
                      f"{hist_b.sim_time_s[-1]:>8.1f}")
                out[f"population_byz_{arm}"] = {
                    **hist_record(hist_b), "threat": args.byzantine,
                    "robust": robust,
                }
            print(f"{'':>13} gap attacked {gaps['attacked']:.2e} vs "
                  f"trimmed {gaps['trimmed']:.2e}")

    dest = pathlib.Path("results/examples")
    dest.mkdir(parents=True, exist_ok=True)
    (dest / "edge_clients.json").write_text(json.dumps(out, indent=1))
    print("\nwrote results/examples/edge_clients.json")


if __name__ == "__main__":
    main()
