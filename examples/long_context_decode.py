"""Long-context decode demo: O(1)-state SSM serving vs KV-cache attention.

Streams a long context through a reduced Mamba-2 and a reduced gemma3
(sliding-window) model, then decodes continuations — demonstrating the
two sub-quadratic serving paths that back the long_500k dry-run shape.

  PYTHONPATH=src python examples/long_context_decode.py --context 2048
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.lm import LM


def run(arch: str, context: int, gen: int):
    cfg = get_config(arch).reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, context), 0, cfg.vocab)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=context + gen))
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    logits, state = prefill(params, {"inputs": toks})
    jax.block_until_ready(logits)
    t_pre = time.perf_counter() - t0

    # state size = the serving memory footprint per request
    state_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))

    tok = jnp.argmax(logits, axis=-1)[:, None]
    t0 = time.perf_counter()
    for _ in range(gen):
        logits, state = decode(params, state, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None]
    jax.block_until_ready(tok)
    t_dec = (time.perf_counter() - t0) / gen

    print(f"{arch:>18} ctx={context:>6}  prefill={t_pre*1e3:8.1f}ms  "
          f"decode={t_dec*1e3:6.1f}ms/tok  state={state_bytes/1e6:7.2f}MB")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--context", type=int, default=2048)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()
    print("(reduced configs; the full-size variants are exercised by the "
          "long_500k dry-run)")
    for arch in ("mamba2-780m", "recurrentgemma-2b", "gemma3-1b"):
        run(arch, args.context, args.gen)


if __name__ == "__main__":
    main()
