"""FLeNS-head: the paper's optimizer inside an LLM fine-tuning loop.

Scenario: m federated clients share a (reduced) TinyLlama backbone and
fine-tune a binary classification head on their private token data. The
head objective given backbone features is exactly the paper's convex
problem, so FLeNS applies *soundly* (DESIGN.md §4.1):

  1. warm up the backbone with a few AdamW LM steps (shared, public data);
  2. every client extracts features from its private sequences;
  3. run FLeNS rounds on the federated head objective — sketched k x k
     Hessian uplink per client — and compare with FedAvg on the same head.

  PYTHONPATH=src python examples/federated_llm.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import make_optimizer, newton_solve, run_rounds
from repro.data.lm_stream import FastLMStream
from repro.models.lm import LM
from repro.optim import adamw_init, adamw_update, extract_features, head_problem


def main():
    m_clients, n_per_client, seq = 8, 64, 32
    cfg = get_config("tinyllama-1.1b").reduced(d_model=128, vocab=256)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # 1. brief LM warmup so the features aren't random projections
    stream = FastLMStream(cfg.vocab, seq, batch=8, seed=0)
    opt_state = adamw_init(params)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        p2, o2, _ = adamw_update(params, grads, opt_state, lr=1e-3)
        return p2, o2, loss

    for i, batch in enumerate(stream.batches(30)):
        params, opt_state, loss = step(params, opt_state, batch)
    print(f"backbone warmup done (lm loss {float(loss):.3f})")

    # 2. private client data: label = does the sequence contain a marker
    #    token pattern (a nonlinear function of the tokens -> the backbone
    #    features are genuinely useful)
    rng = np.random.default_rng(1)
    toks = rng.integers(0, cfg.vocab, size=(m_clients * n_per_client, seq))
    labels = np.where((toks < 8).sum(axis=1) >= 2, 1.0, -1.0)
    feats = extract_features(model, params, jnp.asarray(toks, jnp.int32))
    print(f"features: {feats.shape}, positives: {(labels>0).mean():.2f}")

    # 3. federated second-order head training with FLeNS
    prob = head_problem(feats, jnp.asarray(labels), m_clients, lam=1e-3)
    w0 = jnp.zeros((prob.dim,), jnp.float64)
    w_star = newton_solve(prob, w0, iters=40)

    k = min(64, prob.dim)
    for name, kw in [
        ("fedavg", dict(lr=1.0, local_steps=5)),
        ("flens", dict(k=k)),
        ("fednewton", {}),
    ]:
        hist = run_rounds(make_optimizer(name, **kw), prob, w0, w_star,
                          rounds=10)
        print(f"{hist.name:>10} uplink/round={hist.uplink_floats:>6} "
              f"gap: " + "  ".join(f"{g:.1e}" for g in hist.gap[::2]))

    # head accuracy at the FLeNS solution
    hist = run_rounds(make_optimizer("flens", k=k), prob, w0, w_star, rounds=10)
    # (re-run returns final w via state; reuse problem to score w_star)
    acc = float(jnp.mean((feats @ np.asarray(w_star) > 0) == (labels > 0)))
    print(f"head accuracy at w*: {acc:.3f} (chance 0.5)")


if __name__ == "__main__":
    main()
