"""Asynchronous FL on straggler-heavy edge links — sync vs async drivers.

The synchronous driver waits for the slowest delivering client every
round, so with 30% stragglers at 10x slowdown the round clock is owned
by the unluckiest device. The async driver (``repro.comm.async_driver``)
lets every client run its own download -> compute -> upload cycle against
a persistent clock and commits a server step once a quorum of uploads
has arrived, weighting stale contributions by 1/(1+tau).

Semantics in one line: sync = one global round clock, everyone's payload
lands in the step it was computed for; async = per-client clocks, a
payload computed on model version v may land at version t > v and is
staleness-weighted accordingly. With a full quorum (``async_quantile=1.0``,
full participation, no dropout) the async driver is lock-step-equivalent
and reproduces the synchronous trajectory bit-for-bit — which this demo
checks before printing the comparison.

The channel, the anchor check, and the three-driver race all live in
``benchmarks/paper_common.py`` (``straggler_edge_channel``,
``check_async_lockstep_anchor``, ``sync_async_race``) and are shared
with ``benchmarks/run.py --only async`` — tune them there and both
consumers move together.

Every run is instrumented with the ``repro.obs`` telemetry layer
(``obs=TelemetryConfig(...)``), the trajectories are exported with
``History.to_jsonl`` (one self-describing artifact per driver, loss /
byte / staleness curves plus per-commit ``RoundTrace`` lines — load
them back with ``History.from_jsonl``), and the shared telemetry
stream can be rendered with::

  PYTHONPATH=src python -m repro.obs.report results/examples/async_edge_telemetry.jsonl

Run me::

  PYTHONPATH=src python examples/async_edge.py
  PYTHONPATH=src python examples/async_edge.py --rounds 16 --buffer 8
"""

import argparse
import pathlib
import sys

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.paper_common import (
    build_problem,
    check_async_lockstep_anchor,
    loss_at,
    straggler_edge_channel,
    sync_async_race,
)
from repro.core import make_optimizer
from repro.core.base import History
from repro.obs import TelemetryConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="phishing")
    ap.add_argument("--rounds", type=int, default=10, help="sync server rounds")
    ap.add_argument(
        "--buffer", type=int, default=None, help="async buffer K (default m//4)"
    )
    ap.add_argument("--n-cap", type=int, default=20000)
    args = ap.parse_args()

    spec, prob, w0, w_star = build_problem(args.dataset, n_cap=args.n_cap)
    m = prob.m
    chan = straggler_edge_channel(m)

    def fedavg():
        return make_optimizer("fedavg", lr=2.0, local_steps=5)

    # --- anchor: full-quorum async == sync, bit for bit -------------------
    anchored, _, _ = check_async_lockstep_anchor(
        fedavg, prob, w0, w_star, chan, rounds=3
    )
    print(f"full-quorum async reproduces sync bit-identically: {anchored}")
    assert anchored

    # --- the race: same channel, same seed, three drivers ------------------
    # every driver shares one telemetry artifact (records carry the
    # driver name as their label); instrumentation is null-overhead on
    # the optimization itself — trajectories stay bit-identical
    dest = pathlib.Path("results/examples")
    dest.mkdir(parents=True, exist_ok=True)
    telemetry_path = dest / "async_edge_telemetry.jsonl"
    telemetry_path.unlink(missing_ok=True)  # the jsonl sink appends
    hists = sync_async_race(
        fedavg,
        prob,
        w0,
        w_star,
        chan,
        rounds=args.rounds,
        buffer_size=args.buffer,
        obs_for=lambda name: TelemetryConfig(
            sink=f"jsonl:{telemetry_path}", label=name
        ),
    )
    print(
        f"\n=== {spec.name}: M={prob.dim} m={m} | 30% stragglers x10, "
        f"log-spaced uplinks ==="
    )
    print(
        f"{'driver':>16} {'commits':>7} {'sim_s':>7} {'s/commit':>8} "
        f"{'loss_final':>10} {'mean_tau':>8}"
    )
    for name, hist in hists.items():
        r = hist.rounds
        tau = float(np.nanmean(hist.staleness)) if hist.staleness is not None else 0.0
        print(
            f"{name:>16} {r:>7d} {hist.sim_time_s[-1]:>7.2f} "
            f"{hist.sim_time_s[-1] / r:>8.3f} {hist.loss[-1]:>10.6f} {tau:>8.2f}"
        )

    sync_h = hists["sync"]
    print("\n--- loss at common simulated-time points ---")
    for frac in (0.25, 0.5, 1.0):
        t = frac * min(h.sim_time_s[-1] for h in hists.values())
        row = "  ".join(f"{n}={loss_at(h, t):.6f}" for n, h in hists.items())
        print(f"t={t:6.2f}s  {row}")
    t_final = min(h.sim_time_s[-1] for h in hists.values())
    best = min(hists, key=lambda n: loss_at(hists[n], t_final))
    margin = loss_at(sync_h, t_final) - loss_at(hists[best], t_final)
    if best == "sync":
        print(f"\nat t={t_final:.2f}s sync still leads on this channel/seed")
    else:
        print(
            f"\nat t={t_final:.2f}s the async drivers sit below sync by "
            f"{margin:.2e} loss (best: {best})"
        )

    # --- export: one self-describing JSONL per driver ----------------------
    # History.to_jsonl replaces the old ad-hoc curve dump: the artifact
    # round-trips through History.from_jsonl with every per-commit
    # RoundTrace (incl. staleness) and the telemetry summary intact
    print()
    for name, hist in hists.items():
        path = hist.to_jsonl(dest / f"async_edge_{name}.jsonl")
        back = History.from_jsonl(path)
        assert np.array_equal(hist.loss, back.loss)
        print(f"wrote {path} ({len(back.traces or [])} round traces)")
    print(
        f"wrote {telemetry_path} (render with "
        f"`python -m repro.obs.report {telemetry_path}`)"
    )


if __name__ == "__main__":
    main()
