"""Asynchronous FL on straggler-heavy edge links — sync vs async drivers.

The synchronous driver waits for the slowest delivering client every
round, so with 30% stragglers at 10x slowdown the round clock is owned
by the unluckiest device. The async driver (``repro.comm.async_driver``)
lets every client run its own download -> compute -> upload cycle against
a persistent clock and commits a server step once a quorum of uploads
has arrived, weighting stale contributions by 1/(1+tau).

Semantics in one line: sync = one global round clock, everyone's payload
lands in the step it was computed for; async = per-client clocks, a
payload computed on model version v may land at version t > v and is
staleness-weighted accordingly. With a full quorum (``async_quantile=1.0``,
full participation, no dropout) the async driver is lock-step-equivalent
and reproduces the synchronous trajectory bit-for-bit — which this demo
checks before printing the comparison.

The channel, the anchor check, and the three-driver race all live in
``benchmarks/paper_common.py`` (``straggler_edge_channel``,
``check_async_lockstep_anchor``, ``sync_async_race``) and are shared
with ``benchmarks/run.py --only async`` — tune them there and both
consumers move together.

  PYTHONPATH=src python examples/async_edge.py
  PYTHONPATH=src python examples/async_edge.py --rounds 16 --buffer 8
"""

import argparse
import json
import pathlib
import sys

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.paper_common import (
    build_problem,
    check_async_lockstep_anchor,
    hist_record,
    loss_at,
    straggler_edge_channel,
    sync_async_race,
)
from repro.core import make_optimizer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="phishing")
    ap.add_argument("--rounds", type=int, default=10, help="sync server rounds")
    ap.add_argument(
        "--buffer", type=int, default=None, help="async buffer K (default m//4)"
    )
    ap.add_argument("--n-cap", type=int, default=20000)
    args = ap.parse_args()

    spec, prob, w0, w_star = build_problem(args.dataset, n_cap=args.n_cap)
    m = prob.m
    chan = straggler_edge_channel(m)

    def fedavg():
        return make_optimizer("fedavg", lr=2.0, local_steps=5)

    # --- anchor: full-quorum async == sync, bit for bit -------------------
    anchored, _, _ = check_async_lockstep_anchor(
        fedavg, prob, w0, w_star, chan, rounds=3
    )
    print(f"full-quorum async reproduces sync bit-identically: {anchored}")
    assert anchored

    # --- the race: same channel, same seed, three drivers ------------------
    hists = sync_async_race(
        fedavg, prob, w0, w_star, chan, rounds=args.rounds, buffer_size=args.buffer
    )
    print(
        f"\n=== {spec.name}: M={prob.dim} m={m} | 30% stragglers x10, "
        f"log-spaced uplinks ==="
    )
    print(
        f"{'driver':>16} {'commits':>7} {'sim_s':>7} {'s/commit':>8} "
        f"{'loss_final':>10} {'mean_tau':>8}"
    )
    out = {}
    for name, hist in hists.items():
        r = hist.rounds
        tau = float(np.nanmean(hist.staleness)) if hist.staleness is not None else 0.0
        print(
            f"{name:>16} {r:>7d} {hist.sim_time_s[-1]:>7.2f} "
            f"{hist.sim_time_s[-1] / r:>8.3f} {hist.loss[-1]:>10.6f} {tau:>8.2f}"
        )
        out[name] = hist_record(hist)

    sync_h = hists["sync"]
    print("\n--- loss at common simulated-time points ---")
    for frac in (0.25, 0.5, 1.0):
        t = frac * min(h.sim_time_s[-1] for h in hists.values())
        row = "  ".join(f"{n}={loss_at(h, t):.6f}" for n, h in hists.items())
        print(f"t={t:6.2f}s  {row}")
    t_final = min(h.sim_time_s[-1] for h in hists.values())
    best = min(hists, key=lambda n: loss_at(hists[n], t_final))
    margin = loss_at(sync_h, t_final) - loss_at(hists[best], t_final)
    if best == "sync":
        print(f"\nat t={t_final:.2f}s sync still leads on this channel/seed")
    else:
        print(
            f"\nat t={t_final:.2f}s the async drivers sit below sync by "
            f"{margin:.2e} loss (best: {best})"
        )

    dest = pathlib.Path("results/examples")
    dest.mkdir(parents=True, exist_ok=True)
    (dest / "async_edge.json").write_text(json.dumps(out, indent=1))
    print("wrote results/examples/async_edge.json")


if __name__ == "__main__":
    main()
