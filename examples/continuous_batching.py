"""Continuous-batching serving demo.

Submits a stream of variable-length requests to the slot-based engine
(per-slot decode indices — sequences at different positions share one
batched decode step) and reports throughput + per-request latency.

  PYTHONPATH=src python examples/continuous_batching.py --arch gemma3-1b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.lm import LM
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_batch=args.max_batch,
                           cache_len=args.cache_len)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 40))
        reqs.append(Request(
            uid=i,
            prompt=list(rng.integers(0, cfg.vocab, size=plen)),
            max_new_tokens=int(rng.integers(4, 16)),
        ))
        engine.submit(reqs[-1])

    t0 = time.perf_counter()
    steps = 0
    while engine.queue or engine.active.any():
        engine.step()
        steps += 1
    dt = time.perf_counter() - t0

    total_new = sum(len(r.generated) for r in reqs)
    print(f"arch={cfg.arch_id} (reduced)  requests={len(reqs)} "
          f"max_batch={args.max_batch}")
    print(f"decode steps={steps}  new tokens={total_new}  "
          f"wall={dt:.2f}s  ({total_new/dt:.1f} tok/s)")
    occupancy = total_new / (steps * args.max_batch)
    print(f"slot occupancy={occupancy:.2f} "
          f"(continuous batching keeps slots busy across request lengths)")
    for r in reqs[:4]:
        print(f"  req {r.uid}: prompt {len(r.prompt):2d} toks -> "
              f"{len(r.generated)} new, first: {r.generated[:6]}")


if __name__ == "__main__":
    main()
