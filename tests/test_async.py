"""Asynchronous round driver tests (`repro.comm.async_driver`).

Covers the PR's contract:
  * lock-step equivalence — async with a full quorum, full participation
    and no dropout reproduces the synchronous `History` bit-identically
    (losses and cumulative bytes), even with stragglers drawn;
  * event-driven progress — a FedBuff-style buffer commits without
    waiting for stragglers, so the server clock runs ahead of sync and
    loss-vs-sim-time dominates under heterogeneous links;
  * staleness — weights parse/apply, traces record per-client lag, and
    `History.staleness` exposes the per-commit mean;
  * composition — error feedback, dropout-with-retry, quantile quorums
    and partial-participation schedulers all stay finite and converge.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.paper_common import straggler_edge_channel
from repro.comm import ChannelModel, CommConfig, make_staleness, summarize
from repro.core import make_optimizer, make_problem, newton_solve, run_rounds
from repro.core.losses import logistic
from repro.data import make_classification


@pytest.fixture(scope="module")
def het_problem():
    """12 clients on the shared heterogeneous straggler channel (two
    decades of uplink spread, 30% stragglers, no dropout)."""
    X, y = make_classification(jax.random.PRNGKey(2), 900, 24)
    prob = make_problem(X, y, m=12, lam=1e-3, objective=logistic)
    w0 = jnp.zeros(prob.dim, jnp.float64)
    w_star = newton_solve(prob, w0, iters=30)
    return prob, w0, w_star, straggler_edge_channel(prob.m)


def _fedavg():
    return make_optimizer("fedavg", lr=2.0, local_steps=5)


# ---------------------------------------------------------------------------
# lock-step equivalence (the PR's backward-compatibility anchor)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "name,kw",
    [("flens", dict(k=8)), ("flens_plus", dict(k=8)), ("fedavg", {}),
     ("fednl", {})],
)
def test_async_full_quorum_bit_identical_to_sync(het_problem, name, kw):
    """async_quantile=1.0 + full participation + constant staleness must
    reproduce the synchronous trajectory bit-for-bit — same key
    schedule, same jaxpr — including under straggler draws."""
    prob, w0, w_star, chan = het_problem
    sync = run_rounds(make_optimizer(name, **kw), prob, w0, w_star, rounds=4,
                      comm=CommConfig(channel=chan, seed=1))
    asy = run_rounds(make_optimizer(name, **kw), prob, w0, w_star, rounds=4,
                     comm=CommConfig(channel=chan, seed=1, async_mode=True,
                                     async_quantile=1.0,
                                     staleness="constant"))
    np.testing.assert_array_equal(sync.loss, asy.loss)
    np.testing.assert_array_equal(sync.grad_norm, asy.grad_norm)
    np.testing.assert_array_equal(sync.cumulative_bytes, asy.cumulative_bytes)
    # the server clock telescopes the same per-round maxima the sync
    # driver records (float association differs, hence allclose)
    np.testing.assert_allclose(sync.sim_time_s, asy.sim_time_s, rtol=1e-12)
    # full fresh cohort every commit: zero staleness throughout
    assert asy.staleness is not None
    np.testing.assert_array_equal(asy.staleness, np.zeros(4))


def test_async_lossy_lockstep_matches_sync_bytes(het_problem):
    """Codecs price identically in both drivers (the plan is discovered
    by an abstract probe in async, by the first trace in sync)."""
    prob, w0, w_star, chan = het_problem
    cfg = dict(codecs={"h_sk": "sympack+qint8", "sg": "qint8"},
               channel=chan, seed=3)
    sync = run_rounds(make_optimizer("flens", k=8), prob, w0, w_star,
                      rounds=3, comm=CommConfig(**cfg))
    asy = run_rounds(make_optimizer("flens", k=8), prob, w0, w_star,
                     rounds=3, comm=CommConfig(async_mode=True, **cfg))
    np.testing.assert_array_equal(sync.loss, asy.loss)
    np.testing.assert_array_equal(sync.cumulative_bytes, asy.cumulative_bytes)


# ---------------------------------------------------------------------------
# event-driven progress under stragglers
# ---------------------------------------------------------------------------


def test_async_buffer_outruns_sync_on_sim_time(het_problem):
    """A K=m/3 buffer commits without waiting for stragglers: at any
    common sim-time point the async run has taken more server steps and
    sits at a lower loss than the synchronous run."""
    prob, w0, w_star, chan = het_problem
    sync = run_rounds(_fedavg(), prob, w0, w_star, rounds=10,
                      comm=CommConfig(channel=chan, seed=1))
    asy = run_rounds(_fedavg(), prob, w0, w_star, rounds=40,
                     comm=CommConfig(channel=chan, seed=1, async_mode=True,
                                     buffer_size=prob.m // 3))
    # async commits are much cheaper in simulated seconds
    assert asy.sim_time_s[-1] / 40 < sync.sim_time_s[-1] / 10
    # loss-vs-sim-time dominance at the latest common time point
    t_common = min(sync.sim_time_s[-1], asy.sim_time_s[-1])
    loss_sync = float(np.interp(t_common, sync.sim_time_s, sync.loss))
    loss_asy = float(np.interp(t_common, asy.sim_time_s, asy.loss))
    assert loss_asy < loss_sync
    # buffered commits genuinely reuse stale model versions
    assert float(np.nanmean(asy.staleness)) > 0.0
    # each commit aggregates exactly the buffer quorum
    for tr in asy.traces:
        assert tr.delivered.sum() == prob.m // 3


def test_async_quantile_quorum_size(het_problem):
    prob, w0, w_star, chan = het_problem
    asy = run_rounds(_fedavg(), prob, w0, w_star, rounds=6,
                     comm=CommConfig(channel=chan, seed=2, async_mode=True,
                                     async_quantile=0.5))
    for tr in asy.traces:
        assert tr.delivered.sum() == prob.m // 2
    stats = summarize(asy.traces)
    assert stats["mean_participation"] == pytest.approx(0.5)
    assert stats["mean_staleness"] >= 0.0


def test_async_traces_record_staleness_and_versions(het_problem):
    prob, w0, w_star, chan = het_problem
    asy = run_rounds(_fedavg(), prob, w0, w_star, rounds=8,
                     comm=CommConfig(channel=chan, seed=1, async_mode=True,
                                     buffer_size=3, staleness="inverse"))
    assert asy.staleness is not None and asy.staleness.shape == (8,)
    for t, tr in enumerate(asy.traces):
        assert tr.version == t + 1
        committed = ~np.isnan(tr.staleness)
        np.testing.assert_array_equal(committed, tr.delivered)
        assert (tr.staleness[committed] >= 0).all()
        # a client can lag at most the number of commits so far
        assert (tr.staleness[committed] <= t).all()
    assert float(np.nanmean(asy.staleness)) > 0.0


def test_async_dropout_retries_and_converges(het_problem):
    """Dropped uploads re-dispatch (the client refetches the current
    model) instead of silencing the client forever."""
    prob, w0, w_star, _ = het_problem
    chan = ChannelModel(straggler_prob=0.2, dropout_prob=0.3)
    asy = run_rounds(_fedavg(), prob, w0, w_star, rounds=25,
                     comm=CommConfig(channel=chan, seed=5, async_mode=True,
                                     buffer_size=4, staleness="inverse"))
    assert np.isfinite(asy.loss).all()
    assert asy.gap[-1] < asy.gap[0] * 0.5
    # every client keeps contributing despite dropout
    contributed = np.zeros(prob.m, dtype=bool)
    for tr in asy.traces:
        contributed |= tr.delivered
    assert contributed.all()
    # retried downlinks are billed: more broadcast bytes than commits
    # strictly need
    down = sum(float(tr.bytes_down.sum()) for tr in asy.traces)
    assert down > 0
    # lost uploads are visible in the traces (scheduled \ delivered),
    # not silently absorbed by the retry machinery
    assert summarize(asy.traces)["dropped_client_rounds"] > 0


def test_async_ef_composes(het_problem):
    """EF memory advances only on actual delivery, which now spans
    server steps; the run stays finite and beats EF-off."""
    prob, w0, w_star, chan = het_problem
    base = dict(codecs="topk0.1", channel=chan, seed=1, async_mode=True,
                buffer_size=4)
    off = run_rounds(_fedavg(), prob, w0, w_star, rounds=25,
                     comm=CommConfig(**base))
    on = run_rounds(_fedavg(), prob, w0, w_star, rounds=25,
                    comm=CommConfig(error_feedback=True, **base))
    assert np.isfinite(on.loss).all()
    assert on.gap[-1] < off.gap[-1]
    assert set(on.ef_residuals) == {"w_local"}
    np.testing.assert_array_equal(on.cumulative_bytes, off.cumulative_bytes)


def test_async_partial_scheduler_still_progresses(het_problem):
    prob, w0, w_star, chan = het_problem
    asy = run_rounds(_fedavg(), prob, w0, w_star, rounds=12,
                     comm=CommConfig(channel=chan, seed=4, async_mode=True,
                                     scheduler="uniform:0.5", buffer_size=3))
    assert np.isfinite(asy.loss).all()
    assert asy.gap[-1] < asy.gap[0]


def test_async_zero_rounds(het_problem):
    prob, w0, w_star, chan = het_problem
    hist = run_rounds(_fedavg(), prob, w0, w_star, rounds=0,
                      comm=CommConfig(channel=chan, async_mode=True,
                                      buffer_size=2))
    assert len(hist.loss) == 1 and np.isfinite(hist.loss).all()
    assert hist.staleness is not None and hist.staleness.shape == (0,)


def test_async_trajectory_reproducible(het_problem):
    prob, w0, w_star, chan = het_problem
    cfg = dict(channel=chan, seed=9, async_mode=True, buffer_size=4,
               staleness="poly:1")
    a = run_rounds(_fedavg(), prob, w0, w_star, rounds=10,
                   comm=CommConfig(**cfg))
    b = run_rounds(_fedavg(), prob, w0, w_star, rounds=10,
                   comm=CommConfig(**cfg))
    np.testing.assert_array_equal(a.loss, b.loss)
    np.testing.assert_array_equal(a.sim_time_s, b.sim_time_s)
    for ta, tb in zip(a.traces, b.traces):
        np.testing.assert_array_equal(ta.delivered, tb.delivered)
        np.testing.assert_array_equal(ta.staleness, tb.staleness)


# ---------------------------------------------------------------------------
# staleness weighting + config validation
# ---------------------------------------------------------------------------


def test_async_drop_stale_callable(het_problem):
    """A staleness callable that zeroes stale contributions is legal: a
    commit whose whole buffer is stale advances the clock but leaves the
    model in place instead of dividing by zero."""
    prob, w0, w_star, chan = het_problem
    asy = run_rounds(_fedavg(), prob, w0, w_star, rounds=15,
                     comm=CommConfig(channel=chan, seed=1, async_mode=True,
                                     buffer_size=3,
                                     staleness=lambda tau:
                                         0.0 if tau > 0 else 1.0))
    assert np.isfinite(asy.loss).all()
    assert asy.gap[-1] < asy.gap[0]


def test_make_staleness_specs():
    assert make_staleness("constant")(7.0) == 1.0
    assert make_staleness("inverse")(3.0) == pytest.approx(0.25)
    assert make_staleness("poly:1")(3.0) == pytest.approx(0.25)
    assert make_staleness("poly:2")(1.0) == pytest.approx(0.25)
    assert make_staleness("poly")(0.0) == 1.0  # default exponent
    fn = make_staleness(lambda tau: 42.0)
    assert fn(1.0) == 42.0
    with pytest.raises(ValueError):
        make_staleness("bogus")


def test_server_lr_default_bit_identical(het_problem):
    """server_lr=1.0 (the default) must not change a single float — the
    lock-step fast path stays engaged and async still reproduces sync."""
    prob, w0, w_star, chan = het_problem
    sync = run_rounds(_fedavg(), prob, w0, w_star, rounds=4,
                      comm=CommConfig(channel=chan, seed=1))
    asy = run_rounds(_fedavg(), prob, w0, w_star, rounds=4,
                     comm=CommConfig(channel=chan, seed=1, async_mode=True,
                                     server_lr=1.0))
    np.testing.assert_array_equal(sync.loss, asy.loss)
    np.testing.assert_array_equal(sync.cumulative_bytes, asy.cumulative_bytes)


def test_server_lr_scales_committed_delta(het_problem):
    """FedBuff-style global server lr: on a full-quorum fresh commit the
    applied update is exactly eta_s * (round output - current model)."""
    prob, w0, w_star, chan = het_problem
    opt = _fedavg()
    w1 = opt.round(prob, opt.init(prob, w0), jax.random.PRNGKey(0))["w"]
    w_half = w0 + 0.5 * (w1 - w0)
    expect = float(prob.global_value(w_half))
    asy = run_rounds(_fedavg(), prob, w0, w_star, rounds=1,
                     comm=CommConfig(channel=chan, seed=1, async_mode=True,
                                     server_lr=0.5))
    np.testing.assert_allclose(asy.loss[-1], expect, rtol=1e-12)


def test_server_lr_composes_with_staleness_and_converges(het_problem):
    prob, w0, w_star, chan = het_problem
    asy = run_rounds(_fedavg(), prob, w0, w_star, rounds=25,
                     comm=CommConfig(channel=chan, seed=1, async_mode=True,
                                     buffer_size=4, staleness="inverse",
                                     server_lr=0.7))
    assert np.isfinite(asy.loss).all()
    assert asy.gap[-1] < asy.gap[0] * 0.5


def test_server_lr_validation():
    with pytest.raises(ValueError):
        CommConfig(async_mode=True, server_lr=0.0)
    with pytest.raises(ValueError):
        CommConfig(async_mode=True, server_lr=-0.5)
    # an async-driver knob: configuring it on the sync driver is an error,
    # not a silent no-op
    with pytest.raises(ValueError):
        CommConfig(server_lr=0.5)
    assert CommConfig(server_lr=1.0).server_lr == 1.0  # default passes


def test_async_config_validation():
    with pytest.raises(ValueError):
        CommConfig(async_mode=True, buffer_size=0)
    with pytest.raises(ValueError):
        CommConfig(async_mode=True, async_quantile=0.0)
    with pytest.raises(ValueError):
        CommConfig(async_mode=True, async_quantile=1.5)
    with pytest.raises(ValueError):
        CommConfig(async_mode=True, staleness="exponential!")
    # a buffer larger than m clamps to m (lock-step-equivalent)
    cfg = CommConfig(async_mode=True, buffer_size=10**6)
    assert cfg.buffer_size == 10**6  # config keeps the request; the
    # session clamps (m is only known there)
