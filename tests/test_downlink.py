"""Symmetric transport API tests: downlink broadcast codecs, the
``Session`` driver protocol, and explicit per-client compute time.

Covers the redesign's contract:
  * direction-aware codec resolution — ``codecs["down:<name>"]`` / the
    ``downlink_codecs`` shorthand, with the uplink default never leaking
    into the broadcast direction;
  * identity-downlink bit-exactness in BOTH drivers (the symmetric
    extension of the PR-1 guarantee);
  * downlink byte accounting cross-checked against codec wire sizes,
    and ``History`` axes consistency (up + down == total, per round);
  * one protocol-driven ``run_rounds`` loop — no isinstance driver
    ladder — with ``NullSession`` / ``CommSession`` / ``AsyncSession``
    all satisfying ``prepare`` / ``step`` / ``finalize``;
  * ``ChannelModel.compute_s``: compute time billed explicitly in
    ``client_times`` for both clocks, without touching trajectories.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    AsyncSession,
    ChannelModel,
    CommConfig,
    CommSession,
    NullSession,
    cumulative_bytes_down,
    cumulative_bytes_up,
    make_codec,
    make_session,
)
from repro.core import make_optimizer, make_problem, newton_solve, run_rounds
from repro.core.base import run_rounds as _run_rounds_fn
from repro.core.losses import logistic
from repro.data import make_classification


@pytest.fixture(scope="module")
def small_problem():
    X, y = make_classification(jax.random.PRNGKey(3), 600, 24)
    prob = make_problem(X, y, m=6, lam=1e-3, objective=logistic)
    w0 = jnp.zeros(prob.dim, jnp.float64)
    w_star = newton_solve(prob, w0, iters=30)
    return prob, w0, w_star


# ---------------------------------------------------------------------------
# direction-aware codec resolution
# ---------------------------------------------------------------------------

def test_downlink_codec_resolution_is_direction_aware():
    # uplink compression never leaks into the broadcast direction
    cfg = CommConfig(codecs="qint8")
    assert cfg.codec_for("w_local").name == "qint8"
    assert cfg.codec_for("down:w").name == "identity"

    # the shorthand covers the downlink default only
    cfg = CommConfig(downlink_codecs="bf16")
    assert cfg.codec_for("down:w").name == "bf16"
    assert cfg.codec_for("down:anything").name == "bf16"
    assert cfg.codec_for("w_local").name == "identity"

    # per-name shorthand merges under the down: prefix; explicit codecs
    # entries win on conflict; the sketch seed stays lossless by default
    cfg = CommConfig(codecs={"down:w": "qint8"},
                     downlink_codecs={"w": "bf16", "grad": "fp16"})
    assert cfg.codec_for("down:w").name == "qint8"
    assert cfg.codec_for("down:grad").name == "fp16"
    assert cfg.codec_for("down:seed").name == "identity"

    # ...unless overridden explicitly (their foot)
    cfg = CommConfig(codecs={"down:seed": "bf16"})
    assert cfg.codec_for("down:seed").name == "bf16"


def test_codecs_dict_not_mutated_across_configs():
    """Configs sharing one codec-spec dict must not contaminate each
    other: the downlink_codecs merge works on a private copy."""
    shared = {"h_sk": "sympack+qint8"}
    plain = CommConfig(codecs=shared)
    with_down = CommConfig(codecs=shared, downlink_codecs="bf16")
    assert with_down.codec_for("down:w").name == "bf16"
    assert plain.codec_for("down:w").name == "identity"
    assert "down:default" not in shared


# ---------------------------------------------------------------------------
# identity-downlink bit-exactness, sync and async
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,kw", [
    ("flens", dict(k=8)), ("fedavg", {}), ("distributed_newton", {}),
    ("fednew", {}),
])
def test_identity_downlink_bit_exact_sync_and_async(small_problem, name, kw):
    """Explicit identity downlink codecs reproduce the no-comm
    trajectory bit-for-bit through both drivers."""
    prob, w0, w_star = small_problem
    h0 = run_rounds(make_optimizer(name, **kw), prob, w0, w_star, rounds=3)
    h1 = run_rounds(make_optimizer(name, **kw), prob, w0, w_star, rounds=3,
                    comm=CommConfig(downlink_codecs="identity"))
    np.testing.assert_array_equal(h0.loss, h1.loss)
    np.testing.assert_array_equal(h0.grad_norm, h1.grad_norm)
    h2 = run_rounds(make_optimizer(name, **kw), prob, w0, w_star, rounds=3,
                    comm=CommConfig(downlink_codecs="identity",
                                    async_mode=True))
    np.testing.assert_array_equal(h0.loss, h2.loss)
    np.testing.assert_array_equal(h1.cumulative_bytes, h2.cumulative_bytes)


# ---------------------------------------------------------------------------
# downlink byte accounting
# ---------------------------------------------------------------------------

def test_downlink_bytes_match_codec_wire_sizes(small_problem):
    prob, w0, w_star = small_problem
    M = prob.dim
    f64 = jnp.float64

    # fedavg broadcasts exactly the model: bf16 halves-of-halves it
    hist = run_rounds(make_optimizer("fedavg"), prob, w0, w_star, rounds=2,
                      comm=CommConfig(downlink_codecs="bf16"))
    assert (hist.traces[0].bytes_down
            == make_codec("bf16").nbytes((M,), f64)).all()

    # qint8 broadcast: 1 byte per entry + one fp32 scale
    hist = run_rounds(make_optimizer("fedavg"), prob, w0, w_star, rounds=2,
                      comm=CommConfig(downlink_codecs="qint8", seed=1))
    assert (hist.traces[0].bytes_down == M + 4).all()

    # guarded flens broadcasts w AND w_next (both priced by the codec)
    # plus the lossless (2,)-uint32 sketch seed
    hist = run_rounds(make_optimizer("flens", k=8), prob, w0, w_star,
                      rounds=2, comm=CommConfig(downlink_codecs="bf16"))
    assert (hist.traces[0].bytes_down == 2 * (M * 2) + 8).all()

    # uplink accounting is untouched by downlink codecs
    ident = run_rounds(make_optimizer("flens", k=8), prob, w0, w_star,
                       rounds=2, comm=CommConfig())
    np.testing.assert_array_equal(hist.traces[0].bytes_up,
                                  ident.traces[0].bytes_up)


def test_history_axes_match_directional_trace_sums(small_problem):
    """`History.cumulative_bytes` is exactly the sum of the two
    per-direction trace curves, in every mode and under lossy codecs +
    partial participation."""
    prob, w0, w_star = small_problem
    for comm in (
        CommConfig(seed=1),
        CommConfig(codecs="qint8", downlink_codecs="bf16",
                   scheduler="uniform:0.7",
                   channel=ChannelModel(dropout_prob=0.1), seed=1),
        CommConfig(codecs="qint8", downlink_codecs="bf16", async_mode=True,
                   buffer_size=3, channel=ChannelModel(straggler_prob=0.3),
                   seed=1),
    ):
        hist = run_rounds(make_optimizer("fedavg"), prob, w0, w_star,
                          rounds=5, comm=comm)
        up = cumulative_bytes_up(hist.traces)
        down = cumulative_bytes_down(hist.traces)
        np.testing.assert_allclose(hist.cumulative_bytes, up + down)
        assert down[-1] > 0 and up[-1] > 0
        total = sum(float(t.bytes_up.sum() + t.bytes_down.sum())
                    for t in hist.traces)
        assert float(hist.cumulative_bytes[-1]) == total


def test_lossy_downlink_saves_bytes_and_time_and_converges(small_problem):
    """A bf16 broadcast strictly lowers both transport axes at a bounded
    loss penalty — the benchmark acceptance criterion, in miniature."""
    prob, w0, w_star = small_problem
    chan = ChannelModel(uplink_bytes_per_s=1e4, downlink_bytes_per_s=1e5)
    ident = run_rounds(make_optimizer("fedavg", lr=2.0, local_steps=5),
                       prob, w0, w_star, rounds=8,
                       comm=CommConfig(channel=chan, seed=1))
    lossy = run_rounds(make_optimizer("fedavg", lr=2.0, local_steps=5),
                       prob, w0, w_star, rounds=8,
                       comm=CommConfig(downlink_codecs="bf16", channel=chan,
                                       seed=1))
    assert lossy.cumulative_bytes[-1] < ident.cumulative_bytes[-1]
    assert lossy.sim_time_s[-1] < ident.sim_time_s[-1]
    assert np.isfinite(lossy.loss).all()
    assert lossy.gap[-1] < lossy.gap[0] * 0.5  # still converges
    assert abs(lossy.loss[-1] - ident.loss[-1]) < 1e-2  # bounded gap


def test_lossy_downlink_lockstep_matches_sync(small_problem):
    """Both drivers price and apply downlink codecs identically on the
    lock-step-equivalent path (stochastic broadcast included)."""
    prob, w0, w_star = small_problem
    cfg = dict(downlink_codecs="qint8", codecs={"h_sk": "sympack+qint8"},
               channel=ChannelModel(straggler_prob=0.3), seed=3)
    sync = run_rounds(make_optimizer("flens", k=8), prob, w0, w_star,
                      rounds=3, comm=CommConfig(**cfg))
    asy = run_rounds(make_optimizer("flens", k=8), prob, w0, w_star,
                     rounds=3, comm=CommConfig(async_mode=True, **cfg))
    np.testing.assert_array_equal(sync.loss, asy.loss)
    np.testing.assert_array_equal(sync.cumulative_bytes, asy.cumulative_bytes)


# ---------------------------------------------------------------------------
# the Session protocol
# ---------------------------------------------------------------------------

def test_run_rounds_has_no_isinstance_driver_branching():
    """The driver loop is protocol-driven: mode dispatch lives in
    ``make_session``, not in an isinstance ladder inside run_rounds."""
    src = inspect.getsource(_run_rounds_fn)
    assert "isinstance" not in src
    assert "make_session" in src


def test_all_sessions_implement_the_protocol(small_problem):
    prob, w0, w_star = small_problem
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    kw = dict(m=prob.m, mask_dtype=prob.X.dtype,
              client_weights=np.asarray(prob.client_weights), keys=keys,
              state0={"w": w0}, formula_bytes_per_round=1.0)
    null = make_session(None, **kw)
    sync = make_session(CommConfig(), **kw)
    asyn = make_session(CommConfig(async_mode=True), **kw)
    assert isinstance(null, NullSession)
    assert isinstance(sync, CommSession)
    assert isinstance(asyn, AsyncSession)
    for sess in (null, sync, asyn):
        for method in ("prepare", "begin_variant", "comm_round", "step",
                       "finalize"):
            assert callable(getattr(sess, method)), (sess, method)


def test_null_session_formula_axes(small_problem):
    """comm=None still derives the byte curve from the float formulas
    (all clients, raw dtype width) with zero simulated time."""
    prob, w0, w_star = small_problem
    opt = make_optimizer("fedavg")
    hist = run_rounds(opt, prob, w0, w_star, rounds=4)
    per_round = (opt.uplink_floats(prob) + opt.downlink_floats(prob)) \
        * 8 * prob.m
    np.testing.assert_allclose(hist.cumulative_bytes,
                               np.arange(5) * float(per_round))
    np.testing.assert_array_equal(hist.sim_time_s, np.zeros(5))
    assert hist.traces is None and hist.staleness is None
    assert hist.ef_residuals is None


# ---------------------------------------------------------------------------
# explicit per-client compute time (ChannelModel.compute_s)
# ---------------------------------------------------------------------------

def test_compute_s_enters_client_times():
    m = 4
    base = ChannelModel(uplink_bytes_per_s=1e3, downlink_bytes_per_s=1e4,
                        latency_s=0.1)
    busy = ChannelModel(uplink_bytes_per_s=1e3, downlink_bytes_per_s=1e4,
                        latency_s=0.1, compute_s=2.0)
    draw = base.draw(jax.random.PRNGKey(0), m)
    bytes_up = np.full(m, 1000.0)
    bytes_down = np.full(m, 500.0)
    t0 = base.client_times(draw, bytes_up, bytes_down)
    t1 = busy.client_times(draw, bytes_up, bytes_down)
    np.testing.assert_allclose(t1 - t0, 2.0)
    # per-client heterogeneity: (m,) arrays broadcast, wrong shapes fail
    per = ChannelModel(compute_s=np.arange(1.0, 5.0))
    np.testing.assert_allclose(per.compute_times(4), [1.0, 2.0, 3.0, 4.0])
    with pytest.raises(ValueError):
        per.compute_times(8)
    # stragglers slow the whole cycle, compute included
    slow = ChannelModel(uplink_bytes_per_s=1e3, latency_s=0.0,
                        compute_s=1.0, straggler_prob=1.0,
                        straggler_slowdown=10.0)
    draw = slow.draw(jax.random.PRNGKey(1), m)
    t = slow.client_times(draw, np.zeros(m), np.zeros(m))
    np.testing.assert_allclose(t, 10.0)


def test_compute_s_shifts_sim_time_not_trajectory(small_problem):
    """Compute time is a clock effect in both drivers: identical losses,
    strictly larger sim_time_s, and the sync round_time grows by exactly
    the (unstraggled) compute term."""
    prob, w0, w_star = small_problem
    fast = ChannelModel()
    busy = ChannelModel(compute_s=3.0)
    a = run_rounds(make_optimizer("fedavg"), prob, w0, w_star, rounds=3,
                   comm=CommConfig(channel=fast, seed=1))
    b = run_rounds(make_optimizer("fedavg"), prob, w0, w_star, rounds=3,
                   comm=CommConfig(channel=busy, seed=1))
    np.testing.assert_array_equal(a.loss, b.loss)
    np.testing.assert_allclose(np.diff(b.sim_time_s) - np.diff(a.sim_time_s),
                               3.0)
    # async: per-client clocks advance with compute, trajectory intact
    a2 = run_rounds(make_optimizer("fedavg"), prob, w0, w_star, rounds=3,
                    comm=CommConfig(channel=fast, seed=1, async_mode=True))
    b2 = run_rounds(make_optimizer("fedavg"), prob, w0, w_star, rounds=3,
                    comm=CommConfig(channel=busy, seed=1, async_mode=True))
    np.testing.assert_array_equal(a2.loss, b2.loss)
    assert b2.sim_time_s[-1] > a2.sim_time_s[-1]
