"""Continuous batching == isolated generation, token-for-token."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.lm import LM
from repro.serving import Request, ServingEngine


def _isolated_generate(model, params, prompt, n_new, cache_len):
    """Oracle: exact-length prefill + greedy decode, one request alone."""
    toks = jnp.asarray([prompt], jnp.int32)
    logits, state = model.prefill(params, {"inputs": toks},
                                  cache_len=cache_len)
    state["index"] = jnp.full((1,), len(prompt), jnp.int32)
    out = [int(jnp.argmax(logits[0]))]
    tok = jnp.asarray([[out[-1]]], jnp.int32)
    for _ in range(n_new - 1):
        logits, state = model.decode_step(params, state, tok)
        out.append(int(jnp.argmax(logits[0])))
        tok = jnp.asarray([[out[-1]]], jnp.int32)
    return out


@pytest.fixture(scope="module", params=["tinyllama-1.1b", "gemma3-1b"])
def setup(request):
    cfg = get_config(request.param).reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_continuous_batching_matches_isolated(setup):
    cfg, model, params = setup
    cache_len = 64
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, size=n)) for n in
               (5, 16, 9, 12, 7)]
    n_new = [4, 6, 5, 3, 6]

    engine = ServingEngine(model, params, max_batch=2, cache_len=cache_len)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=k)
            for i, (p, k) in enumerate(zip(prompts, n_new))]
    for r in reqs:
        engine.submit(r)
    engine.run()

    for r, p, k in zip(reqs, prompts, n_new):
        want = _isolated_generate(model, params, p, k, cache_len)
        assert r.done
        assert r.generated == want, (r.uid, r.generated, want)


def test_eos_stops_early(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    prompt = list(rng.integers(0, cfg.vocab, size=8))
    # discover the greedy continuation, then set eos to its 2nd token
    ref = _isolated_generate(model, params, prompt, 6, 64)
    eos = ref[1]
    engine = ServingEngine(model, params, max_batch=1, cache_len=64)
    req = Request(uid=0, prompt=prompt, max_new_tokens=6, eos_id=eos)
    engine.submit(req)
    engine.run()
    assert req.done
    # generation stops at the FIRST occurrence of eos (greedy tokens may
    # repeat on the reduced model, so locate it rather than assuming idx 1)
    expected = ref[: ref.index(eos) + 1]
    assert req.generated == expected


def test_slots_reused_under_queue_pressure(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(2)
    engine = ServingEngine(model, params, max_batch=2, cache_len=64)
    reqs = [Request(uid=i, prompt=list(rng.integers(0, cfg.vocab, size=6)),
                    max_new_tokens=2) for i in range(6)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 2 for r in reqs)
