"""Closed-form grad/Hessian of core losses vs autodiff ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import OBJECTIVES


def _data(key, n=64, m=12):
    kx, ky, kw = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, m), jnp.float64)
    y = jnp.where(jax.random.uniform(ky, (n,)) > 0.5, 1.0, -1.0)
    w = jax.random.normal(kw, (m,), jnp.float64) * 0.5
    return X, y, w


@pytest.mark.parametrize("name", list(OBJECTIVES))
@pytest.mark.parametrize("lam", [0.0, 1e-3, 0.1])
def test_grad_matches_autodiff(name, lam):
    obj = OBJECTIVES[name]
    X, y, w = _data(jax.random.PRNGKey(0))
    if name == "least_squares":
        y = y * 2.0 + 0.3
    g_closed = obj.grad(X, y, w, lam)
    g_auto = jax.grad(lambda w_: obj.value(X, y, w_, lam))(w)
    np.testing.assert_allclose(g_closed, g_auto, rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("name", list(OBJECTIVES))
@pytest.mark.parametrize("lam", [1e-3, 0.1])
def test_hessian_matches_autodiff(name, lam):
    obj = OBJECTIVES[name]
    X, y, w = _data(jax.random.PRNGKey(1))
    h_closed = obj.hessian(X, y, w, lam)
    h_auto = jax.hessian(lambda w_: obj.value(X, y, w_, lam))(w)
    np.testing.assert_allclose(h_closed, h_auto, rtol=1e-9, atol=1e-11)


@pytest.mark.parametrize("name", list(OBJECTIVES))
def test_hess_sqrt_factorization(name):
    """H == A^T A + lam I for the closed-form square root A."""
    obj = OBJECTIVES[name]
    lam = 1e-2
    X, y, w = _data(jax.random.PRNGKey(2))
    a = obj.hess_sqrt(X, y, w, lam)
    h = obj.hessian(X, y, w, lam)
    np.testing.assert_allclose(
        a.T @ a + lam * jnp.eye(X.shape[1]), h, rtol=1e-9, atol=1e-11
    )


@pytest.mark.parametrize("name", list(OBJECTIVES))
def test_hvp_matches_hessian(name):
    obj = OBJECTIVES[name]
    lam = 1e-2
    X, y, w = _data(jax.random.PRNGKey(3))
    v = jax.random.normal(jax.random.PRNGKey(4), w.shape, w.dtype)
    np.testing.assert_allclose(
        obj.hvp(X, y, w, v, lam), obj.hessian(X, y, w, lam) @ v,
        rtol=1e-9, atol=1e-11,
    )
