"""Substrate tests: checkpointing, data pipeline, optimizers, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.data.lm_stream import FastLMStream
from repro.data.libsvm_like import PAPER_DATASETS, load
from repro.optim import adamw_init, adamw_update, linear_warmup_cosine


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16),
                   "c": jnp.asarray(3, jnp.int32)},
    }
    save(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    got = restore(tmp_path, 7, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_latest_and_overwrite(tmp_path):
    tree = {"w": jnp.zeros((4,))}
    save(tmp_path, 1, tree)
    save(tmp_path, 5, tree)
    assert latest_step(tmp_path) == 5
    save(tmp_path, 5, {"w": jnp.ones((4,))})  # overwrite is atomic
    got = restore(tmp_path, 5, tree)
    np.testing.assert_array_equal(got["w"], jnp.ones((4,)))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save(tmp_path, 0, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        restore(tmp_path, 0, {"w": jnp.zeros((5,))})


def test_lm_stream_deterministic_and_learnable():
    s1 = FastLMStream(vocab=64, seq_len=32, batch=4, seed=3)
    s2 = FastLMStream(vocab=64, seq_len=32, batch=4, seed=3)
    b1 = next(iter(s1.batches(1)))
    b2 = next(iter(s2.batches(1)))
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    # bigram structure: the deterministic follower appears far above chance
    toks = np.asarray(b1["inputs"])
    labs = np.asarray(b1["labels"])
    shift = s1.shift
    follows = (toks + shift[toks]) % 64
    frac = float(np.mean(follows == labs))
    assert frac > 0.3  # chance is ~1/64


def test_libsvm_like_stats():
    spec, X, y = load("phishing")
    assert X.shape == (spec.n, spec.dim)
    assert set(np.unique(np.asarray(y))) <= {-1.0, 1.0}
    # classes roughly balanced-ish (generator sanity)
    frac_pos = float(np.mean(np.asarray(y) == 1.0))
    assert 0.2 < frac_pos < 0.8
    assert PAPER_DATASETS["phishing"].dim == 68  # paper Table II


def test_adamw_decreases_quadratic():
    w = {"w": jnp.array([3.0, -2.0, 1.5])}
    opt = adamw_init(w)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(w)
        w, opt, _ = adamw_update(w, g, opt, lr=0.05, weight_decay=0.0)
    assert float(loss(w)) < 1e-2


def test_adamw_bf16_state_dtype():
    w = {"w": jnp.zeros((4,), jnp.bfloat16)}
    opt = adamw_init(w, state_dtype=jnp.bfloat16)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4,), jnp.bfloat16)}
    w2, opt2, _ = adamw_update(w, g, opt, lr=0.1)
    assert w2["w"].dtype == jnp.bfloat16
    assert opt2["v"]["w"].dtype == jnp.bfloat16


def test_schedule_warmup_and_decay():
    lrs = [float(linear_warmup_cosine(s, base_lr=1.0, warmup_steps=10,
                                      total_steps=100)) for s in range(100)]
    assert lrs[0] < 0.11
    assert abs(lrs[10] - 1.0) < 0.02
    assert lrs[-1] < 0.2
    assert max(lrs) <= 1.0 + 1e-6
