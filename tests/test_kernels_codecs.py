"""Fused codec kernels vs reference: value parity + identical byte bills.

The transport hot loop (top-k select+pack, qint8 quantize) dispatches
through ``repro.kernels.ops``; the Pallas bodies (interpret mode on CPU
CI) must agree with the ``repro.kernels.ref`` oracles — which are
op-for-op the pre-kernel ``repro.comm.codecs`` bodies — and the codec
classes must bill exactly the same encoded bytes whichever impl serves
the values.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.codecs import QInt8Codec, TopKCodec, make_codec
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# top-k select
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64,), (13, 17), (7, 9, 5)])
@pytest.mark.parametrize("frac", [0.01, 0.25, 1.0])
def test_topk_parity_exact(shape, frac):
    """Interpret-mode kernel selects the identical index SET (bitwise
    equal dense mask) as the jax.lax.top_k reference."""
    size = math.prod(shape)
    kept = max(1, int(math.ceil(frac * size)))
    x = jax.random.normal(jax.random.PRNGKey(size + kept), shape, jnp.float32)
    want = kops.topk_mask(x, kept, impl="ref")
    got = kops.topk_mask(x, kept, impl="interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(np.count_nonzero(np.asarray(got))) <= kept


def test_topk_tie_breaking_matches_lax_top_k():
    """Ties at the threshold keep the LOWEST flat indices — the
    jax.lax.top_k convention the byte accounting assumes."""
    x = jnp.asarray([[1.0, -1.0, 0.5, 1.0], [0.5, -0.5, 0.5, 0.25]])
    for kept in range(1, 9):
        want = kops.topk_mask(x, kept, impl="ref")
        got = kops.topk_mask(x, kept, impl="interpret")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_topk_all_zero_payload():
    x = jnp.zeros((5, 5), jnp.float32)
    got = kops.topk_mask(x, 3, impl="interpret")
    np.testing.assert_array_equal(np.asarray(got), np.zeros((5, 5)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(3), (40,), dtype)
    want = kops.topk_mask(x, 7, impl="ref")
    got = kops.topk_mask(x, 7, impl="interpret")
    assert got.dtype == dtype
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


# ---------------------------------------------------------------------------
# qint8 quantize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64,), (13, 17), (3, 5, 7)])
def test_qint8_parity(shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32) * 3.0
    u = jax.random.uniform(jax.random.PRNGKey(2), shape, jnp.float32)
    want = kops.qint8_roundtrip(x, u, impl="ref")
    got = kops.qint8_roundtrip(x, u, impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_qint8_all_zero_payload_is_finite():
    """The subnormal-flush guard (scale clamped to tiny) must hold in
    the kernel too: an all-zero payload decodes to zeros, not NaN."""
    x = jnp.zeros((9,), jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(4), (9,), jnp.float32)
    got = np.asarray(kops.qint8_roundtrip(x, u, impl="interpret"))
    assert np.isfinite(got).all()
    np.testing.assert_array_equal(got, np.zeros(9))


def test_qint8_unbiasedness_survives_kernel():
    """Stochastic rounding stays unbiased through the fused body."""
    x = jnp.full((4096,), 0.3, jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(5), (4096,), jnp.float32)
    got = np.asarray(kops.qint8_roundtrip(x, u, impl="interpret"))
    assert abs(got.mean() - 0.3) < 5e-4


# ---------------------------------------------------------------------------
# codec classes: same values through dispatch, identical byte bills
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["qint8", "topk0.1", "topk@5",
                                  "topk0.25+qint8", "sympack+topk0.5+qint8"])
def test_codec_roundtrip_equivalent_across_impls(spec):
    codec = make_codec(spec)
    shape = (16, 16)
    x = jax.random.normal(jax.random.PRNGKey(7), shape, jnp.float64)
    if spec.startswith("sympack"):
        x = 0.5 * (x + x.T)
    key = jax.random.PRNGKey(8)
    with kops.use_impl("ref"):
        want = codec.roundtrip(key, x)
    # f64 payloads (the convex experiments run x64) exercise the ref
    # path only; the kernel body is checked at f32
    with kops.use_impl("ref"):
        again = codec.roundtrip(key, x)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(again))
    xf = x.astype(jnp.float32)
    with kops.use_impl("ref"):
        want32 = codec.roundtrip(key, xf)
    with kops.use_impl("interpret"):
        got32 = codec.roundtrip(key, xf)
    np.testing.assert_allclose(np.asarray(got32), np.asarray(want32),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("spec,shape", [
    ("qint8", (32, 8)),
    ("topk0.1", (257,)),
    ("topk@9+qint8", (64,)),
    ("sympack+qint8", (24, 24)),
])
def test_codec_bytes_identical_across_impls(spec, shape):
    """nbytes is static Python — the fused path must bill exactly the
    bytes the existing Codec wire formats define, impl-independent."""
    codec = make_codec(spec)
    with kops.use_impl("ref"):
        ref_bytes = codec.nbytes(shape, jnp.float32)
    with kops.use_impl("interpret"):
        fused_bytes = codec.nbytes(shape, jnp.float32)
    assert ref_bytes == fused_bytes


def test_topk_codec_keeps_exactly_k_through_kernel():
    codec = TopKCodec(k=9)
    x = jax.random.normal(jax.random.PRNGKey(9), (100,), jnp.float32)
    with kops.use_impl("interpret"):
        out = np.asarray(codec.roundtrip(jax.random.PRNGKey(0), x))
    assert int(np.count_nonzero(out)) == 9
    assert codec.nbytes((100,), jnp.float32) == 9 * 4 + 9 * 4


def test_qint8_codec_jit_and_vmap_through_dispatch():
    """Codecs run inside jitted, vmapped rounds — both impls must trace."""
    codec = QInt8Codec()
    xs = jax.random.normal(jax.random.PRNGKey(10), (4, 33), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(11), 4)
    with kops.use_impl("ref"):
        want = jax.jit(jax.vmap(codec.roundtrip))(keys, xs)
    with kops.use_impl("interpret"):
        got = jax.jit(jax.vmap(codec.roundtrip))(keys, xs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)
