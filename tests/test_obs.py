"""Observability layer tests (`repro.obs`).

Covers the PR's contract:
  * zero interference — running any driver (no-comm, sync, async) with
    telemetry enabled (null sink AND jsonl sink) reproduces the
    uninstrumented trajectory bit-identically: the instrumentation
    wraps jit boundaries from the host and can never perturb the
    optimization;
  * the run summary — compile-vs-exec wall-clock split, phase
    attribution, session metrics (bytes, deliveries, staleness
    distribution, async queue depths), flight-recorder stats;
  * primitives — metrics registry kind safety, flight-recorder ring
    truncation semantics, sink specs, `mean_staleness` edge cases;
  * artifacts — `History.to_jsonl`/`from_jsonl` round-trip (traces,
    staleness, non-finite values), `repro.obs.report` rendering and
    schema checking, the `benchmarks/compare.py` drift table and
    `--bench` gate;
  * diagnostics — driver warnings stay API-visible through the
    structured logger.
"""

import json
import logging
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import ChannelModel, CommConfig
from repro.comm.metrics import RoundTrace
from repro.core import make_optimizer, make_problem, newton_solve, run_rounds
from repro.core.base import History
from repro.core.losses import logistic
from repro.data import make_classification
from repro.obs import (
    FlightRecorder,
    MetricsRegistry,
    TelemetryConfig,
    make_sink,
)
from repro.obs import log as obs_log

pytestmark = pytest.mark.telemetry


@pytest.fixture(scope="module")
def small_problem():
    X, y = make_classification(jax.random.PRNGKey(3), 400, 16)
    prob = make_problem(X, y, m=6, lam=1e-3, objective=logistic)
    w0 = jnp.zeros(prob.dim, jnp.float64)
    w_star = newton_solve(prob, w0, iters=30)
    return prob, w0, w_star


def _flens():
    return make_optimizer("flens", k=6)


# ---------------------------------------------------------------------------
# zero interference: instrumented == uninstrumented, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "comm_fn",
    [
        pytest.param(lambda: None, id="no-comm"),
        pytest.param(lambda: CommConfig(seed=1), id="sync"),
        pytest.param(
            lambda: CommConfig(
                seed=1,
                async_mode=True,
                buffer_size=3,
                channel=ChannelModel(straggler_prob=0.3,
                                     straggler_slowdown=4.0),
            ),
            id="async",
        ),
    ],
)
def test_null_sink_bit_identical(small_problem, comm_fn, tmp_path):
    """Telemetry (null sink and jsonl sink alike) must not perturb the
    trajectory on any driver: same losses, same grads, same bytes."""
    prob, w0, w_star = small_problem
    bare = run_rounds(_flens(), prob, w0, w_star, rounds=4, comm=comm_fn())
    null = run_rounds(_flens(), prob, w0, w_star, rounds=4, comm=comm_fn(),
                      obs=TelemetryConfig())
    jsonl = run_rounds(
        _flens(), prob, w0, w_star, rounds=4, comm=comm_fn(),
        obs=TelemetryConfig(sink=f"jsonl:{tmp_path / 'tel.jsonl'}"))
    for instrumented in (null, jsonl):
        assert np.array_equal(bare.loss, instrumented.loss)
        assert np.array_equal(bare.grad_norm, instrumented.grad_norm)
        assert np.array_equal(bare.cumulative_bytes,
                              instrumented.cumulative_bytes)
        assert np.array_equal(bare.sim_time_s, instrumented.sim_time_s)
    # default is uninstrumented: no summary on the history
    assert bare.telemetry is None
    assert null.telemetry is not None


def test_summary_compile_exec_split(small_problem):
    """Exactly one compile round per jit variant; wall-clock splits into
    compile_s (first call, trace+compile) and exec_s (steady state)."""
    prob, w0, w_star = small_problem
    hist = run_rounds(_flens(), prob, w0, w_star, rounds=5,
                      comm=CommConfig(seed=1), obs=TelemetryConfig())
    tel = hist.telemetry
    assert tel["rounds"] == 5
    assert tel["compile_rounds"] == 1
    assert tel["compile_s"] > 0
    assert tel["exec_s"] > 0
    assert tel["exec_s_per_round"] == pytest.approx(tel["exec_s"] / 4)
    # phase spans partition the loop: step + eval at minimum
    assert {"step", "eval"} <= set(tel["phase_s"])
    counters = tel["metrics"]["counters"]
    assert counters["bytes_up"] == float(
        sum(t.bytes_up.sum() for t in hist.traces))
    assert counters["bytes_down"] == float(
        sum(t.bytes_down.sum() for t in hist.traces))
    assert counters["variant_retraces"] == 0


def test_async_summary_metrics(small_problem):
    """Async runs populate the flight recorder and the staleness /
    queue-depth histograms."""
    prob, w0, w_star = small_problem
    comm = CommConfig(
        seed=1, async_mode=True, buffer_size=2,
        channel=ChannelModel(straggler_prob=0.3, straggler_slowdown=4.0),
        staleness="inverse")
    hist = run_rounds(_flens(), prob, w0, w_star, rounds=5, comm=comm,
                      obs=TelemetryConfig(flight_capacity=8))
    tel = hist.telemetry
    hists = tel["metrics"]["histograms"]
    assert hists["staleness"]["count"] == sum(
        int((~np.isnan(t.staleness)).sum()) for t in hist.traces)
    assert hists["commit_buffer_depth"]["count"] == len(hist.traces)
    assert hists["buffered_upload_age_s"]["min"] >= 0.0
    assert "inflight_depth" in hists
    fl = tel["flight"]
    assert fl["capacity"] == 8
    assert fl["total"] > 8  # dispatches + arrivals + commits overflow 8
    assert fl["kept"] == 8
    assert fl["truncated"] == fl["total"] - 8


def test_variant_retraces_counted(small_problem):
    """Every NEW jitted round variant after the first counts as one
    retrace; its first execution is billed as a compile round."""
    prob, w0, w_star = small_problem
    opt = make_optimizer("fedavg", lr=1.0, local_steps=2)
    # two static variants over four rounds (an adaptive-k policy would
    # announce its k changes exactly like this)
    opt.round_signature = lambda t, state: t // 2
    hist = run_rounds(opt, prob, w0, w_star, rounds=4,
                      comm=CommConfig(seed=1), obs=TelemetryConfig())
    tel = hist.telemetry
    assert tel["metrics"]["counters"]["variant_retraces"] == 1
    assert tel["compile_rounds"] == 2


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def test_metrics_registry_kinds():
    reg = MetricsRegistry()
    c = reg.counter("n")
    c.inc()
    c.inc(2.5)
    assert reg.counter("n") is c  # get-or-create
    reg.gauge("g").set(7)
    reg.histogram("h").observe_many([1.0, 2.0, 3.0])
    with pytest.raises(TypeError):
        reg.gauge("n")  # kind clash must not silently shadow
    snap = reg.snapshot()
    assert snap["counters"]["n"] == 3.5
    assert snap["gauges"]["g"] == 7.0
    assert snap["histograms"]["h"]["count"] == 3
    assert snap["histograms"]["h"]["p50"] == 2.0


def test_flight_recorder_ring_truncation():
    """The ring keeps the MOST RECENT capacity events; total/truncated
    count everything ever recorded."""
    rec = FlightRecorder(capacity=3)
    for i in range(7):
        rec.record("dispatch", float(i), client=i)
    assert rec.total == 7
    assert rec.truncated == 4
    assert [e["client"] for e in rec.events()] == [4, 5, 6]  # oldest first
    assert rec.stats() == {"capacity": 3, "total": 7, "kept": 3,
                           "truncated": 4}
    with pytest.raises(ValueError):
        rec.record("teleport", 0.0)
    with pytest.raises(ValueError):
        FlightRecorder(capacity=0)


def test_sink_specs(tmp_path, capsys):
    path = tmp_path / "sub" / "records.jsonl"
    sink = make_sink(f"jsonl:{path}")
    sink.emit({"type": "round", "x": float("nan"), "y": float("inf")})
    sink.close()
    rec = json.loads(path.read_text())
    assert rec["x"] is None and rec["y"] is None  # strict JSON, no NaN token
    make_sink("stdout").emit({"type": "round", "n": 1})
    assert json.loads(capsys.readouterr().out)["n"] == 1
    make_sink("null").emit({"whatever": 1})
    with pytest.raises(ValueError):
        make_sink("csv:nope")


def test_mean_staleness_all_nan():
    """A commit that delivered nobody has no lag to report: 0.0, not
    NaN (and not a RuntimeWarning from an empty mean)."""
    m = 4
    tr = RoundTrace(
        round=0,
        scheduled=np.zeros(m, dtype=bool),
        delivered=np.zeros(m, dtype=bool),
        straggler=np.zeros(m, dtype=bool),
        bytes_up=np.zeros(m),
        bytes_down=np.zeros(m),
        sim_time_s=0.0,
        staleness=np.full(m, np.nan),
    )
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert tr.mean_staleness == 0.0
    # sync traces (no staleness array) are 0.0 too
    assert RoundTrace(
        round=0, scheduled=np.ones(m, bool), delivered=np.ones(m, bool),
        straggler=np.zeros(m, bool), bytes_up=np.zeros(m),
        bytes_down=np.zeros(m), sim_time_s=1.0).mean_staleness == 0.0


# ---------------------------------------------------------------------------
# artifacts: History JSONL round-trip + report CLI
# ---------------------------------------------------------------------------


def test_history_jsonl_roundtrip(small_problem, tmp_path):
    """to_jsonl/from_jsonl must preserve every curve, per-round trace
    (incl. per-client NaN staleness), and the telemetry summary."""
    prob, w0, w_star = small_problem
    comm = CommConfig(
        seed=1, async_mode=True, buffer_size=2,
        channel=ChannelModel(straggler_prob=0.3, straggler_slowdown=4.0))
    hist = run_rounds(_flens(), prob, w0, w_star, rounds=4, comm=comm,
                      obs=TelemetryConfig(label="rt"))
    path = hist.to_jsonl(tmp_path / "hist.jsonl")
    back = History.from_jsonl(path)
    assert back.name == hist.name
    assert np.array_equal(hist.loss, back.loss)
    assert np.array_equal(hist.gap, back.gap)
    assert np.array_equal(hist.cumulative_bytes, back.cumulative_bytes)
    assert np.allclose(hist.staleness, back.staleness, equal_nan=True)
    assert back.telemetry["label"] == "rt"
    assert len(back.traces) == len(hist.traces)
    for a, b in zip(hist.traces, back.traces):
        assert np.array_equal(a.delivered, b.delivered)
        assert np.array_equal(a.bytes_up, b.bytes_up)
        assert np.allclose(a.staleness, b.staleness, equal_nan=True)
        assert a.version == b.version
        assert a.mean_staleness == b.mean_staleness


def test_history_jsonl_nonfinite(tmp_path):
    """Diverged runs (inf gap) must survive the strict-JSON round trip
    as NaN-free null tokens."""
    hist = History(
        name="diverged",
        loss=np.array([1.0, np.inf, np.nan]),
        gap=np.array([1.0, np.inf, np.nan]),
        grad_norm=np.array([1.0, 2.0, 3.0]),
        uplink_floats=4, downlink_floats=4, wall_time_s=0.1, rounds=2)
    back = History.from_jsonl(hist.to_jsonl(tmp_path / "d.jsonl"))
    assert back.loss[0] == 1.0
    # inf and NaN both travel as null -> come back as NaN
    assert np.isnan(back.loss[1]) and np.isnan(back.loss[2])
    with pytest.raises(ValueError):
        bad = tmp_path / "bad.jsonl"
        bad.write_text('{"type": "history", "schema": "repro.history/v999"}\n')
        History.from_jsonl(bad)


def test_report_cli_jsonl(small_problem, tmp_path, capsys):
    """`python -m repro.obs.report` renders the summary (phases,
    compile/exec split, bytes, staleness) and --check-schema passes on a
    healthy stream / fails on a drifted one."""
    from repro.obs import report

    prob, w0, w_star = small_problem
    path = tmp_path / "tel.jsonl"
    comm = CommConfig(
        seed=1, async_mode=True, buffer_size=2,
        channel=ChannelModel(straggler_prob=0.3, straggler_slowdown=4.0))
    run_rounds(_flens(), prob, w0, w_star, rounds=4, comm=comm,
               obs=TelemetryConfig(sink=f"jsonl:{path}", label="probe"))

    assert report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "== run probe ==" in out
    assert "compile" in out and "staleness" in out and "bytes" in out

    assert report.main([str(path), "--check-schema"]) == 0
    capsys.readouterr()

    # schema drift: summary missing a required key must fail loudly
    records = [json.loads(line) for line in path.read_text().splitlines()]
    summary = next(r for r in records if r["type"] == "summary")
    del summary["compile_s"]
    drifted = tmp_path / "drifted.jsonl"
    drifted.write_text("\n".join(json.dumps(r) for r in records) + "\n")
    assert report.main([str(drifted), "--check-schema"]) == 1
    assert "SCHEMA DRIFT" in capsys.readouterr().out
    # a stream with no summary (truncated run) also fails
    truncated = tmp_path / "trunc.jsonl"
    truncated.write_text(json.dumps(
        {"type": "round", "round": 0, "wall_s": 0.1, "compile": True,
         "phases": {}}) + "\n")
    assert report.main([str(truncated), "--check-schema"]) == 1


def test_report_cli_bench(tmp_path, capsys):
    from repro.obs import report

    doc = {
        "schema": report.BENCH_SCHEMA,
        "dataset": "phishing", "rounds": 12, "budget_bytes": 1000.0,
        "optimizers": {"flens": {
            "compile_s": 1.0, "exec_s_per_round": 0.01,
            "bytes_total": 1000.0, "loss_final": 0.5,
            "loss_at_budget": 0.5}},
    }
    path = tmp_path / "BENCH_round_time.json"
    path.write_text(json.dumps(doc))
    assert report.main([str(path), "--check-schema"]) == 0
    assert report.main([str(path)]) == 0
    assert "flens" in capsys.readouterr().out
    del doc["optimizers"]["flens"]["loss_at_budget"]
    path.write_text(json.dumps(doc))
    assert report.main([str(path), "--check-schema"]) == 1


# ---------------------------------------------------------------------------
# compare.py: drift table + bench gate
# ---------------------------------------------------------------------------


def _bench_doc(exec_s=0.01, loss=0.5, bytes_total=1000):
    return {
        "schema": "bench_round_time/v1", "dataset": "phishing",
        "rounds": 12, "clients": 8, "budget_bytes": float(bytes_total),
        "optimizers": {"flens": {
            "compile_s": 1.0, "exec_s": exec_s * 11,
            "exec_s_per_round": exec_s, "wall_time_s": 1.0 + exec_s * 11,
            "bytes_total": float(bytes_total), "uplink_floats": 100,
            "loss_final": loss, "loss_at_budget": loss}},
    }


def test_compare_drift_table():
    """Every (record, field) comparison appears in the table — not just
    the first failure — with old/new values and pass/fail status."""
    from benchmarks.compare import compare, drift_table, violations_of

    base = {"variants": {"a": {
        "cumulative_bytes": [0, 100], "loss_final": 0.5,
        "stats": {"total_bytes_up": 60, "total_bytes_down": 40}}}}
    cur = {"variants": {"a": {
        "cumulative_bytes": [0, 120], "loss_final": 0.5 * (1 + 1e-5),
        "stats": {"total_bytes_up": 80, "total_bytes_down": 40}}}}
    rows = compare(cur, base, loss_rtol=5e-3)
    # all four fields compared, two fail
    assert [r["field"] for r in rows] == [
        "bytes_total", "stats.total_bytes_up", "stats.total_bytes_down",
        "loss_final"]
    assert [r["ok"] for r in rows] == [False, False, True, True]
    table = drift_table(rows)
    assert table.count("\n") >= 5  # header + rule + 4 rows
    assert "FAIL" in table and "PASS" in table
    assert "100" in table and "120" in table  # old AND new values shown
    viol = violations_of(rows)
    assert len(viol) == 2 and all("drifted" in v for v in viol)


def test_compare_bench_gate():
    """Deterministic fields gate exactly / at rtol; wall-clock only
    fails past the slowdown factor (speedups always pass)."""
    from benchmarks.compare import compare_bench, violations_of

    base = _bench_doc()
    # identical -> clean pass
    assert violations_of(compare_bench(_bench_doc(), base, 5e-3, 5.0)) == []
    # 3x slower passes at factor 5, 10x slower fails
    assert violations_of(
        compare_bench(_bench_doc(exec_s=0.03), base, 5e-3, 5.0)) == []
    viol = violations_of(
        compare_bench(_bench_doc(exec_s=0.1), base, 5e-3, 5.0))
    assert len(viol) == 1 and "exec_s_per_round" in viol[0]
    # 10x FASTER passes (slowdown-only gate)
    assert violations_of(
        compare_bench(_bench_doc(exec_s=0.001), base, 5e-3, 5.0)) == []
    # byte drift is exact-gated
    assert any("bytes_total" in v for v in violations_of(
        compare_bench(_bench_doc(bytes_total=1001), base, 5e-3, 5.0)))
    # loss drift past rtol fails
    assert any("loss_final" in v for v in violations_of(
        compare_bench(_bench_doc(loss=0.51), base, 5e-3, 5.0)))


def test_compare_bench_record_then_gate(tmp_path):
    """A missing bench baseline is installed from the current record
    (exit 0); the next run gates against it."""
    from benchmarks.compare import main as compare_main

    cur = tmp_path / "BENCH_round_time.json"
    baseline = tmp_path / "bench_baseline.json"
    cur.write_text(json.dumps(_bench_doc()))
    assert compare_main(["--bench", str(cur), str(baseline)]) == 0
    assert json.loads(baseline.read_text()) == _bench_doc()
    # second run: gate passes against the recorded baseline
    assert compare_main(["--bench", str(cur), str(baseline)]) == 0
    # a byte drift now fails the gate
    cur.write_text(json.dumps(_bench_doc(bytes_total=2000)))
    assert compare_main(["--bench", str(cur), str(baseline)]) == 1


# ---------------------------------------------------------------------------
# diagnostics: structured logging keeps warnings API-visible
# ---------------------------------------------------------------------------


def test_warn_with_context_dual_emission(caplog):
    """Driver diagnostics emit BOTH a structured log record (with
    machine-readable context) and a real warnings.warn."""
    with caplog.at_level(logging.WARNING, logger="repro.obs"):
        with pytest.warns(UserWarning, match="probe failed"):
            obs_log.warn_with_context("probe failed", round=3,
                                      optimizer="flens", policy=None)
    assert len(caplog.records) == 1
    rec = caplog.records[0]
    assert rec.context == {"round": 3, "optimizer": "flens", "policy": None}
    # None-valued context is dropped from the rendered suffix
    assert "round=3" in rec.getMessage() and "policy" not in rec.getMessage()


def test_quorum_cap_warning_api_visible(small_problem):
    """The async quorum-cap diagnostic must still surface through the
    warnings machinery after the logger conversion."""
    prob, w0, w_star = small_problem
    comm = CommConfig(
        seed=1, async_mode=True, buffer_size=prob.m,  # demands full quorum
        scheduler="uniform:0.4",  # but idles most clients
        channel=ChannelModel())
    with pytest.warns(UserWarning, match="quorum capped"):
        run_rounds(_flens(), prob, w0, w_star, rounds=2, comm=comm)
