"""Per-arch smoke tests: reduced variant, one forward + one train step.

Each assigned architecture is instantiated in its REDUCED form (2-3
layers, d_model<=256, <=4 experts) and must (a) produce finite logits of
the right shape, (b) take one SGD step that changes the params and keeps
the loss finite, and (c) run prefill + a decode step whose logits agree
with the full forward (KV-cache consistency).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import _ALIASES, get_config
from repro.models.lm import LM

ARCHS = list(_ALIASES)


def _batch(cfg, key, b=2, t=16):
    kt, kl, kv, ka = jax.random.split(key, 4)
    batch = {
        "inputs": jax.random.randint(kt, (b, t), 0, cfg.vocab),
        "labels": jax.random.randint(kl, (b, t), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            kv, (b, cfg.vision_tokens, cfg.vision_dim), jnp.float32
        )
    if cfg.family == "audio":
        batch["audio_frames"] = jax.random.normal(
            ka, (b, cfg.audio_frames, cfg.d_model), jnp.float32
        )
    return batch


@pytest.fixture(scope="module")
def built():
    """Build (model, params, batch) per arch once."""
    cache = {}

    def get(arch):
        if arch not in cache:
            # generous MoE capacity: no token drops, so prefill and decode
            # paths are numerically identical (drop tests live elsewhere)
            cfg = get_config(arch).reduced(capacity_factor=4.0)
            model = LM(cfg)
            params = model.init(jax.random.PRNGKey(0))
            batch = _batch(cfg, jax.random.PRNGKey(1))
            cache[arch] = (cfg, model, params, batch)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(built, arch):
    cfg, model, params, batch = built(arch)

    loss_fn = jax.jit(lambda p, b: model.loss(p, b))
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p: model.loss(p, batch), has_aux=True)
    )(params)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0
    # rough sanity: CE within a constant of log(vocab) at init (tied-embed
    # models start with a large logit on the input token, hence the slack)
    assert float(metrics["ce"]) < np.log(cfg.vocab) + 12.0

    # one SGD step -> params change, loss stays finite
    lr = 1e-2
    new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    loss2, _ = loss_fn(new_params, batch)
    assert np.isfinite(float(loss2))
    diffs = jax.tree.leaves(
        jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params)
    )
    assert max(diffs) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(built, arch):
    """decode_step after prefill(T-1 tokens) must match full forward at T."""
    cfg, model, params, batch = built(arch)
    b, t = batch["inputs"].shape

    # full forward logits at every position
    full_batch = dict(batch)
    prefill_batch = dict(batch)
    prefill_batch["inputs"] = batch["inputs"][:, : t - 1]

    logits_pre, state = jax.jit(
        lambda p, bt: model.prefill(p, bt, cache_len=t + 4)
    )(params, prefill_batch)
    last_tok = batch["inputs"][:, t - 1 : t]
    logits_dec, state = jax.jit(model.decode_step)(params, state, last_tok)

    # oracle: prefill on all t tokens gives the logits after token t
    logits_full, _ = jax.jit(lambda p, bt: model.prefill(p, bt, cache_len=t + 4))(
        params, full_batch
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32),
        rtol=2e-2, atol=2e-2,
    )
    assert np.isfinite(np.asarray(logits_dec, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_zeroed_decode_state_step(built, arch):
    """serve_step runs from a zero-initialized state (dry-run path)."""
    cfg, model, params, batch = built(arch)
    b = batch["inputs"].shape[0]
    state = model.init_decode_state(b, 32, index=7)
    if cfg.family == "vlm":
        pass  # cross_k/v zeros are fine for a shape/NaN check
    tok = batch["inputs"][:, :1]
    logits, state2 = jax.jit(model.decode_step)(params, state, tok)
    assert logits.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(state2["index"]) == 8
