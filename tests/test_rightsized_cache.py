"""Right-sized (ring-buffer) sliding-window caches vs uniform caches.

The dense_sb super-block path (cache_mode="rightsized") must produce the
SAME decode logits as the uniform meta-array path — only the cache
footprint may differ.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.lm import LM


@pytest.fixture(scope="module")
def setup():
    base = get_config("gemma3-4b").reduced(
        n_layers=6, local_per_global=2, window=8
    )
    uni = dataclasses.replace(base, cache_mode="uniform")
    rs = dataclasses.replace(base, cache_mode="rightsized")
    m_uni, m_rs = LM(uni), LM(rs)
    params = m_uni.init(jax.random.PRNGKey(0))
    return uni, rs, m_uni, m_rs, params


def test_group_plans_differ_but_layer_count_matches(setup):
    uni, rs, m_uni, m_rs, params = setup
    assert [g.kind for g in m_uni.groups] == ["dense"]
    assert [g.kind for g in m_rs.groups] == ["dense_sb"]
    layers_rs = sum(
        g.n * (rs.local_per_global + 1) if g.kind == "dense_sb" else g.n
        for g in m_rs.groups
    )
    assert layers_rs == uni.n_layers


def test_same_params_same_forward_loss(setup):
    """The rightsized variant reuses a re-stacked view of the same math;
    with independently-inited params the LOSS path must agree when params
    are reshaped from the uniform layout."""
    uni, rs, m_uni, m_rs, params = setup
    batch = {
        "inputs": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, uni.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, uni.vocab),
    }
    # restack uniform (L, ...) params into ((n_super, per, ...), (n_super, ...))
    per = uni.local_per_global + 1
    n_super = uni.n_layers // per
    g0 = params["group0"]

    def to_sb(a):
        folded = a[: n_super * per].reshape((n_super, per) + a.shape[1:])
        return folded

    sb = jax.tree.map(to_sb, g0)
    loc = jax.tree.map(lambda a: a[:, : per - 1], sb)
    glob = jax.tree.map(lambda a: a[:, per - 1], sb)
    params_rs = dict(params)
    params_rs["group0"] = {"loc": loc, "glob": glob}

    l_uni, _ = m_uni.loss(params, batch)
    l_rs, _ = m_rs.loss(params_rs, batch)
    np.testing.assert_allclose(float(l_uni), float(l_rs), rtol=1e-5)

    # decode from zero states agrees too
    s_uni = m_uni.init_decode_state(2, 24, index=0)
    s_rs = m_rs.init_decode_state(2, 24, index=0)
    tok = batch["inputs"][:, :1]
    lo_u, _ = m_uni.decode_step(params, s_uni, tok)
    lo_r, _ = m_rs.decode_step(params_rs, s_rs, tok)
    np.testing.assert_allclose(np.asarray(lo_u, np.float32),
                               np.asarray(lo_r, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_rightsized_cache_is_smaller(setup):
    uni, rs, m_uni, m_rs, params = setup
    cache_len = 64
    s_uni = m_uni.init_decode_state(2, cache_len)
    s_rs = m_rs.init_decode_state(2, cache_len)
    def size(s):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(s))

    assert size(s_rs) < 0.6 * size(s_uni)


def test_rightsized_decode_matches_uniform_decode(setup):
    """Multi-step decode: logits equal while index < window, and remain
    equal beyond the window (ring buffer evicts exactly the masked keys)."""
    uni, rs, m_uni, m_rs, params = setup
    per = uni.local_per_global + 1
    n_super = uni.n_layers // per
    g0 = params["group0"]
    sb = jax.tree.map(
        lambda a: a[: n_super * per].reshape((n_super, per) + a.shape[1:]), g0
    )
    params_rs = dict(params)
    params_rs["group0"] = {
        "loc": jax.tree.map(lambda a: a[:, : per - 1], sb),
        "glob": jax.tree.map(lambda a: a[:, per - 1], sb),
    }
    cache_len = 32
    s_uni = m_uni.init_decode_state(2, cache_len, index=0)
    s_rs = m_rs.init_decode_state(2, cache_len, index=0)
    dec_u = jax.jit(m_uni.decode_step)
    dec_r = jax.jit(m_rs.decode_step)
    key = jax.random.PRNGKey(3)
    tok = jax.random.randint(key, (2, 1), 0, uni.vocab)
    for step in range(uni.window + 6):  # run past the window
        lo_u, s_uni = dec_u(params, s_uni, tok)
        lo_r, s_rs = dec_r(params_rs, s_rs, tok)
        np.testing.assert_allclose(
            np.asarray(lo_u, np.float32), np.asarray(lo_r, np.float32),
            rtol=5e-4, atol=5e-4, err_msg=f"step {step}",
        )
        tok = jnp.argmax(lo_u, axis=-1)[:, None]
