"""FWHT Pallas kernel vs pure-jnp oracle: shape/dtype sweeps + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.fwht import fwht_pallas
from repro.kernels.ops import fwht


@pytest.mark.parametrize("n", [2, 8, 64, 128, 256, 2048, 4096])
@pytest.mark.parametrize("rows", [1, 3, 8, 17])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_matches_oracle(n, rows, dtype):
    x = jax.random.normal(jax.random.PRNGKey(n + rows), (rows, n), dtype)
    got = fwht_pallas(x, interpret=True)
    if dtype == jnp.bfloat16:
        # the kernel accumulates in f32, so it is *closer* to the f32 truth
        # than the bf16 butterfly oracle — compare against the f32 oracle
        want = ref.fwht(x.astype(jnp.float32))
        tol, atol = 5e-2, 2e-2 * max(1.0, n**0.5)
    else:
        want = ref.fwht(x)
        tol, atol = 1e-4, 1e-4 * n**0.5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=atol,
    )


@pytest.mark.parametrize("normalize", [False, True])
def test_fwht_normalized(normalize):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 256), jnp.float32)
    got = fwht_pallas(x, normalize=normalize, interpret=True)
    want = ref.fwht(x, normalize=normalize)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_fwht_batched_shape():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 128), jnp.float32)
    got = fwht_pallas(x, interpret=True)
    assert got.shape == x.shape
    np.testing.assert_allclose(got, ref.fwht(x), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    logn=st.integers(1, 11),
    rows=st.integers(1, 9),
    seed=st.integers(0, 2**30),
)
def test_fwht_involution_property(logn, rows, seed):
    """H(H(x))/n == x — the WHT is an involution up to scale."""
    n = 2**logn
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, n), jnp.float32)
    y = fwht_pallas(fwht_pallas(x, interpret=True), interpret=True) / n
    np.testing.assert_allclose(y, x, rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(logn=st.integers(1, 10), seed=st.integers(0, 2**30))
def test_fwht_orthogonality_property(logn, seed):
    """Normalized WHT preserves L2 norms (orthogonal transform)."""
    n = 2**logn
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, n), jnp.float32)
    y = fwht_pallas(x, normalize=True, interpret=True)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1),
        rtol=1e-4,
    )


def test_ops_dispatch_reference_matches_interpret():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 512), jnp.float32)
    a = fwht(x, impl="reference")
    b = fwht(x, impl="interpret")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
