"""Population-scale client axis: lazy cohort materialization.

Three invariant families:

  * **Golden bit-identity** — the legacy dense-``m`` construction
    (``make_problem``) is now a thin wrapper over ``DatasetPopulation``;
    the pre-refactor fixture ``tests/golden/population_golden.json``
    pins sha256 fingerprints of the constructed problems AND full
    loss/bytes trajectories across all three drivers (no-comm, sync,
    async) so the wrapper cannot drift by a single bit.
  * **Cohort determinism** — the same ``(seed, round)`` yields identical
    cohort ids, shards, and channel draws across runs; per-id channel
    coins are independent of cohort composition (the property that makes
    sync and async drivers agree on any shared client).
  * **Bounded memory** — the EF hot-set store (``BoundedMemory``) and
    the m=100k smoke (slow-marked, subprocess-isolated RSS budget).
"""
import hashlib
import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import BoundedMemory, ChannelModel, CommConfig
from repro.core import (
    DatasetPopulation,
    SyntheticPopulation,
    make_optimizer,
    make_problem,
    newton_solve,
    run_rounds,
)
from repro.core.losses import logistic
from repro.data.libsvm_like import make_classification

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" / "population_golden.json")
    .read_text())


def _sha(a) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(np.asarray(a)).tobytes()).hexdigest()


@pytest.fixture(scope="module")
def class_data():
    return make_classification(jax.random.PRNGKey(4), 600, 16)


def _golden_problem(class_data, het: str):
    X, y = class_data
    key = {"iid": 7, "dirichlet": 11}[het]
    return make_problem(X, y, m=8, lam=1e-3, objective=logistic,
                        key=jax.random.PRNGKey(key), heterogeneity=het)


def _golden_channel(m: int) -> ChannelModel:
    return ChannelModel(
        uplink_bytes_per_s=np.logspace(4, 6, m),
        downlink_bytes_per_s=1e7, latency_s=0.05,
        straggler_prob=0.2, dropout_prob=0.1)


def _golden_runs(m: int):
    chan = _golden_channel(m)
    return {
        "flens_nocomm": ("flens", dict(k=8), None),
        "flens_sync_identity": ("flens", dict(k=8), CommConfig()),
        "flens_async_lockstep": ("flens", dict(k=8),
                                 CommConfig(async_mode=True)),
        "flens_sync_rich": ("flens", dict(k=8),
                            CommConfig(codecs={"sg": "qint8"},
                                       scheduler="uniform:0.5",
                                       channel=chan, seed=3)),
        "fedavg_sync_ef": ("fedavg", dict(lr=2.0, local_steps=3),
                           CommConfig(codecs="topk0.25", error_feedback=True,
                                      scheduler="uniform:0.5",
                                      channel=chan, seed=3)),
        "fedavg_async_buf": ("fedavg", dict(lr=2.0, local_steps=3),
                             CommConfig(async_mode=True, buffer_size=3,
                                        staleness="inverse",
                                        channel=chan, seed=3)),
    }


# -- golden bit-identity ------------------------------------------------------

@pytest.mark.parametrize("het", ["iid", "dirichlet"])
def test_make_problem_fingerprint_matches_pre_refactor(class_data, het):
    prob = _golden_problem(class_data, het)
    want = GOLDEN[het]["problem"]
    assert list(prob.X.shape) == want["shape"]
    assert _sha(prob.X) == want["X"]
    assert _sha(prob.y) == want["y"]
    assert _sha(prob.mask) == want["mask"]


@pytest.mark.parametrize("het", ["iid", "dirichlet"])
@pytest.mark.parametrize("run", [
    "flens_nocomm", "flens_sync_identity", "flens_async_lockstep",
    "flens_sync_rich", "fedavg_sync_ef", "fedavg_async_buf",
])
def test_dense_trajectory_matches_pre_refactor_golden(class_data, het, run):
    prob = _golden_problem(class_data, het)
    w0 = jnp.zeros(prob.dim, jnp.float64)
    w_star = newton_solve(prob, w0, iters=30)
    opt_name, kw, comm = _golden_runs(prob.m)[run]
    h = run_rounds(make_optimizer(opt_name, **kw), prob, w0, w_star,
                   rounds=4, comm=comm)
    want = GOLDEN[het]["runs"][run]
    assert [float(v) for v in h.loss] == want["loss"]
    assert [float(v) for v in h.cumulative_bytes] == want["cumulative_bytes"]


def test_dataset_population_wrapper_is_the_dense_constructor(class_data):
    X, y = class_data
    key = jax.random.PRNGKey(11)
    dense = make_problem(X, y, m=8, lam=1e-3, objective=logistic,
                         key=key, heterogeneity="dirichlet")
    pop = DatasetPopulation(X, y, m=8, lam=1e-3, objective=logistic,
                            key=key, heterogeneity="dirichlet")
    full = pop.materialize_all()
    assert _sha(dense.X) == _sha(full.X)
    assert _sha(dense.y) == _sha(full.y)
    assert _sha(dense.mask) == _sha(full.mask)
    # cohort materialization gathers the same rows the dense problem holds
    ids = np.array([6, 1, 3])
    cohort = pop.materialize(ids)
    for j, cid in enumerate(ids):
        np.testing.assert_array_equal(np.asarray(cohort.X[j]),
                                      np.asarray(dense.X[cid]))
        np.testing.assert_array_equal(np.asarray(cohort.mask[j]),
                                      np.asarray(dense.mask[cid]))


# -- cohort determinism -------------------------------------------------------

def test_synthetic_population_shards_deterministic_per_id():
    a = SyntheticPopulation(m=32, dim=6, seed=9)
    b = SyntheticPopulation(m=32, dim=6, seed=9)
    ca = a.materialize(np.array([4, 17, 30]))
    # a different cohort containing a shared id must produce the same
    # shard for that id — client data depends on (seed, client_id) only
    cb = b.materialize(np.array([17, 2]))
    np.testing.assert_array_equal(np.asarray(ca.X[1]), np.asarray(cb.X[0]))
    np.testing.assert_array_equal(np.asarray(ca.y[1]), np.asarray(cb.y[0]))
    np.testing.assert_array_equal(np.asarray(ca.mask[1]),
                                  np.asarray(cb.mask[0]))


def test_scheduler_cohort_ids_deterministic_and_sorted():
    cfg = CommConfig(scheduler="uniform:0.25", seed=5)
    k = jax.random.fold_in(jax.random.PRNGKey(5), 3)
    k_sched, _, _ = jax.random.split(k, 3)
    ids1 = cfg.scheduler.sample_ids(k_sched, 3, 64, cfg.channel)
    ids2 = cfg.scheduler.sample_ids(k_sched, 3, 64, cfg.channel)
    np.testing.assert_array_equal(ids1, ids2)
    assert list(ids1) == sorted(set(int(v) for v in ids1))
    assert len(ids1) == cfg.scheduler.cohort_size(64) == 16
    # participants() is the dense view of the same draw
    mask = cfg.scheduler.participants(k_sched, 3, 64, cfg.channel)
    np.testing.assert_array_equal(np.flatnonzero(mask), ids1)


def test_channel_coins_independent_of_cohort_composition():
    chan = ChannelModel(straggler_prob=0.4, dropout_prob=0.3)
    key = jax.random.PRNGKey(21)
    solo = chan.draw_for(key, np.array([5]))
    crowd = chan.draw_for(key, np.array([1, 5, 9]))
    assert bool(solo.straggler[0]) == bool(crowd.straggler[1])
    assert bool(solo.dropout[0]) == bool(crowd.dropout[1])


def test_population_runs_reproducible_and_cohorts_logged():
    pop = SyntheticPopulation(m=64, dim=8, seed=3)
    w0 = jnp.zeros(pop.dim, jnp.float64)
    ev = pop.eval_problem()
    w_star = newton_solve(ev, w0)
    opt = make_optimizer("flens", k=4)
    comm = dict(scheduler="uniform:0.25", seed=2)
    h1 = run_rounds(opt, pop, w0, w_star, rounds=3,
                    comm=CommConfig(**comm))
    h2 = run_rounds(opt, pop, w0, w_star, rounds=3,
                    comm=CommConfig(**comm))
    np.testing.assert_array_equal(h1.loss, h2.loss)
    for t1, t2 in zip(h1.traces, h2.traces):
        np.testing.assert_array_equal(t1.ids, t2.ids)
        np.testing.assert_array_equal(t1.delivered, t2.delivered)
        assert t1.population == 64
        assert len(t1.ids) == 16  # cohort-length arrays, never (m,)
        assert len(t1.delivered) == 16


def test_population_lockstep_bit_identical_across_drivers():
    """Full scheduler + no dropout + full quorum: sync and async
    population drivers share key schedule, cohorts, and jaxpr."""
    pop = SyntheticPopulation(m=16, dim=6, seed=4)
    w0 = jnp.zeros(pop.dim, jnp.float64)
    ev = pop.eval_problem()
    w_star = newton_solve(ev, w0)
    opt = make_optimizer("flens", k=4)
    hs = run_rounds(opt, pop, w0, w_star, rounds=3, comm=CommConfig())
    ha = run_rounds(opt, pop, w0, w_star, rounds=3,
                    comm=CommConfig(async_mode=True))
    np.testing.assert_array_equal(hs.loss, ha.loss)


def test_population_async_partial_matches_dense_prefix():
    """Population and dense async drivers share the commit machinery;
    the trajectories agree to reduction-order rounding while the flight
    pools coincide (population rounds reduce over (c,)-cohorts, dense
    rounds over the masked (m,) axis — same math, different summation
    geometry, so equality is to ULPs rather than bits; bitwise identity
    across drivers holds on the lockstep path, tested above)."""
    pop = SyntheticPopulation(m=64, dim=8, seed=3)
    dense = pop.materialize_all()
    w0 = jnp.zeros(pop.dim, jnp.float64)
    w_star = newton_solve(pop.eval_problem(), w0)
    opt = make_optimizer("flens", k=4)
    cfg = dict(scheduler="uniform:0.25", async_mode=True, buffer_size=4)
    hd = run_rounds(opt, dense, w0, w_star, rounds=3, comm=CommConfig(**cfg))
    hp = run_rounds(opt, pop, w0, w_star, rounds=3, comm=CommConfig(**cfg))
    np.testing.assert_allclose(hd.loss, hp.loss, rtol=1e-12)
    # the schedules themselves are identical: same delivered cohorts
    # (population ids also list dispatch-only clients carrying broadcast
    # bytes, so compare the delivered subset)
    for td, tp in zip(hd.traces, hp.traces):
        np.testing.assert_array_equal(np.flatnonzero(td.delivered),
                                      tp.ids[tp.delivered])


# -- guard rails --------------------------------------------------------------

def test_population_requires_comm():
    pop = SyntheticPopulation(m=8, dim=4)
    w0 = jnp.zeros(4, jnp.float64)
    with pytest.raises(ValueError, match="population-mode runs need"):
        run_rounds(make_optimizer("fedavg"), pop, w0, w0, rounds=1)


def test_fednew_rejected_in_population_mode():
    pop = SyntheticPopulation(m=8, dim=4)
    w0 = jnp.zeros(4, jnp.float64)
    with pytest.raises(NotImplementedError, match="per_client_state"):
        run_rounds(make_optimizer("fednew"), pop, w0, w0, rounds=1,
                   comm=CommConfig(scheduler="uniform:0.5"))


def test_dirichlet_pad_blowup_warns_and_caps(class_data):
    X, y = class_data
    key = jax.random.PRNGKey(11)  # known 472-row max vs 75-row mean
    with pytest.warns(UserWarning, match="pad"):
        dense = make_problem(X, y, m=8, lam=1e-3, objective=logistic,
                             key=key, heterogeneity="dirichlet")
    capped = make_problem(X, y, m=8, lam=1e-3, objective=logistic,
                          key=key, heterogeneity="dirichlet",
                          max_pad_factor=2.0)
    assert capped.X.shape[1] <= 2 * int(np.ceil(600 / 8))
    assert capped.X.shape[1] < dense.X.shape[1]
    # every row still lands on exactly one client
    assert int(np.asarray(capped.mask).sum()) == 600


def test_channel_wrong_length_array_raises():
    chan = ChannelModel(uplink_bytes_per_s=np.ones(5))
    with pytest.raises(ValueError, match=r"shape \(5,\), want \(8,\)"):
        chan.uplink_rates(8)
    with pytest.raises(ValueError, match="compute_s"):
        ChannelModel(compute_s=np.ones(3)).compute_times(8)
    with pytest.raises(ValueError, match="latency_s"):
        ChannelModel(latency_s=np.ones(3)).latencies(8)


def test_channel_distribution_specs_deterministic():
    chan = ChannelModel(uplink_bytes_per_s="loguniform:1e4,1e6",
                        latency_s="uniform:0.01,0.1", attr_seed=7)
    full = chan.uplink_rates(32)
    sub = chan.uplink_rates_for(np.array([3, 19]), 32)
    np.testing.assert_array_equal(sub, full[[3, 19]])
    assert np.all(full >= 1e4) and np.all(full <= 1e6)
    lat = chan.latencies(32)
    assert np.all(lat >= 0.01) and np.all(lat <= 0.1)
    # a different attr_seed is a different population
    other = ChannelModel(uplink_bytes_per_s="loguniform:1e4,1e6",
                         attr_seed=8).uplink_rates(32)
    assert not np.array_equal(full, other)


def test_channel_bad_spec_raises():
    with pytest.raises(ValueError, match="distribution"):
        ChannelModel(uplink_bytes_per_s="zipf:2").uplink_rates(4)


# -- bounded EF memory --------------------------------------------------------

def _spec(dim=4):
    return {"g": jax.ShapeDtypeStruct((1, dim), jnp.float64)}


def test_bounded_memory_roundtrip_and_reset():
    store = BoundedMemory(_spec(), capacity=4)
    ids = [7, 2, 9]
    mem = store.gather(ids)
    assert mem["g"].shape == (3, 4)
    np.testing.assert_array_equal(np.asarray(mem["g"]), 0.0)
    store.scatter(ids, {"g": jnp.arange(12, dtype=jnp.float64)
                        .reshape(3, 4)})
    back = store.gather([9, 7])
    np.testing.assert_array_equal(np.asarray(back["g"][0]),
                                  [8.0, 9.0, 10.0, 11.0])
    np.testing.assert_array_equal(np.asarray(back["g"][1]),
                                  [0.0, 1.0, 2.0, 3.0])


def test_bounded_memory_lru_eviction_resets_cold_rows():
    # scatter follows gather of the same ids — the driver invariant
    store = BoundedMemory(_spec(), capacity=3)
    store.gather([1, 2, 3])
    store.scatter([1, 2, 3], {"g": jnp.ones((3, 4), jnp.float64)})
    store.gather([1])  # refresh 1: now 2 is the LRU
    store.gather([4])  # assigns a fresh slot, evicting 2
    store.scatter([4], {"g": 2 * jnp.ones((1, 4), jnp.float64)})
    assert store.evictions == 1
    got = store.gather([2])  # cold row: on-sample reset to zero
    np.testing.assert_array_equal(np.asarray(got["g"]), 0.0)
    kept = store.gather([1])
    np.testing.assert_array_equal(np.asarray(kept["g"]), 1.0)


def test_bounded_memory_capacity_and_overflow():
    store = BoundedMemory(_spec(), capacity=2)
    assert store.nbytes == 2 * 4 * 8
    with pytest.raises(ValueError, match="ef_capacity"):
        store.gather([1, 2, 3])


def test_bounded_memory_duplicate_ids_share_slot():
    store = BoundedMemory(_spec(), capacity=4)
    store.gather([5])
    store.scatter([5], {"g": jnp.ones((1, 4), jnp.float64)})
    got = store.gather([5, 5, 5])  # pad-style duplicates
    np.testing.assert_array_equal(np.asarray(got["g"]),
                                  np.ones((3, 4)))
    assert store.evictions == 0


def test_population_ef_footprint_bounded():
    """EF memory scales with the hot set, not the population."""
    from repro.obs import TelemetryConfig

    pop = SyntheticPopulation(m=256, dim=6, seed=2)
    w0 = jnp.zeros(pop.dim, jnp.float64)
    w_star = newton_solve(pop.eval_problem(), w0)
    h = run_rounds(make_optimizer("fedavg", lr=1.0, local_steps=2),
                   pop, w0, w_star, rounds=3,
                   comm=CommConfig(scheduler="uniform:0.125",
                                   codecs="topk0.5", error_feedback=True),
                   obs=TelemetryConfig())
    gauges = h.telemetry["metrics"]["gauges"]
    cohort, dim = 32, 6
    assert gauges["ef_memory_bytes"] == 8 * cohort * dim * 8  # hot set
    assert gauges["ef_memory_bytes"] < 256 * dim * 8 * 2  # << dense-ish
    assert h.ef_residuals  # residuals survive the bounded store


# -- population-scale smoke ---------------------------------------------------

_SMOKE_100K = """
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.core import SyntheticPopulation, make_optimizer, run_rounds, \
    newton_solve
from repro.comm import CommConfig

pop = SyntheticPopulation(m=100_000, dim=16, seed=1)
w0 = jnp.zeros(pop.dim, jnp.float64)
w_star = newton_solve(pop.eval_problem(), w0)
h = run_rounds(make_optimizer("flens", k=8), pop, w0, w_star, rounds=5,
               comm=CommConfig(scheduler="uniform:0.001"))
assert len(h.traces[0].ids) == 100, len(h.traces[0].ids)
assert h.traces[0].population == 100_000
assert h.loss[-1] < h.loss[0], list(h.loss)
# VmHWM, not getrusage: ru_maxrss survives exec on Linux, so a child
# forked from a fat pytest parent inherits the PARENT's high-water mark
# (multi-GiB after the kernel/model tests); VmHWM lives on the mm and
# is reset by exec, so it measures only this process
hwm_kib = next(line for line in open("/proc/self/status")
               if line.startswith("VmHWM")).split()[1]
rss_mib = int(hwm_kib) / 1024
# dense materialization would need X (100_000 * 64 * 16 * 8 B ~ 820 MiB)
# plus y/mask/row storage — well over 1.5 GiB on top of the ~300 MiB
# interpreter+XLA baseline. Measured population-mode peak: ~360 MiB;
# the budget separates that from any (m, n_shard, M) materialization
# with compile-cache headroom.
assert rss_mib < 700, f"peak RSS {rss_mib:.0f} MiB exceeds budget"
print(f"OK loss={h.loss[-1]:.5f} rss={rss_mib:.0f}MiB")
"""


@pytest.mark.slow
def test_population_100k_memory_bounded():
    """m=100k, q=1e-3: runs in bounded memory (subprocess-isolated so
    the RSS high-water mark is this run's, not the test session's)."""
    proc = subprocess.run(
        [sys.executable, "-c", _SMOKE_100K], capture_output=True, text=True,
        timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout.startswith("OK"), proc.stdout
