"""SketchPolicy tests: spec grammar, schedule semantics, EF eligibility
flowing from ``basis_persistent``, adaptive-k ramping + round-varying
byte billing, and the redesign's backward-compatibility contract (the
default fresh/constant-k policy reproduces the pre-policy trajectories
bit for bit — golden values captured from the seed code).
"""
import inspect
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import ChannelModel, CommConfig, CommSession
from repro.core import (
    SketchPolicy,
    as_policy,
    make_optimizer,
    make_problem,
    newton_solve,
    run_rounds,
)
from repro.core.losses import logistic
from repro.data import make_classification

# no-comm losses of the default (fresh basis, constant k) policy, captured
# from the pre-SketchPolicy code on this exact problem/seed — the redesign's
# bit-identity contract for every sketched optimizer
GOLDEN_LOSSES = {
    "flens": [0.6931471805599452, 0.6101396628666327, 0.5886880709327852,
              0.5886880709327852, 0.5836630185920685],
    "flens_plus": [0.6931471805599452, 0.6015472835168161, 0.6015472835168161,
                   0.5866587222754482, 0.5747659024283325],
    "fedns": [0.6931471805599452, 0.7166734224450081, 1.420287152953094,
              4.742821066312273, 19.734619500330894],
    "fedndes": [0.6931471805599452, 0.5633062504196183, 0.5571608398764784,
                0.5565957824063676, 0.5565779318201288],
}


@pytest.fixture(scope="module")
def small_problem():
    X, y = make_classification(jax.random.PRNGKey(2), 600, 24)
    prob = make_problem(X, y, m=6, lam=1e-3, objective=logistic)
    w0 = jnp.zeros(prob.dim, jnp.float64)
    w_star = newton_solve(prob, w0, iters=30)
    return prob, w0, w_star


# ---------------------------------------------------------------------------
# spec grammar
# ---------------------------------------------------------------------------

def test_spec_grammar_parses():
    p = SketchPolicy.parse("srht")
    assert (p.kind, p.schedule, p.adaptive) == ("srht", "fresh", False)
    p = SketchPolicy.parse("srht:fixed")
    assert p.schedule == "fixed"
    p = SketchPolicy.parse("srht:rotate=8")
    assert (p.schedule, p.period) == ("rotate", 8)
    p = SketchPolicy.parse("gaussian:adaptive")
    assert (p.kind, p.adaptive) == ("gaussian", True)
    p = SketchPolicy.parse("sjlt:rotate=4,seed=3")
    assert (p.kind, p.period, p.seed) == ("sjlt", 4, 3)
    p = SketchPolicy.parse("srht:adaptive=8..64,c=1.5")
    assert (p.k_min, p.k_max, p.c) == (8, 64, 1.5)
    p = SketchPolicy.parse("srht:k=12,fixed")
    assert (p.k, p.schedule) == (12, "fixed")


def test_spec_roundtrips_through_spec():
    for spec in ("srht", "srht:fixed", "srht:rotate=8", "gaussian:adaptive",
                 "sjlt:rotate=4,seed=3", "srht:adaptive=8..64"):
        assert SketchPolicy.parse(spec).spec() == spec
    # spec() is COMPLETE: parsing it reproduces the policy exactly, with
    # bound k and non-default c included (reports never under-describe)
    for pol in (SketchPolicy.parse("srht").with_k(17),
                SketchPolicy.parse("srht:rotate=8,c=3.0").with_k(8),
                SketchPolicy.parse("srht:adaptive=4..64,c=0.5").with_k(4)):
        assert SketchPolicy.parse(pol.spec()) == pol
        assert f"k={pol.k}" in pol.spec()


@pytest.mark.parametrize("bad", [
    "zstd", "srht:rotate", "srht:rotate=0", "srht:warp=2", "srht:adaptive=8",
])
def test_spec_grammar_rejects(bad):
    with pytest.raises(ValueError):
        SketchPolicy.parse(bad)


def test_as_policy_binds_k_without_overriding():
    assert as_policy("srht", k=8).k == 8
    assert as_policy("srht:k=12", k=8).k == 12  # explicit spec k wins
    pol = SketchPolicy.parse("srht").with_k(5)
    assert as_policy(pol, k=8).k == 5  # pre-bound policy wins
    with pytest.raises(TypeError):
        as_policy(17)


# ---------------------------------------------------------------------------
# schedule semantics
# ---------------------------------------------------------------------------

def test_basis_persistent_predicate():
    fresh = SketchPolicy.parse("srht")
    fixed = SketchPolicy.parse("srht:fixed")
    rot = SketchPolicy.parse("srht:rotate=4")
    assert not fresh.basis_persistent()
    assert fixed.basis_persistent()
    assert rot.basis_persistent()
    # per-round: a rotating basis persists except across epoch boundaries
    assert [rot.basis_persistent(t) for t in range(8)] == [
        True, True, True, False, True, True, True, False]
    assert not SketchPolicy.parse("srht:rotate=1").basis_persistent()
    # adaptive-k can resize the payload: never EF-eligible
    assert not SketchPolicy.parse("srht:adaptive,fixed").basis_persistent()
    # FedNL-style locally re-derived bases are fresh by construction
    assert not SketchPolicy.per_round("rank1-eig").basis_persistent()


def test_basis_key_schedules():
    fresh = SketchPolicy.parse("srht")
    key = jax.random.PRNGKey(3)
    assert fresh.basis_key(key, 5) is key  # fresh rides the driver key

    rot = SketchPolicy.parse("srht:rotate=4")
    # within an epoch the basis key ignores the per-round driver key
    k0 = rot.basis_key(jax.random.PRNGKey(0), 0)
    k3 = rot.basis_key(jax.random.PRNGKey(99), 3)
    k4 = rot.basis_key(jax.random.PRNGKey(0), 4)
    np.testing.assert_array_equal(k0, k3)
    assert not np.array_equal(np.asarray(k0), np.asarray(k4))

    fixed = SketchPolicy.parse("srht:fixed")
    np.testing.assert_array_equal(fixed.basis_key(jax.random.PRNGKey(1), 0),
                                  fixed.basis_key(jax.random.PRNGKey(2), 77))
    # the seed option picks an independent basis stream
    other = SketchPolicy.parse("srht:fixed,seed=5")
    assert not np.array_equal(
        np.asarray(fixed.basis_key(key, 0)), np.asarray(other.basis_key(key, 0)))


def test_sample_unbound_k_raises():
    with pytest.raises(ValueError, match="no k bound"):
        SketchPolicy.parse("srht").sample(jax.random.PRNGKey(0), 0, 16)


def test_adaptive_resolution_and_ramp():
    pol = SketchPolicy.parse("srht:adaptive=8..32,c=2.0")
    r = pol.resolved(d_eff=6.1, cap=100)
    assert (r.k, r.k_min, r.k_max) == (13, 8, 32)  # ceil(2 * 6.1) = 13
    assert pol.resolved(d_eff=0.5, cap=100).k == 8  # clipped to k_min
    assert pol.resolved(d_eff=1000.0, cap=100).k == 32  # clipped to k_max
    assert pol.resolved(d_eff=1000.0, cap=20).k == 20  # cap wins
    # ramp doubles toward k_max, saturating there
    r = r.ramped()
    assert r.k == 26
    assert r.ramped().k == 32
    assert r.ramped().ramped().k == 32
    # bounds default to (declared k, 8 * k_min) when the spec omits them
    r = SketchPolicy.parse("srht:adaptive").with_k(4).resolved(d_eff=0.1,
                                                               cap=100)
    assert (r.k_min, r.k_max, r.k) == (4, 32, 4)
    # constant-k policies pass through untouched
    pol = SketchPolicy.parse("srht").with_k(8)
    assert pol.resolved(d_eff=50.0, cap=100) is pol


# ---------------------------------------------------------------------------
# the backward-compatibility contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,kw", [
    ("flens", dict(k=8)), ("flens_plus", dict(k=8)), ("fedns", dict(k=8)),
    ("fedndes", {}),
])
def test_default_policy_matches_pre_redesign_golden(small_problem, name, kw):
    """Fresh basis + constant k reproduces the pre-SketchPolicy
    trajectories bit for bit, in the no-comm, sync-identity, and
    async-lockstep drivers alike."""
    prob, w0, w_star = small_problem
    h = run_rounds(make_optimizer(name, **kw), prob, w0, w_star, rounds=4)
    np.testing.assert_array_equal(h.loss, np.asarray(GOLDEN_LOSSES[name]))
    hs = run_rounds(make_optimizer(name, **kw), prob, w0, w_star, rounds=4,
                    comm=CommConfig())
    np.testing.assert_array_equal(h.loss, hs.loss)
    ha = run_rounds(make_optimizer(name, **kw), prob, w0, w_star, rounds=4,
                    comm=CommConfig(async_mode=True))
    np.testing.assert_array_equal(h.loss, ha.loss)


def test_no_ef_eligible_literals_at_optimizer_call_sites():
    """EF eligibility flows from ``SketchPolicy.basis_persistent`` — no
    optimizer hardcodes ``ef_eligible=True/False`` at an uplink call
    site anymore."""
    from repro.core import first_order, flens, newton_family, sketched

    pat = re.compile(r"ef_eligible\s*=\s*(True|False)")
    for mod in (flens, sketched, newton_family, first_order):
        assert not pat.search(inspect.getsource(mod)), mod.__name__


def test_policy_object_and_spec_string_are_equivalent(small_problem):
    prob, w0, w_star = small_problem
    by_str = run_rounds(make_optimizer("flens", k=8, sketch="srht:rotate=2"),
                        prob, w0, w_star, rounds=3)
    by_pol = run_rounds(
        make_optimizer("flens", k=8,
                       sketch=SketchPolicy.parse("srht:rotate=2")),
        prob, w0, w_star, rounds=3)
    np.testing.assert_array_equal(by_str.loss, by_pol.loss)


# ---------------------------------------------------------------------------
# schedules through the round drivers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["srht:fixed", "srht:rotate=2"])
def test_persistent_schedules_run_and_stay_lockstep(small_problem, spec):
    """Fixed/rotating bases converge and keep the sync/async lock-step
    equivalence (the rotation epoch rides the state's round counter, so
    both drivers derive the same basis per version)."""
    prob, w0, w_star = small_problem
    h = run_rounds(make_optimizer("flens", k=8, sketch=spec), prob, w0,
                   w_star, rounds=4)
    assert np.isfinite(h.loss).all()
    assert h.gap[-1] < h.gap[0]
    ha = run_rounds(make_optimizer("flens", k=8, sketch=spec), prob, w0,
                    w_star, rounds=4, comm=CommConfig(async_mode=True))
    np.testing.assert_array_equal(h.loss, ha.loss)


def test_ef_memory_follows_basis_persistence(small_problem):
    """The EF shape probe allocates memory for sketch-basis payloads
    exactly when the schedule keeps the basis across rounds."""
    prob, w0, w_star = small_problem

    def discover(name, **kw):
        opt = make_optimizer(name, **kw)
        state = opt.init(prob, w0)
        sess = CommSession(CommConfig(codecs="topk0.25", error_feedback=True),
                           m=prob.m)
        return set(sess.init_error_feedback(
            lambda cr: opt.round(prob, state, jax.random.PRNGKey(0), comm=cr)))

    assert discover("flens", k=8) == set()  # fresh: ineligible
    assert discover("flens", k=8, sketch="srht:rotate=4") == {"h_sk", "sg"}
    assert discover("flens", k=8, sketch="srht:fixed") == {"h_sk", "sg"}
    assert discover("fedns", k=8) == {"grad"}  # sa fresh, grad always
    assert discover("fedns", k=8, sketch="srht:fixed") == {"grad", "sa"}
    # rotate=1 redraws every round: fresh in all but name
    assert discover("flens", k=8, sketch="srht:rotate=1") == set()


def test_rotating_ef_same_bytes_as_fresh(small_problem):
    """EF on a rotating basis changes which values ride the wire, never
    how many bytes — the equal-byte comparison the benchmark gate
    (flens_rot_ef) builds on."""
    prob, w0, w_star = small_problem
    codecs = {"h_sk": "topk0.25", "sg": "topk0.5"}
    fresh = run_rounds(make_optimizer("flens", k=8), prob, w0, w_star,
                       rounds=4, comm=CommConfig(codecs=codecs, seed=1))
    rot = run_rounds(make_optimizer("flens", k=8, sketch="srht:rotate=2"),
                     prob, w0, w_star, rounds=4,
                     comm=CommConfig(codecs=codecs, error_feedback=True,
                                     seed=1))
    np.testing.assert_array_equal(fresh.cumulative_bytes,
                                  rot.cumulative_bytes)
    assert np.isfinite(rot.loss).all()
    assert set(rot.ef_residuals) == {"h_sk", "sg"}
    assert fresh.ef_residuals == {}


# ---------------------------------------------------------------------------
# adaptive-k: ramping, re-billing, driver support
# ---------------------------------------------------------------------------

def test_adaptive_k_ramps_on_guard_rejects_and_rebills(small_problem):
    """The guard-driven ramp doubles k after rejected steps, and BOTH
    drivers bill the round-varying payload sizes truthfully (the
    round-trace bytes move with k; the no-comm formula axis derived from
    the identity plan matches the traced wire exactly)."""
    prob, w0, w_star = small_problem
    kw = dict(k=4, sketch="srht:adaptive=4..16,c=0.1")
    opt = make_optimizer("flens", **kw)
    hist = run_rounds(opt, prob, w0, w_star, rounds=8, comm=CommConfig())
    assert opt.policy.k_min == 4 and opt.policy.k_max == 16
    assert opt.k > 4  # the guard rejected at least once on this problem
    per_round = [int(t.bytes_up[0]) for t in hist.traces]
    assert len(set(per_round)) > 1  # round-varying billing
    # every billed size is (k^2 + k + 1) * 8 for a k in the ramp 4,8,16
    assert set(per_round) <= {(k * k + k + 1) * 8 for k in (4, 8, 16)}
    assert per_round == sorted(per_round)  # k never shrinks
    # the no-comm formula axis re-bills identically
    hist2 = run_rounds(make_optimizer("flens", **kw), prob, w0, w_star,
                       rounds=8)
    np.testing.assert_array_equal(hist.cumulative_bytes,
                                  hist2.cumulative_bytes)


def test_ef_reset_indicator_semantics():
    rot = SketchPolicy.parse("srht:rotate=4")
    assert [bool(rot.ef_reset(t)) for t in range(8)] == [
        True, False, False, False, True, False, False, False]
    # schedules that never rotate mid-run need no reset
    assert SketchPolicy.parse("srht").ef_reset(0) is None
    assert SketchPolicy.parse("srht:fixed").ef_reset(0) is None
    assert SketchPolicy.parse("srht:rotate=1").ef_reset(0) is None


def test_uplink_ef_reset_discards_stale_basis_memory():
    """At an epoch boundary the EF residual accumulated in the previous
    basis is zeroed BEFORE compensation: the round behaves exactly like
    one starting from fresh memory."""
    from repro.comm import CommRound

    cfg = CommConfig(codecs="topk0.25", error_feedback=True)
    m, d = 3, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float64)
    stale = 0.7 * jnp.ones((m, d), jnp.float64)
    key = jax.random.PRNGKey(1)

    def run(memory, reset):
        cr = CommRound(cfg, {}, None, key, memory={"g": memory})
        decoded = cr.uplink("g", x, ef_reset=reset)
        return np.asarray(decoded), np.asarray(cr.memory_out["g"])

    dec_reset, mem_reset = run(stale, reset=jnp.asarray(True))
    dec_zero, mem_zero = run(jnp.zeros_like(stale), reset=None)
    np.testing.assert_array_equal(dec_reset, dec_zero)
    np.testing.assert_array_equal(mem_reset, mem_zero)
    # without the reset the stale memory leaks into the decode
    dec_stale, _ = run(stale, reset=jnp.asarray(False))
    assert not np.array_equal(dec_stale, dec_zero)

    # a client absent on the boundary round must STILL drop its old
    # epoch's residual (the reset is schedule knowledge, not
    # computation): its frozen row is the post-reset zero, never the
    # stale pre-reset memory
    mask = jnp.asarray([1.0, 0.0, 1.0])
    cr = CommRound(cfg, {}, mask, key, memory={"g": stale})
    cr.uplink("g", x, ef_reset=jnp.asarray(True))
    out = np.asarray(cr.memory_out["g"])
    np.testing.assert_array_equal(out[1], np.zeros(d))  # frozen AT zero
    assert not np.allclose(out[0], 0.0)  # delivered rows advanced


def test_adaptive_ramp_detects_rejects_at_scale_floor(small_problem):
    """Sitting AT the trust-scale floor means the guard is still
    rejecting (an accept doubles away from it): the ramp must not go
    blind once the scale pins there."""
    prob, w0, _ = small_problem
    opt = make_optimizer("flens", k=4, sketch="srht:adaptive=4..64")
    opt.init(prob, w0)
    opt.policy = opt.policy.with_k(4)
    floor = jnp.asarray(1.0 / 64.0)
    opt.round_signature(1, {"scale": floor})  # drop to floor: reject
    k_after_first = opt.k
    opt.round_signature(2, {"scale": floor})  # pinned at floor: STILL a reject
    assert opt.k > k_after_first
    # a recovery (accept doubled the scale away from the floor) stops it
    k_now = opt.k
    opt.round_signature(3, {"scale": floor * 2})
    assert opt.k == k_now


def test_adaptive_rejected_where_nothing_ramps():
    """Optimizers with no ramp signal refuse adaptive specs instead of
    silently running constant-k."""
    from repro.core.distributed import DistributedFLeNS
    from repro.core.losses import logistic

    with pytest.raises(ValueError, match="adaptive"):
        make_optimizer("fedns", k=8, sketch="srht:adaptive")
    with pytest.raises(ValueError, match="adaptive"):
        make_optimizer("fedndes", sketch="srht:adaptive")
    # FLeNS without the guard has no ramp signal either
    with pytest.raises(ValueError, match="restart"):
        make_optimizer("flens", k=8, sketch="srht:adaptive", restart=False)
    mesh = jax.make_mesh((1,), ("data",))
    dist = DistributedFLeNS(mesh=mesh, objective=logistic, dim=16, k=8,
                            lam=1e-3, client_axes=("data",),
                            sketch="srht:adaptive")
    with pytest.raises(ValueError, match="adaptive"):
        dist.round_fn()


def test_adaptive_k_rejected_by_async_driver(small_problem):
    prob, w0, w_star = small_problem
    with pytest.raises(NotImplementedError, match="adaptive-k"):
        run_rounds(make_optimizer("flens", k=4, sketch="srht:adaptive"),
                   prob, w0, w_star, rounds=2,
                   comm=CommConfig(async_mode=True))


def test_fedndes_adaptive_k_unchanged_by_policy_routing(small_problem):
    """FedNDES's dimension-efficient k now routes through the shared
    ``adaptive_k`` rule and lands on the same value as before."""
    prob, w0, w_star = small_problem
    opt = make_optimizer("fedndes")
    opt.init(prob, w0)
    from repro.core.sketch import effective_dimension

    h = prob.global_hessian(w0)
    h_loss = h - prob.lam * jnp.eye(prob.dim, dtype=h.dtype)
    d_lam = float(effective_dimension(h_loss, prob.lam))
    want = int(min(max(8, int(jnp.ceil(2.0 * d_lam))), prob.X.shape[1]))
    assert opt.k == want


# ---------------------------------------------------------------------------
# formula bytes == measured wire (NullSession payload-plan probe)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,kw", [
    ("flens", dict(k=8)),  # guarded: 2M + 1 downlink
    ("fednew", {}),  # w + d_bar broadcast
    ("distributed_newton", {}),  # w + global-gradient broadcast
    ("fednl", {}),  # native rank-1 wire shape
    ("fedavg", {}),
])
def test_formula_bytes_match_measured_wire(small_problem, name, kw):
    """The no-comm byte axis (identity payload-plan probe) equals the
    traced identity-codec wire — and the corrected per-optimizer
    float-count formulas agree with both."""
    prob, w0, w_star = small_problem
    opt = make_optimizer(name, **kw)
    plain = run_rounds(opt, prob, w0, w_star, rounds=2)
    wired = run_rounds(make_optimizer(name, **kw), prob, w0, w_star,
                       rounds=2, comm=CommConfig())
    np.testing.assert_array_equal(plain.cumulative_bytes,
                                  wired.cumulative_bytes)
    formula = (opt.uplink_floats(prob) + opt.downlink_floats(prob)) \
        * 8 * prob.m
    assert float(plain.cumulative_bytes[1]) == float(formula)


def test_unguarded_flens_downlink_formula(small_problem):
    """restart=False drops the w_next broadcast: downlink is M + 1."""
    prob, _, _ = small_problem
    assert make_optimizer("flens", k=8).downlink_floats(prob) \
        == 2 * prob.dim + 1
    assert make_optimizer("flens", k=8, restart=False).downlink_floats(prob) \
        == prob.dim + 1


def test_schedule_composes_with_lossy_partial_participation(small_problem):
    """Rotating basis + EF survives dropout/partial cohorts (memory
    gating spans epochs) and still converges."""
    prob, w0, w_star = small_problem
    comm = CommConfig(
        codecs={"h_sk": "sympack+qint8", "sg": "qint8"},
        scheduler="uniform:0.7",
        channel=ChannelModel(dropout_prob=0.15),
        error_feedback=True,
        seed=3,
    )
    hist = run_rounds(make_optimizer("flens", k=12, sketch="srht:rotate=3"),
                      prob, w0, w_star, rounds=8, comm=comm)
    assert np.isfinite(hist.loss).all()
    assert hist.gap[-1] < hist.gap[0] * 0.5
