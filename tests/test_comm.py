"""Transport-layer tests: codecs, scheduling, accounting, bit-exactness.

Covers the `repro.comm` contract:
  * codec round-trips — exact for lossless codecs, bounded error for
    qint8/top-k, symmetric output for sympack;
  * byte counts match the encoded wire format, not float counts;
  * scheduler/channel draws are exactly reproducible from a key;
  * FLeNS through identity-codec/full-participation comm is bit-identical
    to the no-comm path (the PR's backward-compatibility guarantee).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (
    ChannelModel,
    CommConfig,
    CommRound,
    CommSession,
    compensate,
    make_codec,
    make_scheduler,
    summarize,
)
from repro.core import make_optimizer, make_problem, newton_solve, run_rounds
from repro.core.losses import logistic
from repro.data import make_classification


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

def _payload(shape, seed=0, dtype=jnp.float64):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


@pytest.mark.parametrize("spec", ["identity", "sympack"])
def test_lossless_codecs_roundtrip_exact(spec):
    codec = make_codec(spec)
    x = _payload((12, 12))
    x = 0.5 * (x + x.T)  # sympack requires symmetric payloads
    out = codec.roundtrip(jax.random.PRNGKey(1), x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_identity_roundtrip_returns_same_object():
    """The bit-exactness guarantee hinges on identity being a no-op."""
    codec = make_codec("identity")
    x = _payload((7, 3))
    assert codec.roundtrip(jax.random.PRNGKey(0), x) is x


@pytest.mark.parametrize("spec,rtol", [("fp16", 1e-3), ("bf16", 1e-2)])
def test_cast_codecs_bounded_error(spec, rtol):
    codec = make_codec(spec)
    x = _payload((64,))
    out = codec.roundtrip(jax.random.PRNGKey(1), x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=rtol)


def test_qint8_bounded_and_unbiased():
    codec = make_codec("qint8")
    x = _payload((256,))
    step = float(jnp.max(jnp.abs(x))) / 127.0
    outs = np.stack([
        np.asarray(codec.roundtrip(jax.random.PRNGKey(s), x))
        for s in range(200)
    ])
    # per-draw error bounded by one quantization step
    assert np.abs(outs - np.asarray(x)).max() <= step + 1e-12
    # stochastic rounding is unbiased: the mean over draws converges on x
    np.testing.assert_allclose(outs.mean(0), np.asarray(x), atol=0.2 * step)


def test_topk_keeps_largest_magnitudes():
    codec = make_codec("topk0.25")
    x = _payload((64,))
    out = np.asarray(codec.roundtrip(jax.random.PRNGKey(1), x))
    kept = np.nonzero(out)[0]
    assert len(kept) == 16
    cutoff = np.sort(np.abs(np.asarray(x)))[-16]
    assert (np.abs(np.asarray(x)[kept]) >= cutoff).all()
    np.testing.assert_array_equal(out[kept], np.asarray(x)[kept])


def test_sympack_output_symmetric_even_with_lossy_inner():
    codec = make_codec("sympack+qint8")
    x = _payload((16, 16))
    x = 0.5 * (x + x.T)
    out = np.asarray(codec.roundtrip(jax.random.PRNGKey(1), x))
    np.testing.assert_array_equal(out, out.T)
    step = np.abs(x).max() / 127.0
    assert np.abs(out - np.asarray(x)).max() <= step + 1e-12


def test_codec_byte_counts_match_wire_format():
    f64 = jnp.float64
    assert make_codec("identity").nbytes((17, 3), f64) == 17 * 3 * 8
    assert make_codec("fp16").nbytes((100,), f64) == 200
    assert make_codec("bf16").nbytes((100,), f64) == 200
    # int8 payload + one fp32 scale
    assert make_codec("qint8").nbytes((100,), f64) == 100 + 4
    # 25% of 64 = 16 kept: int32 index + raw value each
    assert make_codec("topk0.25").nbytes((64,), f64) == 16 * (4 + 8)
    assert make_codec("topk@5").nbytes((64,), f64) == 5 * (4 + 8)
    # upper triangle of 16x16 = 136 entries
    assert make_codec("sympack").nbytes((16, 16), f64) == 136 * 8
    assert make_codec("sympack+qint8").nbytes((16, 16), f64) == 136 + 4
    # k x k sympack halves the dominant FLeNS uplink term
    k = 64
    assert make_codec("sympack").nbytes((k, k), f64) <= (
        make_codec("identity").nbytes((k, k), f64) // 2 + k * 8)


def test_sympack_rejects_non_square():
    with pytest.raises(ValueError):
        make_codec("sympack").nbytes((3, 4), jnp.float64)


def test_codec_specs_parse_and_unknown_rejected():
    assert make_codec("topk0.1+qint8").name.startswith("topk0.1")
    with pytest.raises(ValueError):
        make_codec("zstd")
    with pytest.raises(ValueError):
        make_codec("qint8+fp16")  # qint8 is terminal, cannot wrap


# ---------------------------------------------------------------------------
# scheduler + channel
# ---------------------------------------------------------------------------

def test_scheduler_masks_reproducible_from_key():
    chan = ChannelModel()
    for spec in ("full", "uniform:0.4", "bandwidth:0.4"):
        sched = make_scheduler(spec)
        key = jax.random.PRNGKey(7)
        a = sched.participants(key, 0, 20, chan)
        b = sched.participants(key, 0, 20, chan)
        np.testing.assert_array_equal(a, b)
        assert a.dtype == bool and a.shape == (20,)


def test_uniform_sampler_counts():
    sched = make_scheduler("uniform:0.3")
    mask = sched.participants(jax.random.PRNGKey(0), 0, 10, ChannelModel())
    assert mask.sum() == 3


def test_bandwidth_aware_prefers_fast_links():
    m = 40
    rates = np.ones(m)
    rates[: m // 2] = 1e9  # first half has vastly faster uplinks
    chan = ChannelModel(uplink_bytes_per_s=rates)
    sched = make_scheduler("bandwidth:0.25")
    picks = np.zeros(m)
    for t in range(20):
        picks += sched.participants(jax.random.PRNGKey(t), t, m, chan)
    assert picks[: m // 2].sum() > 0.95 * picks.sum()


def test_session_trajectory_reproducible():
    cfg = dict(codecs="qint8", scheduler="uniform:0.5",
               channel=ChannelModel(dropout_prob=0.2, straggler_prob=0.2),
               seed=3)
    s1, s2 = CommSession(CommConfig(**cfg), m=16), \
        CommSession(CommConfig(**cfg), m=16)
    for t in range(5):
        m1, _ = s1.begin_round(t)
        m2, _ = s2.begin_round(t)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
        s1.plan["x"] = s2.plan["x"] = 100
        s1.plan["down:w"] = s2.plan["down:w"] = 800
        t1, t2 = s1.end_round(), s2.end_round()
        np.testing.assert_array_equal(t1.bytes_up, t2.bytes_up)
        np.testing.assert_array_equal(t1.bytes_down, t2.bytes_down)
        assert t1.sim_time_s == t2.sim_time_s


def test_straggler_slows_round_and_dropout_zeroes_bytes():
    m = 8
    chan = ChannelModel(uplink_bytes_per_s=1e3, downlink_bytes_per_s=1e6,
                        latency_s=0.0, straggler_prob=0.0,
                        straggler_slowdown=25.0)
    cfg = CommConfig(channel=chan)
    sess = CommSession(cfg, m=m)
    sess.begin_round(0)
    sess.plan["x"] = 1000  # 1s per client at 1e3 B/s
    base = sess.end_round().sim_time_s
    slow = CommSession(
        CommConfig(channel=ChannelModel(
            uplink_bytes_per_s=1e3, downlink_bytes_per_s=1e6, latency_s=0.0,
            straggler_prob=1.0, straggler_slowdown=25.0)), m=m)
    slow.begin_round(0)
    slow.plan["x"] = 1000
    assert slow.end_round().sim_time_s == pytest.approx(25.0 * base)
    # dropped clients transmit nothing
    drop = CommSession(
        CommConfig(scheduler="full",
                   channel=ChannelModel(dropout_prob=0.5)), m=64)
    drop.begin_round(0)
    drop.plan["x"] = 10
    tr = drop.end_round()
    assert (tr.bytes_up[~tr.delivered] == 0).all()
    assert (tr.bytes_up[tr.delivered] == 10).all()


def test_channel_per_client_rates_wrong_shape_raises():
    """Heterogeneous rate arrays must be scalars or exactly (m,)."""
    chan = ChannelModel(uplink_bytes_per_s=np.ones(5))
    with pytest.raises(ValueError, match=r"shape \(5,\), want \(8,\)"):
        chan.uplink_rates(8)
    chan = ChannelModel(downlink_bytes_per_s=np.ones((4, 2)))
    with pytest.raises(ValueError):
        chan.downlink_rates(8)
    # scalars and exact (m,) arrays broadcast fine
    assert ChannelModel(uplink_bytes_per_s=7.0).uplink_rates(3).shape == (3,)
    np.testing.assert_array_equal(
        ChannelModel(uplink_bytes_per_s=np.arange(1.0, 4.0)).uplink_rates(3),
        [1.0, 2.0, 3.0])


def test_channel_draw_deterministic_from_seed_and_round():
    """Straggler/dropout coins are a pure function of (seed, round): the
    same key reproduces the draw, different rounds decorrelate it."""
    chan = ChannelModel(straggler_prob=0.5, dropout_prob=0.5)
    root = jax.random.PRNGKey(11)
    draws = {}
    for t in (0, 1, 2):
        key = jax.random.fold_in(root, t)
        a = chan.draw(key, 64)
        b = chan.draw(key, 64)
        np.testing.assert_array_equal(a.straggler, b.straggler)
        np.testing.assert_array_equal(a.dropout, b.dropout)
        draws[t] = a
    assert not np.array_equal(draws[0].straggler, draws[1].straggler)
    assert not np.array_equal(draws[1].dropout, draws[2].dropout)


def test_channel_all_clients_dropped_round():
    """dropout_prob=1.0: the session re-polls one deterministic client so
    aggregation weights stay well-defined, and the round's wall-clock is
    that client's delivery time."""
    m = 6
    chan = ChannelModel(dropout_prob=1.0, latency_s=0.25,
                        uplink_bytes_per_s=1e3)
    sess = CommSession(CommConfig(channel=chan), m=m)
    mask, _ = sess.begin_round(0)
    assert float(np.asarray(mask).sum()) == 1.0  # exactly one re-polled
    assert float(np.asarray(mask)[0]) == 1.0  # lowest-index scheduled
    sess.plan["x"] = 1000
    tr = sess.end_round()
    assert tr.delivered.sum() == 1 and tr.delivered[0]
    assert (tr.bytes_up[1:] == 0).all()
    assert tr.sim_time_s > 0.0
    # round_time's no-delivery fallback: latency only
    draw = chan.draw(jax.random.PRNGKey(0), m)
    none_delivered = np.zeros(m, dtype=bool)
    t = chan.round_time(draw, none_delivered, np.zeros(m), np.zeros(m))
    assert t == pytest.approx(0.25)


def test_channel_client_times_match_round_time():
    """round_time is exactly the max of client_times over deliverers."""
    m = 10
    rates = np.logspace(3, 6, m)
    chan = ChannelModel(uplink_bytes_per_s=rates, latency_s=0.1,
                        straggler_prob=0.5, straggler_slowdown=4.0)
    draw = chan.draw(jax.random.PRNGKey(3), m)
    bytes_up = np.full(m, 5000.0)
    bytes_down = np.full(m, 800.0)
    times = chan.client_times(draw, bytes_up, bytes_down)
    assert times.shape == (m,)
    delivered = np.ones(m, dtype=bool)
    delivered[::3] = False
    assert chan.round_time(draw, delivered, bytes_up,
                           bytes_down) == times[delivered].max()


# ---------------------------------------------------------------------------
# end-to-end through the round driver
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_problem():
    X, y = make_classification(jax.random.PRNGKey(2), 600, 24)
    prob = make_problem(X, y, m=6, lam=1e-3, objective=logistic)
    w0 = jnp.zeros(prob.dim, jnp.float64)
    w_star = newton_solve(prob, w0, iters=30)
    return prob, w0, w_star


@pytest.mark.parametrize("name,kw", [
    ("flens", dict(k=8)), ("flens_plus", dict(k=8)), ("fedavg", {}),
    ("fednewton", {}), ("fednew", {}), ("fednl", {}), ("fedns", dict(k=8)),
])
def test_identity_full_participation_bit_identical(small_problem, name, kw):
    prob, w0, w_star = small_problem
    h0 = run_rounds(make_optimizer(name, **kw), prob, w0, w_star, rounds=4)
    h1 = run_rounds(make_optimizer(name, **kw), prob, w0, w_star, rounds=4,
                    comm=CommConfig())
    np.testing.assert_array_equal(h0.loss, h1.loss)
    np.testing.assert_array_equal(h0.grad_norm, h1.grad_norm)
    # error feedback + lossless codecs allocates no memory and leaves the
    # round's jaxpr untouched: still bit-identical to the no-comm path
    h2 = run_rounds(make_optimizer(name, **kw), prob, w0, w_star, rounds=4,
                    comm=CommConfig(error_feedback=True))
    np.testing.assert_array_equal(h0.loss, h2.loss)
    np.testing.assert_array_equal(h0.grad_norm, h2.grad_norm)


def test_flens_byte_accounting_matches_payload_shapes(small_problem):
    prob, w0, w_star = small_problem
    k = 8
    hist = run_rounds(make_optimizer("flens", k=k), prob, w0, w_star,
                      rounds=3, comm=CommConfig())
    # identity codec: h_sk (k,k) + sg (k,) + guard loss scalar, 8B floats
    per_client = (k * k + k + 1) * 8
    tr = hist.traces[0]
    assert (tr.bytes_up == per_client).all()
    # downlink, as measured on the wire: look-ahead model (M floats) +
    # guard candidate w_next (M floats) + the (2,)-uint32 sketch seed —
    # a guarded round genuinely broadcasts twice, unlike the
    # ``downlink_floats`` formula's M + 1
    per_client_down = 2 * prob.dim * 8 + 8
    assert (tr.bytes_down == per_client_down).all()
    np.testing.assert_allclose(
        hist.cumulative_bytes[-1],
        3 * prob.m * (per_client + per_client_down))
    # an unguarded round drops the w_next broadcast
    bare = run_rounds(make_optimizer("flens", k=k, restart=False), prob, w0,
                      w_star, rounds=1, comm=CommConfig())
    assert (bare.traces[0].bytes_down == prob.dim * 8 + 8).all()


def test_fednl_billed_at_native_wire_format(small_problem):
    """FedNL transmits a rank-1 eigenpair, not the (M, M) difference it
    materializes in simulation — and codecs price that wire shape."""
    prob, w0, w_star = small_problem
    M = prob.dim
    ident = run_rounds(make_optimizer("fednl"), prob, w0, w_star, rounds=2,
                       comm=CommConfig())
    # grad (M,) + eigenpair (M+1,), 8-byte floats — matches uplink_floats
    assert (ident.traces[0].bytes_up == (2 * M + 1) * 8).all()
    quant = run_rounds(make_optimizer("fednl"), prob, w0, w_star, rounds=2,
                       comm=CommConfig(codecs="qint8"))
    # qint8 prices the SAME wire shapes: 1 byte/entry + fp32 scale each
    assert (quant.traces[0].bytes_up == (M + 4) + (M + 1 + 4)).all()


def test_sympack_halves_flens_hessian_uplink(small_problem):
    prob, w0, w_star = small_problem
    k = 16
    h_raw = run_rounds(make_optimizer("flens", k=k), prob, w0, w_star,
                       rounds=2, comm=CommConfig())
    h_packed = run_rounds(make_optimizer("flens", k=k), prob, w0, w_star,
                          rounds=2,
                          comm=CommConfig(codecs={"h_sk": "sympack"}))
    # sympack is lossless -> identical trajectory, ~2x fewer Hessian bytes
    np.testing.assert_array_equal(h_raw.loss, h_packed.loss)
    raw_h = k * k * 8
    packed_h = k * (k + 1) // 2 * 8
    assert (h_raw.traces[0].bytes_up - h_packed.traces[0].bytes_up
            == raw_h - packed_h).all()


def test_lossy_partial_run_still_converges(small_problem):
    prob, w0, w_star = small_problem
    comm = CommConfig(
        codecs={"h_sk": "sympack+qint8", "default": "qint8"},
        scheduler="uniform:0.7",
        channel=ChannelModel(dropout_prob=0.1, straggler_prob=0.2),
        seed=1,
    )
    hist = run_rounds(make_optimizer("flens", k=12), prob, w0, w_star,
                      rounds=8, comm=comm)
    assert np.isfinite(hist.loss).all()
    assert hist.gap[-1] < hist.gap[0] * 0.5
    stats = summarize(hist.traces)
    assert stats["rounds"] == 8
    # uniform:0.7 of 6 clients schedules ceil(4.2) = 5 per round, and
    # dropout can only reduce delivery below that
    assert 0.0 < stats["mean_participation"] <= 5.0 / 6.0 + 1e-9
    assert stats["sim_time_s"] > 0.0
    assert (np.diff(hist.sim_time_s) > 0).all()


def test_flens_state_has_no_hidden_instance_state(small_problem):
    """FLeNS+ eta lives in the state dict; one optimizer object can be
    reused across problems without leaking per-problem values."""
    prob, w0, w_star = small_problem
    opt = make_optimizer("flens_plus", k=8)
    state = opt.init(prob, w0)
    assert "eta" in state
    assert not any(a.startswith("_eta") for a in vars(opt))
    # a second, differently-scaled problem gets its own eta
    X, y = make_classification(jax.random.PRNGKey(9), 500, 24)
    prob2 = make_problem(10.0 * X, y, m=5, lam=1e-3, objective=logistic)
    state2 = opt.init(prob2, jnp.zeros(prob2.dim, jnp.float64))
    assert float(state2["eta"]) != float(state["eta"])


def test_dirichlet_partition_sizes_follow_draw(small_problem):
    """make_problem heterogeneity='dirichlet' produces genuinely unequal,
    Dirichlet-proportioned shard sizes that sum to n."""
    X, y = make_classification(jax.random.PRNGKey(4), 999, 16)
    m = 8
    prob = make_problem(X, y, m=m, lam=1e-3, objective=logistic,
                        key=jax.random.PRNGKey(11),
                        heterogeneity="dirichlet", dirichlet_alpha=0.3)
    sizes = np.asarray(prob.mask.sum(axis=1)).astype(int)
    assert sizes.sum() == 999
    assert (sizes >= 1).all()
    assert sizes.std() > 0  # alpha=0.3 draws are never uniform
    props = np.asarray(jax.random.dirichlet(
        jax.random.PRNGKey(11), jnp.full((m,), 0.3)))
    # largest-remainder rounding keeps every shard within 1 of n*p_j,
    # except rows moved by the every-client-gets-one-row guarantee
    floor_fixups = int((props * 999 < 1).sum())
    assert np.abs(sizes - props * 999).max() <= 1.0 + floor_fixups + 1e-6
    np.testing.assert_allclose(float(prob.client_weights.sum()), 1.0,
                               rtol=1e-12)


# ---------------------------------------------------------------------------
# byte-accounting bugfixes
# ---------------------------------------------------------------------------

def test_repeated_payload_name_bytes_accumulate():
    """An optimizer uplinking the same payload name twice in one round
    must be billed for both occurrences, not just the last one — and
    downlink occurrences accumulate in their own direction."""
    plan = {}
    cr = CommRound(CommConfig(), plan, None, None)
    x = _payload((3, 10))
    cr.uplink("g", x)
    cr.uplink("g", x)
    cr.uplink("h", x)
    cr.downlink("w", x[0])
    cr.downlink("w", x[0])
    assert set(plan) == {"g", "g#1", "h", "down:w", "down:w#1"}
    assert sum(plan.values()) == 3 * 10 * 8 + 2 * 10 * 8

    sess = CommSession(CommConfig(), m=3)
    sess.plan.update(plan)
    assert sess.bytes_up_per_client == 3 * 10 * 8
    assert sess.bytes_down_per_client == 2 * 10 * 8


def test_cumulative_uplink_in_bytes_matches_traced(small_problem):
    """History.cumulative_uplink is total uplink BYTES across all
    clients — the same units as cumulative_bytes — and on the
    identity/full-participation path it equals the traced wire bytes."""
    prob, w0, w_star = small_problem
    hist = run_rounds(make_optimizer("flens", k=8), prob, w0, w_star,
                      rounds=3, comm=CommConfig())
    per_round = hist.uplink_floats * 8 * prob.m
    np.testing.assert_allclose(hist.cumulative_uplink,
                               np.arange(4) * float(per_round))
    traced = sum(float(t.bytes_up.sum()) for t in hist.traces)
    assert float(hist.cumulative_uplink[-1]) == traced


# ---------------------------------------------------------------------------
# error feedback (repro.comm.feedback)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["topk0.25", "qint8", "topk0.25+qint8"])
def test_ef21_residual_contracts_on_fixed_stream(spec):
    """EF21 estimate tracking: on a constant payload stream the residual
    ``x - g_t`` contracts toward zero under any contractive codec."""
    codec = make_codec(spec)
    x = _payload((3, 32))
    mem = jnp.zeros_like(x)
    x_norm = float(jnp.linalg.norm(x))
    norms = []
    for t in range(30):
        keys = jax.random.split(jax.random.PRNGKey(t), 3)
        decoded, mem = compensate(codec, keys, x, mem, variant="ef21")
        norms.append(float(jnp.linalg.norm(x - mem)))
        # the decoded payload IS the estimate the server holds
        np.testing.assert_array_equal(np.asarray(decoded), np.asarray(mem))
    assert norms[0] < x_norm  # one step already removes energy
    assert norms[-1] < 0.02 * x_norm  # ~geometric contraction
    assert norms[-1] < 0.1 * norms[0]


@pytest.mark.parametrize("spec", ["topk0.25", "qint8"])
def test_ef14_residual_bounded_and_time_average_converges(spec):
    """EF14 compensation: the residual stays bounded (it does not blow
    up) and the time-averaged decoded payload converges to x, while a
    single memoryless decode keeps a fixed bias."""
    codec = make_codec(spec)
    x = _payload((3, 32))
    mem = jnp.zeros_like(x)
    acc = jnp.zeros_like(x)
    T = 40
    norms = []
    for t in range(T):
        keys = jax.random.split(jax.random.PRNGKey(t), 3)
        decoded, mem = compensate(codec, keys, x, mem, variant="ef14")
        acc = acc + decoded
        norms.append(float(jnp.linalg.norm(mem)))
    single = jax.vmap(codec.roundtrip)(
        jax.random.split(jax.random.PRNGKey(99), 3), x)
    err_avg = float(jnp.linalg.norm(acc / T - x))
    err_single = float(jnp.linalg.norm(single - x))
    assert max(norms) < 5.0 * float(jnp.linalg.norm(x))  # bounded memory
    assert err_avg < 0.25 * err_single  # EF beats the memoryless bias


def test_ef_unknown_variant_rejected():
    with pytest.raises(ValueError):
        CommConfig(codecs="topk0.1", error_feedback=True, ef_variant="ef99")
    with pytest.raises(ValueError):
        compensate(make_codec("qint8"),
                   jnp.zeros((1, 2), jnp.uint32),
                   jnp.ones((1, 4)), jnp.zeros((1, 4)), variant="ef99")


def test_ef_memory_frozen_for_dropped_clients():
    """Non-delivering clients never ran the round: their memory rows must
    not move, while delivered rows advance."""
    cfg = CommConfig(codecs="topk0.25", error_feedback=True)
    m, d = 4, 16
    x = _payload((m, d))
    stale = 0.5 * jnp.ones((m, d), x.dtype)
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    cr = CommRound(cfg, {}, mask, jax.random.PRNGKey(0),
                   memory={"g": stale})
    cr.uplink("g", x)
    new = np.asarray(cr.memory_out["g"])
    np.testing.assert_array_equal(new[1], 0.5)  # frozen
    np.testing.assert_array_equal(new[3], 0.5)  # frozen
    assert not np.allclose(new[0], 0.5)  # delivered: memory advanced
    assert not np.allclose(new[2], 0.5)


def test_ef_memory_allocation_per_payload(small_problem):
    """Shape discovery allocates memory only for lossy, EF-enabled,
    EF-eligible payloads: flens_plus's fixed-basis ``grad`` qualifies;
    the per-round sketch-basis ``h_sk``/``sg`` and the lossless ``loss``
    never do; identity codecs allocate nothing at all."""
    prob, w0, w_star = small_problem
    key = jax.random.PRNGKey(0)

    def discover(cfg, name, **kw):
        opt = make_optimizer(name, **kw)
        state = opt.init(prob, w0)
        sess = CommSession(cfg, m=prob.m)
        return sess.init_error_feedback(
            lambda cr: opt.round(prob, state, key, comm=cr))

    mem = discover(CommConfig(codecs="topk0.1", error_feedback=True),
                   "flens_plus", k=8)
    assert set(mem) == {"grad"}
    assert mem["grad"].shape == (prob.m, prob.dim)
    assert not np.asarray(mem["grad"]).any()  # zero-initialized

    assert discover(CommConfig(codecs="topk0.1", error_feedback=True),
                    "flens", k=8) == {}  # only sketch-basis payloads
    assert discover(CommConfig(error_feedback=True),
                    "flens_plus", k=8) == {}  # lossless: no memory
    mem = discover(CommConfig(codecs="topk0.1", error_feedback=True),
                   "fedavg")
    assert set(mem) == {"w_local"}
    # fednl's hess_delta has a native rank-1 wire format and does its own
    # Hessian-space error feedback (the B update): never EF'd
    mem = discover(CommConfig(codecs="qint8", error_feedback=True), "fednl")
    assert set(mem) == {"grad"}
    # a bare string means ONE payload name, not a character collection
    assert discover(CommConfig(codecs="topk0.1", error_feedback="w"),
                    "fedavg") == {}
    mem = discover(CommConfig(codecs="topk0.1", error_feedback="w_local"),
                   "fedavg")
    assert set(mem) == {"w_local"}


@pytest.mark.parametrize("variant", ["ef21", "ef14"])
def test_ef_improves_topk_convergence_same_bytes(small_problem, variant):
    """End-to-end through run_rounds: error feedback shrinks the top-k
    convergence gap without changing a single encoded byte."""
    prob, w0, w_star = small_problem

    def fedavg():
        return make_optimizer("fedavg", lr=2.0, local_steps=5)

    off = run_rounds(fedavg(), prob, w0, w_star, rounds=12,
                     comm=CommConfig(codecs="topk0.1", seed=1))
    on = run_rounds(fedavg(), prob, w0, w_star, rounds=12,
                    comm=CommConfig(codecs="topk0.1", error_feedback=True,
                                    ef_variant=variant, seed=1))
    assert on.gap[-1] < off.gap[-1]
    np.testing.assert_array_equal(on.cumulative_bytes, off.cumulative_bytes)
    # the History surfaces the final memory norms for diagnostics
    assert off.ef_residuals == {}
    assert set(on.ef_residuals) == {"w_local"}
    assert np.isfinite(on.ef_residuals["w_local"])
    assert on.ef_residuals["w_local"] > 0


def test_ef_zero_rounds_still_valid(small_problem):
    """The EF shape probe must not depend on per-round keys: rounds=0
    with EF enabled returns the initial-point History like always."""
    prob, w0, w_star = small_problem
    hist = run_rounds(make_optimizer("fedavg"), prob, w0, w_star, rounds=0,
                      comm=CommConfig(codecs="topk0.1", error_feedback=True))
    assert len(hist.loss) == 1 and np.isfinite(hist.loss).all()


def test_ef_composes_with_dropout_and_scheduler(small_problem):
    """EF memory threads through the masked (partial-participation)
    round path and the run stays finite and converging."""
    prob, w0, w_star = small_problem
    comm = CommConfig(
        codecs="topk0.2+qint8",
        scheduler="uniform:0.7",
        channel=ChannelModel(dropout_prob=0.15),
        error_feedback=True,
        seed=3,
    )
    hist = run_rounds(make_optimizer("fedavg", lr=2.0, local_steps=5),
                      prob, w0, w_star, rounds=10, comm=comm)
    assert np.isfinite(hist.loss).all()
    assert hist.gap[-1] < hist.gap[0] * 0.5


@pytest.mark.slow
def test_ef_closes_topk_gap_on_edge_clients_problem():
    """Acceptance: on the edge_clients problem (phishing twin, dirichlet
    shards, heterogeneous edge channel), topk0.05 + EF shrinks the final
    loss gap to the no-compression baseline by >= 2x vs EF off."""
    from repro.data.libsvm_like import load

    spec, X, y = load("phishing")
    X, y = X[:8000], y[:8000]
    prob = make_problem(X, y, m=spec.m_clients, lam=1e-3, objective=logistic,
                        key=jax.random.PRNGKey(0), heterogeneity="dirichlet")
    w0 = jnp.zeros(prob.dim, jnp.float64)
    w_star = newton_solve(prob, w0, iters=40)
    rates = np.logspace(np.log10(3e4), np.log10(3e6), prob.m)
    chan = ChannelModel(
        uplink_bytes_per_s=rates, downlink_bytes_per_s=10.0 * rates,
        latency_s=0.08, straggler_prob=0.20, straggler_slowdown=10.0,
        dropout_prob=0.10)

    def run(comm):
        return run_rounds(make_optimizer("fedavg", lr=2.0, local_steps=5),
                          prob, w0, w_star, rounds=30, comm=comm)

    base = run(CommConfig(channel=chan, seed=1))
    off = run(CommConfig(codecs="topk0.05", channel=chan, seed=1))
    on = run(CommConfig(codecs="topk0.05", error_feedback=True,
                        channel=chan, seed=1))
    d_off = float(off.loss[-1] - base.loss[-1])
    d_on = float(on.loss[-1] - base.loss[-1])
    assert d_off > 0  # the compression floor is real
    assert d_on > 0
    assert d_off / d_on >= 2.0  # EF recovers >= half the gap (meas. ~4x)
    # identical wire cost: EF changes which values ride, not how many bytes
    np.testing.assert_array_equal(on.cumulative_bytes, off.cumulative_bytes)
