"""Fused SRHT Pallas kernel vs reference: parity + dispatch API.

The kernel body runs in interpret mode (CPU CI); ``impl="ref"`` is the
pure-jnp oracle every golden trajectory is pinned to. Parity covers
pow2/non-pow2 dims, fp32/bf16, forward and transpose, batched/vmapped
callers, and the redesigned ``repro.kernels.ops`` selection API
(per-call > config > env > auto).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sketch import SrhtSketch, make_sketch
from repro.kernels import ops as kops
from repro.kernels import ref


def _srht(dim, k=8, dtype=jnp.float32, seed=0):
    s = make_sketch(jax.random.PRNGKey(seed), "srht", k, dim, dtype=dtype)
    assert isinstance(s, SrhtSketch)
    return s


def _tol(n, dtype):
    if dtype == jnp.bfloat16:
        return dict(rtol=5e-2, atol=2e-2 * max(1.0, n ** 0.5))
    return dict(rtol=2e-4, atol=2e-4 * n ** 0.5)


@pytest.mark.parametrize("dim", [16, 24, 37, 64, 100, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_srht_forward_parity(dim, dtype):
    s = _srht(dim, dtype=dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, dim), dtype)
    want = kops.srht_apply(x, s.signs, s.rows, impl="ref")
    got = kops.srht_apply(x, s.signs, s.rows, impl="interpret")
    n = s.signs.shape[-1]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(n, dtype))


@pytest.mark.parametrize("dim", [16, 24, 37, 64, 100, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_srht_transpose_parity(dim, dtype):
    s = _srht(dim, dtype=dtype)
    y = jax.random.normal(jax.random.PRNGKey(2), (5, s.k), dtype)
    want = kops.srht_apply_t(y, s.signs, s.rows, dim, impl="ref")
    got = kops.srht_apply_t(y, s.signs, s.rows, dim, impl="interpret")
    assert got.shape == (5, dim)
    n = s.signs.shape[-1]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(n, dtype))


def test_srht_fused_scatter_zeroes_unsampled_lanes():
    """The transpose's in-kernel masked write: on the pow2 domain the
    padded-domain image of S^T y is exactly zero outside span(H D e_r),
    equivalently S(S^T y) = (n/k) y — check through the fused path."""
    dim, k = 64, 8
    s = _srht(dim, k=k)
    y = jax.random.normal(jax.random.PRNGKey(3), (3, k), jnp.float32)
    z = kops.srht_apply(
        kops.srht_apply_t(y, s.signs, s.rows, dim, impl="interpret"),
        s.signs, s.rows, impl="interpret")
    np.testing.assert_allclose(z, (dim / k) * y, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(37,), (2, 3, 37)])
def test_srht_batched_shapes(shape):
    """1-D and deep-batched callers (flens applies S to vectors and
    stacked matrices alike)."""
    dim = shape[-1]
    s = _srht(dim)
    x = jax.random.normal(jax.random.PRNGKey(4), shape, jnp.float32)
    want = s.apply(x, impl="ref")
    got = s.apply(x, impl="interpret")
    assert got.shape == shape[:-1] + (s.k,)
    np.testing.assert_allclose(got, want, **_tol(s.signs.shape[-1], jnp.float32))


def test_srht_vmap_through_dispatch():
    """jax.vmap(s.apply) is how every optimizer maps clients; both impls
    must batch."""
    s = _srht(24)
    g = jax.random.normal(jax.random.PRNGKey(5), (6, 24), jnp.float32)
    want = jax.vmap(s.apply)(g)
    got = jax.vmap(lambda x: s.apply(x, impl="interpret"))(g)
    np.testing.assert_allclose(got, want, **_tol(32, jnp.float32))


def test_srht_sketch_matches_dense_through_interpret():
    """Fused kernel agrees with the materialized (k, dim) matrix."""
    s = _srht(37)
    mat = np.asarray(s.dense(), np.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 37), jnp.float32)
    got = s.apply(x, impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(x) @ mat.T,
                               rtol=2e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# dispatch API
# ---------------------------------------------------------------------------

def test_registry_lists_impls():
    for op in ("fwht", "srht_apply", "srht_apply_t", "topk_mask",
               "qint8_roundtrip", "flash_attention"):
        assert kops.available_impls(op) == ("interpret", "pallas", "ref")


def test_resolve_precedence_call_config_env(monkeypatch):
    # env alone
    monkeypatch.setenv(kops.ENV_VAR, "interpret")
    assert kops.resolve_impl() == "interpret"
    # config beats env
    with kops.use_impl("ref"):
        assert kops.resolve_impl() == "ref"
        # per-call beats config
        assert kops.resolve_impl("interpret") == "interpret"
    # config cleared again -> env
    assert kops.resolve_impl() == "interpret"
    monkeypatch.delenv(kops.ENV_VAR)
    # auto resolves to ref off-TPU
    assert kops.resolve_impl() in ("ref", "pallas")
    if jax.default_backend() != "tpu":
        assert kops.resolve_impl() == "ref"


def test_env_var_routes_ops(monkeypatch):
    """REPRO_KERNEL_IMPL steers an un-annotated call site (the CI leg)."""
    s = _srht(24)
    x = jax.random.normal(jax.random.PRNGKey(7), (3, 24), jnp.float32)
    monkeypatch.setenv(kops.ENV_VAR, "ref")
    want = s.apply(x)
    monkeypatch.setenv(kops.ENV_VAR, "interpret")
    got = s.apply(x)
    np.testing.assert_allclose(got, want, **_tol(32, jnp.float32))


def test_reference_alias_and_unknown_impl():
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 16), jnp.float32)
    np.testing.assert_array_equal(kops.fwht(x, impl="reference"),
                                  kops.fwht(x, impl="ref"))
    with pytest.raises(ValueError, match="unknown kernel impl"):
        kops.fwht(x, impl="vulkan")


def test_forcing_pallas_off_tpu_raises():
    if jax.default_backend() == "tpu":
        pytest.skip("compiled path is legitimate on TPU")
    s = _srht(16)
    x = jnp.ones((2, 16), jnp.float32)
    with pytest.raises(RuntimeError, match="requires a TPU backend"):
        s.apply(x, impl="pallas")


def test_ref_impl_is_bit_identical_to_sketch_default_on_cpu():
    """On CPU, auto == ref: the dispatch rework must not perturb the
    jaxpr the goldens were recorded through."""
    if jax.default_backend() == "tpu":
        pytest.skip("auto resolves to pallas on TPU")
    s = _srht(37, dtype=jnp.float64)
    x = jax.random.normal(jax.random.PRNGKey(9), (5, 37), jnp.float64)
    np.testing.assert_array_equal(np.asarray(s.apply(x)),
                                  np.asarray(s.apply(x, impl="ref")))
    y = jax.random.normal(jax.random.PRNGKey(10), (5, s.k), jnp.float64)
    np.testing.assert_array_equal(np.asarray(s.apply_t(y)),
                                  np.asarray(s.apply_t(y, impl="ref")))


def test_ref_oracle_matches_pre_refactor_inline_graph():
    """ref.srht_apply/_t reproduce the exact pad->sign->fwht->take /
    scatter->fwht->sign->slice pipeline the pre-kernel Sketch traced."""
    dim, k = 37, 8
    s = _srht(dim, k=k, dtype=jnp.float64)
    n = s.signs.shape[-1]
    x = jax.random.normal(jax.random.PRNGKey(11), (5, dim), jnp.float64)
    xp = jnp.pad(x, ((0, 0), (0, n - dim))) * s.signs
    h = ref.fwht(xp, normalize=True)
    want = jnp.take(h, s.rows, axis=-1) * jnp.sqrt(jnp.asarray(n / k, h.dtype))
    np.testing.assert_array_equal(
        np.asarray(ref.srht_apply(x, s.signs, s.rows)), np.asarray(want))

    y = jax.random.normal(jax.random.PRNGKey(12), (5, k), jnp.float64)
    z = jnp.zeros((5, n), y.dtype).at[..., s.rows].set(
        y * jnp.sqrt(jnp.asarray(n / k, y.dtype)))
    want_t = (ref.fwht(z, normalize=True) * s.signs)[..., :dim]
    np.testing.assert_array_equal(
        np.asarray(ref.srht_apply_t(y, s.signs, s.rows, dim)),
        np.asarray(want_t))


def test_default_impl_none_clears_config(monkeypatch):
    """set_default_impl(None) clears the config layer back to env/auto."""
    monkeypatch.delenv(kops.ENV_VAR, raising=False)
    kops.set_default_impl("interpret")
    try:
        assert kops.resolve_impl() == "interpret"
    finally:
        kops.set_default_impl(None)
    if jax.default_backend() != "tpu":
        assert kops.resolve_impl() == "ref"
