"""MoE dispatch unit tests: routing, capacity drops, dense-oracle match."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import moe as moe_mod


def _cfg(**kw):
    base = get_config("arctic-480b").reduced(capacity_factor=8.0)
    import dataclasses

    return dataclasses.replace(base, moe_dense_residual=False, **kw)


def _dense_oracle(params, x, cfg):
    """No-capacity reference: every token exactly by its top-k experts."""
    b, t, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    out = jnp.zeros_like(xf)
    for e in range(cfg.n_experts):
        h = act(xf @ params["w_gate"][e]) * (xf @ params["w_up"][e])
        ye = h @ params["w_down"][e]
        mask = jnp.sum(jnp.where(ids == e, w, 0.0), axis=-1)
        out = out + ye * mask[:, None].astype(ye.dtype)
    return out.reshape(b, t, d)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    return cfg, params, x


def test_matches_dense_oracle_with_ample_capacity(setup):
    cfg, params, x = setup
    out, aux, drop = moe_mod.moe_apply(params, x, cfg, capacity=32)
    want = _dense_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(drop) == 0.0


def test_capacity_drops_tokens(setup):
    cfg, params, x = setup
    out, aux, drop = moe_mod.moe_apply(params, x, cfg, capacity=1)
    assert 0.0 < float(drop) < 1.0
    # dropped tokens pass through with zero MoE contribution — output norm
    # strictly below the no-drop output norm
    full, _, _ = moe_mod.moe_apply(params, x, cfg, capacity=32)
    assert float(jnp.linalg.norm(out)) < float(jnp.linalg.norm(full)) + 1e-3


def test_aux_loss_near_topk_for_uniform_router(setup):
    """Switch LB loss ~= top_k under uniform routing: sum_e f_e = top_k and
    p_e ~= 1/E, so E * sum_e f_e p_e ~= top_k."""
    cfg, params, x = setup
    _, aux, _ = moe_mod.moe_apply(params, x, cfg, capacity=32)
    assert 0.8 * cfg.top_k < float(aux) < 2.0 * cfg.top_k


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**30), topk=st.sampled_from([1, 2]))
def test_router_weights_sum_to_one_property(seed, topk):
    cfg = _cfg(top_k=topk)
    x = jax.random.normal(jax.random.PRNGKey(seed), (6, cfg.d_model),
                          jnp.float32)
    router = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                               (cfg.d_model, cfg.n_experts), jnp.float32)
    ids, w, aux = moe_mod._route(router, x, cfg)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, axis=-1)), 1.0, rtol=1e-5)
    assert int(ids.max()) < cfg.n_experts
