"""Shared test config.

x64 is enabled for the convex-core tests (Newton convergence to 1e-12
needs it); model code paths specify dtypes explicitly so they are
unaffected. NOTE: no XLA_FLAGS device-count forcing here — smoke tests
and benches must see the single real CPU device; sharding tests spawn
subprocesses that set the flag themselves.
"""
import jax

jax.config.update("jax_enable_x64", True)
