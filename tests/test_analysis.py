"""repro.analysis: lint rules, the trace auditor, baseline semantics.

Three layers under test:

  * **Lint rules** (RA000–RA006) — each rule fires on a minimal
    positive source blob, stays silent on the sanctioned idiom, and a
    ``# noqa`` without a justification is itself a finding. The repo's
    own tree must lint clean (every sanction carries a why).
  * **Trace auditor** — deliberately-broken optimizer instances are
    the positive cases: a carry-dtype drift, a weak-type leak, a bloated
    closure constant, and a host callback each trip exactly their check,
    while the honest toy round passes all five.
  * **Baseline protocol** — fingerprints ignore line drift, the diff
    splits new/accepted/resolved, and the CLI exits 1 on a seeded
    violation until ``--update`` accepts it.
"""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (
    Finding,
    RULES,
    diff_baseline,
    lint_repo,
    lint_source,
    load_baseline,
)
from repro.analysis.__main__ import main as analysis_main
from repro.analysis.audit import (
    _AuditTarget,
    check_const_bloat,
    check_dtypes,
    check_primitives,
    check_retrace,
    check_threat_scope,
    check_wire,
    combos,
)
from repro.analysis.findings import save_baseline
from repro.core.base import FederatedOptimizer

ROOT = pathlib.Path(__file__).resolve().parents[1]

LIB = "src/repro/some_module.py"  # a generic library path for lint blobs


def codes(findings):
    return [f.code for f in findings]


# -- lint rules: positive + sanctioned idiom per rule ------------------------

def test_ra001_raw_prngkey():
    src = "import jax\nk = jax.random.PRNGKey(0)\n"
    assert codes(lint_source(src, LIB)) == ["RA001"]


def test_ra001_suppressed_with_justification():
    src = ("import jax\n"
           "k = jax.random.PRNGKey(0)  # noqa: RA001 — documented salt\n")
    assert lint_source(src, LIB) == []


def test_ra000_suppression_without_why():
    src = "import jax\nk = jax.random.PRNGKey(0)  # noqa: RA001\n"
    out = lint_source(src, LIB)
    assert codes(out) == ["RA000"]  # RA001 suppressed, sanction audited


def test_ra002_key_reuse():
    src = ("import jax\n"
           "def f(key):\n"
           "    a = jax.random.normal(key)\n"
           "    b = jax.random.uniform(key)\n"
           "    return a + b\n")
    assert codes(lint_source(src, LIB)) == ["RA002"]


def test_ra002_split_and_reassignment_are_clean():
    src = ("import jax\n"
           "def f(key):\n"
           "    k1, k2 = jax.random.split(key)\n"
           "    a = jax.random.normal(k1)\n"
           "    key = jax.random.fold_in(key, 1)\n"
           "    b = jax.random.normal(key)\n"
           "    return a + b + jax.random.normal(k2)\n")
    assert lint_source(src, LIB) == []


def test_ra002_exclusive_return_branches_are_clean():
    # regression: `if kind == 'a': return draw(k)` branches are
    # exclusive — the terminated branch's consumption must not leak
    src = ("import jax\n"
           "def f(kind, key):\n"
           "    if kind == 'a':\n"
           "        return jax.random.normal(key)\n"
           "    return jax.random.uniform(key)\n")
    assert lint_source(src, LIB) == []


def test_ra002_loop_reuse_across_iterations():
    src = ("import jax\n"
           "def f(key, n):\n"
           "    out = 0.0\n"
           "    for _ in range(n):\n"
           "        out += jax.random.normal(key)\n"
           "    return out\n")
    assert "RA002" in codes(lint_source(src, LIB))


def test_ra003_warn_outside_funnel():
    src = "import warnings\nwarnings.warn('x')\n"
    assert codes(lint_source(src, LIB)) == ["RA003"]
    assert lint_source(src, "src/repro/obs/log.py") == []


def test_ra004_wall_clock_and_global_rng():
    src = "import time\nt = time.time()\n"
    assert codes(lint_source(src, LIB)) == ["RA004"]
    src = "import numpy as np\nx = np.random.rand(3)\n"
    assert codes(lint_source(src, LIB)) == ["RA004"]
    # seeded numpy generators are a dataset-synthesis tool
    assert lint_source(src, "src/repro/data/synth.py") == []


def test_ra005_float64_leak():
    src = "import jax.numpy as jnp\nx = jnp.zeros(3, jnp.float64)\n"
    assert codes(lint_source(src, LIB)) == ["RA005"]
    # the documented allowlist path and the same-line x64 gate are clean
    assert lint_source(src, "src/repro/optim/flens_head.py") == []
    gated = ("import jax, jax.numpy as jnp\n"
             "dt = jnp.float64 if jax.config.jax_enable_x64 "
             "else jnp.float32\n")
    assert lint_source(gated, LIB) == []


def test_ra006_mutable_default_and_bare_assert():
    src = "def f(x=[]):\n    assert x\n    return x\n"
    assert sorted(codes(lint_source(src, LIB))) == ["RA006", "RA006"]


def test_rules_table_covers_emitted_codes():
    assert set(RULES) == {f"RA00{i}" for i in range(7)}


def test_repo_tree_lints_clean():
    """The committed baseline is empty, so the tree itself must be:
    every historical violation is fixed or carries a justified noqa."""
    assert lint_repo(ROOT) == []


# -- baseline protocol -------------------------------------------------------

def test_fingerprint_ignores_line_drift():
    a = Finding("RA001", "p.py", 10, "msg", "k = PRNGKey(0)")
    b = Finding("RA001", "p.py", 99, "different msg", "  k = PRNGKey(0) ")
    assert a.fingerprint == b.fingerprint
    assert a.fingerprint != Finding("RA002", "p.py", 10, "msg",
                                    "k = PRNGKey(0)").fingerprint


def test_baseline_diff_semantics(tmp_path):
    old = Finding("RA001", "a.py", 1, "m", "ctx-old")
    new = Finding("RA005", "b.py", 2, "m", "ctx-new")
    path = tmp_path / "baseline.json"
    assert load_baseline(path) == set()  # missing file: everything new

    save_baseline(path, [old])
    d = diff_baseline([old, new], load_baseline(path))
    assert codes(d.new) == ["RA005"] and codes(d.accepted) == ["RA001"]
    assert d.resolved == set() and d.failed

    d = diff_baseline([], load_baseline(path))
    assert d.new == [] and d.accepted == []
    assert d.resolved == {old.fingerprint} and not d.failed


def test_baseline_schema_mismatch_rejected(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text('{"schema": "something/v9", "findings": []}')
    with pytest.raises(ValueError, match="schema"):
        load_baseline(p)


def test_cli_fails_on_seeded_violation_until_updated(tmp_path, capsys):
    """The CI contract end-to-end: a raw PRNGKey in the tree exits 1
    against an empty baseline, ``--update`` accepts it, reruns pass."""
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "bad.py").write_text(
        "import jax\nkey = jax.random.PRNGKey(0)\n")
    baseline = tmp_path / "baseline.json"
    argv = ["lint", "--root", str(tmp_path), "--baseline", str(baseline)]

    assert analysis_main(argv) == 1
    assert "NEW" in capsys.readouterr().out

    assert analysis_main(argv + ["--update"]) == 0
    assert analysis_main(argv) == 0
    assert "ACCEPTED" in capsys.readouterr().out


# -- trace auditor: broken rounds must be caught -----------------------------

class _ToyOpt(FederatedOptimizer):
    """Minimal honest round: broadcast, per-client copy, weighted mean.
    The broken variants below each violate exactly one invariant."""

    name = "toy"

    def round(self, problem, state, key, comm=None):
        w = comm.downlink("w", state["w"])
        w_locals = comm.uplink(
            "w_local", jnp.broadcast_to(w, (problem.m, problem.dim)))
        p = comm.weights(problem.client_weights)
        return {"w": jnp.einsum("j,jm->m", p, w_locals)}

    def uplink_floats(self, problem):
        return problem.dim


class _DtypeDrift(_ToyOpt):
    name = "toy-dtype-drift"

    def round(self, problem, state, key, comm=None):
        out = super().round(problem, state, key, comm=comm)
        return {"w": out["w"].astype(jnp.float32)}  # x64 carry narrows


class _WeakLeak(_ToyOpt):
    name = "toy-weak-leak"

    def round(self, problem, state, key, comm=None):
        super().round(problem, state, key, comm=comm)
        # same shape and dtype, but a python-scalar fill is weak-typed
        return {"w": jnp.full((problem.dim,), 2.0)}


class _ConstBloat(_ToyOpt):
    name = "toy-const-bloat"

    def __init__(self):
        self.big = jnp.arange(128 * 128, dtype=jnp.float32).reshape(
            128, 128)

    def round(self, problem, state, key, comm=None):
        out = super().round(problem, state, key, comm=comm)
        return {"w": out["w"] + self.big[0, 0]}  # 64 KiB baked in


class _HostCallback(_ToyOpt):
    name = "toy-host-callback"

    def round(self, problem, state, key, comm=None):
        out = super().round(problem, state, key, comm=comm)
        jax.debug.print("w[0] = {}", out["w"][0])
        return out


def _target(opt):
    return _AuditTarget(opt, "sync", "identity")


def test_audit_clean_on_honest_toy_round():
    t = _target(_ToyOpt())
    for check in (check_retrace, check_dtypes, check_const_bloat,
                  check_primitives, check_wire):
        assert check(t) == [], check.__name__


def test_audit_catches_carry_dtype_drift():
    out = check_retrace(_target(_DtypeDrift()))
    assert codes(out) == ["AUDIT-RETRACE"]
    assert "drift" in out[0].message


def test_audit_catches_weak_type_leak():
    out = check_retrace(_target(_WeakLeak()))
    assert "AUDIT-WEAKTYPE" in codes(out)


def test_audit_catches_const_bloat():
    out = check_const_bloat(_target(_ConstBloat()))
    assert codes(out) == ["AUDIT-CONST"]
    assert "(128, 128)" in out[0].message


def test_audit_catches_forbidden_primitive():
    out = check_primitives(_target(_HostCallback()))
    assert codes(out) == ["AUDIT-PRIMITIVE"]


def test_dtype_census_flags_f64_only_when_x64_off():
    """conftest enables x64 (so the census is vacuous in-process); feed
    it a pre-traced f64 jaxpr with the flag toggled off to prove it
    fires, and back on to prove it stands down."""
    closed = jax.make_jaxpr(lambda x: x * x)(jnp.zeros((4,), jnp.float64))

    class _Stub:
        id = "stub/sync/identity"

        def closed_jaxpr(self, args=None):
            return closed

    jax.config.update("jax_enable_x64", False)
    try:
        assert codes(check_dtypes(_Stub())) == ["AUDIT-DTYPE"]
    finally:
        jax.config.update("jax_enable_x64", True)
    assert check_dtypes(_Stub()) == []


def test_threat_scope_check_clean_and_vacuity_guard():
    assert check_threat_scope() == []
    # scoping to a payload fedavg never uplinks is flagged, not ignored
    out = check_threat_scope(payload="h_sk")
    assert codes(out) == ["AUDIT-THREAT"]
    assert "vacuous" in out[0].message


def test_combos_cover_all_optimizers_and_skip_fednew_population():
    cs = list(combos())
    opts = {o for o, _, _ in cs}
    assert len(opts) == 11
    assert ("fednew", "population", "identity") not in cs
    assert ("fednew", "sync", "identity") in cs


# -- the CLI gate itself -----------------------------------------------------

def _run_cli(*argv, timeout):
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=timeout)


def test_analysis_all_restricted_is_clean():
    """Tier-1 smoke of the shipped gate: one optimizer across every
    driver and codec leg, lint included, against the committed (empty)
    baseline."""
    r = _run_cli("all", "--optimizers", "flens", "--no-dynamic",
                 timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 new" in r.stdout


@pytest.mark.slow
def test_analysis_all_full_is_clean():
    """The exact CI static-analysis invocation: all 11 optimizers x 3
    codecs x 3 drivers, threat scope, and the dynamic retrace
    cross-check — in a fresh process, so the x64-off dtype census is
    live (conftest keeps it vacuous in-process)."""
    r = _run_cli("all", timeout=1800)
    assert r.returncode == 0, r.stdout + r.stderr
