"""Sketch-operator properties (incl. hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.sketch import effective_dimension, make_sketch, sketch_psd


@pytest.mark.parametrize("kind", ["srht", "gaussian", "sjlt"])
@pytest.mark.parametrize("dim", [16, 37, 64])
def test_apply_matches_dense(kind, dim):
    """apply / apply_t agree with the materialized (k, dim) matrix."""
    k = 8
    s = make_sketch(jax.random.PRNGKey(0), kind, k, dim, dtype=jnp.float64)
    mat = s.dense()
    x = jax.random.normal(jax.random.PRNGKey(1), (5, dim), jnp.float64)
    np.testing.assert_allclose(s.apply(x), x @ mat.T, rtol=1e-10, atol=1e-12)
    y = jax.random.normal(jax.random.PRNGKey(2), (5, k), jnp.float64)
    np.testing.assert_allclose(s.apply_t(y), y @ mat, rtol=1e-10, atol=1e-12)


def test_srht_rows_orthogonal_when_pow2():
    """For dim a power of two, S S^T = (dim/k) I exactly."""
    dim, k = 64, 16
    s = make_sketch(jax.random.PRNGKey(0), "srht", k, dim, dtype=jnp.float64)
    mat = s.dense()
    np.testing.assert_allclose(
        mat @ mat.T, (dim / k) * jnp.eye(k), rtol=1e-10, atol=1e-10
    )


@pytest.mark.parametrize("kind", ["srht", "gaussian", "sjlt"])
def test_unbiased_identity(kind):
    """E[S^T S / scale] ~ I over sketch draws."""
    dim, k, reps = 32, 16, 400
    keys = jax.random.split(jax.random.PRNGKey(0), reps)

    def one(key):
        s = make_sketch(key, kind, k, dim, dtype=jnp.float64)
        mat = s.dense()
        return mat.T @ mat

    acc = np.mean([np.asarray(one(k)) for k in keys[:reps]], axis=0)
    # normalize by the mean diagonal so one tolerance covers all kinds
    acc = acc / np.mean(np.diag(acc))
    np.testing.assert_allclose(acc, np.eye(dim), atol=0.25)


@settings(max_examples=20, deadline=None)
@given(
    k=st.sampled_from([8, 16, 24]),
    dim=st.sampled_from([24, 32, 50]),
    seed=st.integers(0, 2**30),
)
def test_sketch_psd_is_psd_and_correct(k, dim, seed):
    """S H S^T is PSD for PSD H and equals the dense computation."""
    if k > dim:
        k = dim
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (dim + 4, dim), jnp.float64)
    h = a.T @ a / dim
    s = make_sketch(jax.random.fold_in(key, 1), "srht", k, dim, dtype=jnp.float64)
    shs = sketch_psd(s, h)
    mat = s.dense()
    np.testing.assert_allclose(shs, mat @ h @ mat.T, rtol=1e-8, atol=1e-9)
    evals = np.linalg.eigvalsh(np.asarray(shs))
    assert evals.min() >= -1e-9


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_subspace_embedding_quality(seed):
    """Sketched PSD spectrum is sandwiched for k comfortably > d_eff."""
    dim, k = 64, 48
    key = jax.random.PRNGKey(seed)
    # low effective dimension: fast-decaying spectrum
    evals = jnp.concatenate([jnp.ones(4), 1e-3 * jnp.ones(dim - 4)])
    q, _ = jnp.linalg.qr(jax.random.normal(key, (dim, dim), jnp.float64))
    h = (q * evals) @ q.T
    s = make_sketch(jax.random.fold_in(key, 7), "srht", k, dim, dtype=jnp.float64)
    shs = sketch_psd(s, h)
    # top eigenvalue of the sketch must be within a constant of the true top
    top_sk = float(jnp.linalg.eigvalsh(shs)[-1])
    assert 0.3 <= top_sk / 1.0 <= 3.5


def test_effective_dimension():
    evals = jnp.array([10.0, 1.0, 0.1, 0.001])
    h = jnp.diag(evals)
    lam = 0.1
    expect = float(jnp.sum(evals / (evals + lam)))
    assert abs(float(effective_dimension(h, lam)) - expect) < 1e-9


@pytest.mark.parametrize("kind", ["srht", "gaussian", "sjlt"])
@pytest.mark.parametrize("dtype", ["float16", "bfloat16", "float32",
                                   "float64"])
def test_dense_dtype_matches_operator(kind, dtype):
    """Regression: ``dense()`` used to materialize ``jnp.eye(dim)`` in
    the DEFAULT dtype regardless of the operator's own dtype, so an
    fp16/bf16 sketch densified (and silently promoted every downstream
    comparison) in fp64 under x64. The identity must be built in the
    operator's dtype."""
    dt = jnp.dtype(dtype)
    s = make_sketch(jax.random.PRNGKey(0), kind, 8, 24, dtype=dt)
    mat = s.dense()
    assert mat.dtype == dt
    assert mat.shape == (8, 24)
    # and it still IS the operator: apply agrees with the materialization
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 24), dt)
    tol = 1e-10 if dtype == "float64" else (1e-5 if dtype == "float32"
                                            else 5e-2)
    np.testing.assert_allclose(np.asarray(s.apply(x), np.float64),
                               np.asarray(x, np.float64)
                               @ np.asarray(mat, np.float64).T,
                               rtol=tol, atol=tol * 24)


def test_per_kind_operator_protocol():
    """The union-of-nullable-fields dataclass is gone: each kind is its
    own operator class behind one apply/apply_t/dense protocol, and the
    kind tag / parameter fields survive for callers that introspect."""
    from repro.core.sketch import (
        GaussianSketch,
        SjltSketch,
        Sketch,
        SrhtSketch,
    )

    expect = {"srht": SrhtSketch, "gaussian": GaussianSketch,
              "sjlt": SjltSketch}
    for kind, cls in expect.items():
        s = make_sketch(jax.random.PRNGKey(0), kind, 8, 24)
        assert type(s) is cls and isinstance(s, Sketch)
        assert s.kind == kind and s.k == 8 and s.dim == 24
    srht = make_sketch(jax.random.PRNGKey(0), "srht", 8, 24)
    assert srht.signs.shape == (32,) and srht.rows.shape == (8,)
    # operators stay jit/pytree-compatible (they ride inside rounds)
    leaves, treedef = jax.tree_util.tree_flatten(srht)
    assert len(leaves) == 2
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 24))
    np.testing.assert_array_equal(
        np.asarray(jax.jit(lambda s_, x_: s_.apply(x_))(rebuilt, x)),
        np.asarray(srht.apply(x)))


# ---------------------------------------------------------------------------
# operator invariants (property tests across dims / dtypes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gaussian", "sjlt"])
@pytest.mark.parametrize("dim", [24, 37])  # non-powers of two
@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_gaussian_sjlt_unbiased_identity_property(kind, dim, dtype):
    """E[S^T S] = I for gaussian/sjlt (columns normalized to unit mean
    energy), for any dim — power of two or not — and both dtypes."""
    k, reps, seed = 16, 250, 0
    dt = jnp.dtype(dtype)
    keys = jax.random.split(jax.random.PRNGKey(seed), reps)

    def one(key):
        mat = make_sketch(key, kind, k, dim, dtype=dt).dense()
        return mat.T @ mat

    acc = np.mean([np.asarray(one(kk), np.float64) for kk in keys], axis=0)
    # diagonal is exactly unbiased at 1; off-diagonal concentrates at 0
    np.testing.assert_allclose(np.diag(acc), np.ones(dim), atol=0.35)
    off = acc - np.diag(np.diag(acc))
    assert np.abs(off).max() < 0.35


@pytest.mark.parametrize("dim", [16, 64, 128])
@pytest.mark.parametrize("k", [4, 16])
@pytest.mark.parametrize("dtype", ["float32", "float64"])
def test_srht_rows_exactly_orthogonal_property(dim, k, dtype):
    """S S^T = (dim/k) I_k EXACTLY (to fp roundoff) on the SRHT's native
    power-of-two domain, both dtypes: the rows are sampled without
    replacement from an orthogonal matrix."""
    dt = jnp.dtype(dtype)
    s = make_sketch(jax.random.PRNGKey(dim + k), "srht", k, dim, dtype=dt)
    mat = s.dense()
    tol = 1e-10 if dtype == "float64" else 1e-4
    np.testing.assert_allclose(
        np.asarray(mat @ mat.T, np.float64), (dim / k) * np.eye(k),
        rtol=tol, atol=tol * dim)


@pytest.mark.parametrize("dim", [24, 37, 100])  # strictly non-pow2
@pytest.mark.parametrize("seed", [0, 7])
def test_srht_nonpow2_restriction_invariants(dim, seed):
    """Non-power-of-two dims embed into n = next_pow2(dim): the
    restricted S satisfies the exact complement identity
    S S^T = (n/k) I - S_c S_c^T (S_c = the truncated columns), hence
    0 <= S S^T <= (n/k) I in the PSD order."""
    k = 8
    n = 1
    while n < dim:
        n *= 2
    assert n != dim
    s = make_sketch(jax.random.PRNGKey(seed), "srht", k, dim, dtype=jnp.float64)
    mat = s.dense()  # (k, dim) — the first dim columns of the full k x n S
    # rebuild the FULL padded-domain operator from the same draw: apply
    # on padded eye == taking all n columns
    eye_n = np.eye(n)
    signs = np.asarray(s.signs)
    from repro.kernels import ref

    h = np.asarray(ref.fwht(jnp.asarray(eye_n * signs[None, :]),
                            normalize=True))
    full = h[:, np.asarray(s.rows)].T * np.sqrt(n / k)
    np.testing.assert_allclose(full[:, :dim], np.asarray(mat),
                               rtol=1e-10, atol=1e-12)
    comp = full[:, dim:]
    np.testing.assert_allclose(
        np.asarray(mat) @ np.asarray(mat).T + comp @ comp.T,
        (n / k) * np.eye(k), rtol=1e-10, atol=1e-10)
    evals = np.linalg.eigvalsh(np.asarray(mat) @ np.asarray(mat).T)
    assert evals.min() >= -1e-10
    assert evals.max() <= n / k + 1e-10


@pytest.mark.parametrize("dim", [24, 37, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_srht_apply_pallas_interpret_parity(dim, dtype):
    """The SRHT hot loop through the Pallas kernel body (interpret mode,
    so it runs on CPU CI) matches the reference-path ``Sketch.apply``
    bit-for-float: the policy -> sketch -> kernel path is exercised
    without a TPU."""
    from repro.kernels import ops as kops

    k = 8
    s = make_sketch(jax.random.PRNGKey(1), "srht", k, dim, dtype=dtype)
    x = jax.random.normal(jax.random.PRNGKey(2), (5, dim), dtype)
    want = s.apply(x)  # CPU dispatch: reference fwht

    n = s.signs.shape[-1]
    pad = n - dim
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    xp = xp * s.signs
    h = kops.fwht(xp, normalize=True, impl="interpret")  # Pallas body
    got = jnp.take(h, s.rows, axis=-1) * jnp.sqrt(jnp.asarray(n / k, h.dtype))
    # the kernel accumulates in f32; compare at f32 accuracy
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-4, atol=2e-4 * n**0.5)
