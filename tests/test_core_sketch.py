"""Sketch-operator properties (incl. hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.sketch import effective_dimension, make_sketch, sketch_psd


@pytest.mark.parametrize("kind", ["srht", "gaussian", "sjlt"])
@pytest.mark.parametrize("dim", [16, 37, 64])
def test_apply_matches_dense(kind, dim):
    """apply / apply_t agree with the materialized (k, dim) matrix."""
    k = 8
    s = make_sketch(jax.random.PRNGKey(0), kind, k, dim, dtype=jnp.float64)
    mat = s.dense()
    x = jax.random.normal(jax.random.PRNGKey(1), (5, dim), jnp.float64)
    np.testing.assert_allclose(s.apply(x), x @ mat.T, rtol=1e-10, atol=1e-12)
    y = jax.random.normal(jax.random.PRNGKey(2), (5, k), jnp.float64)
    np.testing.assert_allclose(s.apply_t(y), y @ mat, rtol=1e-10, atol=1e-12)


def test_srht_rows_orthogonal_when_pow2():
    """For dim a power of two, S S^T = (dim/k) I exactly."""
    dim, k = 64, 16
    s = make_sketch(jax.random.PRNGKey(0), "srht", k, dim, dtype=jnp.float64)
    mat = s.dense()
    np.testing.assert_allclose(
        mat @ mat.T, (dim / k) * jnp.eye(k), rtol=1e-10, atol=1e-10
    )


@pytest.mark.parametrize("kind", ["srht", "gaussian", "sjlt"])
def test_unbiased_identity(kind):
    """E[S^T S / scale] ~ I over sketch draws."""
    dim, k, reps = 32, 16, 400
    keys = jax.random.split(jax.random.PRNGKey(0), reps)

    def one(key):
        s = make_sketch(key, kind, k, dim, dtype=jnp.float64)
        mat = s.dense()
        return mat.T @ mat

    acc = np.mean([np.asarray(one(k)) for k in keys[:reps]], axis=0)
    # normalize by the mean diagonal so one tolerance covers all kinds
    acc = acc / np.mean(np.diag(acc))
    np.testing.assert_allclose(acc, np.eye(dim), atol=0.25)


@settings(max_examples=20, deadline=None)
@given(
    k=st.sampled_from([8, 16, 24]),
    dim=st.sampled_from([24, 32, 50]),
    seed=st.integers(0, 2**30),
)
def test_sketch_psd_is_psd_and_correct(k, dim, seed):
    """S H S^T is PSD for PSD H and equals the dense computation."""
    if k > dim:
        k = dim
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (dim + 4, dim), jnp.float64)
    h = a.T @ a / dim
    s = make_sketch(jax.random.fold_in(key, 1), "srht", k, dim, dtype=jnp.float64)
    shs = sketch_psd(s, h)
    mat = s.dense()
    np.testing.assert_allclose(shs, mat @ h @ mat.T, rtol=1e-8, atol=1e-9)
    evals = np.linalg.eigvalsh(np.asarray(shs))
    assert evals.min() >= -1e-9


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_subspace_embedding_quality(seed):
    """Sketched PSD spectrum is sandwiched for k comfortably > d_eff."""
    dim, k = 64, 48
    key = jax.random.PRNGKey(seed)
    # low effective dimension: fast-decaying spectrum
    evals = jnp.concatenate([jnp.ones(4), 1e-3 * jnp.ones(dim - 4)])
    q, _ = jnp.linalg.qr(jax.random.normal(key, (dim, dim), jnp.float64))
    h = (q * evals) @ q.T
    s = make_sketch(jax.random.fold_in(key, 7), "srht", k, dim, dtype=jnp.float64)
    shs = sketch_psd(s, h)
    # top eigenvalue of the sketch must be within a constant of the true top
    top_sk = float(jnp.linalg.eigvalsh(shs)[-1])
    assert 0.3 <= top_sk / 1.0 <= 3.5


def test_effective_dimension():
    evals = jnp.array([10.0, 1.0, 0.1, 0.001])
    h = jnp.diag(evals)
    lam = 0.1
    expect = float(jnp.sum(evals / (evals + lam)))
    assert abs(float(effective_dimension(h, lam)) - expect) < 1e-9
