"""SSD (mamba2) and RG-LRU numerics vs naive sequential recurrences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import rglru as rg
from repro.models import ssd as ssd_mod


# ---------------------------------------------------------------------------
# SSD: chunked scan == naive per-step recurrence
# ---------------------------------------------------------------------------

def naive_ssd(x, dt, a_neg, b_, c_, d_skip, init_state=None):
    """h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t^T ; y_t = C_t h_t + D x_t."""
    bsz, t, h, p = x.shape
    n = b_.shape[-1]
    if init_state is None:
        state = jnp.zeros((bsz, h, n, p))
    else:
        state = init_state
    ys = []
    for i in range(t):
        dec = jnp.exp(dt[:, i] * a_neg[None, :])  # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt[:, i], b_[:, i], x[:, i])
        state = state * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", c_[:, i], state)
        y = y + d_skip[None, :, None] * x[:, i]
        ys.append(y)
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("t,chunk", [(8, 4), (16, 4), (12, 12), (32, 8)])
def test_ssd_chunked_matches_naive(t, chunk):
    bsz, h, p, n = 2, 3, 4, 5
    key = jax.random.PRNGKey(t)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bsz, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, t, h)))
    a_neg = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b_ = jax.random.normal(ks[3], (bsz, t, n))
    c_ = jax.random.normal(ks[4], (bsz, t, n))
    d_skip = jnp.ones((h,))
    y, s = ssd_mod.ssd_scan(x, dt, a_neg, b_, c_, d_skip, chunk=chunk)
    y2, s2 = naive_ssd(x, dt, a_neg, b_, c_, d_skip)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)


def test_ssd_init_state_continuation():
    """Scanning [first half] then [second half with carried state] must
    equal one full scan — the property decode streaming relies on."""
    bsz, t, h, p, n = 1, 16, 2, 4, 3
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bsz, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, t, h)))
    a_neg = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b_ = jax.random.normal(ks[3], (bsz, t, n))
    c_ = jax.random.normal(ks[4], (bsz, t, n))
    d_skip = jnp.zeros((h,))
    y_full, s_full = ssd_mod.ssd_scan(x, dt, a_neg, b_, c_, d_skip, chunk=4)
    y1, s1 = ssd_mod.ssd_scan(x[:, :8], dt[:, :8], a_neg, b_[:, :8],
                              c_[:, :8], d_skip, chunk=4)
    y2, s2 = ssd_mod.ssd_scan(x[:, 8:], dt[:, 8:], a_neg, b_[:, 8:],
                              c_[:, 8:], d_skip, chunk=4, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=2e-4,
                               atol=2e-4)


def test_ssd_decode_step_matches_scan_tail():
    """One ssd_decode_step from the scan's state == the scan's last output."""
    cfg = get_config("mamba2-780m").reduced()
    model_params = ssd_mod.ssd_init(jax.random.PRNGKey(0), cfg)
    t = 12
    u = jax.random.normal(jax.random.PRNGKey(1), (2, t, cfg.d_model),
                          jnp.float32) * 0.3
    out_full, s_full, conv_full = ssd_mod.ssd_block_apply(
        model_params, u, cfg, return_state=True)
    out_pre, s_pre, conv_pre = ssd_mod.ssd_block_apply(
        model_params, u[:, : t - 1], cfg, return_state=True)
    out_step, s_step, conv_step = ssd_mod.ssd_decode_step(
        model_params, u[:, t - 1 :], cfg, ssm_state=s_pre, conv_state=conv_pre)
    np.testing.assert_allclose(np.asarray(out_step[:, 0]),
                               np.asarray(out_full[:, -1]), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_step), np.asarray(s_full),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# RG-LRU: associative scan == sequential gate recurrence
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(t=st.integers(2, 24), seed=st.integers(0, 2**30))
def test_rglru_scan_matches_sequential(t, seed):
    d = 8
    cfg = get_config("recurrentgemma-2b").reduced()
    params = rg.rglru_init(jax.random.PRNGKey(seed), cfg)
    # operate directly on the recurrence inputs
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (2, t, cfg.d_model), jnp.float32) * 0.5
    y, h_last = rg.rglru_scan(params, x)
    # sequential oracle
    a, b = rg._gates(params, x)
    h = jnp.zeros((2, cfg.d_model))
    ys = []
    for i in range(t):
        h = a[:, i] * h + b[:, i]
        ys.append(h)
    y2 = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(y2[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_rglru_decay_in_unit_interval():
    cfg = get_config("recurrentgemma-2b").reduced()
    params = rg.rglru_init(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 10, cfg.d_model)) * 2.0
    a, b = rg._gates(params, x)
    assert float(a.min()) >= 0.0
    assert float(a.max()) <= 1.0


def test_rglru_step_continuation():
    cfg = get_config("recurrentgemma-2b").reduced()
    params = rg.rglru_init(jax.random.PRNGKey(5), cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 9, cfg.d_model)) * 0.5
    y_full, h_full = rg.rglru_scan(params, x)
    y_pre, h_pre = rg.rglru_scan(params, x[:, :8])
    y_step, h_step = rg.rglru_step(params, x[:, 8:], h_pre)
    np.testing.assert_allclose(np.asarray(h_step), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_step[:, 0], np.float32),
                               np.asarray(y_full[:, -1], np.float32),
                               rtol=2e-4, atol=2e-4)
