"""Behavioural tests for FLeNS and every Table-I baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ALGORITHMS,
    logistic,
    make_optimizer,
    make_problem,
    newton_solve,
    run_rounds,
)
from repro.data import make_classification


@pytest.fixture(scope="module")
def problem():
    X, y = make_classification(jax.random.PRNGKey(0), 1500, 48)
    prob = make_problem(X, y, m=6, lam=1e-3, objective=logistic)
    w0 = jnp.zeros(prob.dim, jnp.float64)
    w_star = newton_solve(prob, w0, iters=30)
    return prob, w0, w_star


def _kwargs(name, dim):
    return {
        "fedavg": dict(lr=2.0, local_steps=5),
        "fedprox": dict(lr=2.0, local_steps=5, mu_prox=0.01),
        "fedns": dict(k=32),
        "flens": dict(k=32),
        "flens_plus": dict(k=32),
    }.get(name, {})


def test_newton_solve_reaches_stationarity(problem):
    prob, w0, w_star = problem
    gnorm = float(jnp.linalg.norm(prob.global_grad(w_star)))
    assert gnorm < 1e-10


def _legacy_newton(prob, w0, iters):
    """The seed's newton_solve, verbatim: fixed-iteration scan, no tol."""

    def body(w, _):
        g = prob.global_grad(w)
        h = prob.global_hessian(w)
        return w - jnp.linalg.solve(h, g), jnp.linalg.norm(g)

    w, gnorms = jax.lax.scan(body, w0, None, length=iters)
    return w, np.asarray(gnorms)


def test_newton_solve_tol_zero_matches_legacy(problem):
    """tol=0 disables the halt and reproduces the seed's fixed-iteration
    recursion bit for bit."""
    prob, w0, _ = problem
    legacy, _ = _legacy_newton(prob, w0, 8)
    out = newton_solve(prob, w0, iters=8, tol=0.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(legacy))


def test_newton_solve_loose_tol_halts_early(problem):
    """A loose tol freezes the iterate at the FIRST point that satisfies
    ||grad|| <= tol — extra iterations change nothing — while tol=0 keeps
    refining past it."""
    prob, w0, _ = problem
    tol = 1e-4
    # gnorms[i] = ||grad|| at iterate i (measured before step i is taken)
    _, gnorms = _legacy_newton(prob, w0, 30)
    hit = next(i for i, gn in enumerate(gnorms) if gn <= tol)
    assert 0 < hit < 30  # the threshold is crossed strictly inside the run
    out = newton_solve(prob, w0, iters=30, tol=tol)
    assert float(jnp.linalg.norm(prob.global_grad(out))) <= tol
    # the halting iterate is the hit-step one, not the fully-refined one
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(newton_solve(prob, w0, iters=hit, tol=0.0)),
        rtol=0, atol=0)
    # once halted, more iterations are an exact no-op (same jaxpr, the
    # masked update copies w through)
    np.testing.assert_array_equal(
        np.asarray(newton_solve(prob, w0, iters=hit + 7, tol=tol)),
        np.asarray(out))
    # whereas the unhalted run keeps moving past the loose iterate
    exact = newton_solve(prob, w0, iters=30, tol=0.0)
    assert not np.array_equal(np.asarray(exact), np.asarray(out))
    assert float(jnp.linalg.norm(prob.global_grad(exact))) < gnorms[hit]


@pytest.mark.parametrize("name", ALGORITHMS)
def test_all_algorithms_decrease_loss(problem, name):
    prob, w0, w_star = problem
    opt = make_optimizer(name, **_kwargs(name, prob.dim))
    hist = run_rounds(opt, prob, w0, w_star, rounds=8)
    assert np.isfinite(hist.loss).all()
    assert hist.loss[-1] < hist.loss[0] * 0.9
    # every method's gap shrinks by >=2x over 8 rounds on this easy problem
    assert hist.gap[-1] < hist.gap[0] * 0.5


def test_fednewton_superlinear(problem):
    """Exact federated Newton hits ~machine precision in <6 rounds."""
    prob, w0, w_star = problem
    hist = run_rounds(make_optimizer("fednewton"), prob, w0, w_star, rounds=6)
    assert hist.gap[-1] < 1e-12


def test_flens_full_sketch_matches_newton(problem):
    """k = next_pow2(M): the SRHT spans the full space -> Newton behaviour."""
    prob, w0, w_star = problem
    opt = make_optimizer("flens", k=64)  # dim=48 pads to 64
    hist = run_rounds(opt, prob, w0, w_star, rounds=6)
    # tail accuracy floors at the lam_damp=1e-8 solve regularization
    # (~5e-10 here, BLAS-dependent), far below the k=32 sketch floor
    assert hist.gap[-1] < 1e-9


def test_flens_sketch_floor_monotone_in_k(problem):
    """Larger sketches converge further (paper Fig. 2 behaviour)."""
    prob, w0, w_star = problem
    gaps = {}
    for k in (12, 24, 64):
        opt = make_optimizer("flens", k=k, beta=0.0)
        gaps[k] = run_rounds(opt, prob, w0, w_star, rounds=10, seed=3).gap[-1]
    assert gaps[64] < gaps[24] < gaps[12]


def test_flens_beats_fedavg_in_rounds(problem):
    """Paper Fig. 1: FLeNS converges in far fewer rounds than FedAvg."""
    prob, w0, w_star = problem
    flens = run_rounds(make_optimizer("flens", k=32), prob, w0, w_star, rounds=10)
    fedavg = run_rounds(
        make_optimizer("fedavg", lr=2.0, local_steps=5), prob, w0, w_star, rounds=10
    )
    assert flens.gap[-1] < fedavg.gap[-1] * 0.5


def test_flens_plus_beats_paper_variant_floor(problem):
    """FLeNS+ (complement gradient step) reaches a lower gap at small k."""
    prob, w0, w_star = problem
    base = run_rounds(
        make_optimizer("flens", k=12, beta=0.0), prob, w0, w_star, rounds=25, seed=1
    )
    plus = run_rounds(
        make_optimizer("flens_plus", k=12, beta=0.0), prob, w0, w_star, rounds=25, seed=1
    )
    assert plus.gap[-1] < base.gap[-1]


def test_flens_restart_prevents_divergence(problem):
    """The literal A7 momentum (beta ~ 1) diverges without restart; the
    restart safeguard keeps it monotone-ish and convergent."""
    prob, w0, w_star = problem
    unsafe = make_optimizer("flens", k=24, beta="paper", restart=False)
    safe = make_optimizer("flens", k=24, beta="paper", restart=True)
    h_unsafe = run_rounds(unsafe, prob, w0, w_star, rounds=12)
    h_safe = run_rounds(safe, prob, w0, w_star, rounds=12)
    assert h_safe.gap[-1] < 1e-2
    assert h_safe.gap[-1] < h_unsafe.gap[-1]


def test_uplink_accounting_matches_table_i(problem):
    """Communication-per-round formulas (Table I), measured in floats."""
    prob, _, _ = problem
    m_dim = prob.dim
    k = 16
    assert make_optimizer("fedavg").uplink_floats(prob) == m_dim
    assert make_optimizer("fednewton").uplink_floats(prob) == m_dim**2 + m_dim
    assert make_optimizer("fedns", k=k).uplink_floats(prob) == k * m_dim + m_dim
    fl = make_optimizer("flens", k=k)
    assert fl.uplink_floats(prob) == k * k + k + 1  # + restart scalar
    assert fl.uplink_floats(prob) < make_optimizer("fedns", k=k).uplink_floats(prob)


def test_heterogeneous_partition_still_converges():
    """Label-skewed (non-iid) clients: FLeNS still approaches w*."""
    X, y = make_classification(jax.random.PRNGKey(5), 1500, 32)
    prob = make_problem(
        X, y, m=6, lam=1e-3, objective=logistic, heterogeneity="label"
    )
    w0 = jnp.zeros(prob.dim, jnp.float64)
    w_star = newton_solve(prob, w0, iters=30)
    hist = run_rounds(make_optimizer("flens", k=32), prob, w0, w_star, rounds=10)
    assert hist.gap[-1] < 1e-6


def test_client_weights_sum_to_one(problem):
    prob, _, _ = problem
    np.testing.assert_allclose(float(jnp.sum(prob.client_weights)), 1.0, rtol=1e-12)
