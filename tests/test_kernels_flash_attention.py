"""Flash-attention Pallas kernel vs naive oracle: sweeps + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas


def _qkv(key, b, tq, tk, h, hkv, d, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, tq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, tk, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, tk, hkv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("tq,tk", [(64, 64), (100, 100), (32, 96), (1, 128)])
@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_oracle(tq, tk, h, hkv, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(tq + h), 2, tq, tk, h, hkv, 32, dtype)
    qoff = tk - tq  # decode-style offset keeps causal well-defined
    got = flash_attention_pallas(q, k, v, q_offset=qoff, block_q=32,
                                 block_k=32, interpret=True)
    want = ref.mha(q, k, v, q_offset=qoff)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("window", [1, 7, 32, 1000])
def test_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(window), 2, 96, 96, 4, 2, 16)
    got = flash_attention_pallas(q, k, v, window=window, block_q=32,
                                 block_k=32, interpret=True)
    want = ref.mha(q, k, v, window=window)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_noncausal():
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 64, 48, 4, 4, 32)
    got = flash_attention_pallas(q, k, v, causal=False, block_q=32,
                                 block_k=16, interpret=True)
    want = ref.mha(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("block_q,block_k", [(16, 16), (32, 64), (128, 128)])
def test_block_shape_invariance(block_q, block_k):
    """Output must not depend on the BlockSpec tiling."""
    q, k, v = _qkv(jax.random.PRNGKey(5), 1, 128, 128, 4, 2, 32)
    got = flash_attention_pallas(q, k, v, block_q=block_q, block_k=block_k,
                                 interpret=True)
    want = ref.mha(q, k, v)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=20, deadline=None)
@given(
    tq=st.integers(1, 80),
    extra=st.integers(0, 64),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 4]),
    causal=st.booleans(),
    seed=st.integers(0, 2**30),
)
def test_property_matches_oracle(tq, extra, hkv, group, causal, seed):
    tk = tq + extra
    h = hkv * group
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, tq, tk, h, hkv, 16)
    got = flash_attention_pallas(q, k, v, causal=causal, q_offset=extra,
                                 block_q=32, block_k=32, interpret=True)
    want = ref.mha(q, k, v, causal=causal, q_offset=extra)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_softmax_rows_sum_to_one_property():
    """With v = ones, attention output must be exactly ones (prob simplex)."""
    q, k, _ = _qkv(jax.random.PRNGKey(9), 2, 64, 64, 4, 2, 32)
    v = jnp.ones((2, 64, 2, 32), jnp.float32)
    got = flash_attention_pallas(q, k, v, block_q=32, block_k=32,
                                 interpret=True)
    np.testing.assert_allclose(got, jnp.ones_like(got), rtol=1e-5, atol=1e-5)
