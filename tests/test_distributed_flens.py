"""Mesh-resident FLeNS == simulator FLeNS, exactly.

The equivalence test runs in a subprocess with 4 forced host devices so
the psum really crosses device boundaries.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np

    from repro.core import make_problem, newton_solve
    from repro.core.distributed import DistributedFLeNS, run_distributed
    from repro.core.flens import FLeNS
    from repro.core.losses import logistic
    from repro.data import make_classification

    m, dim, k = 4, 32, 16
    X, y = make_classification(jax.random.PRNGKey(0), 400, dim)
    prob = make_problem(X, y, m=m, lam=1e-3, objective=logistic)
    w0 = jnp.zeros((dim,), jnp.float64)

    # --- simulator (vmap) rounds with beta=0, no restart, fixed seeds ---
    opt = FLeNS(k=k, beta=0.0, restart=False)
    state = opt.init(prob, w0)
    sim_ws = [w0]
    for t in range(3):
        state = opt.round(prob, state, jax.random.PRNGKey(t))
        sim_ws.append(state["w"])

    # --- distributed rounds on a 4-device mesh (clients = data axis) ---
    mesh = jax.make_mesh((4,), ("data",))
    dist = DistributedFLeNS(mesh=mesh, objective=logistic, dim=dim, k=k,
                            lam=1e-3, beta=0.0, client_axes=("data",))
    # same data layout as the simulator's shards, concatenated
    Xs = prob.X.reshape(-1, dim)
    ys = prob.y.reshape(-1)
    step = dist.round_fn()
    Xd, yd = dist.shard_data(Xs, ys)
    w, w_prev = w0, w0
    for t in range(3):
        w, w_prev = step(Xd, yd, w, w_prev, t)
        ref = sim_ws[t + 1]
        err = float(jnp.max(jnp.abs(w - ref)))
        print(f"round {t} err {err:.3e}")
        assert err < 1e-8, (t, err)
    print("EQUIVALENT")
""")


@pytest.mark.slow
def test_distributed_round_matches_simulator():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "EQUIVALENT" in out.stdout


def test_distributed_single_device_runs():
    """Degenerate 1-slice mesh: the API works on one device too."""
    from repro.core.distributed import DistributedFLeNS
    from repro.core.losses import logistic
    from repro.data import make_classification

    X, y = make_classification(jax.random.PRNGKey(1), 200, 16)
    mesh = jax.make_mesh((1,), ("data",))
    dist = DistributedFLeNS(mesh=mesh, objective=logistic, dim=16, k=8,
                            lam=1e-3, client_axes=("data",))
    step = dist.round_fn()
    Xd, yd = dist.shard_data(X.astype(jnp.float64), y.astype(jnp.float64))
    w0 = jnp.zeros((16,), jnp.float64)
    w, wp = step(Xd, yd, w0, w0, 0)
    assert w.shape == (16,)
    assert np.isfinite(np.asarray(w)).all()
    assert float(jnp.linalg.norm(w - w0)) > 0
