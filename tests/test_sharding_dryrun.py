"""Sharding integration tests: real multi-device lower+compile in a
subprocess (the forced-host-device flag must not leak into this process).
"""
import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def _run_dryrun(arch, shape, mesh="single", devices="512", extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["REPRO_DRYRUN_DEVICES"] = devices
    if extra_env:
        env.update(extra_env)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", "/tmp/repro_test_dryrun"],
        env=env, capture_output=True, text=True, timeout=560, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(
        (pathlib.Path("/tmp/repro_test_dryrun") /
         f"{arch.replace('.', '_')}__{shape}__{mesh}.json").read_text()
    )
    return rec


@pytest.mark.slow
def test_dense_train_lowers_on_production_mesh():
    rec = _run_dryrun("tinyllama-1.1b", "train_4k", "single")
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    assert rec["collective_bytes_per_chip"] > 0  # grad sync exists
    assert rec["roofline"]["dominant"] in ("compute_s", "memory_s",
                                           "collective_s")


@pytest.mark.slow
def test_moe_decode_lowers_multi_pod():
    rec = _run_dryrun("arctic-480b", "decode_32k", "multi")
    assert rec["status"] == "ok"
    assert rec["chips"] == 512
    # expert-parallel MoE must emit cross-shard communication
    assert "all-reduce" in rec["collectives"] or "all-to-all" in rec["collectives"]


@pytest.mark.slow
def test_ssm_long_context_is_state_not_cache():
    rec = _run_dryrun("mamba2-780m", "long_500k", "single")
    assert rec["status"] == "ok"
    # O(1)-state decode: argument bytes are tiny (no 500k KV cache)
    assert rec["memory"]["argument_bytes"] < 2e9


@pytest.mark.slow
def test_unsupported_shape_records_skip():
    rec = _run_dryrun("qwen1.5-110b", "long_500k", "single")
    assert rec["status"] == "skipped"


def test_spec_guards_divisibility():
    """Unit-level: the _guard helper drops non-divisible assignments."""
    import jax

    from repro.sharding import rules

    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = rules._guard(mesh, (8, 128), ("data", "model"))
    assert tuple(spec) in ((None, None), ("data", "model"), ())
    # kv-head case: 8 heads on a 16-way axis must fall back to replication
    mesh16 = None
    try:
        mesh16 = jax.make_mesh((1, 1), ("data", "model"))
    except Exception:
        pytest.skip("cannot build mesh")
    p = rules._guard(mesh16, (8,), ("model",))
    assert True  # structural check only on 1-dev CI; real check in subprocs
