"""Degrade gracefully when ``hypothesis`` is not installed.

Property tests import ``given/settings/st`` from here instead of from
``hypothesis`` directly. With hypothesis present this module is a pure
re-export; without it, ``@given``-decorated tests become individual
skips while every other test in the module still collects and runs.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every strategy factory
        exists and returns None (never drawn from — tests skip first)."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            def _skip():
                pytest.skip("hypothesis not installed")

            _skip.__name__ = fn.__name__
            _skip.__doc__ = fn.__doc__
            return _skip

        return deco

# the whole point of this module is re-export (with graceful fallback):
# declare it so linters don't flag the pass-through imports as unused
__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
