"""Scenario dynamics: churn, time-varying channels, Byzantine robustness.

Invariant families:

  * **Off-switch bit-identity** — a null ``DynamicsConfig`` normalizes
    away and every driver (sync, async, population) reproduces the
    no-dynamics trajectory bit-for-bit.
  * **Per-id determinism** — churn lifetimes, channel multipliers,
    outage windows, and the attacker subset are pure functions of
    ``(seed, client_id, round)``: identical across runs, drivers, and
    cohort compositions.
  * **Correlated outages** — every member of a dark region drops
    together, something no iid dropout coin reproduces.
  * **Robust aggregation** — clip bounds row norms, trimmed mean /
    median defeat a minority of sign-flipped rows, undelivered rows
    never consume the trim budget; end-to-end, ``trimmed`` recovers
    most of the loss gap a sign-flip attack opens.
  * **Bookkeeping** — departed clients' EF rows are retired (dense rows
    zeroed; ``BoundedMemory`` slots freed and reused), and the
    dynamics counters land in the telemetry summary.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import BoundedMemory, CommConfig
from repro.comm.codecs import make_codec
from repro.comm.scheduler import make_scheduler
from repro.core import (
    SyntheticPopulation,
    make_optimizer,
    make_problem,
    newton_solve,
    run_rounds,
)
from repro.core.losses import logistic
from repro.data import make_classification
from repro.dynamics import (
    ChannelProcess,
    DynamicsConfig,
    make_aggregator,
    make_churn,
    make_threat,
)
from repro.obs import TelemetryConfig


# ---------------------------------------------------------------------------
# spec parsing: offending spec + known names in every error
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("maker,bad,fragment", [
    (make_churn, "stepp:3", "step:"),
    (make_churn, "step:frac=x", "step:"),
    (make_threat, "gaussian:0.1", "signflip:"),
    (make_aggregator, "trim:0.1", "clip:tau"),
    (make_scheduler, "unifrom:0.5", "uniform:<q>"),
    (make_codec, "fp8", "qint8"),
    (lambda s: ChannelProcess(uplink_bytes_per_s=s), "cos:2,1", "sin:"),
    (lambda s: ChannelProcess(outage=s), "outage:0.1", "p, dur[, groups]"),
])
def test_parse_errors_name_spec_and_alternatives(maker, bad, fragment):
    """An unknown spec head is echoed back with the known alternatives."""
    with pytest.raises(ValueError) as ei:
        maker(bad)
    msg = str(ei.value)
    assert fragment in msg
    # the offending spec itself is always quoted back
    assert bad.split(":")[0] in msg


@pytest.mark.parametrize("maker,bad,fragment", [
    (make_threat, "signflip:2.0", "must be in [0, 1]"),
    (make_aggregator, "trimmed:0.7", "must be in (0, 0.5)"),
])
def test_out_of_range_parameters_rejected(maker, bad, fragment):
    with pytest.raises(ValueError, match="must be in"):
        maker(bad)


def test_null_dynamics_normalizes_away():
    cfg = CommConfig(dynamics=DynamicsConfig())
    assert cfg.dynamics is None
    with pytest.raises(ValueError, match="DynamicsConfig"):
        CommConfig(dynamics="signflip:0.1")


def test_forces_mask_gate():
    assert DynamicsConfig(churn="step:t=1").forces_mask
    assert DynamicsConfig(
        channel=ChannelProcess(outage="outage:0.1,2")).forces_mask
    assert not DynamicsConfig(
        channel=ChannelProcess(uplink_bytes_per_s="sin:8,0.5")).forces_mask
    assert not DynamicsConfig(threat="signflip:0.1",
                              robust="median").forces_mask


# ---------------------------------------------------------------------------
# churn
# ---------------------------------------------------------------------------

def test_step_churn_departs_once_at_t0():
    ch = make_churn("step:t=3,frac=0.4", seed=7)
    m = 200
    before = ch.eligible_mask(2, m)
    assert before.all()
    after = ch.eligible_mask(3, m)
    assert 0.2 < 1.0 - after.mean() < 0.6  # ~frac depart
    np.testing.assert_array_equal(after, ch.eligible_mask(9, m))


def test_churn_per_id_purity_and_determinism():
    for spec in ("poisson:0.2", "lifetime:5,3"):
        ch1 = make_churn(spec, seed=5)
        ch2 = make_churn(spec, seed=5)
        full = ch1.alive(np.arange(64), 4, 64)
        # a sub-cohort sees exactly the full draw's restriction
        sub = np.array([3, 17, 42])
        np.testing.assert_array_equal(ch1.alive(sub, 4, 64), full[sub])
        np.testing.assert_array_equal(full, ch2.alive(np.arange(64), 4, 64))
        # a different seed is a different population
        assert not np.array_equal(
            full, make_churn(spec, seed=6).alive(np.arange(64), 4, 64))


def test_poisson_churn_clients_come_and_go():
    ch = make_churn("poisson:0.2", seed=1)
    m = 50
    alive = np.stack([ch.eligible_mask(t, m) for t in range(40)])
    per_client_changes = (alive[1:] != alive[:-1]).sum(axis=0)
    assert (per_client_changes > 0).any()  # departures happen
    assert alive.any(axis=1).all()  # never a fully-dead round at this rate
    # departures are spells, not coin flips: some client returns
    came_back = ((~alive[:-1]) & alive[1:]).any()
    assert came_back


# ---------------------------------------------------------------------------
# time-varying channels
# ---------------------------------------------------------------------------

def test_channel_multiplier_deterministic_across_cohorts():
    cp = ChannelProcess(uplink_bytes_per_s="sin:24,0.5", seed=3)
    full = cp.multiplier("uplink_bytes_per_s", np.arange(100), t=7)
    sub = np.array([5, 50, 99])
    np.testing.assert_array_equal(
        cp.multiplier("uplink_bytes_per_s", sub, t=7), full[sub])
    # bit-identical on a fresh construction (no hidden state)
    cp2 = ChannelProcess(uplink_bytes_per_s="sin:24,0.5", seed=3)
    np.testing.assert_array_equal(
        cp2.multiplier("uplink_bytes_per_s", np.arange(100), t=7), full)
    # fields draw independent phases
    assert not np.array_equal(
        cp.multiplier("uplink_bytes_per_s", np.arange(100), t=7),
        ChannelProcess(latency_s="sin:24,0.5", seed=3).multiplier(
            "latency_s", np.arange(100), t=7))


def test_channel_multiplier_clipped_and_time_varying():
    cp = ChannelProcess(uplink_bytes_per_s="sin:8,0.9+drift:0.5", seed=0)
    vals = np.stack([
        cp.multiplier("uplink_bytes_per_s", np.arange(32), t) for t in
        range(16)])
    assert (vals >= 0.05).all() and (vals <= 20.0).all()
    assert (np.ptp(vals, axis=0) > 0).all()  # every link actually moves


def test_outage_groups_are_correlated():
    cp = ChannelProcess(outage="outage:0.5,3,4", seed=2)
    m, groups = 64, 4
    hit_any = False
    for t in range(12):
        dark = cp.outage_mask(np.arange(m), t)
        for g in range(groups):
            region = dark[np.arange(m) % groups == g]
            # a region is all-dark or all-up — never split
            assert region.all() or not region.any()
        hit_any = hit_any or dark.any()
        # constant within an outage window
        np.testing.assert_array_equal(
            dark, cp.outage_mask(np.arange(m), (t // 3) * 3))
    assert hit_any  # p=0.5 over 4 windows x 4 groups: some region went dark


# ---------------------------------------------------------------------------
# threat + robust aggregation (unit level)
# ---------------------------------------------------------------------------

def test_attacker_subset_is_pure_per_id():
    th = make_threat("signflip:0.3", seed=4)
    full = th.attacker_mask(np.arange(500))
    sub = np.array([7, 77, 477])
    np.testing.assert_array_equal(th.attacker_mask(sub), full[sub])
    assert 0.15 < full.mean() < 0.45


def test_signflip_corrupts_exactly_the_attacker_rows():
    th = make_threat("signflip:0.5", seed=0)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 5))
    att = jnp.asarray(np.array([1, 0, 1, 0, 0, 0, 1, 0]), x.dtype)
    out = th.corrupt(jax.random.PRNGKey(1), x, att)
    np.testing.assert_array_equal(np.asarray(out[0]), -np.asarray(x[0]))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(x[1]))


def test_clip_bounds_row_norms_and_counts():
    agg = make_aggregator("clip:1.0")
    x = jnp.asarray(np.array([[3.0, 4.0], [0.3, 0.4], [0.0, 0.0]]))
    stats = {}
    out = agg(x, None, stats)
    norms = np.linalg.norm(np.asarray(out), axis=1)
    assert norms.max() <= 1.0 + 1e-12
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(x[1]))
    assert float(stats["uploads_clipped"]) == 1.0


def test_trimmed_mean_defeats_sign_flips():
    rng = np.random.default_rng(0)
    honest = rng.normal(1.0, 0.05, size=(10, 6))
    x = honest.copy()
    x[:2] = -x[:2] * 5  # 20% attackers, large negative outliers
    agg = make_aggregator("trimmed:0.2")
    out = np.asarray(agg(jnp.asarray(x), None, {}))
    # every row carries the robust aggregate; it tracks the honest mean
    np.testing.assert_allclose(out, out[:1].repeat(10, axis=0))
    np.testing.assert_allclose(out[0], honest[2:].mean(axis=0), atol=0.05)


def test_trimmed_mean_ignores_undelivered_rows():
    x = np.ones((6, 4))
    x[0] = 1e6  # undelivered garbage must not eat the trim budget
    x[1] = -50.0  # the actual attacker
    mask = jnp.asarray(np.array([0.0, 1, 1, 1, 1, 1]))
    stats = {}
    out = np.asarray(make_aggregator("trimmed:0.2")(
        jnp.asarray(x), mask, stats))
    np.testing.assert_allclose(out[2], np.ones(4), atol=1e-9)
    assert float(stats["uploads_trimmed"]) > 0


def test_median_is_delivered_only():
    x = np.zeros((5, 3))
    x[0] = 1e9  # undelivered
    x[1:] = [[1, 1, 1], [2, 2, 2], [3, 3, 3], [4, 4, 4]]
    mask = jnp.asarray(np.array([0.0, 1, 1, 1, 1]))
    out = np.asarray(make_aggregator("median")(jnp.asarray(x), mask, {}))
    np.testing.assert_allclose(out[0], [2.5, 2.5, 2.5])


# ---------------------------------------------------------------------------
# EF retirement under churn
# ---------------------------------------------------------------------------

def test_bounded_memory_retire_frees_and_zeroes():
    spec = {"g": jax.ShapeDtypeStruct((4, 3), jnp.float64)}
    store = BoundedMemory(spec, capacity=4)
    store.gather([10, 11, 12, 13])
    store.scatter([10, 11, 12, 13],
                  {"g": jnp.ones((4, 3), jnp.float64)})
    assert store.retire([11, 13, 99]) == 2  # 99 was never hot
    assert store.retirements == 2
    # freed slots are reused (no eviction needed at capacity)
    rows = store.gather([10, 12, 20, 21])
    assert store.evictions == 0
    got = np.asarray(rows["g"])
    np.testing.assert_array_equal(got[0], np.ones(3))  # 10 kept its row
    np.testing.assert_array_equal(got[2], np.zeros(3))  # 20 starts clean
    # slot invariant held: all four ids fit without eviction
    assert store.retire([10, 12, 20, 21]) == 4


# ---------------------------------------------------------------------------
# end-to-end: the three drivers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def edge_problem():
    X, y = make_classification(jax.random.PRNGKey(2), 600, 24)
    prob = make_problem(X, y, m=6, lam=1e-3, objective=logistic)
    w0 = jnp.zeros(prob.dim, jnp.float64)
    w_star = newton_solve(prob, w0, iters=30)
    return prob, w0, w_star


def test_dynamics_disabled_bit_identical_all_drivers(edge_problem):
    """A null DynamicsConfig must leave every driver's trajectory
    untouched — the PR's backward-compatibility guarantee."""
    prob, w0, w_star = edge_problem
    opt = lambda: make_optimizer("flens", k=8)
    h0 = run_rounds(opt(), prob, w0, w_star, rounds=3)
    hs = run_rounds(opt(), prob, w0, w_star, rounds=3,
                    comm=CommConfig(dynamics=DynamicsConfig()))
    ha = run_rounds(opt(), prob, w0, w_star, rounds=3,
                    comm=CommConfig(async_mode=True,
                                    dynamics=DynamicsConfig()))
    np.testing.assert_array_equal(h0.loss, hs.loss)
    np.testing.assert_array_equal(h0.loss, ha.loss)

    pop = SyntheticPopulation(m=16, dim=6, seed=4)
    w0p = jnp.zeros(pop.dim, jnp.float64)
    wsp = newton_solve(pop.eval_problem(), w0p)
    hp0 = run_rounds(make_optimizer("flens", k=4), pop, w0p, wsp, rounds=3,
                     comm=CommConfig())
    hp1 = run_rounds(make_optimizer("flens", k=4), pop, w0p, wsp, rounds=3,
                     comm=CommConfig(dynamics=DynamicsConfig()))
    np.testing.assert_array_equal(hp0.loss, hp1.loss)


def test_churn_shrinks_cohorts_and_is_reproducible(edge_problem):
    prob, w0, w_star = edge_problem
    mk = lambda: CommConfig(dynamics=DynamicsConfig(
        churn="step:t=2,frac=0.5", seed=9))
    h1 = run_rounds(make_optimizer("fedavg"), prob, w0, w_star, rounds=4,
                    comm=mk())
    h2 = run_rounds(make_optimizer("fedavg"), prob, w0, w_star, rounds=4,
                    comm=mk())
    np.testing.assert_array_equal(h1.loss, h2.loss)
    sched = np.stack([t.scheduled for t in h1.traces])
    assert sched[:2].all()  # everyone participates before the step
    assert sched[2:].sum() < sched[:2].sum()  # departures bite after
    # the departed set is persistent (step churn never returns)
    np.testing.assert_array_equal(sched[2], sched[3])


def test_outage_drops_whole_regions_in_round_traces(edge_problem):
    prob, w0, w_star = edge_problem
    cp = ChannelProcess(outage="outage:0.6,2,3", seed=11)
    h = run_rounds(make_optimizer("fedavg"), prob, w0, w_star, rounds=6,
                   comm=CommConfig(dynamics=DynamicsConfig(channel=cp)))
    m = prob.m
    outage_rounds = 0
    for t, tr in enumerate(h.traces):
        dark = cp.outage_mask(np.arange(m), t)
        # every scheduled member of a dark region fails to deliver
        assert not (tr.delivered & dark).any() or dark.sum() == m
        outage_rounds += int(dark.any())
    assert outage_rounds > 0


def test_sin_modulation_changes_round_times(edge_problem):
    prob, w0, w_star = edge_problem
    cp = ChannelProcess(uplink_bytes_per_s="sin:4,0.8", seed=0)
    h = run_rounds(make_optimizer("flens", k=8), prob, w0, w_star, rounds=6,
                   comm=CommConfig(dynamics=DynamicsConfig(channel=cp)))
    h0 = run_rounds(make_optimizer("flens", k=8), prob, w0, w_star, rounds=6,
                    comm=CommConfig())
    times = np.array([t.sim_time_s for t in h.traces])
    base = np.array([t.sim_time_s for t in h0.traces])
    # modulation must move the clock round-to-round; the base is flat
    assert np.ptp(times) > 10 * np.ptp(base)
    # the trajectory itself is untouched (no outage => no mask change)
    np.testing.assert_array_equal(h.loss, h0.loss)


def test_signflip_attack_hurts_and_trimmed_recovers(edge_problem):
    """The acceptance gate in miniature: a 1/3 sign-flip coalition
    stalls FedAvg; the trimmed mean recovers most of the gap."""
    prob, w0, w_star = edge_problem
    rounds = 6
    clean = run_rounds(make_optimizer("fedavg"), prob, w0, w_star,
                       rounds=rounds, comm=CommConfig())
    attacked = run_rounds(
        make_optimizer("fedavg"), prob, w0, w_star, rounds=rounds,
        comm=CommConfig(dynamics=DynamicsConfig(threat="signflip:0.34",
                                                seed=1)))
    defended = run_rounds(
        make_optimizer("fedavg"), prob, w0, w_star, rounds=rounds,
        comm=CommConfig(dynamics=DynamicsConfig(
            threat="signflip:0.34", robust="trimmed:0.34", seed=1)))
    gap_attacked = float(attacked.loss[-1] - clean.loss[-1])
    gap_defended = float(defended.loss[-1] - clean.loss[-1])
    assert gap_attacked > 0
    assert gap_defended < 0.5 * gap_attacked  # >= 2x recovery


def test_threat_deterministic_across_drivers(edge_problem):
    """The same seeded coalition attacks in the sync and async drivers;
    on the lockstep path (threat only — no mask change) the corrupted
    trajectories still agree bit-for-bit."""
    prob, w0, w_star = edge_problem
    dk = dict(threat="scale:0.34,10", robust="clip:2.0", seed=2)
    hs = run_rounds(make_optimizer("fedavg"), prob, w0, w_star, rounds=4,
                    comm=CommConfig(dynamics=DynamicsConfig(**dk)))
    ha = run_rounds(make_optimizer("fedavg"), prob, w0, w_star, rounds=4,
                    comm=CommConfig(async_mode=True,
                                    dynamics=DynamicsConfig(**dk)))
    np.testing.assert_array_equal(hs.loss, ha.loss)


def test_threat_payload_scope_grammar():
    """``kind:frac[,param]@p1+p2`` restricts the attack to named
    payloads; an empty scope is a spec error, not corrupt-nothing."""
    th = make_threat("signflip:0.3@h_sk+sg", seed=1)
    assert th.payloads == ("h_sk", "sg")
    assert th.applies("h_sk") and th.applies("sg")
    assert not th.applies("w_local")
    assert make_threat("scale:0.2,5", seed=1).payloads is None
    with pytest.raises(ValueError, match="empty @payload"):
        make_threat("signflip:0.3@")


def test_threat_scoped_to_absent_payload_is_inert(edge_problem):
    """FedAvg never uplinks ``h_sk``: a threat scoped there must leave
    the trajectory bit-identical to no threat at all."""
    prob, w0, w_star = edge_problem
    clean = run_rounds(make_optimizer("fedavg"), prob, w0, w_star,
                       rounds=4, comm=CommConfig())
    scoped = run_rounds(
        make_optimizer("fedavg"), prob, w0, w_star, rounds=4,
        comm=CommConfig(dynamics=DynamicsConfig(
            threat="signflip:0.34@h_sk", seed=1)))
    np.testing.assert_array_equal(clean.loss, scoped.loss)


def test_threat_scoped_to_uplinked_payload_equals_full(edge_problem):
    """FedAvg's only uplink IS ``w_local``: scoping the attack there is
    the whole attack — bit-identical to the unscoped threat, and
    different from the clean run."""
    prob, w0, w_star = edge_problem
    full = run_rounds(
        make_optimizer("fedavg"), prob, w0, w_star, rounds=4,
        comm=CommConfig(dynamics=DynamicsConfig(
            threat="signflip:0.34", seed=1)))
    scoped = run_rounds(
        make_optimizer("fedavg"), prob, w0, w_star, rounds=4,
        comm=CommConfig(dynamics=DynamicsConfig(
            threat="signflip:0.34@w_local", seed=1)))
    clean = run_rounds(make_optimizer("fedavg"), prob, w0, w_star,
                       rounds=4, comm=CommConfig())
    np.testing.assert_array_equal(full.loss, scoped.loss)
    assert float(abs(scoped.loss[-1] - clean.loss[-1])) > 0


def test_population_dynamics_deterministic():
    pop = SyntheticPopulation(m=64, dim=8, seed=3)
    w0 = jnp.zeros(pop.dim, jnp.float64)
    w_star = newton_solve(pop.eval_problem(), w0)
    mk = lambda: CommConfig(
        scheduler="uniform:0.25", async_mode=True, buffer_size=4,
        dynamics=DynamicsConfig(
            churn="poisson:0.1",
            channel=ChannelProcess(uplink_bytes_per_s="sin:8,0.5",
                                   outage="outage:0.2,2,4", seed=1),
            threat="signflip:0.2", robust="trimmed:0.25", seed=5))
    h1 = run_rounds(make_optimizer("flens", k=4), pop, w0, w_star,
                    rounds=5, comm=mk())
    h2 = run_rounds(make_optimizer("flens", k=4), pop, w0, w_star,
                    rounds=5, comm=mk())
    np.testing.assert_array_equal(h1.loss, h2.loss)
    for t1, t2 in zip(h1.traces, h2.traces):
        np.testing.assert_array_equal(t1.ids, t2.ids)
        np.testing.assert_array_equal(t1.delivered, t2.delivered)


def test_dynamics_counters_in_telemetry(edge_problem):
    prob, w0, w_star = edge_problem
    cp = ChannelProcess(outage="outage:0.4,2,3", seed=11)
    h = run_rounds(
        make_optimizer("fedavg"), prob, w0, w_star, rounds=6,
        comm=CommConfig(dynamics=DynamicsConfig(
            churn="step:t=3,frac=0.5", channel=cp,
            threat="signflip:0.34", robust="clip:0.5+trimmed:0.34",
            seed=1)),
        obs=TelemetryConfig())
    counters = h.telemetry["metrics"]["counters"]
    assert counters["uploads_corrupted"] > 0
    assert counters["uploads_clipped"] > 0
    assert counters["uploads_trimmed"] > 0
    assert counters.get("clients_departed", 0) > 0
    gauges = h.telemetry["metrics"]["gauges"]
    assert 0 < gauges["active_population"] < prob.m
