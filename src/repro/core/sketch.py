"""Sketch operators for Newton sketching (SRHT / Gaussian / SJLT).

A sketch is a random linear map ``S : R^dim -> R^k`` (conceptually a
``k x dim`` matrix) normalized so that ``E[S^T S / k] ~ I`` in the
Gaussian/SJLT case and ``S S^T = (dim/k) I_k`` exactly for SRHT.

The SRHT is ``S = sqrt(dim/k) * P * H_n * D`` restricted to the first
``dim`` input coordinates, where ``n = next_pow2(dim)``, ``D`` is a
diagonal Rademacher sign matrix, ``H_n`` the orthonormal Hadamard
transform and ``P`` a uniform row sampler without replacement. Its
application cost is O(n log n) per vector via the fast Walsh-Hadamard
transform — the compute hot spot served by ``repro.kernels.ops``:
``SrhtSketch`` routes through the ``srht_apply``/``srht_apply_t`` ops,
so the fused Pallas kernel (``repro.kernels.srht``), its interpreted
body, and the pure-jnp reference are selectable per call / via config /
via ``REPRO_KERNEL_IMPL`` without touching optimizer code.

Each sketch kind is its own operator class behind one
``apply``/``apply_t``/``dense`` protocol (the ``Sketch`` base); all are
small registered-dataclass pytrees plus pure apply methods, so they can
live inside jitted/vmapped federated rounds. ``make_sketch`` remains the
single sampling entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

SketchKind = Literal["srht", "gaussian", "sjlt"]


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class Sketch:
    """Protocol base for a sampled sketch operator (one realization of S).

    Subclasses are frozen dataclass pytrees with static ``k``/``dim``
    and a class-level ``kind`` tag; they implement ``apply``/``apply_t``
    and expose ``op_dtype`` (the dtype the operator was drawn in).
    """

    kind: str = "?"
    k: int
    dim: int

    # -- application ------------------------------------------------------
    def apply(self, x: jax.Array, *, impl: str | None = None) -> jax.Array:
        """S @ x for x of shape (..., dim) -> (..., k)."""
        raise NotImplementedError

    def apply_t(self, y: jax.Array, *, impl: str | None = None) -> jax.Array:
        """S^T @ y for y of shape (..., k) -> (..., dim)."""
        raise NotImplementedError

    @property
    def op_dtype(self):
        """The dtype the operator's parameters were drawn in."""
        raise NotImplementedError

    def dense(self) -> jax.Array:
        """Materialize S as a (k, dim) matrix in the operator's own
        dtype (tests / tiny dims)."""
        return self.apply(jnp.eye(self.dim, dtype=self.op_dtype)).T


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SrhtSketch(Sketch):
    """Subsampled randomized Hadamard transform: signs (n,), rows (k,)."""

    k: int = dataclasses.field(metadata={"static": True})
    dim: int = dataclasses.field(metadata={"static": True})
    signs: jax.Array
    rows: jax.Array

    kind = "srht"

    def apply(self, x, *, impl=None):
        return kops.srht_apply(x, self.signs, self.rows, impl=impl)

    def apply_t(self, y, *, impl=None):
        return kops.srht_apply_t(y, self.signs, self.rows, self.dim,
                                 impl=impl)

    @property
    def op_dtype(self):
        return self.signs.dtype


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenseSketch(Sketch):
    """A sketch materialized as its (k, dim) matrix (small-dim kinds)."""

    k: int = dataclasses.field(metadata={"static": True})
    dim: int = dataclasses.field(metadata={"static": True})
    mat: jax.Array

    def apply(self, x, *, impl=None):
        return x @ self.mat.T

    def apply_t(self, y, *, impl=None):
        return y @ self.mat

    @property
    def op_dtype(self):
        return self.mat.dtype


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GaussianSketch(DenseSketch):
    kind = "gaussian"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SjltSketch(DenseSketch):
    """Sparse JL transform, materialized dense for the convex dims."""

    kind = "sjlt"


def make_sketch(key: jax.Array, kind: SketchKind, k: int, dim: int,
                dtype=jnp.float32, sjlt_nnz_per_col: int = 4) -> Sketch:
    """Sample one sketch operator S in R^{k x dim}."""
    if kind == "srht":
        n = _next_pow2(dim)
        ks, kr = jax.random.split(key)
        signs = jax.random.rademacher(ks, (n,), dtype=dtype)
        rows = jax.random.choice(kr, n, (k,), replace=False)
        return SrhtSketch(k, dim, signs, rows)
    if kind == "gaussian":
        mat = jax.random.normal(key, (k, dim), dtype) / jnp.sqrt(
            jnp.asarray(k, dtype)
        )
        return GaussianSketch(k, dim, mat)
    if kind == "sjlt":
        # s nonzeros per column, value ±1/sqrt(s); materialized dense for
        # the small dims of the convex experiments.
        s = min(sjlt_nnz_per_col, k)
        kr, ks = jax.random.split(key)
        rows = jax.random.randint(kr, (s, dim), 0, k)
        signs = jax.random.rademacher(ks, (s, dim), dtype=dtype)
        mat = jnp.zeros((k, dim), dtype)
        cols = jnp.broadcast_to(jnp.arange(dim)[None, :], (s, dim))
        mat = mat.at[rows.reshape(-1), cols.reshape(-1)].add(
            signs.reshape(-1) / jnp.sqrt(jnp.asarray(s, dtype))
        )
        return SjltSketch(k, dim, mat)
    raise ValueError(f"unknown sketch kind {kind!r}")


def sketch_psd(sketch: Sketch, h_mat: jax.Array) -> jax.Array:
    """S H S^T (k, k) for symmetric H (dim, dim)."""
    hs_t = sketch.apply(h_mat)          # (dim, k): row i is S @ H[i] == (H S^T)[i]
    shs_t = sketch.apply(hs_t.T)        # (k, k):   row j is S @ (S H)[j] == (S H S^T)[j]
    return 0.5 * (shs_t + shs_t.T)      # symmetrize against fp error


def sketch_sqrt_rows(sketch: Sketch, a_mat: jax.Array) -> jax.Array:
    """Left sketch of the Hessian square root: S @ A for A (n_rows, dim_feat).

    FedNS-style: S acts on the *data* axis, so ``sketch.dim == n_rows``;
    returns (k, dim_feat).
    """
    return sketch.apply(a_mat.T).T


def effective_dimension(h_mat: jax.Array, lam: float) -> jax.Array:
    """Empirical effective dimension d_lambda = tr(H (H + lam I)^-1)."""
    evals = jnp.linalg.eigvalsh(h_mat)
    evals = jnp.maximum(evals, 0.0)
    return jnp.sum(evals / (evals + lam))
