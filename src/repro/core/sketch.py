"""Sketch operators for Newton sketching (SRHT / Gaussian / SJLT).

A sketch is a random linear map ``S : R^dim -> R^k`` (conceptually a
``k x dim`` matrix) normalized so that ``E[S^T S / k] ~ I`` in the
Gaussian/SJLT case and ``S S^T = (dim/k) I_k`` exactly for SRHT.

The SRHT is ``S = sqrt(dim/k) * P * H_n * D`` restricted to the first
``dim`` input coordinates, where ``n = next_pow2(dim)``, ``D`` is a
diagonal Rademacher sign matrix, ``H_n`` the orthonormal Hadamard
transform and ``P`` a uniform row sampler without replacement. Its
application cost is O(n log n) per vector via the fast Walsh-Hadamard
transform — the compute hot spot accelerated by the Pallas kernel in
``repro.kernels.fwht``.

All sketches are represented as small parameter pytrees plus pure apply
functions, so they can live inside jitted/vmapped federated rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

SketchKind = Literal["srht", "gaussian", "sjlt"]


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Sketch:
    """A sampled sketch operator (one realization of S)."""

    kind: str = dataclasses.field(metadata={"static": True})
    k: int = dataclasses.field(metadata={"static": True})
    dim: int = dataclasses.field(metadata={"static": True})
    # srht: signs (n,), rows (k,) ; gaussian: mat (k, dim);
    # sjlt: rows (s, dim) int32, signs (s, dim)
    signs: jax.Array | None
    rows: jax.Array | None
    mat: jax.Array | None

    # -- application ------------------------------------------------------
    def apply(self, x: jax.Array) -> jax.Array:
        """S @ x for x of shape (..., dim) -> (..., k)."""
        if self.kind == "gaussian":
            return x @ self.mat.T
        if self.kind == "sjlt":
            return x @ self.mat.T  # materialized sparse-as-dense (small dims)
        # SRHT
        n = self.signs.shape[-1]
        pad = n - self.dim
        xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
        xp = xp * self.signs
        h = kops.fwht(xp, normalize=True)
        scale = jnp.sqrt(jnp.asarray(n / self.k, h.dtype))
        return jnp.take(h, self.rows, axis=-1) * scale

    def apply_t(self, y: jax.Array) -> jax.Array:
        """S^T @ y for y of shape (..., k) -> (..., dim)."""
        if self.kind in ("gaussian", "sjlt"):
            return y @ self.mat
        n = self.signs.shape[-1]
        scale = jnp.sqrt(jnp.asarray(n / self.k, y.dtype))
        z = jnp.zeros(y.shape[:-1] + (n,), y.dtype)
        z = z.at[..., self.rows].set(y * scale)
        h = kops.fwht(z, normalize=True)
        h = h * self.signs
        return h[..., : self.dim]

    def dense(self) -> jax.Array:
        """Materialize S as a (k, dim) matrix (tests / tiny dims)."""
        return self.apply(jnp.eye(self.dim)).T


def make_sketch(key: jax.Array, kind: SketchKind, k: int, dim: int,
                dtype=jnp.float32, sjlt_nnz_per_col: int = 4) -> Sketch:
    """Sample one sketch operator S in R^{k x dim}."""
    if kind == "srht":
        n = _next_pow2(dim)
        ks, kr = jax.random.split(key)
        signs = jax.random.rademacher(ks, (n,), dtype=dtype)
        rows = jax.random.choice(kr, n, (k,), replace=False)
        return Sketch(kind, k, dim, signs, rows, None)
    if kind == "gaussian":
        mat = jax.random.normal(key, (k, dim), dtype) / jnp.sqrt(
            jnp.asarray(k, dtype)
        )
        return Sketch(kind, k, dim, None, None, mat)
    if kind == "sjlt":
        # s nonzeros per column, value ±1/sqrt(s); materialized dense for
        # the small dims of the convex experiments.
        s = min(sjlt_nnz_per_col, k)
        kr, ks = jax.random.split(key)
        rows = jax.random.randint(kr, (s, dim), 0, k)
        signs = jax.random.rademacher(ks, (s, dim), dtype=dtype)
        mat = jnp.zeros((k, dim), dtype)
        cols = jnp.broadcast_to(jnp.arange(dim)[None, :], (s, dim))
        mat = mat.at[rows.reshape(-1), cols.reshape(-1)].add(
            signs.reshape(-1) / jnp.sqrt(jnp.asarray(s, dtype))
        )
        return Sketch(kind, k, dim, None, None, mat)
    raise ValueError(f"unknown sketch kind {kind!r}")


def sketch_psd(sketch: Sketch, h_mat: jax.Array) -> jax.Array:
    """S H S^T (k, k) for symmetric H (dim, dim)."""
    hs_t = sketch.apply(h_mat)          # (dim, k): row i is S @ H[i] == (H S^T)[i]
    shs_t = sketch.apply(hs_t.T)        # (k, k):   row j is S @ (S H)[j] == (S H S^T)[j]
    return 0.5 * (shs_t + shs_t.T)      # symmetrize against fp error


def sketch_sqrt_rows(sketch: Sketch, a_mat: jax.Array) -> jax.Array:
    """Left sketch of the Hessian square root: S @ A for A (n_rows, dim_feat).

    FedNS-style: S acts on the *data* axis, so ``sketch.dim == n_rows``;
    returns (k, dim_feat).
    """
    return sketch.apply(a_mat.T).T


def effective_dimension(h_mat: jax.Array, lam: float) -> jax.Array:
    """Empirical effective dimension d_lambda = tr(H (H + lam I)^-1)."""
    evals = jnp.linalg.eigvalsh(h_mat)
    evals = jnp.maximum(evals, 0.0)
    return jnp.sum(evals / (evals + lam))
