"""FLeNS — Federated Learning with Enhanced Nesterov-Newton Sketch.

The paper's algorithm (Algorithm 1), made dimensionally consistent as
described in DESIGN.md §1.1:

  1. Nesterov look-ahead       v_t = w_t + beta_t (w_t - w_{t-1})
  2. Every client j computes   g_j(v_t)   and the two-sided sketch
                               H~_j = S H_j(v_t) S^T  in R^{k x k},
     with the SAME per-round SRHT S (the server broadcasts the O(1) seed).
     Efficient form: H_j = A_j^T A_j + lam I  (A_j = sqrt-Hessian rows),
     so  H~_j = (A_j S^T)^T (A_j S^T) + lam * S S^T  — never materializes
     the M x M Hessian; cost O(n_j M log M) via the FWHT.
  3. Uplink per client: H~_j (k^2 floats) + S g_j (k floats)  ->  O(k^2).
  4. Server aggregates and takes the sketched-subspace Newton step
         delta = S^T (H~ + lam_damp I)^{-1} (S g),
         w_{t+1} = v_t - mu * delta.

The sketch is a first-class scheduled object (``repro.core.
sketch_policy``): ``sketch="srht"`` reproduces the paper's fresh
per-round basis bit-for-bit, while ``"srht:fixed"`` / ``"srht:rotate=R"``
persist the basis across rounds (making the sketch uplinks EF-eligible)
and ``"...:adaptive"`` ramps k within declared bounds on guard rejects.

``variant="plus"`` is the beyond-paper FLeNS+ of DESIGN.md §1.2: clients
additionally upload the raw gradient (O(M), the same uplink order as
FedAvg) and the server adds a first-order step in the orthogonal
complement of the sketch subspace, removing the sketch floor:
         w_{t+1} = v_t - mu * delta - eta * (g - P_S g),
with P_S the exact projector onto range(S^T).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm import NULL_COMM
from repro.core.base import FederatedOptimizer, OptState
from repro.core.federated import FederatedProblem
from repro.core.sketch_policy import (
    SketchPolicy,
    as_policy,
    loss_effective_dimension,
)


# lower bound of the guard's backtracking trust scale: rejects halve the
# scale down to this floor, accepts double it back (capped at 1)
_MIN_TRUST_SCALE = 1.0 / 64.0


class FLeNS(FederatedOptimizer):
    name = "flens"

    def __init__(
        self,
        k: int,
        mu: float = 1.0,
        beta: float | str = "paper",
        sketch: "str | SketchPolicy" = "srht",
        lam_damp: float = 1e-8,
        variant: str = "paper",  # "paper" | "plus"
        eta: float | None = None,  # complement step size (plus); None -> 1/L1
        step_from: str = "v",  # "v" (standard accelerated) | "w" (paper literal)
        restart: bool = True,  # function-value adaptive momentum restart
    ):
        # the sketch is a scheduled first-class object: "srht" (fresh,
        # the paper's per-round basis), "srht:fixed", "srht:rotate=8",
        # "gaussian:adaptive", ... — see repro.core.sketch_policy
        self.policy = as_policy(sketch, k=k)
        self.mu = mu
        self.beta = beta
        self.lam_damp = lam_damp
        self.variant = variant
        self.eta = eta
        self.step_from = step_from
        self.restart = restart
        self._guard_scale = 1.0  # host-side adaptive-k reject detector
        if self.policy.adaptive and not restart:
            # the ramp is driven by guard rejections; without the guard
            # the trust scale never moves and "adaptive" would silently
            # degenerate to constant-k
            raise ValueError(
                "adaptive-k sketch policies need the guard (restart=True): "
                "the k ramp is driven by its rejected steps")
        if variant == "plus":
            self.name = "flens_plus"

    @property
    def k(self) -> int:
        return self.policy.k

    @k.setter
    def k(self, value: int) -> None:
        self.policy = self.policy.with_k(value)

    # -- momentum schedule ---------------------------------------------------
    def _beta_value(self, problem: FederatedProblem, w0: jax.Array) -> float:
        if isinstance(self.beta, (int, float)):
            return float(self.beta)
        h = problem.global_hessian(w0)
        evals = jnp.linalg.eigvalsh(h)
        l1 = float(evals[-1])
        gam = float(jnp.maximum(evals[0], problem.lam))
        if self.beta == "paper":  # Assumption A7: (L1 - gamma)/(L1 + gamma)
            return (l1 - gam) / (l1 + gam)
        if self.beta == "sqrt":  # classical accelerated-GD schedule
            sl, sg = l1 ** 0.5, gam ** 0.5
            return (sl - sg) / (sl + sg)
        raise ValueError(f"unknown beta rule {self.beta!r}")

    def init(self, problem, w0):
        if self.policy.adaptive:
            # adaptive-k: start from the effective dimension of the loss
            # Hessian clipped into the declared (k_min, k_max); the
            # guard-driven ramp happens in round_signature as the
            # trajectory unfolds
            d_eff = loss_effective_dimension(problem, w0)
            self.policy = self.policy.resolved(d_eff, cap=problem.dim)
            self._guard_scale = 1.0
        beta = self._beta_value(problem, w0)
        state = {
            "w": w0,
            "w_prev": w0,
            "beta": jnp.asarray(beta, w0.dtype),
            "loss": problem.global_value(w0),
            "scale": jnp.asarray(1.0, w0.dtype),
            # round counter: the rotation-epoch input of the sketch
            # schedule (and a no-op for the default fresh basis)
            "t": jnp.asarray(0, jnp.int32),
        }
        if self.variant == "plus":
            # eta lives in the state dict (NOT on the optimizer instance):
            # one optimizer object stays reusable across problems
            if self.eta is None:
                h = problem.global_hessian(w0)
                l1 = float(jnp.linalg.eigvalsh(h)[-1])
                eta = 1.0 / l1
            else:
                eta = float(self.eta)
            state["eta"] = jnp.asarray(eta, w0.dtype)
        return state

    # -- host-side adaptive-k hook (run_rounds calls this pre-round) ---------
    def round_signature(self, round_idx: int, state: OptState):
        if not self.policy.adaptive:
            return None
        # the FLeNS guard halves the trust scale on every rejected step:
        # a scale drop since the last round means the sketched model was
        # too coarse — ramp k (doubling, capped at k_max). At the scale
        # floor a reject no longer drops the value (max() pins it), but
        # sitting AT the floor still means the last round rejected — an
        # accept would have doubled away from it — so it counts too.
        # k never shrinks; the signature re-traces and re-bills.
        scale = float(state.get("scale", 1.0))
        rejected = scale < self._guard_scale or scale <= _MIN_TRUST_SCALE
        if round_idx > 0 and rejected:
            self.policy = self.policy.ramped()
        self._guard_scale = scale
        return ("flens_k", self.policy.k)

    # -- one communication round ----------------------------------------------
    def round(self, problem, state: OptState, key, comm=None) -> OptState:
        comm = NULL_COMM if comm is None else comm
        w, w_prev, beta = state["w"], state["w_prev"], state["beta"]
        t = state["t"]
        dim = problem.dim
        dtype = w.dtype

        # (1) Nesterov look-ahead (common knowledge: server-known w, w_prev)
        v = w + beta * (w - w_prev)

        # server broadcast: the look-ahead iterate clients compute on,
        # plus the O(1) sketch basis key (lossless by default — a
        # compressed key would desynchronize the shared basis). Fresh
        # schedules broadcast the per-round driver key; fixed/rotating
        # schedules broadcast the epoch key from the policy's own seed
        # stream, which is what keeps S identical across the rounds of
        # one epoch. The server keeps the exact v for its own step;
        # only client-side quantities see the decoded broadcast.
        v_bcast = comm.downlink("w", v)
        skey = comm.downlink("seed", self.policy.basis_key(key, t))

        # (2) the round's shared sketch, per the declared schedule
        s = self.policy.materialize(skey, dim, dtype=dtype)
        sst = s.apply(s.apply_t(jnp.eye(self.k, dtype=dtype)))  # S S^T (k,k)

        # client-side: local gradient + two-sided sketched Hessian
        gs = self._local_grads_at(problem, v_bcast)  # (m, M)
        a = self._local_hess_sqrt_at(problem, v_bcast)  # (m, n_shard, M)

        def client_sketch(aj):
            bj = s.apply(aj)  # A_j S^T : (n_shard, k)
            return bj.T @ bj  # (k, k), + lam S S^T added after aggregation

        h_sk = jax.vmap(client_sketch)(a)  # (m, k, k)
        sg = jax.vmap(s.apply)(gs)  # (m, k)

        # uplink: the k×k sketched Hessian (symmetric — sympack applies)
        # and the sketched gradient flow through the transport codecs.
        # EF eligibility flows from the schedule: both payloads live in
        # the basis S_t, so cross-round memory is meaningful exactly
        # when the basis persists across rounds (fixed/rotating
        # schedules) and meaningless for a fresh per-round draw. A
        # rotating schedule additionally resets the residual the round
        # the basis rotates — memory from the previous epoch lives in
        # the old basis.
        persistent = self.policy.basis_persistent()
        reset = self.policy.ef_reset(t)
        h_sk = comm.uplink("h_sk", h_sk, ef_eligible=persistent,
                           ef_reset=reset)
        sg = comm.uplink("sg", sg, ef_eligible=persistent, ef_reset=reset)

        # (3)+(4) server aggregation and sketched-subspace Newton step
        p = comm.weights(problem.client_weights)
        h_tilde = jnp.einsum("j,jab->ab", p, h_sk) + problem.lam * sst
        g_sk = jnp.einsum("j,jk->k", p, sg)
        eye_k = jnp.eye(self.k, dtype=dtype)
        delta_k = jnp.linalg.solve(h_tilde + self.lam_damp * eye_k, g_sk)
        delta = s.apply_t(delta_k)

        base = v if self.step_from == "v" else w
        scale = state.get("scale", jnp.asarray(1.0, dtype))
        w_next = base - scale * self.mu * delta

        if self.variant == "plus":
            gs_hat = comm.uplink("grad", gs)  # full gradient (O(M) uplink)
            g = jnp.einsum("j,jm->m", p, gs_hat)
            proj = s.apply_t(jnp.linalg.solve(sst, s.apply(g)))  # P_S g
            w_next = w_next - scale * state["eta"] * (g - proj)

        # Guarded step + adaptive momentum restart (O'Donoghue & Candes
        # flavour): clients piggyback their local loss (1 scalar of uplink),
        # so the server knows L(w_next). If the loss increased, the step is
        # rejected and the momentum killed for the next round — this is what
        # keeps the literal Assumption-A7 momentum (beta ~ 1) stable; see
        # EXPERIMENTS.md §Paper for the unguarded divergence measurement.
        if self.restart:
            # guard broadcast: clients evaluate the candidate iterate,
            # so the server ships w_next too — a guarded round's real
            # downlink is 2M + seed, not the M + 1 of the formula
            lv = problem.local_value(comm.downlink("w_next", w_next))
            lv = comm.uplink("loss", lv)  # the piggybacked scalar
        else:
            lv = problem.local_value(w_next)
        loss_next = jnp.sum(p * lv)
        if self.restart:
            # NaN-safe acceptance: a NaN loss is a rejected step, and the
            # stored loss must never become NaN (jnp.minimum would poison it)
            ok = loss_next <= state["loss"]
            w_out = jnp.where(ok, w_next, w)
            w_prev_out = jnp.where(ok, w, w_out)  # reject -> zero momentum
            loss_out = jnp.where(ok, loss_next, state["loss"])
            # backtracking across rounds: halve the trust scale on reject,
            # grow it back (capped at 1) on accept
            scale_out = jnp.where(ok, jnp.minimum(scale * 2.0, 1.0),
                                  jnp.maximum(scale * 0.5, _MIN_TRUST_SCALE))
        else:
            w_out, w_prev_out, loss_out = w_next, w, loss_next
            scale_out = scale
        out = {"w": w_out, "w_prev": w_prev_out, "beta": beta,
               "loss": loss_out, "scale": scale_out, "t": t + 1}
        if self.variant == "plus":
            out["eta"] = state["eta"]
        return out

    # Evaluated at the look-ahead point v (Algorithm 1 step 2 updates the
    # gradient/Hessian at v_t before communication).
    def _local_grads_at(self, problem, v):
        return problem.local_grad(v)

    def _local_hess_sqrt_at(self, problem, v):
        return problem.local_hess_sqrt(v)

    def uplink_floats(self, problem) -> int:
        extra = 1 if self.restart else 0  # piggybacked local-loss scalar
        if self.variant == "plus":
            return self.k * self.k + self.k + problem.dim + extra
        return self.k * self.k + self.k + extra

    def downlink_floats(self, problem) -> int:
        # a guarded round broadcasts BOTH the look-ahead model and the
        # candidate iterate w_next (clients evaluate the guard loss at
        # it) plus the O(1) sketch basis key — 2M + 1, matching the
        # measured wire (PR 4 found the old M + 1 undercounting by ~2x)
        if self.restart:
            return 2 * problem.dim + 1
        return problem.dim + 1  # model + sketch basis key
