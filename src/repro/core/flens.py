"""FLeNS — Federated Learning with Enhanced Nesterov-Newton Sketch.

The paper's algorithm (Algorithm 1), made dimensionally consistent as
described in DESIGN.md §1.1:

  1. Nesterov look-ahead       v_t = w_t + beta_t (w_t - w_{t-1})
  2. Every client j computes   g_j(v_t)   and the two-sided sketch
                               H~_j = S H_j(v_t) S^T  in R^{k x k},
     with the SAME per-round SRHT S (the server broadcasts the O(1) seed).
     Efficient form: H_j = A_j^T A_j + lam I  (A_j = sqrt-Hessian rows),
     so  H~_j = (A_j S^T)^T (A_j S^T) + lam * S S^T  — never materializes
     the M x M Hessian; cost O(n_j M log M) via the FWHT.
  3. Uplink per client: H~_j (k^2 floats) + S g_j (k floats)  ->  O(k^2).
  4. Server aggregates and takes the sketched-subspace Newton step
         delta = S^T (H~ + lam_damp I)^{-1} (S g),
         w_{t+1} = v_t - mu * delta.

``variant="plus"`` is the beyond-paper FLeNS+ of DESIGN.md §1.2: clients
additionally upload the raw gradient (O(M), the same uplink order as
FedAvg) and the server adds a first-order step in the orthogonal
complement of the sketch subspace, removing the sketch floor:
         w_{t+1} = v_t - mu * delta - eta * (g - P_S g),
with P_S the exact projector onto range(S^T).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm import NULL_COMM
from repro.core.base import FederatedOptimizer, OptState
from repro.core.federated import FederatedProblem
from repro.core.sketch import Sketch, make_sketch


class FLeNS(FederatedOptimizer):
    name = "flens"

    def __init__(
        self,
        k: int,
        mu: float = 1.0,
        beta: float | str = "paper",
        sketch: str = "srht",
        lam_damp: float = 1e-8,
        variant: str = "paper",  # "paper" | "plus"
        eta: float | None = None,  # complement step size (plus); None -> 1/L1
        step_from: str = "v",  # "v" (standard accelerated) | "w" (paper literal)
        restart: bool = True,  # function-value adaptive momentum restart
    ):
        self.k = k
        self.mu = mu
        self.beta = beta
        self.sketch = sketch
        self.lam_damp = lam_damp
        self.variant = variant
        self.eta = eta
        self.step_from = step_from
        self.restart = restart
        if variant == "plus":
            self.name = "flens_plus"

    # -- momentum schedule ---------------------------------------------------
    def _beta_value(self, problem: FederatedProblem, w0: jax.Array) -> float:
        if isinstance(self.beta, (int, float)):
            return float(self.beta)
        h = problem.global_hessian(w0)
        evals = jnp.linalg.eigvalsh(h)
        l1 = float(evals[-1])
        gam = float(jnp.maximum(evals[0], problem.lam))
        if self.beta == "paper":  # Assumption A7: (L1 - gamma)/(L1 + gamma)
            return (l1 - gam) / (l1 + gam)
        if self.beta == "sqrt":  # classical accelerated-GD schedule
            sl, sg = l1 ** 0.5, gam ** 0.5
            return (sl - sg) / (sl + sg)
        raise ValueError(f"unknown beta rule {self.beta!r}")

    def init(self, problem, w0):
        beta = self._beta_value(problem, w0)
        state = {
            "w": w0,
            "w_prev": w0,
            "beta": jnp.asarray(beta, w0.dtype),
            "loss": problem.global_value(w0),
            "scale": jnp.asarray(1.0, w0.dtype),
        }
        if self.variant == "plus":
            # eta lives in the state dict (NOT on the optimizer instance):
            # one optimizer object stays reusable across problems
            if self.eta is None:
                h = problem.global_hessian(w0)
                l1 = float(jnp.linalg.eigvalsh(h)[-1])
                eta = 1.0 / l1
            else:
                eta = float(self.eta)
            state["eta"] = jnp.asarray(eta, w0.dtype)
        return state

    # -- one communication round ----------------------------------------------
    def round(self, problem, state: OptState, key, comm=None) -> OptState:
        comm = NULL_COMM if comm is None else comm
        w, w_prev, beta = state["w"], state["w_prev"], state["beta"]
        dim = problem.dim
        dtype = w.dtype

        # (1) Nesterov look-ahead (common knowledge: server-known w, w_prev)
        v = w + beta * (w - w_prev)

        # server broadcast: the look-ahead iterate clients compute on,
        # plus the O(1) sketch seed (lossless by default — a compressed
        # seed would desynchronize the shared basis). The server keeps
        # the exact v for its own step; only client-side quantities see
        # the decoded broadcast.
        v_bcast = comm.downlink("w", v)
        key = comm.downlink("seed", key)

        # (2) per-round shared sketch, seed broadcast by the server
        s = make_sketch(key, self.sketch, self.k, dim, dtype=dtype)
        sst = s.apply(s.apply_t(jnp.eye(self.k, dtype=dtype)))  # S S^T (k,k)

        # client-side: local gradient + two-sided sketched Hessian
        gs = self._local_grads_at(problem, v_bcast)  # (m, M)
        a = self._local_hess_sqrt_at(problem, v_bcast)  # (m, n_shard, M)

        def client_sketch(aj):
            bj = s.apply(aj)  # A_j S^T : (n_shard, k)
            return bj.T @ bj  # (k, k), + lam S S^T added after aggregation

        h_sk = jax.vmap(client_sketch)(a)  # (m, k, k)
        sg = jax.vmap(s.apply)(gs)  # (m, k)

        # uplink: the k×k sketched Hessian (symmetric — sympack applies)
        # and the sketched gradient flow through the transport codecs.
        # Both live in the per-round sketch basis S_t, so they are not
        # EF-eligible: cross-round memory would mix incompatible bases.
        h_sk = comm.uplink("h_sk", h_sk, ef_eligible=False)
        sg = comm.uplink("sg", sg, ef_eligible=False)

        # (3)+(4) server aggregation and sketched-subspace Newton step
        p = comm.weights(problem.client_weights)
        h_tilde = jnp.einsum("j,jab->ab", p, h_sk) + problem.lam * sst
        g_sk = jnp.einsum("j,jk->k", p, sg)
        eye_k = jnp.eye(self.k, dtype=dtype)
        delta_k = jnp.linalg.solve(h_tilde + self.lam_damp * eye_k, g_sk)
        delta = s.apply_t(delta_k)

        base = v if self.step_from == "v" else w
        scale = state.get("scale", jnp.asarray(1.0, dtype))
        w_next = base - scale * self.mu * delta

        if self.variant == "plus":
            gs_hat = comm.uplink("grad", gs)  # full gradient (O(M) uplink)
            g = jnp.einsum("j,jm->m", p, gs_hat)
            proj = s.apply_t(jnp.linalg.solve(sst, s.apply(g)))  # P_S g
            w_next = w_next - scale * state["eta"] * (g - proj)

        # Guarded step + adaptive momentum restart (O'Donoghue & Candes
        # flavour): clients piggyback their local loss (1 scalar of uplink),
        # so the server knows L(w_next). If the loss increased, the step is
        # rejected and the momentum killed for the next round — this is what
        # keeps the literal Assumption-A7 momentum (beta ~ 1) stable; see
        # EXPERIMENTS.md §Paper for the unguarded divergence measurement.
        if self.restart:
            # guard broadcast: clients evaluate the candidate iterate,
            # so the server ships w_next too — a guarded round's real
            # downlink is 2M + seed, not the M + 1 of the formula
            lv = problem.local_value(comm.downlink("w_next", w_next))
            lv = comm.uplink("loss", lv)  # the piggybacked scalar
        else:
            lv = problem.local_value(w_next)
        loss_next = jnp.sum(p * lv)
        if self.restart:
            # NaN-safe acceptance: a NaN loss is a rejected step, and the
            # stored loss must never become NaN (jnp.minimum would poison it)
            ok = loss_next <= state["loss"]
            w_out = jnp.where(ok, w_next, w)
            w_prev_out = jnp.where(ok, w, w_out)  # reject -> zero momentum
            loss_out = jnp.where(ok, loss_next, state["loss"])
            # backtracking across rounds: halve the trust scale on reject,
            # grow it back (capped at 1) on accept
            scale_out = jnp.where(ok, jnp.minimum(scale * 2.0, 1.0),
                                  jnp.maximum(scale * 0.5, 1.0 / 64.0))
        else:
            w_out, w_prev_out, loss_out = w_next, w, loss_next
            scale_out = scale
        out = {"w": w_out, "w_prev": w_prev_out, "beta": beta,
               "loss": loss_out, "scale": scale_out}
        if self.variant == "plus":
            out["eta"] = state["eta"]
        return out

    # Evaluated at the look-ahead point v (Algorithm 1 step 2 updates the
    # gradient/Hessian at v_t before communication).
    def _local_grads_at(self, problem, v):
        return problem.local_grad(v)

    def _local_hess_sqrt_at(self, problem, v):
        return problem.local_hess_sqrt(v)

    def uplink_floats(self, problem) -> int:
        extra = 1 if self.restart else 0  # piggybacked local-loss scalar
        if self.variant == "plus":
            return self.k * self.k + self.k + problem.dim + extra
        return self.k * self.k + self.k + extra

    def downlink_floats(self, problem) -> int:
        return problem.dim + 1  # model + sketch seed
