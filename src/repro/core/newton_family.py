"""Newton-type federated baselines from the paper's Table I.

* FedNewton           — exact aggregated Hessian (O(M^2) uplink)
* DistributedNewton   — GIANT-style averaged local-Newton directions
* LocalNewton         — L local Newton iterations, average weights
* FedNew              — one-pass ADMM direction (Elgabli et al. 2022)
* FedNL               — rank-1 compressed Hessian learning (Safaryan 2022)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm import NULL_COMM
from repro.core.base import FederatedOptimizer, OptState
from repro.core.sketch_policy import SketchPolicy


class FedNewton(FederatedOptimizer):
    """Exact federated Newton: aggregate full local Hessians + gradients."""

    name = "fednewton"

    def __init__(self, mu: float = 1.0):
        self.mu = mu

    def round(self, problem, state: OptState, key, comm=None) -> OptState:
        comm = NULL_COMM if comm is None else comm
        w = state["w"]
        # clients differentiate at the decoded broadcast; the server
        # steps from its own exact iterate
        w_bcast = comm.downlink("w", w)
        gs = comm.uplink("grad", problem.local_grad(w_bcast))
        hs = comm.uplink("hess", problem.local_hessian(w_bcast))
        p = comm.weights(problem.client_weights)
        g = jnp.einsum("j,jm->m", p, gs)
        h = jnp.einsum("j,jab->ab", p, hs)
        return {"w": w - self.mu * jnp.linalg.solve(h, g)}

    def uplink_floats(self, problem) -> int:
        return problem.dim * problem.dim + problem.dim


class DistributedNewton(FederatedOptimizer):
    """GIANT-style (Ghosh et al. 2020): average of H_j^{-1} g_global.

    Two-phase round: (1) clients upload local gradients, server broadcasts
    the global gradient; (2) clients return local-Newton directions
    H_j^{-1} g, server averages. Uplink 2M per round.
    """

    name = "distributed_newton"

    def __init__(self, mu: float = 1.0):
        self.mu = mu

    def round(self, problem, state: OptState, key, comm=None) -> OptState:
        comm = NULL_COMM if comm is None else comm
        w = state["w"]
        w_bcast = comm.downlink("w", w)
        p = comm.weights(problem.client_weights)
        # phase 1: gradients up, global gradient broadcast back — a
        # genuine second O(M) downlink this round is billed for
        gs = comm.uplink("grad", problem.local_grad(w_bcast))
        g = comm.downlink("grad", jnp.einsum("j,jm->m", p, gs))
        # phase 2: local-Newton directions up
        hs = problem.local_hessian(w_bcast)  # (m, M, M)
        dirs = jax.vmap(lambda h: jnp.linalg.solve(h, g))(hs)
        dirs = comm.uplink("dir", dirs)
        d = jnp.einsum("j,jm->m", p, dirs)
        return {"w": w - self.mu * d}

    def uplink_floats(self, problem) -> int:
        return 2 * problem.dim

    def downlink_floats(self, problem) -> int:
        # model + the global-gradient broadcast of phase 1 — 2M, matching
        # the measured wire (PR 4 found the inherited M undercounting 2x)
        return 2 * problem.dim


class LocalNewton(FederatedOptimizer):
    """Gupta et al. 2021: L local Newton iterations, average the weights."""

    name = "local_newton"

    def __init__(self, mu: float = 1.0, local_iters: int = 2):
        self.mu = mu
        self.local_iters = local_iters

    def round(self, problem, state: OptState, key, comm=None) -> OptState:
        comm = NULL_COMM if comm is None else comm
        # clients iterate from the decoded broadcast
        w = comm.downlink("w", state["w"])
        eye = jnp.eye(problem.dim, dtype=problem.X.dtype)

        def client(Xj, yj, mj):
            nj = jnp.sum(mj)

            def local_grad(wl):
                if problem.objective.name == "logistic":
                    margins = yj * (Xj @ wl)
                    s = jax.nn.sigmoid(-margins) * mj
                    return -(Xj.T @ (s * yj)) / nj + problem.lam * wl
                r = (Xj @ wl - yj) * mj
                return Xj.T @ r / nj + problem.lam * wl

            def local_hess(wl):
                if problem.objective.name == "logistic":
                    margins = yj * (Xj @ wl)
                    pr = jax.nn.sigmoid(margins)
                    d = pr * (1 - pr) * mj
                else:
                    d = mj
                return (Xj.T * d) @ Xj / nj + problem.lam * eye

            def body(wl, _):
                step = jnp.linalg.solve(local_hess(wl), local_grad(wl))
                return wl - self.mu * step, None

            wl, _ = jax.lax.scan(body, w, None, length=self.local_iters)
            return wl

        w_locals = jax.vmap(client)(problem.X, problem.y, problem.mask)
        w_locals = comm.uplink("w_local", w_locals)
        p = comm.weights(problem.client_weights)
        return {"w": jnp.einsum("j,jm->m", p, w_locals)}

    def uplink_floats(self, problem) -> int:
        return problem.dim


class FedNew(FederatedOptimizer):
    """Elgabli et al. 2022: one-pass ADMM for the Newton direction.

    Clients maintain direction d_j and dual y_j; each round performs one
    ADMM sweep on  min_d 0.5 d^T H_j d - g_j^T d  s.t. d_j = d_bar:
        d_j   <- (H_j + rho I)^{-1} (g_j + rho d_bar - y_j)
        d_bar <- weighted mean of d_j
        y_j   <- y_j + alpha (d_j - d_bar)
    and the server steps  w <- w - mu d_bar.
    """

    name = "fednew"
    # ADMM directions/duals are dense (m, dim) state carried across
    # rounds; population mode (sampled cohorts) would leave unsampled
    # clients' duals silently stale, so run_rounds rejects it
    per_client_state = True

    def __init__(self, mu: float = 1.0, rho: float = 0.1, alpha: float = 0.25):
        self.mu = mu
        self.rho = rho
        self.alpha = alpha

    def init(self, problem, w0):
        m, dim = problem.m, problem.dim
        return {
            "w": w0,
            "d_bar": jnp.zeros((dim,), w0.dtype),
            "duals": jnp.zeros((m, dim), w0.dtype),
        }

    def round(self, problem, state: OptState, key, comm=None) -> OptState:
        comm = NULL_COMM if comm is None else comm
        w, d_bar, duals = state["w"], state["d_bar"], state["duals"]
        # clients receive the model AND the averaged direction — two
        # O(M) broadcasts per ADMM sweep, both billed
        w_bcast = comm.downlink("w", w)
        d_bar_bcast = comm.downlink("d_bar", d_bar)
        gs = problem.local_grad(w_bcast)  # (m, M)
        hs = problem.local_hessian(w_bcast)  # (m, M, M)
        eye = jnp.eye(problem.dim, dtype=w.dtype)

        def client(hj, gj, yj):
            rhs = gj + self.rho * d_bar_bcast - yj
            return jnp.linalg.solve(hj + self.rho * eye, rhs)

        ds = jax.vmap(client)(hs, gs, duals)
        ds_wire = comm.uplink("dir", ds)  # server sees the decoded copy...
        p = comm.weights(problem.client_weights)
        d_new = jnp.einsum("j,jm->m", p, ds_wire)
        # ...but each client advances its dual from its own EXACT d_j —
        # only delivering clients observe d_bar and update at all
        duals = comm.where_delivered(
            duals + self.alpha * (ds - d_new[None]), duals)
        return {"w": w - self.mu * d_new, "d_bar": d_new, "duals": duals}

    def uplink_floats(self, problem) -> int:
        return problem.dim

    def downlink_floats(self, problem) -> int:
        # model + the averaged-direction broadcast d_bar — 2M per ADMM
        # sweep, matching the measured wire (PR 4: old M undercounted 2x)
        return 2 * problem.dim


class FedNL(FederatedOptimizer):
    """Safaryan et al. 2022: compressed Hessian learning.

    Server maintains a Hessian model B; clients send a rank-1 (top
    eigenpair, by power iteration) compression of (H_j(w_t) - B_t) plus
    their gradient; B is updated with the aggregated compressed
    differences and the step uses (B + l_reg I)^{-1}.
    """

    name = "fednl"

    # the rank-1 eigenbasis is re-derived by power iteration every round:
    # a per-round basis in SketchPolicy terms, so EF eligibility for the
    # hess_delta payload flows from the same basis_persistent predicate
    # the sketched optimizers use (and stays False by construction)
    _eig_basis = SketchPolicy.per_round("rank1-eig")

    def __init__(self, mu: float = 1.0, power_iters: int = 16, l_reg: float = 1e-3):
        self.mu = mu
        self.power_iters = power_iters
        self.l_reg = l_reg

    def init(self, problem, w0):
        b0 = problem.global_hessian(w0)
        return {"w": w0, "B": b0}

    def _rank1_compress(self, delta: jax.Array, key: jax.Array) -> jax.Array:
        """Top eigenpair of the symmetric difference via power iteration."""
        dim = delta.shape[-1]
        v = jax.random.normal(key, (dim,), delta.dtype)
        v = v / jnp.linalg.norm(v)

        def body(v, _):
            v = delta @ v
            return v / (jnp.linalg.norm(v) + 1e-30), None

        v, _ = jax.lax.scan(body, v, None, length=self.power_iters)
        lam = v @ (delta @ v)
        return lam * jnp.outer(v, v)

    def round(self, problem, state: OptState, key, comm=None) -> OptState:
        comm = NULL_COMM if comm is None else comm
        w, B = state["w"], state["B"]
        # clients differentiate at the decoded broadcast; B needs no
        # broadcast — clients mirror it from the same compressed updates
        # the server applies (standard FedNL bookkeeping)
        w_bcast = comm.downlink("w", w)
        p = comm.weights(problem.client_weights)
        gs = comm.uplink("grad", problem.local_grad(w_bcast))
        g = jnp.einsum("j,jm->m", p, gs)
        hs = problem.local_hessian(w_bcast)  # (m, M, M)
        keys = jax.random.split(key, problem.m)
        comps = jax.vmap(lambda h, k: self._rank1_compress(h - B, k))(hs, keys)
        # native wire format: one (value, vector) eigenpair per client,
        # not the materialized (M, M) outer product. A compensated
        # decode would not be rank-1 (breaking that wire format), and
        # the B update below IS Hessian-space error feedback already —
        # generic EF would silently change the algorithm. Both facts are
        # captured by the per-round eigenbasis never persisting.
        comps = comm.uplink("hess_delta", comps,
                            wire_shape=(problem.dim + 1,),
                            ef_eligible=self._eig_basis.basis_persistent())
        B = B + jnp.einsum("j,jab->ab", p, comps)
        # PSD safeguard: project to symmetric + ridge
        B = 0.5 * (B + B.T)
        step = jnp.linalg.solve(B + self.l_reg * jnp.eye(problem.dim, dtype=w.dtype), g)
        return {"w": w - self.mu * step, "B": B}

    def uplink_floats(self, problem) -> int:
        # rank-1 eigenpair (M + 1) + gradient (M)
        return 2 * problem.dim + 1
