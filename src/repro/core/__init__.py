"""Core library: the paper's contribution (FLeNS) + every Table-I baseline."""
from repro.core.base import (
    FederatedOptimizer,
    History,
    build_round,
    root_key,
    run_rounds,
)
from repro.core.federated import (
    ClientPopulation,
    DatasetPopulation,
    FederatedProblem,
    SyntheticPopulation,
    make_problem,
    newton_solve,
)
from repro.core.first_order import FedAvg, FedProx
from repro.core.flens import FLeNS
from repro.core.losses import OBJECTIVES, least_squares, logistic
from repro.core.newton_family import (
    DistributedNewton,
    FedNew,
    FedNewton,
    FedNL,
    LocalNewton,
)
from repro.core.sketch import Sketch, effective_dimension, make_sketch, sketch_psd
from repro.core.sketch_policy import SketchPolicy, as_policy
from repro.core.sketched import FedNDES, FedNS


def make_optimizer(name: str, **kw) -> FederatedOptimizer:
    """Factory over every implemented algorithm (Table I)."""
    registry = {
        "fedavg": FedAvg,
        "fedprox": FedProx,
        "fednewton": FedNewton,
        "distributed_newton": DistributedNewton,
        "local_newton": LocalNewton,
        "fednew": FedNew,
        "fednl": FedNL,
        "fedns": FedNS,
        "fedndes": FedNDES,
        "flens": FLeNS,
        "flens_plus": lambda **k: FLeNS(variant="plus", **k),
    }
    return registry[name](**kw)


ALGORITHMS = (
    "fedavg",
    "fedprox",
    "fednewton",
    "distributed_newton",
    "local_newton",
    "fednew",
    "fednl",
    "fedns",
    "fedndes",
    "flens",
    "flens_plus",
)
