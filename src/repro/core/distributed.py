"""Mesh-resident FLeNS: clients as data-parallel mesh slices.

The simulator in ``core/`` vmaps over a client axis on one host. This
module runs the SAME round on a real device mesh: every ``(pod, data)``
slice holds one client's shard, local sketches are computed on-device,
and the server aggregation is a ``psum`` over the client axes — the
O(k²) wire pattern shown in EXPERIMENTS §Dry-run, now as a usable
training API.

Numerical contract (tested in tests/test_distributed_flens.py): one
``distributed_round`` on an m-slice mesh == one simulator round with the
same m clients, same sketch seed — exactly, to float tolerance.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core.losses import Objective
from repro.core.sketch_policy import SketchPolicy, as_policy


@dataclasses.dataclass(frozen=True)
class DistributedFLeNS:
    """FLeNS with clients distributed over mesh axes.

    The sketch basis is keyed by the broadcast int32 seed through a
    ``SketchPolicy`` (the seed doubles as the round index, so
    ``sketch="srht:rotate=R"`` / ``"srht:fixed"`` schedule the basis
    exactly like the simulator's optimizers); `round_fn()` returns a
    jit-compiled step.
    """

    mesh: Mesh
    objective: Objective
    dim: int
    k: int
    lam: float
    mu: float = 1.0
    beta: float = 0.0
    lam_damp: float = 1e-8
    client_axes: tuple = ("pod", "data")
    sketch: "str | SketchPolicy" = "srht"

    def _axes(self):
        return tuple(a for a in self.client_axes if a in self.mesh.axis_names)

    # -- client-local math ---------------------------------------------------
    def _local_grad(self, X, y, w):
        if self.objective.name == "logistic":
            margins = y * (X @ w)
            s = jax.nn.sigmoid(-margins)
            return -(X.T @ (s * y)) / X.shape[0] + self.lam * w
        r = X @ w - y
        return X.T @ r / X.shape[0] + self.lam * w

    def _local_hess_sqrt(self, X, y, w):
        if self.objective.name == "logistic":
            margins = y * (X @ w)
            p = jax.nn.sigmoid(margins)
            d = p * (1 - p)
        else:
            d = jnp.ones_like(y)
        return X * jnp.sqrt(d / X.shape[0])[:, None]

    # -- one communication round ------------------------------------------------
    def round_fn(self):
        axes = self._axes()
        dim, k = self.dim, self.k
        policy = as_policy(self.sketch, k=k)
        if policy.adaptive:
            raise ValueError(
                "DistributedFLeNS compiles one fixed-shape step: adaptive-k "
                f"sketch policies ({policy.spec()!r}) cannot resize it; "
                "use a constant-k fresh/fixed/rotating schedule")

        def body(X, y, w, w_prev, seed):
            w = w[0]
            w_prev = w_prev[0]
            v = w + self.beta * (w - w_prev)
            # the broadcast seed is the round index: fresh schedules key
            # the basis from PRNGKey(seed) directly (the pre-policy
            # wire contract), fixed/rotating ones from their epoch
            sketch = policy.sample(jax.random.PRNGKey(seed[0]), seed[0],  # noqa: RA001 — wire contract: the broadcast round seed IS the key material every client re-derives
                                   dim, dtype=w.dtype)
            sst = sketch.apply(sketch.apply_t(jnp.eye(k, dtype=w.dtype)))

            a = self._local_hess_sqrt(X, y, v)
            b = sketch.apply(a)  # (n_loc, k)
            h_sk = b.T @ b  # k x k — the uplink payload
            g_sk = sketch.apply(self._local_grad(X, y, v))

            # server aggregation == psum over the client axes
            h_sk = jax.lax.pmean(h_sk, axes)
            g_sk = jax.lax.pmean(g_sk, axes)

            h_tilde = h_sk + self.lam * sst + self.lam_damp * jnp.eye(
                k, dtype=w.dtype)
            delta = sketch.apply_t(jnp.linalg.solve(h_tilde, g_sk))
            w_next = v - self.mu * delta
            return w_next[None], w[None]

        spec_data = P(self._axes() or None, None)
        spec_y = P(self._axes() or None)
        rep = P(None, None)

        wrapped = shard_map(
            body,
            mesh=self.mesh,
            in_specs=(spec_data, spec_y, rep, rep, P(None)),
            out_specs=(rep, rep),
            check_vma=False,
        )

        def step(X, y, w, w_prev, seed):
            w2, wp2 = wrapped(X, y, w[None], w_prev[None],
                              jnp.asarray(seed, jnp.int32)[None])
            return w2[0], wp2[0]

        return jax.jit(step)

    # -- data placement helper ----------------------------------------------------
    def shard_data(self, X, y):
        """Place the global dataset with rows sharded over the client axes."""
        axes = self._axes()
        sx = NamedSharding(self.mesh, P(axes or None, None))
        sy = NamedSharding(self.mesh, P(axes or None))
        return jax.device_put(X, sx), jax.device_put(y, sy)


def run_distributed(
    dist: DistributedFLeNS, X, y, w0, rounds: int, seed0: int = 0
):
    """Convenience driver: runs `rounds` rounds, returns the iterate path."""
    step = dist.round_fn()
    Xs, ys = dist.shard_data(X, y)
    w, w_prev = w0, w0
    ws = [w0]
    for t in range(rounds):
        w, w_prev = step(Xs, ys, w, w_prev, seed0 + t)
        ws.append(w)
    return w, ws
