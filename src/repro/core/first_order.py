"""First-order federated baselines: FedAvg and FedProx.

Both transmit only the locally-updated model (O(M) uplink) and average on
the server — the sublinear-rate baselines of the paper's Table I.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm import NULL_COMM
from repro.core.base import FederatedOptimizer, OptState
from repro.core.federated import FederatedProblem


def _local_grad_at(problem: FederatedProblem, Xj, yj, mj, w):
    """Gradient of one client's local objective at w (masked rows)."""
    nj = jnp.sum(mj)
    if problem.objective.name == "logistic":
        margins = yj * (Xj @ w)
        s = jax.nn.sigmoid(-margins) * mj
        return -(Xj.T @ (s * yj)) / nj + problem.lam * w
    r = (Xj @ w - yj) * mj
    return Xj.T @ r / nj + problem.lam * w


class FedAvg(FederatedOptimizer):
    """McMahan et al. 2017 — E local full-batch GD steps, weighted average."""

    name = "fedavg"

    def __init__(self, lr: float = 1.0, local_steps: int = 5):
        self.lr = lr
        self.local_steps = local_steps

    def round(self, problem, state: OptState, key, comm=None) -> OptState:
        comm = NULL_COMM if comm is None else comm
        # clients start their local runs from the decoded broadcast
        w = comm.downlink("w", state["w"])

        def client(Xj, yj, mj):
            def body(wl, _):
                g = _local_grad_at(problem, Xj, yj, mj, wl)
                return wl - self.lr * g, None

            wl, _ = jax.lax.scan(body, w, None, length=self.local_steps)
            return wl

        w_locals = jax.vmap(client)(problem.X, problem.y, problem.mask)
        w_locals = comm.uplink("w_local", w_locals)
        p = comm.weights(problem.client_weights)
        return {"w": jnp.einsum("j,jm->m", p, w_locals)}

    def uplink_floats(self, problem) -> int:
        return problem.dim


class FedProx(FedAvg):
    """Li et al. 2020 — FedAvg with a proximal term (mu/2)||w - w_t||^2."""

    name = "fedprox"

    def __init__(self, lr: float = 1.0, local_steps: int = 5, mu_prox: float = 0.1):
        super().__init__(lr=lr, local_steps=local_steps)
        self.mu_prox = mu_prox

    def round(self, problem, state: OptState, key, comm=None) -> OptState:
        comm = NULL_COMM if comm is None else comm
        # the proximal anchor is the same decoded broadcast clients
        # start from — a client never sees the server's exact iterate
        w = comm.downlink("w", state["w"])

        def client(Xj, yj, mj):
            def body(wl, _):
                g = _local_grad_at(problem, Xj, yj, mj, wl)
                g = g + self.mu_prox * (wl - w)
                return wl - self.lr * g, None

            wl, _ = jax.lax.scan(body, w, None, length=self.local_steps)
            return wl

        w_locals = jax.vmap(client)(problem.X, problem.y, problem.mask)
        w_locals = comm.uplink("w_local", w_locals)
        p = comm.weights(problem.client_weights)
        return {"w": jnp.einsum("j,jm->m", p, w_locals)}
