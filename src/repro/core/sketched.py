"""Sketched Newton-type federated baselines: FedNS and FedNDES (Li 2024).

FedNS: each client sketches its Hessian *square root* on the data axis —
uploads ``S_j A_j`` of size (k, M) — so the server reconstructs
``H ~= sum_j p_j (S_j A_j)^T (S_j A_j) + lam I``. Uplink O(kM).

FedNDES: FedNS with the sketch size chosen adaptively from the empirical
effective dimension d_lambda of the global Hessian (dimension-efficient
sketching), keeping the same O(kM) uplink at a smaller k.

Both draw their per-client data-axis sketches through a ``SketchPolicy``
(``repro.core.sketch_policy``): the default ``"srht"`` redraws every
round (bit-identical to the pre-policy code), while ``"srht:fixed"`` /
``"srht:rotate=R"`` persist each client's basis across rounds — which is
what makes the O(kM) ``sa`` payload eligible for error feedback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm import NULL_COMM
from repro.core.base import FederatedOptimizer, OptState
from repro.core.sketch_policy import (
    SketchPolicy,
    adaptive_k,
    as_policy,
    loss_effective_dimension,
)


class FedNS(FederatedOptimizer):
    """Federated Newton sketch with per-client data-axis sketches."""

    name = "fedns"

    def __init__(self, k: int, mu: float = 1.0,
                 sketch: "str | SketchPolicy" = "srht"):
        self.policy = as_policy(sketch, k=k)
        if self.policy.adaptive:
            # nothing here ramps k mid-run (the guard signal is a FLeNS
            # construct); silently running constant-k would misrepresent
            # the request. FedNDES provides effective-dimension sizing.
            raise ValueError(
                f"{type(self).__name__} does not support adaptive-k sketch "
                f"policies ({self.policy.spec()!r}); use FLeNS for the "
                f"guard-driven ramp or FedNDES for effective-dimension "
                f"sizing")
        self.mu = mu

    @property
    def k(self) -> int:
        return self.policy.k

    @k.setter
    def k(self, value: int) -> None:
        self.policy = self.policy.with_k(value)

    def init(self, problem, w0):
        return {"w": w0, "t": jnp.asarray(0, jnp.int32)}

    def round(self, problem, state: OptState, key, comm=None) -> OptState:
        comm = NULL_COMM if comm is None else comm
        w, t = state["w"], state["t"]
        # clients sketch at the decoded broadcast (per-client data-axis
        # sketches are drawn locally — no basis broadcast needed); the
        # server steps from its exact iterate
        w_bcast = comm.downlink("w", w)
        p = comm.weights(problem.client_weights)
        gs = comm.uplink("grad", problem.local_grad(w_bcast))
        g = jnp.einsum("j,jm->m", p, gs)
        a = problem.local_hess_sqrt(w_bcast)  # (m, n_shard, M)
        n_shard = a.shape[1]
        # schedule-aware basis stream, split per client: fresh schedules
        # ride the per-round key; fixed/rotating schedules hold each
        # client's S_j constant within a rotation epoch
        keys = jax.random.split(self.policy.basis_key(key, t), problem.m)

        def client(aj, kj):
            s = self.policy.materialize(kj, n_shard, dtype=aj.dtype)
            # S acts on the data axis: (k, n) @ (n, M) -> (k, M)
            return s.apply(aj.T).T

        sa = jax.vmap(client)(a, keys)  # (m, k, M)
        # EF eligibility flows from the schedule: a fresh data-axis
        # basis makes cross-round memory meaningless, a fixed/rotating
        # one keeps the (k, M) payload in a stable coordinate system —
        # with the residual reset whenever a rotation draws a new basis
        sa = comm.uplink("sa", sa,
                         ef_eligible=self.policy.basis_persistent(),
                         ef_reset=self.policy.ef_reset(t))
        h_tilde = jnp.einsum("j,jka,jkb->ab", p, sa, sa)
        h_tilde = h_tilde + problem.lam * jnp.eye(problem.dim, dtype=w.dtype)
        return {"w": w - self.mu * jnp.linalg.solve(h_tilde, g), "t": t + 1}

    def uplink_floats(self, problem) -> int:
        return self.k * problem.dim + problem.dim


class FedNDES(FedNS):
    """FedNS with dimension-efficient (effective-dimension) sketch size.

    ``init`` estimates d_lambda at w0 and sets k = ceil(c * d_lambda),
    clipped to [k_min, n_shard]; thereafter behaves like FedNS.
    (In deployment the estimate comes from a preliminary sketched round;
    the simulator computes it exactly — same k, zero extra rounds.)
    """

    name = "fedndes"

    def __init__(self, mu: float = 1.0, sketch: "str | SketchPolicy" = "srht",
                 c: float = 2.0, k_min: int = 8):
        super().__init__(k=k_min, mu=mu, sketch=sketch)
        self.c = c
        self.k_min = k_min

    def init(self, problem, w0):
        d_lam = loss_effective_dimension(problem, w0)
        n_shard = problem.X.shape[1]
        self.k = adaptive_k(d_lam, c=self.c, k_min=self.k_min, k_max=n_shard)
        return super().init(problem, w0)
