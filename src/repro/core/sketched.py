"""Sketched Newton-type federated baselines: FedNS and FedNDES (Li 2024).

FedNS: each client sketches its Hessian *square root* on the data axis —
uploads ``S_j A_j`` of size (k, M) — so the server reconstructs
``H ~= sum_j p_j (S_j A_j)^T (S_j A_j) + lam I``. Uplink O(kM).

FedNDES: FedNS with the sketch size chosen adaptively from the empirical
effective dimension d_lambda of the global Hessian (dimension-efficient
sketching), keeping the same O(kM) uplink at a smaller k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.comm import NULL_COMM
from repro.core.base import FederatedOptimizer, OptState
from repro.core.sketch import effective_dimension, make_sketch


class FedNS(FederatedOptimizer):
    """Federated Newton sketch with per-client data-axis sketches."""

    name = "fedns"

    def __init__(self, k: int, mu: float = 1.0, sketch: str = "srht"):
        self.k = k
        self.mu = mu
        self.sketch = sketch

    def round(self, problem, state: OptState, key, comm=None) -> OptState:
        comm = NULL_COMM if comm is None else comm
        w = state["w"]
        # clients sketch at the decoded broadcast (per-client data-axis
        # sketches are drawn locally — no basis broadcast needed); the
        # server steps from its exact iterate
        w_bcast = comm.downlink("w", w)
        p = comm.weights(problem.client_weights)
        gs = comm.uplink("grad", problem.local_grad(w_bcast))
        g = jnp.einsum("j,jm->m", p, gs)
        a = problem.local_hess_sqrt(w_bcast)  # (m, n_shard, M)
        n_shard = a.shape[1]
        keys = jax.random.split(key, problem.m)

        def client(aj, kj):
            s = make_sketch(kj, self.sketch, self.k, n_shard, dtype=aj.dtype)
            # S acts on the data axis: (k, n) @ (n, M) -> (k, M)
            return s.apply(aj.T).T

        sa = jax.vmap(client)(a, keys)  # (m, k, M)
        # per-round data-axis sketch basis: not EF-eligible (memory
        # across rounds would mix incompatible sketch draws)
        sa = comm.uplink("sa", sa, ef_eligible=False)
        h_tilde = jnp.einsum("j,jka,jkb->ab", p, sa, sa)
        h_tilde = h_tilde + problem.lam * jnp.eye(problem.dim, dtype=w.dtype)
        return {"w": w - self.mu * jnp.linalg.solve(h_tilde, g)}

    def uplink_floats(self, problem) -> int:
        return self.k * problem.dim + problem.dim


class FedNDES(FedNS):
    """FedNS with dimension-efficient (effective-dimension) sketch size.

    ``init`` estimates d_lambda at w0 and sets k = ceil(c * d_lambda),
    clipped to [k_min, n_shard]; thereafter behaves like FedNS.
    (In deployment the estimate comes from a preliminary sketched round;
    the simulator computes it exactly — same k, zero extra rounds.)
    """

    name = "fedndes"

    def __init__(self, mu: float = 1.0, sketch: str = "srht", c: float = 2.0,
                 k_min: int = 8):
        super().__init__(k=k_min, mu=mu, sketch=sketch)
        self.c = c
        self.k_min = k_min

    def init(self, problem, w0):
        # effective dimension of the *loss* Hessian (exclude the ridge term,
        # which would inflate d_lam by ~dim/2)
        h = problem.global_hessian(w0)
        h_loss = h - problem.lam * jnp.eye(problem.dim, dtype=h.dtype)
        d_lam = float(effective_dimension(h_loss, problem.lam))
        n_shard = problem.X.shape[1]
        self.k = int(min(max(self.k_min, int(jnp.ceil(self.c * d_lam))), n_shard))
        return {"w": w0}
