"""Declarative sketch schedules: the ``SketchPolicy`` protocol.

The paper's central object — the Hessian sketch — used to be a
stringly-typed ``sketch: str`` kind plus an ad-hoc ``make_sketch`` call
per round, which hard-wired one schedule (a fresh basis every round)
into every optimizer. A fresh basis is the right default for embedding
quality, but it permanently locks sketch payloads out of error
feedback: EF memory lives in the payload's coordinate system, and a
basis that is redrawn each round makes cross-round memory meaningless
(the exact ``uplink(..., ef_eligible=False)`` opt-outs PR 2 had to
scatter through the optimizers).

``SketchPolicy`` promotes the sketch to a first-class scheduled
operator, parsed from a compact spec grammar::

    "srht"                      fresh SRHT basis every round (the default)
    "srht:fixed"                one basis for the whole trajectory
    "srht:rotate=8"             rotate the basis every 8 rounds
    "gaussian:adaptive"         adaptive-k (effective-dimension start,
                                guard-driven ramp within (k_min, k_max))
    "sjlt:rotate=4,seed=3"      options compose; ``seed`` picks the
                                basis stream for fixed/rotating bases
    "srht:adaptive=8..64"       explicit adaptive bounds k_min..k_max

The policy answers the three questions a sketched optimizer needs:

  * ``sample(key, round_idx, dim, dtype) -> Sketch`` — the operator for
    this round. Fresh schedules ride the per-round driver key (bit
    identical to the pre-policy code); fixed/rotating schedules derive
    the basis from the policy's own ``seed`` stream at the current
    rotation epoch, so the basis survives across rounds by
    construction.
  * ``basis_persistent(round_idx=None)`` — does the basis at
    ``round_idx`` carry into the next round? With no argument, the
    schedule-level answer (any cross-round persistence at all) — the
    single predicate EF eligibility now flows from at every uplink call
    site. Adaptive-k policies always answer False: a k change resizes
    the payload, and EF memory cannot survive a shape change.
  * the k-schedule — constant (``k`` bound at construction), or
    adaptive: ``resolved(d_eff, cap)`` starts k at ``ceil(c * d_eff)``
    clipped into ``(k_min, k_max)`` (FedNDES-style dimension-efficient
    sizing) and ``ramped()`` doubles it toward ``k_max`` when the
    driver observes the FLeNS guard rejecting steps (the sketch was too
    coarse). k changes are host-side static decisions: the round driver
    re-traces and re-bills through ``FederatedOptimizer.round_signature``.

Policies are immutable; ``with_k`` / ``ramped`` / ``resolved`` return
updated copies, so one optimizer instance can re-bind per problem
without leaking state across runs.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.sketch import Sketch, effective_dimension, make_sketch

KINDS = ("srht", "gaussian", "sjlt")
SCHEDULES = ("fresh", "fixed", "rotate")


def adaptive_k(d_eff: float, *, c: float, k_min: int, k_max: int) -> int:
    """Dimension-efficient sketch size: ceil(c * d_eff) clipped into
    [k_min, k_max] — the FedNDES rule, shared so every adaptive consumer
    sizes k identically."""
    return int(min(max(k_min, int(math.ceil(c * float(d_eff)))), k_max))


def loss_effective_dimension(problem, w0) -> float:
    """Effective dimension of the LOSS Hessian at ``w0`` — the ridge
    term is excluded (it would inflate d_lambda by ~dim/2). The one
    d_eff recipe every adaptive consumer (FLeNS adaptive-k start,
    FedNDES sizing) shares."""
    h = problem.global_hessian(w0)
    h_loss = h - problem.lam * jnp.eye(problem.dim, dtype=h.dtype)
    return float(effective_dimension(h_loss, problem.lam))


@dataclasses.dataclass(frozen=True)
class SketchPolicy:
    """A parsed, immutable sketch schedule (see module docstring)."""

    kind: str = "srht"
    schedule: str = "fresh"
    period: int = 0  # rotation period in rounds (schedule == "rotate")
    k: "int | None" = None  # current sketch size (None until bound)
    adaptive: bool = False
    k_min: "int | None" = None  # adaptive bounds; resolved() fills defaults
    k_max: "int | None" = None
    c: float = 2.0  # adaptive: k0 ~ ceil(c * d_eff)
    seed: int = 0  # basis stream for fixed/rotating schedules

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"unknown sketch schedule {self.schedule!r}; "
                f"want one of {SCHEDULES}")
        if self.schedule == "rotate" and self.period < 1:
            raise ValueError(
                f"rotate schedule needs a period >= 1, got {self.period}")
        if (self.k_min is not None and self.k_max is not None
                and self.k_min > self.k_max):
            raise ValueError(
                f"adaptive bounds inverted: k_min={self.k_min} > "
                f"k_max={self.k_max}")

    # -- spec grammar --------------------------------------------------------
    @classmethod
    def parse(cls, spec: str) -> "SketchPolicy":
        """Parse ``kind[:opt[,opt]*]`` (grammar in the module docstring)."""
        kind, _, rest = spec.partition(":")
        kind = kind.strip()
        if kind not in KINDS:
            raise ValueError(
                f"unknown sketch kind {kind!r} in spec {spec!r}; "
                f"want one of {KINDS}")
        kw: dict = {"kind": kind}
        for raw in (o.strip() for o in rest.split(",")):
            if not raw:
                continue
            name, _, val = raw.partition("=")
            if name in ("fresh", "fixed"):
                kw["schedule"] = name
            elif name == "rotate":
                if not val:
                    raise ValueError(
                        f"rotate needs a period, e.g. 'rotate=8' (in {spec!r})")
                kw["schedule"] = "rotate"
                kw["period"] = int(val)
            elif name == "adaptive":
                kw["adaptive"] = True
                if val:
                    lo, sep, hi = val.partition("..")
                    if not sep:
                        raise ValueError(
                            f"adaptive bounds are 'adaptive=K_MIN..K_MAX', "
                            f"got {raw!r} (in {spec!r})")
                    kw["k_min"], kw["k_max"] = int(lo), int(hi)
            elif name == "seed":
                kw["seed"] = int(val)
            elif name == "c":
                kw["c"] = float(val)
            elif name == "k":
                kw["k"] = int(val)
            else:
                raise ValueError(
                    f"unknown sketch-policy option {raw!r} in spec {spec!r}")
        return cls(**kw)

    @classmethod
    def per_round(cls, basis: str) -> "SketchPolicy":
        """A degenerate fresh-schedule policy for payloads whose
        coordinate basis is locally re-derived every round without ever
        sampling a ``Sketch`` (FedNL's power-iteration eigenbasis): it
        exists so EF eligibility at such call sites flows from the same
        ``basis_persistent`` predicate as the true sketches."""
        return cls(kind=basis, schedule="fresh")

    # -- immutable updates ---------------------------------------------------
    def with_k(self, k: int) -> "SketchPolicy":
        return dataclasses.replace(self, k=int(k))

    def resolved(self, d_eff: float, cap: int) -> "SketchPolicy":
        """Resolve an adaptive k-schedule against a measured effective
        dimension: bounds default to (declared k, min(8 * k_min, cap)),
        and the starting k is ``adaptive_k`` inside them. No-op for
        constant-k policies."""
        if not self.adaptive:
            return self
        k_min = min(int(self.k_min or self.k or 8), int(cap))
        k_max = min(int(self.k_max or 8 * k_min), int(cap))
        k_max = max(k_max, k_min)
        k0 = adaptive_k(d_eff, c=self.c, k_min=k_min, k_max=k_max)
        return dataclasses.replace(self, k=k0, k_min=k_min, k_max=k_max)

    def ramped(self) -> "SketchPolicy":
        """One adaptive ramp step: double k toward ``k_max`` (the guard
        rejected a step — the sketched subspace was too coarse)."""
        if not self.adaptive or self.k_max is None:
            return self
        return self.with_k(min(2 * self.k, self.k_max))

    # -- the schedule --------------------------------------------------------
    def epoch(self, round_idx):
        """Basis epoch at ``round_idx`` (works on traced round counters:
        rotation is plain integer arithmetic inside the jitted round)."""
        if self.schedule == "fixed":
            return 0
        if self.schedule == "rotate":
            return round_idx // self.period
        return round_idx

    def basis_persistent(self, round_idx=None) -> bool:
        """Does the sketch basis at ``round_idx`` survive into the next
        round? ``round_idx=None`` asks at the schedule level: is there
        ANY cross-round persistence — the static predicate EF
        eligibility derives from (EF memory lives in the payload's
        coordinate system, so it is exactly as durable as the basis).
        Adaptive-k never reports persistence: a k change resizes the
        payload and memory cannot survive a shape change."""
        if self.adaptive or self.schedule == "fresh":
            return False
        if self.schedule == "fixed":
            return True
        if round_idx is None:
            return self.period > 1
        return (int(round_idx) + 1) % self.period != 0

    def ef_reset(self, round_idx):
        """Traced indicator (0/1) that the basis at ``round_idx`` is a
        NEW draw under a rotating schedule: error-feedback residuals
        accumulated in the previous epoch live in the old basis and must
        be zeroed before compensating (the reset is common knowledge —
        a pure function of the round index and the declared policy, so
        client and server stay in sync). ``None`` for schedules that
        never need it: fixed (one basis forever) and fresh (EF is
        ineligible there anyway)."""
        if self.schedule != "rotate" or self.period <= 1:
            return None
        return (round_idx % self.period) == 0

    def basis_key(self, key: jax.Array, round_idx) -> jax.Array:
        """The PRNG key the basis at ``round_idx`` is drawn from. Fresh
        schedules return the per-round driver key unchanged (bit
        compatibility with the pre-policy code); fixed/rotating
        schedules fold the rotation epoch into the policy's own seed
        stream, which is what makes the basis identical across the
        rounds of one epoch regardless of the driver's key schedule."""
        if self.schedule == "fresh":
            return key
        return jax.random.fold_in(jax.random.PRNGKey(self.seed),  # noqa: RA001 — documented policy seed stream: the shared basis must be pure in (seed, epoch), not the driver key
                                  self.epoch(round_idx))

    # -- operator construction -----------------------------------------------
    def materialize(self, key: jax.Array, dim: int, dtype=jnp.float32) -> Sketch:
        """Draw the operator from an already-derived basis key (e.g. the
        decoded ``down:seed`` broadcast)."""
        if self.k is None:
            raise ValueError(
                f"sketch policy {self.spec()!r} has no k bound; construct "
                f"the optimizer with k= or call with_k/resolved first")
        return make_sketch(key, self.kind, self.k, dim, dtype=dtype)

    def sample(self, key: jax.Array, round_idx, dim: int,
               dtype=jnp.float32) -> Sketch:
        """The round's sketch operator: schedule-aware basis key, then
        draw. ``round_idx`` may be a traced scalar."""
        return self.materialize(self.basis_key(key, round_idx), dim, dtype)

    # -- display -------------------------------------------------------------
    def spec(self) -> str:
        """Round-trip the policy back to its spec string: parsing the
        result reproduces this policy exactly (non-default ``c`` and a
        bound ``k`` included, so reports never under-describe a run)."""
        opts = []
        if self.schedule == "fixed":
            opts.append("fixed")
        elif self.schedule == "rotate":
            opts.append(f"rotate={self.period}")
        if self.adaptive:
            if self.k_min is not None and self.k_max is not None:
                opts.append(f"adaptive={self.k_min}..{self.k_max}")
            else:
                opts.append("adaptive")
        if self.seed:
            opts.append(f"seed={self.seed}")
        if self.c != 2.0:
            opts.append(f"c={self.c}")
        if self.k is not None:
            opts.append(f"k={self.k}")
        return self.kind + (":" + ",".join(opts) if opts else "")


def as_policy(spec: "str | SketchPolicy", k: "int | None" = None) -> SketchPolicy:
    """Coerce a spec string or policy to a ``SketchPolicy``, binding
    ``k`` when the policy does not already declare one (an explicit
    ``k=`` in the spec, or a previously-bound policy, wins)."""
    pol = SketchPolicy.parse(spec) if isinstance(spec, str) else spec
    if not isinstance(pol, SketchPolicy):
        raise TypeError(f"want a spec string or SketchPolicy, got {pol!r}")
    if k is not None and pol.k is None:
        pol = pol.with_k(int(k))
    return pol
