"""Convex objectives for the paper's setting (regularized GLMs).

Every objective exposes exact closed-form ``value / grad / hessian /
hess_sqrt / hvp`` so that the Newton-family optimizers and their sketches
never rely on autodiff inside the per-round hot loop — matching the
paper's complexity accounting — while the test-suite cross-checks every
formula against ``jax.grad`` / ``jax.hessian``.

Objective convention (paper eq. (1)/(6)):

    L(w) = (1/n) sum_i  l(x_i . w, y_i)  +  (lam/2) ||w||^2

The Hessian factors as ``H = A^T A + lam I`` with the *square root*
``A = diag(sqrt(l''_i / n)) X`` — the matrix that Newton-sketch methods
(FedNS) sketch on the data axis.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Objective:
    """A twice-differentiable regularized GLM objective."""

    name: str
    # per-example scalar maps of the margin/residual
    value: Callable  # (X, y, w, lam) -> scalar
    grad: Callable  # (X, y, w, lam) -> (M,)
    hessian: Callable  # (X, y, w, lam) -> (M, M)
    hess_sqrt: Callable  # (X, y, w, lam) -> (n, M): A with H = A^T A + lam I
    hvp: Callable  # (X, y, w, v, lam) -> (M,)


# ---------------------------------------------------------------------------
# Regularized logistic regression (labels y in {-1, +1})
# ---------------------------------------------------------------------------

def _logistic_value(X, y, w, lam):
    margins = y * (X @ w)
    # log(1 + exp(-m)) = softplus(-m), numerically stable
    return jnp.mean(jax.nn.softplus(-margins)) + 0.5 * lam * jnp.sum(w * w)


def _logistic_sigmoid_neg(X, y, w):
    """sigma(-m_i) for margins m_i = y_i x_i.w ."""
    margins = y * (X @ w)
    return jax.nn.sigmoid(-margins)


def _logistic_grad(X, y, w, lam):
    n = X.shape[0]
    s = _logistic_sigmoid_neg(X, y, w)  # (n,)
    return -(X.T @ (s * y)) / n + lam * w


def _logistic_weights(X, y, w):
    """l''_i = sigma(m_i) sigma(-m_i) (independent of label sign)."""
    margins = y * (X @ w)
    p = jax.nn.sigmoid(margins)
    return p * (1.0 - p)


def _logistic_hessian(X, y, w, lam):
    n, m = X.shape
    d = _logistic_weights(X, y, w)  # (n,)
    return (X.T * d) @ X / n + lam * jnp.eye(m, dtype=X.dtype)


def _logistic_hess_sqrt(X, y, w, lam):
    n = X.shape[0]
    d = _logistic_weights(X, y, w)
    return X * jnp.sqrt(d / n)[:, None]


def _logistic_hvp(X, y, w, v, lam):
    n = X.shape[0]
    d = _logistic_weights(X, y, w)
    return X.T @ (d * (X @ v)) / n + lam * v


logistic = Objective(
    name="logistic",
    value=_logistic_value,
    grad=_logistic_grad,
    hessian=_logistic_hessian,
    hess_sqrt=_logistic_hess_sqrt,
    hvp=_logistic_hvp,
)


# ---------------------------------------------------------------------------
# Regularized least squares
# ---------------------------------------------------------------------------

def _lsq_value(X, y, w, lam):
    r = X @ w - y
    return 0.5 * jnp.mean(r * r) + 0.5 * lam * jnp.sum(w * w)


def _lsq_grad(X, y, w, lam):
    n = X.shape[0]
    return X.T @ (X @ w - y) / n + lam * w


def _lsq_hessian(X, y, w, lam):
    n, m = X.shape
    return X.T @ X / n + lam * jnp.eye(m, dtype=X.dtype)


def _lsq_hess_sqrt(X, y, w, lam):
    n = X.shape[0]
    return X / jnp.sqrt(jnp.asarray(n, X.dtype))


def _lsq_hvp(X, y, w, v, lam):
    n = X.shape[0]
    return X.T @ (X @ (v)) / n + lam * v


least_squares = Objective(
    name="least_squares",
    value=_lsq_value,
    grad=_lsq_grad,
    hessian=_lsq_hessian,
    hess_sqrt=_lsq_hess_sqrt,
    hvp=_lsq_hvp,
)


OBJECTIVES = {"logistic": logistic, "least_squares": least_squares}
