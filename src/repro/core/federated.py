"""Federated problem container + client runtime + client populations.

Clients are stored as equal-sized shards stacked on a leading ``m`` axis
(``X: (m, n_shard, M)``, ``y: (m, n_shard)``) so that every per-client
computation is a ``jax.vmap`` over axis 0 — this is what lets a
1000-client SUSY-scale round run as a single fused XLA computation, and
it is exactly the layout that maps clients onto the ``data`` mesh axis in
the distributed runtime (``repro/launch``): one client shard per mesh
slice, server aggregation = ``psum`` over the client axis.

Unequal client sizes are supported through per-client weights
``p_j = n_j / N`` plus per-client valid-count masks (shards are padded to
the max size; padded rows carry zero weight in the local loss).

Populations vs problems
-----------------------
``FederatedProblem`` materializes every client — fine at workstation
scale (m ≲ 10³), impossible at cross-device scale (m ~ 10⁴–10⁶ with
q ~ 10⁻³ participation). ``ClientPopulation`` is the lazy counterpart:
it *describes* m clients (shard sizes, a deterministic per-client data
rule keyed by ``(seed, client_id)``) and materializes only a requested
cohort — ``materialize(ids)`` returns an ordinary ``FederatedProblem``
over those clients, bit-reproducible per client id regardless of which
cohort it rides in. ``run_rounds`` accepts a population wherever it
accepts a problem (a ``CommConfig`` with a sampling scheduler is then
required); the legacy dense path is ``make_problem``, which is now a
thin wrapper over ``DatasetPopulation.materialize_all()`` and stays
bit-identical to the pre-population construction (golden-tested).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.losses import Objective


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FederatedProblem:
    """m clients of a regularized GLM, padded to equal shard size."""

    X: jax.Array  # (m, n_shard, M)
    y: jax.Array  # (m, n_shard)
    mask: jax.Array  # (m, n_shard) 1.0 for real rows, 0.0 for padding
    lam: float = dataclasses.field(metadata={"static": True})
    objective: Objective = dataclasses.field(metadata={"static": True})

    @property
    def m(self) -> int:
        return self.X.shape[0]

    @property
    def dim(self) -> int:
        return self.X.shape[-1]

    @property
    def n_total(self) -> jax.Array:
        return jnp.sum(self.mask)

    @property
    def client_weights(self) -> jax.Array:
        """p_j = n_j / N."""
        nj = jnp.sum(self.mask, axis=1)
        return nj / jnp.sum(nj)

    # -- local (per-client) quantities, all vmappable -----------------------
    def local_value(self, w: jax.Array) -> jax.Array:
        """(m,) local losses (each on its own n_j)."""

        def one(Xj, yj, mj):
            nj = jnp.sum(mj)
            margins_loss = self._local_loss_sum(Xj, yj, mj, w) / nj
            return margins_loss + 0.5 * self.lam * jnp.sum(w * w)

        return jax.vmap(one)(self.X, self.y, self.mask)

    def _local_loss_sum(self, Xj, yj, mj, w):
        if self.objective.name == "logistic":
            margins = yj * (Xj @ w)
            return jnp.sum(jax.nn.softplus(-margins) * mj)
        r = Xj @ w - yj
        return 0.5 * jnp.sum(r * r * mj)

    def local_grad(self, w: jax.Array) -> jax.Array:
        """(m, M) local gradients."""

        def one(Xj, yj, mj):
            nj = jnp.sum(mj)
            if self.objective.name == "logistic":
                margins = yj * (Xj @ w)
                s = jax.nn.sigmoid(-margins) * mj
                return -(Xj.T @ (s * yj)) / nj + self.lam * w
            r = (Xj @ w - yj) * mj
            return Xj.T @ r / nj + self.lam * w

        return jax.vmap(one)(self.X, self.y, self.mask)

    def local_hess_weights(self, w: jax.Array) -> jax.Array:
        """(m, n_shard) per-example l'' (masked)."""

        def one(Xj, yj, mj):
            if self.objective.name == "logistic":
                margins = yj * (Xj @ w)
                p = jax.nn.sigmoid(margins)
                return p * (1.0 - p) * mj
            return mj

        return jax.vmap(one)(self.X, self.y, self.mask)

    def local_hessian(self, w: jax.Array) -> jax.Array:
        """(m, M, M) local Hessians (including lam I)."""
        d = self.local_hess_weights(w)  # (m, n)
        nj = jnp.sum(self.mask, axis=1)  # (m,)

        def one(Xj, dj, n):
            return (Xj.T * dj) @ Xj / n

        hs = jax.vmap(one)(self.X, d, nj)
        eye = jnp.eye(self.dim, dtype=self.X.dtype)
        return hs + self.lam * eye[None]

    def local_hess_sqrt(self, w: jax.Array) -> jax.Array:
        """(m, n_shard, M) local A_j with H_j = A_j^T A_j + lam I."""
        d = self.local_hess_weights(w)
        nj = jnp.sum(self.mask, axis=1)
        return self.X * jnp.sqrt(d / nj[:, None])[..., None]

    # -- global quantities ---------------------------------------------------
    def global_value(self, w: jax.Array) -> jax.Array:
        p = self.client_weights
        return jnp.sum(p * self.local_value(w))

    def global_grad(self, w: jax.Array) -> jax.Array:
        p = self.client_weights
        return jnp.einsum("j,jm->m", p, self.local_grad(w))

    def global_hessian(self, w: jax.Array) -> jax.Array:
        p = self.client_weights
        return jnp.einsum("j,jab->ab", p, self.local_hessian(w))


# ---------------------------------------------------------------------------
# Client populations: lazy cohort materialization
# ---------------------------------------------------------------------------

# pad-blowup advisory threshold: warn when the largest shard exceeds
# this multiple of the mean (dense construction multiplies memory for
# ALL m clients by the ratio)
_PAD_WARN_FACTOR = 4.0


def _redistribute_cap(sizes: np.ndarray, cap: int) -> np.ndarray:
    """Clip shard sizes at ``cap`` and hand the excess rows to the
    smallest shards (keeping the total exact and every size >= 1).
    Deterministic: pure function of (sizes, cap)."""
    sizes = sizes.copy()
    excess = int(np.maximum(sizes - cap, 0).sum())
    sizes = np.minimum(sizes, cap)
    while excess > 0:
        # fill the currently-smallest shards first, one sweep at a time
        order = np.argsort(sizes, kind="stable")
        room = cap - sizes[order]
        take = np.minimum(room, np.maximum(excess // len(sizes), 1))
        for j, t in zip(order, take):
            t = int(min(t, excess))
            sizes[j] += t
            excess -= t
            if excess == 0:
                break
    return sizes


def _dirichlet_sizes(
    key: jax.Array, n: int, m: int, alpha: float,
    max_pad_factor: "float | None" = None,
) -> np.ndarray:
    """n · Dir(alpha) shard sizes, largest-remainder rounded to sum to n,
    every client >= 1 row. ``max_pad_factor`` (opt-in) caps any shard at
    ``factor * ceil(n/m)`` rows, redistributing the excess — the fix for
    the dense padding blowup where one heavy client multiplies memory
    for all m. ``None`` preserves the raw draw bit-for-bit and only
    warns when the blowup is large."""
    props = np.asarray(
        jax.random.dirichlet(key, jnp.full((m,), alpha)), dtype=np.float64)
    raw = props * n
    sizes = np.floor(raw).astype(np.int64)
    # largest-remainder rounding so sizes sum exactly to n
    short = n - int(sizes.sum())
    order = np.argsort(-(raw - sizes))
    sizes[order[:short]] += 1
    # every client holds at least one real row (p_j = 0 breaks the
    # weighted aggregation and the local 1/n_j normalizations)
    while (sizes == 0).any():
        sizes[int(np.argmax(sizes))] -= 1
        sizes[int(np.argmin(sizes))] += 1
    mean = -(-n // m)  # ceil(n/m)
    if max_pad_factor is not None:
        cap = max(1, int(np.ceil(max_pad_factor * mean)))
        if sizes.max() > cap:
            sizes = _redistribute_cap(sizes, cap)
    elif sizes.max() > _PAD_WARN_FACTOR * mean:
        from repro.obs import log as obs_log

        obs_log.warn_with_context(
            f"dirichlet shard sizes pad every client to the largest chunk "
            f"({int(sizes.max())} rows vs ceil(n/m)={mean}): dense "
            f"materialization costs m*max_j(n_j)*M. Pass "
            f"max_pad_factor=<f> to cap the blowup, or use a "
            f"ClientPopulation to materialize cohorts lazily",
            m=m, n=n, max_shard=int(sizes.max()), mean_shard=mean)
    return sizes


class ClientPopulation:
    """Describes ``m`` clients without materializing their data.

    Subclasses define per-client shard views as a deterministic function
    of the client id; ``materialize(ids)`` builds the ``(c, n_shard, M)``
    ``FederatedProblem`` of one cohort (fixed pad width ``n_shard``, so
    every cohort of the same size traces one jaxpr). Host-side metadata
    is O(m) (shard sizes); client *data* is only ever materialized for
    the cohorts actually scheduled.
    """

    # marks population mode for the driver dispatch in ``run_rounds``
    # (a flag, not an isinstance check — the driver loop stays
    # protocol-driven and source-inspectable)
    is_population = True

    # subclasses set these
    m: int
    dim: int
    lam: float
    objective: Objective
    n_shard: int  # fixed cohort pad width
    sizes: np.ndarray  # (m,) int64 per-client row counts

    @property
    def dtype(self):
        return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32

    @property
    def client_weights(self) -> np.ndarray:
        """(m,) p_j = n_j / N over the whole population (host-side)."""
        s = self.sizes.astype(np.float64)
        return s / s.sum()

    def materialize(self, ids) -> FederatedProblem:
        """Materialize the cohort ``ids`` as a ``FederatedProblem``.

        Bit-reproducible per client id: the same id yields the same
        shard regardless of cohort composition, round, or driver.
        """
        raise NotImplementedError

    def materialize_all(self) -> FederatedProblem:
        """Dense legacy view: every client materialized (workstation
        scale only — this is exactly the blowup populations avoid)."""
        return self.materialize(np.arange(self.m))

    def eval_problem(self, max_clients: int = 64) -> FederatedProblem:
        """A fixed, deterministic evaluation cohort (ids evenly spaced
        across the population) for loss/grad curves: population-mode
        trajectories report the loss of this anchor cohort, never the
        full population."""
        if self.m <= max_clients:
            ids = np.arange(self.m)
        else:
            ids = np.unique(
                np.linspace(0, self.m - 1, max_clients).astype(np.int64))
        return self.materialize(ids)


class DatasetPopulation(ClientPopulation):
    """A real dataset partitioned into m client views, lazily gathered.

    Stores only O(n) host rows + O(m) metadata (per-client sizes and row
    offsets); ``materialize(ids)`` gathers the cohort's rows. The
    partition rule (permutation + shard sizes) is exactly the one
    ``make_problem`` always used, so ``materialize_all()`` is
    bit-identical to the legacy dense construction — ``make_problem`` is
    now a thin wrapper over this class.
    """

    def __init__(
        self,
        X, y, m: int, lam: float, objective: Objective, *,
        key: "jax.Array | None" = None,
        heterogeneity: str = "iid",
        dirichlet_alpha: float = 0.3,
        max_pad_factor: "float | None" = None,
    ):
        n = np.asarray(X).shape[0]
        if key is None:
            key = jax.random.PRNGKey(0)  # noqa: RA001 — documented default partition seed; repro.core.federated cannot import base (cycle)
        if heterogeneity == "dirichlet":
            if n < m:
                raise ValueError(
                    f"dirichlet split needs n >= m, got n={n} m={m}")
            perm = np.asarray(jnp.argsort(y))
            sizes = _dirichlet_sizes(key, n, m, dirichlet_alpha,
                                     max_pad_factor=max_pad_factor)
            rows_X = np.asarray(X)[perm]
            rows_y = np.asarray(y)[perm]
            n_shard = int(sizes.max())
        elif heterogeneity in ("iid", "label"):
            if heterogeneity == "iid":
                perm = np.asarray(jax.random.permutation(key, n))
            else:
                perm = np.asarray(jnp.argsort(y))
            n_shard = -(-n // m)  # ceil
            pad = n_shard * m - n
            rows_X = np.asarray(X)[perm]
            rows_y = np.asarray(y)[perm]
            if pad:
                rows_X = np.concatenate(
                    [rows_X, np.zeros((pad, rows_X.shape[1]), rows_X.dtype)])
                rows_y = np.concatenate(
                    [rows_y, np.zeros((pad,), rows_y.dtype)])
            sizes = np.full((m,), n_shard, dtype=np.int64)
            sizes[-1] = n - n_shard * (m - 1)
        else:
            raise ValueError(heterogeneity)
        self.m = int(m)
        self.dim = int(rows_X.shape[1])
        self.lam = float(lam)
        self.objective = objective
        self.sizes = sizes
        self.n_shard = int(n_shard)
        self._rows_X = rows_X
        self._rows_y = rows_y
        self._starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
        self._n_rows = rows_X.shape[0]

    def materialize(self, ids) -> FederatedProblem:
        ids = np.asarray(ids, dtype=np.int64)
        # clamp the gather window to the row table (short shards read
        # trailing rows that the mask then zeroes — the exact indexing
        # rule the dense dirichlet construction always used)
        idx = np.minimum(
            self._starts[ids][:, None] + np.arange(self.n_shard)[None, :],
            self._n_rows - 1)
        valid = np.arange(self.n_shard)[None, :] < self.sizes[ids][:, None]
        Xc = jnp.asarray(self._rows_X[idx])
        yc = jnp.asarray(self._rows_y[idx])
        mask = jnp.asarray(valid, Xc.dtype)
        return FederatedProblem(
            X=Xc * mask[..., None],
            y=yc * mask.astype(yc.dtype),
            mask=mask,
            lam=self.lam,
            objective=self.objective,
        )


class SyntheticPopulation(ClientPopulation):
    """A generative population: client ``j``'s shard is a pure function
    of ``(seed, j)`` — nothing exists until a cohort is sampled.

    Features follow the same power-law-covariance logistic model as the
    synthetic LIBSVM twins (``repro.data.libsvm_like``); labels come
    from a shared ground-truth ``w_true`` optionally tilted per client
    (``heterogeneity > 0`` adds a per-client N(0, het²) perturbation to
    ``w_true`` — non-iid label rules without non-iid bookkeeping).
    Shard sizes follow a Dirichlet spec over the population
    (``n_total · Dir(alpha)``), clipped into ``[1, n_shard]`` so cohorts
    pad to a FIXED width — cohort materialization is one vmapped,
    jittable generator call and never retraces on cohort membership.
    """

    def __init__(
        self,
        m: int,
        dim: int,
        *,
        lam: float = 1e-3,
        objective: "Objective | None" = None,
        seed: int = 0,
        n_per_client: int = 32,
        n_shard: "int | None" = None,
        dirichlet_alpha: "float | None" = 0.3,
        spectrum_decay: float = 1.0,
        label_noise: float = 0.05,
        heterogeneity: float = 0.0,
    ):
        if objective is None:
            from repro.core.losses import logistic

            objective = logistic
        self.m = int(m)
        self.dim = int(dim)
        self.lam = float(lam)
        self.objective = objective
        self.seed = int(seed)
        self.n_shard = int(n_shard if n_shard is not None
                           else max(2, 2 * n_per_client))
        root = jax.random.PRNGKey(seed)  # noqa: RA001 — the population's own root stream; repro.core.federated cannot import base (cycle)
        k_sizes, k_true, self._k_data = jax.random.split(root, 3)
        if dirichlet_alpha is None:
            self.sizes = np.full((m,), int(n_per_client), dtype=np.int64)
        else:
            props = np.asarray(
                jax.random.dirichlet(
                    k_sizes, jnp.full((m,), float(dirichlet_alpha))),
                dtype=np.float64)
            raw = np.round(props * (n_per_client * m)).astype(np.int64)
            # clip into [1, n_shard]: the pad width is a POPULATION
            # constant, so one heavy draw can never widen every cohort
            self.sizes = np.clip(raw, 1, self.n_shard)
        dt = self.dtype
        evals = jnp.arange(1, dim + 1, dtype=dt) ** (-float(spectrum_decay))
        self._sqrt_evals = jnp.sqrt(evals)
        w_true = jax.random.normal(k_true, (dim,), dt)
        self._w_true = w_true / jnp.linalg.norm(w_true) * 4.0
        self._label_noise = float(label_noise)
        self._het = float(heterogeneity)
        self._gen = jax.jit(jax.vmap(self._one_client))

    def _one_client(self, cid: jax.Array, n_j: jax.Array):
        """(n_shard, dim) features + (n_shard,) labels + mask for one
        client id — keyed by (seed, cid) only."""
        dt = self._sqrt_evals.dtype
        kj = jax.random.fold_in(self._k_data, cid)
        kx, kt, ku, kf = jax.random.split(kj, 4)
        X = jax.random.normal(kx, (self.n_shard, self.dim), dt)
        X = X * self._sqrt_evals[None, :]
        w = self._w_true
        if self._het > 0.0:
            w = w + self._het * jax.random.normal(kt, (self.dim,), dt)
        p = jax.nn.sigmoid(X @ w)
        u = jax.random.uniform(ku, (self.n_shard,), dt)
        y = jnp.where(u < p, 1.0, -1.0).astype(dt)
        flip = jax.random.uniform(kf, (self.n_shard,), dt) < self._label_noise
        y = jnp.where(flip, -y, y)
        mask = (jnp.arange(self.n_shard) < n_j).astype(dt)
        return X * mask[:, None], y * mask, mask

    def materialize(self, ids) -> FederatedProblem:
        ids = np.asarray(ids, dtype=np.int64)
        n_j = jnp.asarray(self.sizes[ids])
        Xc, yc, mask = self._gen(jnp.asarray(ids, jnp.uint32), n_j)
        return FederatedProblem(X=Xc, y=yc, mask=mask, lam=self.lam,
                                objective=self.objective)


def make_problem(
    X: jax.Array,
    y: jax.Array,
    m: int,
    lam: float,
    objective: Objective,
    *,
    key: jax.Array | None = None,
    heterogeneity: str = "iid",
    dirichlet_alpha: float = 0.3,
    max_pad_factor: "float | None" = None,
) -> FederatedProblem:
    """Partition a dataset into m client shards (dense, all clients).

    Thin wrapper over ``DatasetPopulation(...).materialize_all()`` —
    the lazy-population path is the only construction path; this one
    materializes every client up front and is bit-identical to the
    pre-population dense construction (golden-tested).

    heterogeneity:
      * "iid"       — random permutation, equal shards
      * "label"     — sort by label before sharding (pathological non-iid)
      * "dirichlet" — label-sorted rows split into contiguous chunks whose
                      sizes are n · Dir(alpha) (largest-remainder rounded,
                      every client gets ≥ 1 row): clients see both skewed
                      label mixtures AND skewed sample counts, so
                      ``client_weights`` p_j = n_j / N genuinely varies.
                      Shards are padded to the LARGEST chunk, so memory
                      is m · max_j(n_j) · M; ``max_pad_factor=f`` caps
                      any chunk at ``f * ceil(n/m)`` rows (excess
                      redistributed deterministically), and the default
                      ``None`` keeps the raw draw but warns when the
                      blowup exceeds 4x.
    """
    return DatasetPopulation(
        X, y, m, lam, objective, key=key, heterogeneity=heterogeneity,
        dirichlet_alpha=dirichlet_alpha, max_pad_factor=max_pad_factor,
    ).materialize_all()


def newton_solve(
    problem: FederatedProblem, w0: jax.Array, iters: int = 50, tol: float = 1e-12
) -> jax.Array:
    """Reference optimum w* via exact (global) damped Newton.

    Halts at the first iterate with ``‖∇F(w)‖ ≤ tol``: the scan still
    runs ``iters`` steps (static shape), but once converged every later
    update is masked out, so the returned ``w`` is the halting iterate.
    ``tol=0.0`` disables the check and reproduces the full-``iters``
    trajectory exactly.
    """

    def body(carry, _):
        w, done = carry
        g = problem.global_grad(w)
        gnorm = jnp.linalg.norm(g)
        done = done | (gnorm <= tol)
        h = problem.global_hessian(w)
        step = jnp.linalg.solve(h, g)
        # backtracking-free damped step: full Newton is fine for GLM + ridge
        return (jnp.where(done, w, w - step), done), gnorm

    (w, _), _ = jax.lax.scan(
        body, (w0, jnp.asarray(False)), None, length=iters)
    return w
