"""Federated problem container + client runtime.

Clients are stored as equal-sized shards stacked on a leading ``m`` axis
(``X: (m, n_shard, M)``, ``y: (m, n_shard)``) so that every per-client
computation is a ``jax.vmap`` over axis 0 — this is what lets a
1000-client SUSY-scale round run as a single fused XLA computation, and
it is exactly the layout that maps clients onto the ``data`` mesh axis in
the distributed runtime (``repro/launch``): one client shard per mesh
slice, server aggregation = ``psum`` over the client axis.

Unequal client sizes are supported through per-client weights
``p_j = n_j / N`` plus per-client valid-count masks (shards are padded to
the max size; padded rows carry zero weight in the local loss).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.losses import Objective


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FederatedProblem:
    """m clients of a regularized GLM, padded to equal shard size."""

    X: jax.Array  # (m, n_shard, M)
    y: jax.Array  # (m, n_shard)
    mask: jax.Array  # (m, n_shard) 1.0 for real rows, 0.0 for padding
    lam: float = dataclasses.field(metadata={"static": True})
    objective: Objective = dataclasses.field(metadata={"static": True})

    @property
    def m(self) -> int:
        return self.X.shape[0]

    @property
    def dim(self) -> int:
        return self.X.shape[-1]

    @property
    def n_total(self) -> jax.Array:
        return jnp.sum(self.mask)

    @property
    def client_weights(self) -> jax.Array:
        """p_j = n_j / N."""
        nj = jnp.sum(self.mask, axis=1)
        return nj / jnp.sum(nj)

    # -- local (per-client) quantities, all vmappable -----------------------
    def local_value(self, w: jax.Array) -> jax.Array:
        """(m,) local losses (each on its own n_j)."""

        def one(Xj, yj, mj):
            nj = jnp.sum(mj)
            margins_loss = self._local_loss_sum(Xj, yj, mj, w) / nj
            return margins_loss + 0.5 * self.lam * jnp.sum(w * w)

        return jax.vmap(one)(self.X, self.y, self.mask)

    def _local_loss_sum(self, Xj, yj, mj, w):
        if self.objective.name == "logistic":
            margins = yj * (Xj @ w)
            return jnp.sum(jax.nn.softplus(-margins) * mj)
        r = Xj @ w - yj
        return 0.5 * jnp.sum(r * r * mj)

    def local_grad(self, w: jax.Array) -> jax.Array:
        """(m, M) local gradients."""

        def one(Xj, yj, mj):
            nj = jnp.sum(mj)
            if self.objective.name == "logistic":
                margins = yj * (Xj @ w)
                s = jax.nn.sigmoid(-margins) * mj
                return -(Xj.T @ (s * yj)) / nj + self.lam * w
            r = (Xj @ w - yj) * mj
            return Xj.T @ r / nj + self.lam * w

        return jax.vmap(one)(self.X, self.y, self.mask)

    def local_hess_weights(self, w: jax.Array) -> jax.Array:
        """(m, n_shard) per-example l'' (masked)."""

        def one(Xj, yj, mj):
            if self.objective.name == "logistic":
                margins = yj * (Xj @ w)
                p = jax.nn.sigmoid(margins)
                return p * (1.0 - p) * mj
            return mj

        return jax.vmap(one)(self.X, self.y, self.mask)

    def local_hessian(self, w: jax.Array) -> jax.Array:
        """(m, M, M) local Hessians (including lam I)."""
        d = self.local_hess_weights(w)  # (m, n)
        nj = jnp.sum(self.mask, axis=1)  # (m,)

        def one(Xj, dj, n):
            return (Xj.T * dj) @ Xj / n

        hs = jax.vmap(one)(self.X, d, nj)
        eye = jnp.eye(self.dim, dtype=self.X.dtype)
        return hs + self.lam * eye[None]

    def local_hess_sqrt(self, w: jax.Array) -> jax.Array:
        """(m, n_shard, M) local A_j with H_j = A_j^T A_j + lam I."""
        d = self.local_hess_weights(w)
        nj = jnp.sum(self.mask, axis=1)
        return self.X * jnp.sqrt(d / nj[:, None])[..., None]

    # -- global quantities ---------------------------------------------------
    def global_value(self, w: jax.Array) -> jax.Array:
        p = self.client_weights
        return jnp.sum(p * self.local_value(w))

    def global_grad(self, w: jax.Array) -> jax.Array:
        p = self.client_weights
        return jnp.einsum("j,jm->m", p, self.local_grad(w))

    def global_hessian(self, w: jax.Array) -> jax.Array:
        p = self.client_weights
        return jnp.einsum("j,jab->ab", p, self.local_hessian(w))


def make_problem(
    X: jax.Array,
    y: jax.Array,
    m: int,
    lam: float,
    objective: Objective,
    *,
    key: jax.Array | None = None,
    heterogeneity: str = "iid",
    dirichlet_alpha: float = 0.3,
) -> FederatedProblem:
    """Partition a dataset into m client shards.

    heterogeneity:
      * "iid"       — random permutation, equal shards
      * "label"     — sort by label before sharding (pathological non-iid)
      * "dirichlet" — label-sorted rows split into contiguous chunks whose
                      sizes are n · Dir(alpha) (largest-remainder rounded,
                      every client gets ≥ 1 row): clients see both skewed
                      label mixtures AND skewed sample counts, so
                      ``client_weights`` p_j = n_j / N genuinely varies.
                      NOTE: shards are padded to the LARGEST chunk, so
                      memory is m · max_j(n_j) · M — with small alpha the
                      largest chunk can approach n, inflating the stacked
                      arrays by up to ~m×. Fine at this repo's dataset
                      sizes; cap the draw before going paper-scale non-iid.
    """
    n = X.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    if heterogeneity == "dirichlet":
        if n < m:
            raise ValueError(f"dirichlet split needs n >= m, got n={n} m={m}")
        perm = jnp.argsort(y)
        props = np.asarray(
            jax.random.dirichlet(key, jnp.full((m,), dirichlet_alpha)),
            dtype=np.float64,
        )
        raw = props * n
        sizes = np.floor(raw).astype(np.int64)
        # largest-remainder rounding so sizes sum exactly to n
        short = n - int(sizes.sum())
        order = np.argsort(-(raw - sizes))
        sizes[order[:short]] += 1
        # every client holds at least one real row (p_j = 0 breaks the
        # weighted aggregation and the local 1/n_j normalizations)
        while (sizes == 0).any():
            sizes[int(np.argmax(sizes))] -= 1
            sizes[int(np.argmin(sizes))] += 1
        n_shard = int(sizes.max())
        starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
        idx = np.minimum(starts[:, None] + np.arange(n_shard)[None, :], n - 1)
        valid = np.arange(n_shard)[None, :] < sizes[:, None]
        Xp = jnp.asarray(np.asarray(X[perm])[idx])  # (m, n_shard, M)
        yp = jnp.asarray(np.asarray(y[perm])[idx])
        mask = jnp.asarray(valid, X.dtype)
        return FederatedProblem(
            X=Xp * mask[..., None],
            y=yp * mask.astype(y.dtype),
            mask=mask,
            lam=lam,
            objective=objective,
        )
    if heterogeneity == "iid":
        perm = jax.random.permutation(key, n)
    elif heterogeneity == "label":
        perm = jnp.argsort(y)
    else:
        raise ValueError(heterogeneity)
    Xp, yp = X[perm], y[perm]
    n_shard = -(-n // m)  # ceil
    pad = n_shard * m - n
    if pad:
        Xp = jnp.concatenate([Xp, jnp.zeros((pad, X.shape[1]), X.dtype)])
        yp = jnp.concatenate([yp, jnp.zeros((pad,), y.dtype)])
    mask = jnp.concatenate(
        [jnp.ones((n,), X.dtype), jnp.zeros((pad,), X.dtype)]
    )
    return FederatedProblem(
        X=Xp.reshape(m, n_shard, -1),
        y=yp.reshape(m, n_shard),
        mask=mask.reshape(m, n_shard),
        lam=lam,
        objective=objective,
    )


def newton_solve(
    problem: FederatedProblem, w0: jax.Array, iters: int = 50, tol: float = 1e-12
) -> jax.Array:
    """Reference optimum w* via exact (global) damped Newton.

    Halts at the first iterate with ``‖∇F(w)‖ ≤ tol``: the scan still
    runs ``iters`` steps (static shape), but once converged every later
    update is masked out, so the returned ``w`` is the halting iterate.
    ``tol=0.0`` disables the check and reproduces the full-``iters``
    trajectory exactly.
    """

    def body(carry, _):
        w, done = carry
        g = problem.global_grad(w)
        gnorm = jnp.linalg.norm(g)
        done = done | (gnorm <= tol)
        h = problem.global_hessian(w)
        step = jnp.linalg.solve(h, g)
        # backtracking-free damped step: full Newton is fine for GLM + ridge
        return (jnp.where(done, w, w - step), done), gnorm

    (w, _), _ = jax.lax.scan(
        body, (w0, jnp.asarray(False)), None, length=iters)
    return w
