"""Federated optimizer interface + round-loop driver.

Every algorithm implements:

  * ``init(problem, w0) -> state``          (state is a pytree dict)
  * ``round(problem, state, key) -> state`` (pure, jittable; one comm round)
  * ``uplink_floats(problem)`` / ``downlink_floats(problem)``
      static per-client-per-round communication formulas (floats), used to
      reproduce Table I empirically.

``state`` always carries the current iterate under key ``"w"``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.federated import FederatedProblem

OptState = Dict[str, Any]


class FederatedOptimizer:
    name: str = "base"

    def init(self, problem: FederatedProblem, w0: jax.Array) -> OptState:
        return {"w": w0}

    def round(
        self, problem: FederatedProblem, state: OptState, key: jax.Array
    ) -> OptState:
        raise NotImplementedError

    # -- communication accounting (per client, per round) -------------------
    def uplink_floats(self, problem: FederatedProblem) -> int:
        raise NotImplementedError

    def downlink_floats(self, problem: FederatedProblem) -> int:
        # server broadcasts the model every round for every method here
        return problem.dim


@dataclasses.dataclass
class History:
    """Per-round trajectory of one optimizer on one problem."""

    name: str
    loss: np.ndarray  # (T+1,) global loss, loss[0] at w0
    gap: np.ndarray  # (T+1,) loss - loss(w*)
    grad_norm: np.ndarray  # (T+1,)
    uplink_floats: int  # per client per round
    downlink_floats: int
    wall_time_s: float
    rounds: int

    @property
    def cumulative_uplink(self) -> np.ndarray:
        return np.arange(len(self.loss)) * float(self.uplink_floats)


def run_rounds(
    opt: FederatedOptimizer,
    problem: FederatedProblem,
    w0: jax.Array,
    w_star: jax.Array,
    rounds: int,
    seed: int = 0,
) -> History:
    """Drive ``rounds`` communication rounds and record the trajectory."""
    loss_fn = jax.jit(problem.global_value)
    grad_fn = jax.jit(problem.global_grad)
    round_fn = jax.jit(lambda s, k: opt.round(problem, s, k))

    loss_star = float(loss_fn(w_star))
    state = opt.init(problem, w0)
    keys = jax.random.split(jax.random.PRNGKey(seed), rounds)

    losses = [float(loss_fn(state["w"]))]
    gnorms = [float(jnp.linalg.norm(grad_fn(state["w"])))]
    t0 = time.perf_counter()
    for t in range(rounds):
        state = round_fn(state, keys[t])
        losses.append(float(loss_fn(state["w"])))
        gnorms.append(float(jnp.linalg.norm(grad_fn(state["w"]))))
    wall = time.perf_counter() - t0

    losses = np.asarray(losses)
    return History(
        name=opt.name,
        loss=losses,
        gap=np.maximum(losses - loss_star, 0.0),
        grad_norm=np.asarray(gnorms),
        uplink_floats=opt.uplink_floats(problem),
        downlink_floats=opt.downlink_floats(problem),
        wall_time_s=wall,
        rounds=rounds,
    )
