"""Federated optimizer interface + round-loop driver.

Every algorithm implements:

  * ``init(problem, w0) -> state``          (state is a pytree dict)
  * ``round(problem, state, key, comm=None) -> state``
      (pure, jittable; one comm round — client payloads are routed
      through ``comm.uplink``, server broadcasts through
      ``comm.downlink``, and aggregation weights through
      ``comm.weights`` so codecs / partial participation perturb the
      optimization; ``comm=None`` is the exact legacy path)
  * ``uplink_floats(problem)`` / ``downlink_floats(problem)``
      static per-client-per-round communication formulas (floats), used to
      reproduce Table I empirically.

``state`` always carries the current iterate under key ``"w"``.

``run_rounds(..., comm=CommConfig(...))`` threads a simulated transport
(``repro.comm``) through every round: codecs give exact encoded bytes
in BOTH directions (uplink payloads and the server's model broadcast),
the channel model gives simulated wall-clock with compute, stragglers
and dropout, and the scheduler picks the per-round cohort. The
resulting ``History`` carries byte-accurate ``cumulative_bytes`` /
``sim_time_s`` axes next to the legacy float-count formulas.

The loop itself is mode-agnostic: ``make_session`` resolves the
``CommConfig`` (or None) to a ``Session`` — ``NullSession`` (no
transport, the exact legacy jaxpr), ``CommSession`` (synchronous
lock-step), or ``AsyncSession`` (``CommConfig(async_mode=True)``,
event-driven commits where ``sim_time_s`` becomes the server-clock axis
and ``History.staleness`` records each commit's mean model lag) — and
``run_rounds`` drives ``prepare -> step* -> finalize`` identically for
all three. The jitted round function is shared: only the host-side
clock differs.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig, RoundTrace, make_session
from repro.core.federated import ClientPopulation, FederatedProblem
from repro.obs import NULL_TELEMETRY, Telemetry, TelemetryConfig
from repro.obs import log as obs_log

OptState = Dict[str, Any]


def root_key(seed: int, *salts: int) -> jax.Array:
    """Mint a trajectory root PRNG key from an integer seed.

    The one sanctioned place library code turns a raw integer into key
    material (lint rule RA001, ``repro.analysis.lint``): every other
    key must derive from an existing key via ``split`` / ``fold_in``,
    or live at a documented ``(seed, id)``-salted site carrying an
    explicit ``# noqa: RA001`` suppression. Extra ``salts`` fold in
    left to right, giving disjoint deterministic streams (e.g.
    ``root_key(seed, 1)`` for an input batch next to the model init's
    ``root_key(seed)``).
    """
    key = jax.random.PRNGKey(seed)  # noqa: RA001 — the sanctioned mint site itself
    for s in salts:
        key = jax.random.fold_in(key, s)
    return key


def build_round(opt: "FederatedOptimizer", problem, session, probe_key,
                *, population=None, comm=None):
    """Build the one jitted round closure plus its abstract-probe factory.

    Shared by ``run_rounds`` and the trace auditor
    (``repro.analysis.audit``), so the jaxpr the auditor inspects IS the
    driver's jaxpr — not a reconstruction that could drift. Returns
    ``(_round, trace_with)``:

      * ``_round`` carries the dense ``(state, memory, key, mask,
        codec_key)`` signature, or the population ``(cohort, state,
        memory, key, mask, codec_key)`` one when ``population`` is
        given (``comm`` is then required for the probe cohort size);
      * ``trace_with(state)`` builds the ``trace_round`` callback the
        ``Session`` protocol's ``prepare`` / ``begin_variant`` probes
        consume (``jax.eval_shape`` only — nothing executes, so any
        ``probe_key`` works; shapes don't depend on it).

    The EF21 memory rides through as a pytree next to the optimizer
    state; without error feedback (or with only lossless codecs) it is
    an EMPTY pytree — zero extra jaxpr inputs — and on the no-transport
    path ``comm_round`` returns the no-op NULL_COMM view, so the
    identity/legacy jaxprs stay bit-identical.

    Population mode threads the materialized cohort through as a traced
    pytree argument: cohort shapes are fixed at (c, n_shard, M) by the
    scheduler's cohort size, so every round of every cohort reuses one
    jaxpr — only the data changes, never the trace.
    """
    if population is not None:
        def _round(cohort, s, mem, k, mask, ck):
            cr = session.comm_round(mem, mask, ck)
            s_next = opt.round(cohort, s, k, comm=cr)
            return s_next, cr.memory_out, cr.stats_out

        # probe cohort: ids are irrelevant (shape-only eval_shape trace)
        _probe_cohort = population.materialize(np.zeros(
            comm.scheduler.cohort_size(population.m), dtype=np.int64))

        def trace_with(s):
            return lambda cr: opt.round(_probe_cohort, s, probe_key,
                                        comm=cr)
    else:
        def _round(s, mem, k, mask, ck):
            cr = session.comm_round(mem, mask, ck)
            s_next = opt.round(problem, s, k, comm=cr)
            return s_next, cr.memory_out, cr.stats_out

        def trace_with(s):
            return lambda cr: opt.round(problem, s, probe_key, comm=cr)

    return _round, trace_with


class FederatedOptimizer:
    name: str = "base"

    def init(self, problem: FederatedProblem, w0: jax.Array) -> OptState:
        return {"w": w0}

    def round(
        self, problem: FederatedProblem, state: OptState, key: jax.Array,
        comm=None,
    ) -> OptState:
        raise NotImplementedError

    def round_signature(self, round_idx: int, state: OptState):
        """Host-side pre-round hook: return a hashable signature naming
        the static variant of the next round's trace. Rounds sharing a
        signature share one jitted round function and one payload byte
        plan; a new signature re-traces and re-bills (the signature must
        therefore determine every static choice the round makes — e.g.
        the current sketch size). Optimizers with adaptive sketch
        policies update their k here from the trajectory signals the
        driver hands back. Default: one signature (``None``) for the
        whole trajectory — the single-jaxpr fast path."""
        return None

    # -- communication accounting (per client, per round) -------------------
    def uplink_floats(self, problem: FederatedProblem) -> int:
        raise NotImplementedError

    def downlink_floats(self, problem: FederatedProblem) -> int:
        # server broadcasts the model every round for every method here
        return problem.dim


@dataclasses.dataclass
class History:
    """Per-round trajectory of one optimizer on one problem."""

    name: str
    loss: np.ndarray  # (T+1,) global loss, loss[0] at w0
    gap: np.ndarray  # (T+1,) loss - loss(w*)
    grad_norm: np.ndarray  # (T+1,)
    uplink_floats: int  # per client per round
    downlink_floats: int
    wall_time_s: float
    rounds: int
    # byte-accurate transport axes (repro.comm). Without a CommConfig the
    # bytes curve is derived from the float formulas (all clients, raw
    # dtype width) and sim time is zero.
    cumulative_bytes: Optional[np.ndarray] = None  # (T+1,) up+down, all clients
    sim_time_s: Optional[np.ndarray] = None  # (T+1,) cumulative simulated s
    traces: Optional[list] = None  # per-round RoundTrace records (comm runs)
    # async runs: (T,) mean staleness (server steps of model lag) of each
    # commit's cohort; None for sync / no-comm runs
    staleness: Optional[np.ndarray] = None
    clients: int = 1  # m — scales the per-client float formulas to totals
    itemsize: int = 8  # bytes per float of the problem dtype
    # final error-feedback memory norms per payload (comm runs with EF;
    # empty dict when EF is off or nothing was eligible)
    ef_residuals: Optional[dict] = None
    # telemetry run summary (repro.obs) when run_rounds was given an
    # ``obs=TelemetryConfig(...)``; None on uninstrumented runs
    telemetry: Optional[dict] = None

    # -- JSONL export/import -------------------------------------------------
    # One ``history`` header line with every scalar/curve field, then one
    # ``round_trace`` line per RoundTrace — so benchmark curves (and the
    # staleness axis) can be re-plotted without re-running the trajectory.

    _JSONL_SCHEMA = "repro.history/v1"

    def to_jsonl(self, path) -> pathlib.Path:
        """Write this trajectory as JSONL (see ``from_jsonl``)."""

        def arr(a):
            # strict JSON has no NaN/Infinity token: non-finite entries
            # (diverged runs, absent staleness) travel as null
            if a is None:
                return None
            return [None if (isinstance(v, float) and not np.isfinite(v))
                    else v
                    for v in np.asarray(a, dtype=np.float64).tolist()]

        header = {
            "type": "history",
            "schema": self._JSONL_SCHEMA,
            "name": self.name,
            "rounds": int(self.rounds),
            "uplink_floats": int(self.uplink_floats),
            "downlink_floats": int(self.downlink_floats),
            "wall_time_s": float(self.wall_time_s),
            "clients": int(self.clients),
            "itemsize": int(self.itemsize),
            "loss": arr(self.loss),
            "gap": arr(self.gap),
            "grad_norm": arr(self.grad_norm),
            "cumulative_bytes": arr(self.cumulative_bytes),
            "sim_time_s": arr(self.sim_time_s),
            "staleness": arr(self.staleness),
            "ef_residuals": self.ef_residuals,
            "telemetry": self.telemetry,
        }
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with path.open("w") as f:
            f.write(json.dumps(header, allow_nan=False) + "\n")
            for tr in self.traces or []:
                f.write(json.dumps({"type": "round_trace", **tr.to_dict()},
                                   allow_nan=False) + "\n")
        return path

    @classmethod
    def from_jsonl(cls, path) -> "History":
        """Reconstruct a ``History`` written by ``to_jsonl`` (including
        per-round ``RoundTrace`` records and the staleness axis)."""

        def arr(v):
            if v is None:
                return None
            return np.asarray([np.nan if x is None else x for x in v],
                              dtype=np.float64)

        with pathlib.Path(path).open() as f:
            lines = [json.loads(line) for line in f if line.strip()]
        if not lines or lines[0].get("type") != "history":
            raise ValueError(f"{path}: not a History JSONL (missing header)")
        h = lines[0]
        if h.get("schema") != cls._JSONL_SCHEMA:
            raise ValueError(
                f"{path}: schema {h.get('schema')!r} != "
                f"{cls._JSONL_SCHEMA!r}")
        traces = [RoundTrace.from_dict(rec) for rec in lines[1:]
                  if rec.get("type") == "round_trace"]
        return cls(
            name=h["name"],
            loss=arr(h["loss"]),
            gap=arr(h["gap"]),
            grad_norm=arr(h["grad_norm"]),
            uplink_floats=int(h["uplink_floats"]),
            downlink_floats=int(h["downlink_floats"]),
            wall_time_s=float(h["wall_time_s"]),
            rounds=int(h["rounds"]),
            cumulative_bytes=arr(h["cumulative_bytes"]),
            sim_time_s=arr(h["sim_time_s"]),
            traces=traces or None,
            staleness=arr(h["staleness"]),
            clients=int(h["clients"]),
            itemsize=int(h["itemsize"]),
            ef_residuals=h.get("ef_residuals"),
            telemetry=h.get("telemetry"),
        )

    @property
    def cumulative_uplink(self) -> np.ndarray:
        """(T+1,) cumulative uplink BYTES summed across all clients —
        the formula-derived counterpart of the uplink share of
        ``cumulative_bytes`` (same axis and units, so the two are
        directly comparable on identity-codec full-participation runs).
        """
        per_round = float(self.uplink_floats) * self.itemsize * self.clients
        return np.arange(len(self.loss)) * per_round


class _ProfilerHook:
    """Opt-in ``jax.profiler`` trace around the first N executed rounds
    (``TelemetryConfig.profile_rounds``). Host-side start/stop only —
    the traced round functions are untouched."""

    def __init__(self, obs: "TelemetryConfig | None", rounds: int):
        self._remaining = 0
        if obs is None or obs.profile_rounds <= 0 or rounds <= 0:
            return
        try:
            jax.profiler.start_trace(obs.profile_dir)
        except Exception as e:  # profiler backend unavailable: degrade
            obs_log.warn_with_context(
                f"jax.profiler trace hook unavailable ({e!r}); continuing "
                f"without a device trace", profile_dir=obs.profile_dir)
            return
        self._remaining = min(int(obs.profile_rounds), rounds)
        obs_log.info("jax.profiler trace started",
                     profile_dir=obs.profile_dir, rounds=self._remaining)

    def after_round(self) -> None:
        if self._remaining > 0:
            self._remaining -= 1
            if self._remaining == 0:
                jax.profiler.stop_trace()

    def close(self) -> None:
        """Stop a still-open trace (fewer executed rounds than asked)."""
        if self._remaining > 0:
            self._remaining = 0
            jax.profiler.stop_trace()


def run_rounds(
    opt: FederatedOptimizer,
    problem: "FederatedProblem | ClientPopulation",
    w0: jax.Array,
    w_star: jax.Array,
    rounds: int,
    seed: int = 0,
    comm: Optional[CommConfig] = None,
    obs: Optional[TelemetryConfig] = None,
    client_mesh=None,
) -> History:
    """Drive ``rounds`` communication rounds and record the trajectory.

    With ``comm=None`` this is the exact legacy path (identical jaxprs,
    bit-identical trajectories). With a ``CommConfig`` every round flows
    through the simulated transport and the returned ``History`` carries
    per-round ``RoundTrace`` records. All modes run the same loop: the
    ``Session`` protocol (``repro.comm.session``) owns the clock.

    ``problem`` may also be a ``ClientPopulation`` (population mode):
    only the scheduled cohort's shards are materialized each round, so
    the client axis scales to ``m ~ 10^5`` with memory bounded by the
    cohort size. Population mode requires a ``CommConfig`` (there is no
    dense legacy path for a population), evaluates loss/grad on the
    population's deterministic ``eval_problem()`` subsample, and rejects
    optimizers carrying dense per-client state (``per_client_state``,
    e.g. FedNew's ADMM duals — unsampled clients would silently keep
    stale duals). ``client_mesh`` optionally shards each materialized
    cohort's client axis over a device mesh
    (``repro.sharding.rules.shard_cohort``).

    ``obs=TelemetryConfig(...)`` turns on the ``repro.obs`` telemetry
    layer: host-side phase spans around the jit boundaries
    (schedule / client round / account / retrace / eval — never inside
    traced code), a compile-vs-execute wall-clock split (the first call
    of each jitted round variant is billed as compile), session metrics
    (bytes, deliveries, staleness distribution, async queue depths), and
    the async flight recorder. The default (``obs=None``) is the shared
    no-op telemetry: zero overhead and bit-identical trajectories —
    instrumentation can never perturb the optimization (tested). The
    run summary lands on ``History.telemetry``.
    """
    telemetry = Telemetry(obs) if obs is not None else NULL_TELEMETRY
    population = problem if getattr(problem, "is_population", False) else None
    if population is not None:
        if getattr(opt, "per_client_state", False):
            raise NotImplementedError(
                f"{opt.name} keeps dense per-client state across rounds "
                f"(per_client_state=True); population mode materializes "
                f"only the sampled cohort, so unsampled clients would "
                f"silently carry stale state — use a dense problem "
                f"(population.materialize_all()) or a stateless-client "
                f"optimizer")
        # loss/grad (and optimizer init geometry) come from the
        # population's deterministic evaluation subsample
        eval_prob = population.eval_problem()
    else:
        eval_prob = problem
    m = population.m if population is not None else problem.m
    loss_fn = jax.jit(eval_prob.global_value)
    grad_fn = jax.jit(eval_prob.global_grad)

    itemsize = jnp.dtype(eval_prob.X.dtype).itemsize
    loss_star = float(loss_fn(w_star))
    state = opt.init(eval_prob, w0)
    keys = jax.random.split(root_key(seed), rounds)

    formula_bytes = float(
        (opt.uplink_floats(eval_prob) + opt.downlink_floats(eval_prob))
        * itemsize * m)
    session = make_session(
        comm,
        m=m,
        mask_dtype=eval_prob.X.dtype,
        client_weights=(population.client_weights
                        if population is not None
                        else np.asarray(problem.client_weights)),
        keys=keys,
        state0=state,
        formula_bytes_per_round=formula_bytes,
        obs=telemetry,
        population=population,
        client_mesh=client_mesh,
    )

    # Adaptive-k policies change payload sizes mid-trajectory; the async
    # clock prices in-flight uploads at dispatch time, so round-varying
    # plans are a synchronous-driver feature. Fail fast with the fix.
    policy = getattr(opt, "policy", None)
    if comm is not None and comm.async_mode and policy is not None:
        if getattr(policy, "adaptive", False):
            raise NotImplementedError(
                "adaptive-k sketch policies vary payload bytes per round, "
                "which the asynchronous driver cannot bill truthfully "
                "(in-flight uploads are priced at dispatch time); use the "
                "synchronous driver or a constant-k policy")
        if (getattr(policy, "schedule", "fresh") == "rotate"
                and comm.has_error_feedback):
            # stale commit groups share one EF memory pytree across model
            # versions: a group based on the previous epoch can straddle
            # a rotation boundary and briefly compensate across bases
            # (EF21 re-contracts within the epoch). Per-version memory
            # would fix it properly — a known follow-up.
            obs_log.warn_with_context(
                "async driver + rotating sketch policy + error feedback: "
                "commit groups based on pre-rotation model versions share "
                "the EF memory of the new epoch, so residuals can briefly "
                "straddle a rotation boundary under stale commits; the "
                "synchronous driver keeps the epoch-reset invariant exact",
                optimizer=opt.name,
                policy=getattr(policy, "spec", lambda: None)())

    # The one jitted round function every driver mode shares — built by
    # ``build_round`` (also the trace auditor's entry point, so static
    # analysis inspects the exact jaxpr the driver runs).
    probe_key = root_key(seed)
    _round, trace_with = build_round(
        opt, problem, session, probe_key, population=population, comm=comm)

    with telemetry.trace.span("prepare"):
        session.prepare(trace_with(state))

    losses = [float(loss_fn(state["w"]))]
    gnorms = [float(jnp.linalg.norm(grad_fn(state["w"])))]
    # one jitted round PER static variant: the default round_signature
    # (None for every round) keeps the single shared trace; an adaptive
    # sketch policy announces each k change here, and the session probes
    # that variant's byte plan so per-round traces bill the true sizes
    round_fns: Dict[Any, Any] = {}
    retraces = telemetry.metrics.counter("variant_retraces")
    profiler = _ProfilerHook(obs, rounds)
    sig_prev = object()  # sentinel: no signature compares equal to it
    t0 = time.perf_counter()
    for t in range(rounds):
        sig = opt.round_signature(t, state)
        # host wall-clock attribution wraps the jit BOUNDARIES only:
        # begin_variant/step/eval run exactly the code they always ran —
        # the spans never reach inside traced functions
        with telemetry.round(t, compile_expected=sig not in round_fns):
            if sig != sig_prev:
                with telemetry.trace.span("begin_variant"):
                    session.begin_variant(sig, trace_with(state))
                sig_prev = sig
            fn = round_fns.get(sig)
            if fn is None:
                if round_fns:  # a NEW variant after the first = a retrace
                    retraces.inc()
                fn = round_fns[sig] = jax.jit(_round)
            with telemetry.trace.span("step"):
                state = session.step(fn)
                if telemetry.enabled:
                    # honest span timing: settle async dispatch before
                    # the host timer stops (device values are unchanged)
                    jax.block_until_ready(state["w"])
            with telemetry.trace.span("eval"):
                losses.append(float(loss_fn(state["w"])))
                gnorms.append(float(jnp.linalg.norm(grad_fn(state["w"]))))
        profiler.after_round()
    wall = time.perf_counter() - t0
    profiler.close()

    with telemetry.trace.span("finalize"):
        transport = session.finalize()
    losses = np.asarray(losses)
    total_bytes = (float(transport.cumulative_bytes[-1])
                   if len(transport.cumulative_bytes) else 0.0)
    summary = telemetry.finalize(extra={
        "optimizer": opt.name,
        "driver": ("null" if comm is None
                   else "async" if comm.async_mode else "sync"),
        "rounds_requested": rounds,
        "clients": m,
        "total_bytes": total_bytes,
        "sim_time_s": float(transport.sim_time_s[-1])
        if len(transport.sim_time_s) else 0.0,
        "wall_time_s": wall,
    })
    return History(
        name=opt.name,
        loss=losses,
        gap=np.maximum(losses - loss_star, 0.0),
        grad_norm=np.asarray(gnorms),
        uplink_floats=opt.uplink_floats(eval_prob),
        downlink_floats=opt.downlink_floats(eval_prob),
        wall_time_s=wall,
        rounds=rounds,
        cumulative_bytes=transport.cumulative_bytes,
        sim_time_s=transport.sim_time_s,
        traces=transport.traces,
        staleness=transport.staleness,
        clients=m,
        itemsize=itemsize,
        ef_residuals=transport.ef_residuals,
        telemetry=summary,
    )
