"""Byzantine threat model: seeded clients corrupt their uplinks.

A ``ThreatModel`` marks a deterministic, seeded subset of client ids as
attackers (the subset is a pure per-id function, so the same clients
attack in every driver and at any cohort composition) and corrupts
their *uplink payloads inside the traced round*, BEFORE the codec runs
— an attacker crafts what it puts on the wire, so compression and
error feedback operate on the corrupted payload exactly as they would
on an honest one. Downlinks are never corrupted (the server is honest).

Attack kinds (spec grammar ``"kind:fraction[,param][@payloads]"``,
parsed by ``make_threat``):

  * ``"signflip:f"`` — attackers send ``-x`` (gradient/Hessian sign
    flip; norm-preserving, so norm-clipping alone cannot filter it);
  * ``"scale:f,c"`` — attackers send ``c * x`` (default ``c=10``, a
    scaled-gradient / model-boosting attack that norm clipping defeats);
  * ``"noise:f,s"`` — attackers replace the payload with ``N(0, s^2)``
    noise (default ``s=1``, random-noise Hessian sketches / gradients).

``payloads`` (the ``@p1+p2`` spec suffix) optionally restricts the
attack to named payloads (e.g. ``"signflip:0.2@h_sk"`` corrupts only
the Hessian sketch); the default corrupts every uplink the attacker
sends — including scalar control payloads, which is the honest
adversarial reading.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib

import jax
import jax.numpy as jnp
import numpy as np

THREAT_KINDS = ("signflip", "scale", "noise")

_THREAT_TAG = zlib.crc32(b"repro.dynamics.threat")

_DEFAULT_PARAM = {"signflip": 0.0, "scale": 10.0, "noise": 1.0}


@functools.lru_cache(maxsize=None)
def _attacker_sampler(fraction: float, salt: int):
    """Compiled per-id attacker coin: pure in ``(fraction, salt, id)``."""
    key0 = jax.random.PRNGKey(np.uint32(salt))  # noqa: RA001 — documented (seed, id) salt: the attacker set must be pure per id across drivers

    def one(cid):
        return jax.random.uniform(jax.random.fold_in(key0, cid)) < fraction

    return jax.jit(jax.vmap(one))


@dataclasses.dataclass(frozen=True)
class ThreatModel:
    """Seeded Byzantine uplink corruption (see module docstring)."""

    kind: str = "signflip"
    fraction: float = 0.1
    param: float = 0.0
    payloads: "tuple | None" = None
    seed: int = 0

    def __post_init__(self):
        if self.kind not in THREAT_KINDS:
            raise ValueError(
                f"unknown threat kind {self.kind!r}; expected one of "
                f"{', '.join(THREAT_KINDS)}")
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError(
                f"threat fraction must be in [0, 1], got {self.fraction}")

    def applies(self, name: str) -> bool:
        """Does the attack touch the uplink payload ``name``?"""
        return self.payloads is None or name in self.payloads

    def attacker_mask(self, ids) -> np.ndarray:
        """(len(ids),) bool — is each client an attacker? Pure per-id:
        the same ids attack in every cohort, round, and driver."""
        ids = np.asarray(ids, dtype=np.int64)
        salt = (_THREAT_TAG ^ (self.seed & 0xFFFFFFFF)) & 0xFFFFFFFF
        coins = _attacker_sampler(float(self.fraction), salt)(
            jnp.asarray(ids, jnp.uint32))
        return np.asarray(coins, dtype=bool)

    def corrupt(self, key: jax.Array, x: jax.Array,
                attackers: jax.Array) -> jax.Array:
        """Traced corruption of a stacked ``(c, ...)`` uplink payload;
        ``attackers`` is the (c,) 0/1 attacker indicator."""
        a = jnp.asarray(attackers, x.dtype).reshape(
            (-1,) + (1,) * (x.ndim - 1))
        if self.kind == "signflip":
            bad = -x
        elif self.kind == "scale":
            bad = x * jnp.asarray(self.param, x.dtype)
        else:  # noise
            bad = jnp.asarray(self.param, x.dtype) * jax.random.normal(
                key, x.shape, x.dtype)
        return a * bad + (1 - a) * x


def make_threat(spec: "str | ThreatModel", seed: int = 0) -> ThreatModel:
    """Parse ``"kind:fraction[,param][@payload1+payload2]"`` or pass a
    ``ThreatModel`` through.

    The optional ``@`` suffix scopes the attack to the named uplink
    payloads (``ThreatModel.payloads``): ``"signflip:0.2@h_sk"``
    corrupts only the Hessian sketch, every other uplink of an attacker
    stays byte-identical to its honest value (the trace auditor's
    threat-scope check asserts exactly that). Without a suffix every
    uplink is corrupted — the honest adversarial reading.
    """
    if isinstance(spec, ThreatModel):
        return spec
    body, sep, scope = str(spec).partition("@")
    payloads = None
    if sep:
        payloads = tuple(p for p in scope.split("+") if p)
        if not payloads:
            raise ValueError(
                f"threat spec {spec!r} has an empty @payload scope; "
                f"drop the '@' to corrupt every uplink")
    kind, _, rest = body.partition(":")
    known = ", ".join(k + ":fraction" for k in THREAT_KINDS)
    if kind not in THREAT_KINDS:
        raise ValueError(
            f"unknown threat spec {spec!r}; expected one of {known}")
    try:
        params = tuple(float(p) for p in rest.split(",") if p != "")
    except ValueError:
        raise ValueError(
            f"bad parameters in threat spec {spec!r}; expected "
            f"'{kind}:fraction[,param][@payloads]'") from None
    if len(params) not in (1, 2):
        raise ValueError(
            f"threat spec {spec!r} wants 1-2 parameters "
            f"(fraction[, param]), got {len(params)}")
    param = params[1] if len(params) == 2 else _DEFAULT_PARAM[kind]
    return ThreatModel(kind=kind, fraction=params[0], param=param,
                       payloads=payloads, seed=seed)
