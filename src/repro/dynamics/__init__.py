"""Scenario dynamics: churn, time-varying channels, threats, robustness.

``repro.comm`` models a static, honest population over a channel whose
statistics never change. This package composes three *dynamic* layers on
top of it, threaded through ``CommConfig(dynamics=DynamicsConfig(...))``:

  * **population churn** (``repro.dynamics.churn``) — arrival/departure
    processes over rounds shrink/grow the eligible client id set that
    ``Scheduler.sample_ids`` and the population sessions consume;
  * **time-varying channels** (``repro.dynamics.process``) — a
    ``ChannelProcess`` wrapper over ``ChannelModel`` whose per-field
    multipliers follow diurnal cycles, drift, and correlated regional
    outages, keyed by ``(field, client_id, round)``;
  * **adversarial uploads + robust aggregation**
    (``repro.dynamics.threat`` / ``repro.dynamics.robust``) — a
    ``ThreatModel`` corrupting a seeded subset of uplinks inside the
    traced round, and pluggable robust aggregators composed with the
    existing participation and staleness weights.

Every layer defaults off; a ``CommConfig`` without ``dynamics`` (or with
an all-``None`` ``DynamicsConfig``) runs the exact pre-dynamics code
paths, bit-identical on all drivers (tested).
"""
from repro.dynamics.churn import (
    ChurnProcess,
    LifetimeChurn,
    PoissonChurn,
    StepChurn,
    make_churn,
)
from repro.dynamics.config import DynamicsConfig
from repro.dynamics.process import ChannelProcess
from repro.dynamics.robust import (
    ChainAggregator,
    ClipAggregator,
    CoordinateMedian,
    RobustAggregator,
    TrimmedMean,
    make_aggregator,
)
from repro.dynamics.threat import ThreatModel, make_threat

__all__ = [
    "ChainAggregator",
    "ChannelProcess",
    "ChurnProcess",
    "ClipAggregator",
    "CoordinateMedian",
    "DynamicsConfig",
    "LifetimeChurn",
    "PoissonChurn",
    "RobustAggregator",
    "StepChurn",
    "ThreatModel",
    "TrimmedMean",
    "make_aggregator",
    "make_churn",
    "make_threat",
]
