"""Population churn: which clients exist at round ``t``.

A ``ChurnProcess`` is a *deterministic* map from ``(seed, client_id,
round)`` to alive/departed — no mutable state, no ``(m,)`` history.
Client ``j``'s lifetime is a pure function of the per-id PRNG stream
(the same fold-in pattern ``repro.comm.channel`` uses for static link
attributes), so eligibility is reproducible across drivers, cohort
compositions, and restarts, and population-scale ``m`` never stores
more than the O(m) per-id parameter vectors it draws once.

Processes (spec grammar, parsed by ``make_churn``):

  * ``"step:t=T[,frac=f]"`` — a seeded ``f``-fraction of the population
    departs permanently at round ``T`` (mass-departure shock; defaults
    ``frac=0.5``). Positional form ``"step:T,f"`` also parses.
  * ``"poisson:rate"`` — every client alternates between alive and away
    spells with geometric durations of mean ``1/rate`` rounds and a
    seeded phase (a random-telegraph approximation of Poisson
    arrival/departure): the *expected* active fraction is 1/2 at any
    ``t``, while individual membership flickers.
  * ``"lifetime:mean[,stagger]"`` — client ``j`` arrives at a seeded
    round in ``[0, stagger]`` (default 0) and stays for an
    exponential(mean) number of rounds, then departs forever — a
    decaying population with staggered arrivals.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib

import jax
import jax.numpy as jnp
import numpy as np

CHURN_KINDS = ("step", "poisson", "lifetime")

# crc32 tag separating churn uniforms from channel-field streams that
# might share a DynamicsConfig seed
_CHURN_TAG = zlib.crc32(b"repro.dynamics.churn")


@functools.lru_cache(maxsize=None)
def _uniform_sampler(n_streams: int, salt: int):
    """Compiled per-id sampler of ``n_streams`` iid U[0,1) draws —
    client ``j``'s draws are a pure function of ``(salt, j)``."""
    key0 = jax.random.PRNGKey(np.uint32(salt))  # noqa: RA001 — documented (seed, id) salt: lifetimes must be pure per id across drivers

    def one(cid):
        return jax.random.uniform(jax.random.fold_in(key0, cid),
                                  (n_streams,))

    return jax.jit(jax.vmap(one))


def _per_id_uniforms(n_streams: int, seed: int, m: int) -> np.ndarray:
    """(m, n_streams) float64 per-id uniforms for one churn seed."""
    salt = (_CHURN_TAG ^ (seed & 0xFFFFFFFF)) & 0xFFFFFFFF
    u = _uniform_sampler(n_streams, salt)(jnp.arange(m, dtype=jnp.uint32))
    return np.asarray(u, dtype=np.float64)


class ChurnProcess:
    """Base: deterministic eligibility as a function of ``(t, id)``.

    Subclasses implement ``_alive_params(m) -> tuple[np.ndarray, ...]``
    (cached per population size) and ``_alive(params, ids, t)``.
    """

    seed: int = 0

    def __init__(self):
        self._cache: "dict[int, tuple]" = {}

    def _alive_params(self, m: int) -> tuple:
        raise NotImplementedError

    def _alive(self, params: tuple, ids: np.ndarray, t: int) -> np.ndarray:
        raise NotImplementedError

    def _params(self, m: int) -> tuple:
        if m not in self._cache:
            self._cache[m] = self._alive_params(m)
        return self._cache[m]

    def alive(self, ids, t: int, m: int) -> np.ndarray:
        """(len(ids),) bool — is each client alive at round ``t``?"""
        ids = np.asarray(ids, dtype=np.int64)
        return self._alive(self._params(m), ids, t)

    def eligible_mask(self, t: int, m: int) -> np.ndarray:
        """(m,) bool eligibility at round ``t``."""
        return self.alive(np.arange(m, dtype=np.int64), t, m)

    def eligible_ids(self, t: int, m: int) -> np.ndarray:
        """Sorted int64 ids of the clients alive at round ``t``."""
        return np.nonzero(self.eligible_mask(t, m))[0].astype(np.int64)


@dataclasses.dataclass(eq=False)
class StepChurn(ChurnProcess):
    """A seeded ``frac``-fraction departs permanently at round ``t0``."""

    t0: int = 1
    frac: float = 0.5
    seed: int = 0

    def __post_init__(self):
        super().__init__()
        if self.t0 < 0:
            raise ValueError(f"step churn t must be >= 0, got {self.t0}")
        if not 0.0 <= self.frac <= 1.0:
            raise ValueError(
                f"step churn frac must be in [0, 1], got {self.frac}")

    def _alive_params(self, m):
        u = _per_id_uniforms(1, self.seed, m)
        return (u[:, 0] < self.frac,)  # departing set

    def _alive(self, params, ids, t):
        (departing,) = params
        if t < self.t0:
            return np.ones(len(ids), dtype=bool)
        return ~departing[ids]


@dataclasses.dataclass(eq=False)
class PoissonChurn(ChurnProcess):
    """Random-telegraph membership: alternating alive/away spells with
    geometric(rate) durations and a seeded phase per client."""

    rate: float = 0.05
    seed: int = 0

    def __post_init__(self):
        super().__init__()
        if not 0.0 < self.rate < 1.0:
            raise ValueError(
                f"poisson churn rate must be in (0, 1), got {self.rate}")

    def _alive_params(self, m):
        u = _per_id_uniforms(3, self.seed, m)
        # inverse-CDF geometric spell lengths (>= 1 round each)
        log1p = np.log1p(-self.rate)
        up = 1 + np.floor(np.log(1.0 - u[:, 0]) / log1p).astype(np.int64)
        down = 1 + np.floor(np.log(1.0 - u[:, 1]) / log1p).astype(np.int64)
        phase = np.floor(u[:, 2] * (up + down)).astype(np.int64)
        return up, down, phase

    def _alive(self, params, ids, t):
        up, down, phase = params
        period = up[ids] + down[ids]
        return ((t + phase[ids]) % period) < up[ids]


@dataclasses.dataclass(eq=False)
class LifetimeChurn(ChurnProcess):
    """Exponential(mean) lifetimes with arrivals staggered over
    ``[0, stagger]`` rounds; departed clients never return."""

    mean: float = 20.0
    stagger: int = 0
    seed: int = 0

    def __post_init__(self):
        super().__init__()
        if self.mean <= 0:
            raise ValueError(
                f"lifetime churn mean must be > 0, got {self.mean}")
        if self.stagger < 0:
            raise ValueError(
                f"lifetime churn stagger must be >= 0, got {self.stagger}")

    def _alive_params(self, m):
        u = _per_id_uniforms(2, self.seed, m)
        arrival = np.floor(u[:, 0] * (self.stagger + 1)).astype(np.int64)
        life = np.maximum(
            1, np.ceil(-self.mean * np.log(1.0 - u[:, 1]))).astype(np.int64)
        return arrival, life

    def _alive(self, params, ids, t):
        arrival, life = params
        a = arrival[ids]
        return (a <= t) & (t < a + life[ids])


def make_churn(spec: "str | ChurnProcess", seed: int = 0) -> ChurnProcess:
    """Parse a churn spec (see module docstring) or pass one through."""
    if isinstance(spec, ChurnProcess):
        return spec
    kind, _, rest = str(spec).partition(":")
    known = ", ".join(k + ":..." for k in CHURN_KINDS)
    if kind not in CHURN_KINDS:
        raise ValueError(
            f"unknown churn spec {spec!r}; expected one of {known}")
    parts = [p.strip() for p in rest.split(",") if p.strip()]
    try:
        if kind == "step":
            kv = {"frac": 0.5}
            pos = []
            for p in parts:
                k, eq, v = p.partition("=")
                if eq:
                    kv[k.strip()] = float(v)
                else:
                    pos.append(float(p))
            if pos:
                kv["t"] = pos[0]
                if len(pos) > 1:
                    kv["frac"] = pos[1]
            return StepChurn(t0=int(kv["t"]), frac=float(kv["frac"]),
                             seed=seed)
        if kind == "poisson":
            return PoissonChurn(rate=float(parts[0]), seed=seed)
        mean = float(parts[0])
        stagger = int(float(parts[1])) if len(parts) > 1 else 0
        return LifetimeChurn(mean=mean, stagger=stagger, seed=seed)
    except (KeyError, IndexError, ValueError) as e:
        if isinstance(e, ValueError) and e.args and "churn" in str(e):
            raise
        raise ValueError(
            f"bad parameters in churn spec {spec!r} ({e!r}); expected "
            f"'step:t=T[,frac=f]', 'poisson:rate', or "
            f"'lifetime:mean[,stagger]'") from e
