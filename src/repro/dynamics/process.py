"""Time-varying channels: a process wrapper over ``ChannelModel``.

``ChannelModel`` draws static per-client link attributes; a
``ChannelProcess`` modulates them per round with deterministic per-field
multipliers and overlays correlated regional outages. Every draw is a
pure function of ``(field, client_id, round)`` and the process seed —
O(1) storage at any population size, bit-reproducible across drivers
and cohort compositions (the same guarantees the static attribute
streams in ``repro.comm.channel`` give).

Multiplier spec grammar (``"+"``-chained, applied left to right):

  * ``"sin:period,amp"`` — diurnal cycle ``1 + amp*sin(2*pi*(t+phi_j)/
    period)`` with a seeded per-client phase ``phi_j`` in ``[0,
    period)`` (clients peak at different hours);
  * ``"drift:rate"`` — monotone exponential drift ``exp(+/-rate * t)``
    with a seeded per-client direction (half the links improve, half
    degrade).

Multipliers are clipped to ``[0.05, 20]`` so a deep trough can never
zero a bandwidth. Bandwidth fields (``uplink_bytes_per_s``/
``downlink_bytes_per_s``) get *slower* when the multiplier dips below 1;
``latency_s``/``compute_s`` get slower when it rises above 1 — the
multiplier always scales the field's value, whatever its unit.

Outages (``outage="outage:p,dur[,groups]"``): time is cut into windows
of ``dur`` rounds; per window, each of ``groups`` regions (region of
client ``j`` = ``j % groups``, default 8) goes dark with probability
``p`` for the whole window — every member of a dark region is forced to
drop, a *correlated* failure no iid dropout coin reproduces.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.channel import ChannelDraw

MODULATOR_KINDS = ("sin", "drift")

MULT_MIN, MULT_MAX = 0.05, 20.0

_FIELDS = ("uplink_bytes_per_s", "downlink_bytes_per_s", "latency_s",
           "compute_s")

_OUTAGE_TAG = zlib.crc32(b"repro.dynamics.outage")


def _parse_modulator(spec: str) -> "tuple[tuple[str, tuple[float, ...]], ...]":
    """Parse a ``"+"``-chained multiplier spec into (kind, params) stages."""
    stages = []
    known = ", ".join(k + ":..." for k in MODULATOR_KINDS)
    for part in str(spec).split("+"):
        part = part.strip()
        if not part:
            continue
        kind, _, rest = part.partition(":")
        if kind not in MODULATOR_KINDS:
            raise ValueError(
                f"unknown channel modulator {part!r} in {spec!r}; "
                f"expected one of {known}")
        try:
            params = tuple(float(p) for p in rest.split(",") if p != "")
        except ValueError:
            raise ValueError(
                f"bad parameters in channel modulator {part!r} (spec "
                f"{spec!r}); expected {known}") from None
        want = 2 if kind == "sin" else 1
        if len(params) != want:
            raise ValueError(
                f"channel modulator {part!r} wants {want} parameter(s), "
                f"got {len(params)} (spec {spec!r})")
        if kind == "sin" and params[0] <= 0:
            raise ValueError(
                f"sin modulator period must be > 0 in {part!r}")
        stages.append((kind, params))
    if not stages:
        raise ValueError(
            f"empty channel modulator spec {spec!r}; expected one of {known}")
    return tuple(stages)


@functools.lru_cache(maxsize=None)
def _mod_sampler(spec: str, salt: int):
    """Compiled per-id multiplier for one (modulator spec, field salt):
    ``mult(j, t)`` is a pure function of ``(spec, salt, j, t)``."""
    stages = _parse_modulator(spec)
    key0 = jax.random.PRNGKey(np.uint32(salt))  # noqa: RA001 — documented (seed, id) salt: modulator phases must be pure per id across drivers

    def one(cid, t):
        mult = 1.0
        for i, (kind, params) in enumerate(stages):
            k = jax.random.fold_in(jax.random.fold_in(key0, i), cid)
            if kind == "sin":
                period, amp = params
                phase = jax.random.uniform(k) * period
                mult = mult * (1.0 + amp * jnp.sin(
                    2.0 * jnp.pi * (t + phase) / period))
            else:  # drift
                (rate,) = params
                sign = jnp.where(jax.random.bernoulli(k), 1.0, -1.0)
                mult = mult * jnp.exp(sign * rate * t)
        return jnp.clip(mult, MULT_MIN, MULT_MAX)

    return jax.jit(jax.vmap(one, in_axes=(0, None)))


def _parse_outage(spec: str) -> "tuple[float, int, int]":
    kind, _, rest = str(spec).partition(":")
    if kind != "outage":
        raise ValueError(
            f"unknown outage spec {spec!r}; expected "
            f"'outage:p,dur[,groups]'")
    try:
        params = tuple(float(p) for p in rest.split(",") if p != "")
    except ValueError:
        raise ValueError(
            f"bad parameters in outage spec {spec!r}; expected "
            f"'outage:p,dur[,groups]'") from None
    if len(params) not in (2, 3):
        raise ValueError(
            f"outage spec {spec!r} wants 2-3 parameters (p, dur[, groups]), "
            f"got {len(params)}")
    p, dur = params[0], int(params[1])
    groups = int(params[2]) if len(params) == 3 else 8
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"outage probability must be in [0, 1], got {p}")
    if dur < 1 or groups < 1:
        raise ValueError(
            f"outage duration and group count must be >= 1 in {spec!r}")
    return p, dur, groups


@functools.lru_cache(maxsize=None)
def _outage_window(p: float, groups: int, salt: int, window: int) -> tuple:
    """Which regions are dark in one outage window (seeded, correlated)."""
    key = jax.random.fold_in(jax.random.PRNGKey(np.uint32(salt)), window)  # noqa: RA001 — documented (seed, window) salt: outage draws must be pure per window
    dark = jax.random.bernoulli(key, p, (groups,))
    return tuple(bool(b) for b in np.asarray(dark))


@dataclasses.dataclass(frozen=True)
class ChannelProcess:
    """Deterministic round-indexed modulation of a ``ChannelModel``.

    Field attributes take multiplier specs (see module docstring) or
    ``None`` (field untouched); ``outage`` takes an outage spec or
    ``None``. ``at(base, t)`` returns a view with ``ChannelModel``'s
    draw/time signatures, bound to round ``t`` — the sessions swap it in
    per round, so the base model (and every config hashing on it) stays
    frozen and static.
    """

    uplink_bytes_per_s: "str | None" = None
    downlink_bytes_per_s: "str | None" = None
    latency_s: "str | None" = None
    compute_s: "str | None" = None
    outage: "str | None" = None
    seed: int = 0

    def __post_init__(self):
        # parse every spec eagerly: bad grammar fails at config time
        for field in _FIELDS:
            spec = getattr(self, field)
            if spec is not None:
                _parse_modulator(spec)
        if self.outage is not None:
            _parse_outage(self.outage)

    @property
    def has_outage(self) -> bool:
        return self.outage is not None

    def multiplier(self, field: str, ids, t: int) -> np.ndarray:
        """(len(ids),) multiplicative modulation of ``field`` at round
        ``t`` — pure in ``(field, seed, id, round)``."""
        spec = getattr(self, field)
        ids = np.asarray(ids, dtype=np.int64)
        if spec is None:
            return np.ones(len(ids), dtype=np.float64)
        salt = (zlib.crc32(field.encode()) ^ (self.seed & 0xFFFFFFFF)) \
            & 0xFFFFFFFF
        mult = _mod_sampler(str(spec), salt)(
            jnp.asarray(ids, jnp.uint32), float(t))
        return np.asarray(mult, dtype=np.float64)

    def outage_mask(self, ids, t: int) -> np.ndarray:
        """(len(ids),) bool — is each client's region dark at round ``t``?"""
        ids = np.asarray(ids, dtype=np.int64)
        if self.outage is None:
            return np.zeros(len(ids), dtype=bool)
        p, dur, groups = _parse_outage(self.outage)
        salt = (_OUTAGE_TAG ^ (self.seed & 0xFFFFFFFF)) & 0xFFFFFFFF
        dark = np.asarray(
            _outage_window(p, groups, salt, int(t) // dur), dtype=bool)
        return dark[ids % groups]

    def at(self, base, t: int) -> "RoundChannel":
        """The channel as seen at round ``t`` (a ``ChannelModel``-shaped
        view over ``base``)."""
        return RoundChannel(base, self, int(t))


class RoundChannel:
    """One round's view of a modulated channel.

    Mirrors the ``ChannelModel`` methods the sessions call (``draw`` /
    ``draw_for`` / ``client_times`` / ``client_times_for`` /
    ``round_time`` / ``round_time_for`` / the per-field rate views) with
    identical signatures, applying the process's multipliers to the
    base model's fields and OR-ing regional outages into the dropout
    coins. Stateless: constructed per round by the sessions.
    """

    def __init__(self, base, process: ChannelProcess, t: int):
        self._base = base
        self._process = process
        self._t = t

    def _field(self, name: str, ids, m: int) -> np.ndarray:
        vals = self._base._field(name, ids, m)
        idv = np.arange(m, dtype=np.int64) if ids is None else ids
        return vals * self._process.multiplier(name, idv, self._t)

    # -- rate views (BandwidthAware samples on the modulated rates) ---------
    def uplink_rates(self, m: int) -> np.ndarray:
        return self._field("uplink_bytes_per_s", None, m)

    def downlink_rates(self, m: int) -> np.ndarray:
        return self._field("downlink_bytes_per_s", None, m)

    def compute_times(self, m: int) -> np.ndarray:
        return self._field("compute_s", None, m)

    def latencies(self, m: int) -> np.ndarray:
        return self._field("latency_s", None, m)

    def uplink_rates_for(self, ids, m: int) -> np.ndarray:
        return self._field("uplink_bytes_per_s", ids, m)

    def downlink_rates_for(self, ids, m: int) -> np.ndarray:
        return self._field("downlink_bytes_per_s", ids, m)

    def compute_times_for(self, ids, m: int) -> np.ndarray:
        return self._field("compute_s", ids, m)

    def latencies_for(self, ids, m: int) -> np.ndarray:
        return self._field("latency_s", ids, m)

    # -- coins ---------------------------------------------------------------
    def _with_outage(self, draw: ChannelDraw, ids) -> ChannelDraw:
        if not self._process.has_outage:
            return draw
        out = self._process.outage_mask(ids, self._t)
        return dataclasses.replace(draw, dropout=draw.dropout | out)

    def draw(self, key, m: int) -> ChannelDraw:
        return self._with_outage(self._base.draw(key, m),
                                 np.arange(m, dtype=np.int64))

    def draw_for(self, key, ids) -> ChannelDraw:
        return self._with_outage(self._base.draw_for(key, ids),
                                 np.asarray(ids, dtype=np.int64))

    # -- times ---------------------------------------------------------------
    def client_times(self, draw, bytes_up, bytes_down) -> np.ndarray:
        m = draw.straggler.shape[0]
        t = (self.latencies(m) + bytes_down / self.downlink_rates(m)
             + self.compute_times(m) + bytes_up / self.uplink_rates(m))
        return np.where(draw.straggler, t * self._base.straggler_slowdown, t)

    def client_times_for(self, ids, m, draw, bytes_up,
                         bytes_down) -> np.ndarray:
        t = (self.latencies_for(ids, m)
             + bytes_down / self.downlink_rates_for(ids, m)
             + self.compute_times_for(ids, m)
             + bytes_up / self.uplink_rates_for(ids, m))
        return np.where(draw.straggler, t * self._base.straggler_slowdown, t)

    def round_time(self, draw, delivered, bytes_up, bytes_down) -> float:
        t = self.client_times(draw, bytes_up, bytes_down)
        if not delivered.any():
            return float(np.mean(self.latencies(draw.straggler.shape[0])))
        return float(np.max(t[delivered]))

    def round_time_for(self, ids, m, draw, delivered, bytes_up,
                       bytes_down) -> float:
        if not delivered.any():
            lat = self.latencies_for(ids, m)
            return float(np.mean(lat)) if len(lat) else 0.0
        t = self.client_times_for(ids, m, draw, bytes_up, bytes_down)
        return float(np.max(t[delivered]))
