"""``DynamicsConfig``: the one knob that threads scenario dynamics
through ``CommConfig``.

``CommConfig(dynamics=DynamicsConfig(...))`` composes up to four
independent layers — churn, a time-varying channel process, a Byzantine
threat model, and a robust aggregation chain. Each accepts either a
spec string (parsed by the layer's ``make_*``) or a constructed object;
``None`` (the default everywhere) turns the layer off. An all-``None``
config is *null* and ``CommConfig`` normalizes it away entirely, so the
no-dynamics code paths stay literally unchanged.

``seed`` feeds every layer whose spec-string form doesn't carry its
own: churn lifetimes, channel modulator phases, outage windows, and the
attacker subset all derive their per-id streams from it (objects passed
directly keep their own seeds).
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.dynamics.churn import ChurnProcess, make_churn
from repro.dynamics.process import ChannelProcess
from repro.dynamics.robust import RobustAggregator, make_aggregator
from repro.dynamics.threat import ThreatModel, make_threat


@dataclasses.dataclass
class DynamicsConfig:
    """Scenario-dynamics description (see module docstring).

    ``churn`` — ``"step:t=T[,frac=f]" | "poisson:rate" |
    "lifetime:mean[,stagger]"`` or a ``ChurnProcess``;
    ``channel`` — a ``ChannelProcess`` (field multiplier specs +
    optional ``outage="outage:p,dur[,groups]"``);
    ``threat`` — ``"signflip:f" | "scale:f[,c]" | "noise:f[,s]"`` or a
    ``ThreatModel``;
    ``robust`` — ``"clip:tau" | "trimmed:f" | "median"``
    (``"+"``-chainable) or a ``RobustAggregator``.
    """

    churn: "str | ChurnProcess | None" = None
    channel: "ChannelProcess | None" = None
    threat: "str | ThreatModel | None" = None
    robust: "str | RobustAggregator | None" = None
    seed: int = 0

    def __post_init__(self):
        if self.churn is not None:
            self.churn = make_churn(self.churn, seed=self.seed)
        if self.channel is not None and not isinstance(
                self.channel, ChannelProcess):
            raise ValueError(
                f"DynamicsConfig.channel wants a ChannelProcess, got "
                f"{self.channel!r} — field multipliers need to be named "
                f"(e.g. ChannelProcess(uplink_bytes_per_s='sin:24,0.5'))")
        if self.threat is not None:
            self.threat = make_threat(self.threat, seed=self.seed)
        if self.robust is not None:
            self.robust = make_aggregator(self.robust)

    @property
    def is_null(self) -> bool:
        """No layer active: behave exactly as if dynamics were None."""
        return (self.churn is None and self.channel is None
                and self.threat is None and self.robust is None)

    @property
    def forces_mask(self) -> bool:
        """Churn and outages invalidate the statically-full fast paths:
        the delivery mask must be traced even under a full scheduler
        with no iid dropout."""
        return (self.churn is not None
                or (self.channel is not None and self.channel.has_outage))

    def describe(self) -> "dict[str, Any]":
        """JSON-friendly summary for benchmark/example records."""
        return {
            "churn": getattr(self.churn, "__class__", type(None)).__name__
            if self.churn is not None else None,
            "channel": dataclasses.asdict(self.channel)
            if self.channel is not None else None,
            "threat": (f"{self.threat.kind}:{self.threat.fraction}"
                       + (f"@{'+'.join(self.threat.payloads)}"
                          if self.threat.payloads else ""))
            if self.threat is not None else None,
            "robust": self.robust.name if self.robust is not None else None,
        }
