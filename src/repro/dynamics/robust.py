"""Robust server-side aggregation transforms for uplink payloads.

A robust aggregator is a traced transform of the decoded, stacked
``(c, ...)`` uplink payload, applied by ``CommRound.uplink`` AFTER the
codec decode (the server defends itself with what it received) and
BEFORE the optimizer's weighted aggregation — so it composes with the
existing participation weights (``CommRound.weights`` renormalizes over
the delivering cohort) and, in the async driver, with staleness
weights: the composition order is clip -> trim/median -> staleness ->
participation.

Aggregators (spec grammar, ``"+"``-chained left to right, parsed by
``make_aggregator``):

  * ``"clip:tau"`` — per-client norm clipping: row ``i`` is scaled by
    ``min(1, tau/||x_i||)``. Defeats scaled-gradient attacks; leaves
    norm-preserving attacks (sign flips) untouched.
  * ``"trimmed:f"`` — coordinate-wise trimmed mean: per coordinate, the
    ``ceil(f*c)`` largest and smallest delivered contributions are
    discarded and every row is replaced by the mean of the survivors.
    Because the downstream participation weights sum to 1 over the
    cohort, the weighted aggregate then equals the trimmed mean —
    robust to any ``< f`` fraction of outliers, including sign flips.
  * ``"median"`` — coordinate-wise median of the delivered rows
    (the ``f -> 1/2`` limit of trimming; maximally robust, highest
    bias).

Undelivered rows (the delivery mask) never count as extremes: they are
replaced by the delivered mean before sorting, so dropout cannot eat
the trim budget. Row-replacing aggregators (trim/median) broadcast the
robust aggregate back to every row — each client's "contribution" IS
the aggregate, which is exactly what makes the subsequent weighted sum
produce it.

Each transform accumulates traced counters into the round's
``stats_out`` dict (``uploads_clipped``, ``uploads_trimmed``), which
the sessions drain into ``repro.obs`` after each round.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

ROBUST_KINDS = ("clip", "trimmed", "median")


def _bump(stats: dict, key: str, value) -> None:
    stats[key] = stats.get(key, 0.0) + value


def _mask_col(mask, c: int, dtype):
    """(c, 1) 0/1 delivery column, or None for a fully-delivered cohort."""
    if mask is None:
        return None
    return jnp.asarray(mask, dtype).reshape(-1, 1)[:c]


class RobustAggregator:
    """Base: ``__call__(x, mask, stats) -> x_robust`` (traced)."""

    name: str = "robust"

    def __call__(self, x, mask, stats: dict):
        raise NotImplementedError

    def __repr__(self):
        return f"{type(self).__name__}({self.name!r})"


@dataclasses.dataclass(frozen=True)
class ClipAggregator(RobustAggregator):
    """Per-client norm clipping to radius ``tau``."""

    tau: float = 1.0

    def __post_init__(self):
        if self.tau <= 0:
            raise ValueError(f"clip tau must be > 0, got {self.tau}")

    @property
    def name(self):
        return f"clip:{self.tau}"

    def __call__(self, x, mask, stats):
        c = x.shape[0]
        flat = x.reshape(c, -1)
        norms = jnp.linalg.norm(flat, axis=1)
        tau = jnp.asarray(self.tau, x.dtype)
        factor = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-30))
        clipped = (norms > tau).astype(x.dtype)
        mcol = _mask_col(mask, c, x.dtype)
        if mcol is not None:
            clipped = clipped * mcol[:, 0]
        _bump(stats, "uploads_clipped", jnp.sum(clipped))
        return x * factor.reshape((-1,) + (1,) * (x.ndim - 1))


@dataclasses.dataclass(frozen=True)
class TrimmedMean(RobustAggregator):
    """Coordinate-wise trimmed mean over the delivered rows."""

    fraction: float = 0.1

    def __post_init__(self):
        if not 0.0 < self.fraction < 0.5:
            raise ValueError(
                f"trimmed fraction must be in (0, 0.5), got {self.fraction}")

    @property
    def name(self):
        return f"trimmed:{self.fraction}"

    def _trims(self, c: int) -> int:
        k = max(1, int(math.ceil(self.fraction * c)))
        if 2 * k >= c:  # tiny cohorts: keep at least one survivor
            k = (c - 1) // 2
        return k

    def __call__(self, x, mask, stats):
        c = x.shape[0]
        k = self._trims(c)
        flat = x.reshape(c, -1)
        mcol = _mask_col(mask, c, x.dtype)
        if mcol is not None:
            # undelivered rows -> delivered mean: never an extreme, so
            # dropout cannot consume the trim budget
            n_del = jnp.maximum(jnp.sum(mcol), 1.0)
            mean_del = jnp.sum(flat * mcol, axis=0, keepdims=True) / n_del
            flat = mcol * flat + (1 - mcol) * mean_del
        if k == 0:
            agg = jnp.mean(flat, axis=0, keepdims=True)
            _bump(stats, "uploads_trimmed", jnp.asarray(0.0, x.dtype))
        else:
            srt = jnp.sort(flat, axis=0)
            agg = jnp.mean(srt[k:c - k], axis=0, keepdims=True)
            lo, hi = srt[k:k + 1], srt[c - k - 1:c - k]
            out = ((flat < lo) | (flat > hi)).astype(x.dtype)
            if mcol is not None:
                out = out * mcol
            # row-equivalents trimmed: coordinate trims / n_coordinates
            _bump(stats, "uploads_trimmed",
                  jnp.sum(out) / flat.shape[1])
        return jnp.broadcast_to(agg, flat.shape).reshape(x.shape)


@dataclasses.dataclass(frozen=True)
class CoordinateMedian(RobustAggregator):
    """Coordinate-wise median over the delivered rows."""

    name = "median"

    def __call__(self, x, mask, stats):
        c = x.shape[0]
        flat = x.reshape(c, -1)
        mcol = _mask_col(mask, c, x.dtype)
        if mcol is None:
            agg = jnp.median(flat, axis=0, keepdims=True)
        else:
            # delivered-only median: undelivered rows sort to +inf and
            # the (traced) delivered count indexes the middle
            big = jnp.where(mcol > 0, flat, jnp.inf)
            srt = jnp.sort(big, axis=0)
            n = jnp.sum(mcol[:, 0]).astype(jnp.int32)
            n = jnp.maximum(n, 1)
            agg = 0.5 * (srt[(n - 1) // 2] + srt[n // 2])[None, :]
        return jnp.broadcast_to(agg, flat.shape).reshape(x.shape)


@dataclasses.dataclass(frozen=True)
class ChainAggregator(RobustAggregator):
    """Left-to-right composition of robust transforms."""

    stages: "tuple[RobustAggregator, ...]" = ()

    @property
    def name(self):
        return "+".join(s.name for s in self.stages)

    def __call__(self, x, mask, stats):
        for stage in self.stages:
            x = stage(x, mask, stats)
        return x


def make_aggregator(
        spec: "str | RobustAggregator") -> RobustAggregator:
    """Parse ``"clip:tau" | "trimmed:f" | "median"`` (``"+"``-chainable,
    e.g. ``"clip:5+trimmed:0.1"``) or pass an aggregator through."""
    if isinstance(spec, RobustAggregator):
        return spec
    known = "clip:tau, trimmed:f, median"
    stages = []
    for part in str(spec).split("+"):
        part = part.strip()
        if not part:
            continue
        kind, _, rest = part.partition(":")
        if kind not in ROBUST_KINDS:
            raise ValueError(
                f"unknown robust aggregator {part!r} in {spec!r}; "
                f"expected one of {known}")
        try:
            if kind == "clip":
                stages.append(ClipAggregator(tau=float(rest or 1.0)))
            elif kind == "trimmed":
                stages.append(TrimmedMean(fraction=float(rest or 0.1)))
            else:
                if rest:
                    raise ValueError(
                        f"median takes no parameters, got {part!r}")
                stages.append(CoordinateMedian())
        except ValueError as e:
            if e.args and ("must be" in str(e) or "takes no" in str(e)):
                raise
            raise ValueError(
                f"bad parameters in robust aggregator {part!r} (spec "
                f"{spec!r}); expected one of {known}") from e
    if not stages:
        raise ValueError(
            f"empty robust aggregator spec {spec!r}; expected one of {known}")
    if len(stages) == 1:
        return stages[0]
    return ChainAggregator(stages=tuple(stages))
