import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
).strip()

"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every requested (arch x input-shape x mesh) combination
against 512 forced host devices, records memory_analysis / cost_analysis /
collective bytes, and emits one JSON blob per combo for §Dry-run and
§Roofline. MUST set XLA_FLAGS before any other import (above) — jax locks
the device count at first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh single --out results/
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import numpy as np

# TPU v5e hardware model (targets; container runs the compiler only)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link


def run_one(arch: str, shape: str, mesh_kind: str, out_dir: pathlib.Path,
            *, donate: bool = True) -> dict:
    from repro.configs import get_config, supported_shapes
    from repro.launch.hlo_stats import collective_stats, op_histogram
    from repro.launch.input_specs import build
    from repro.launch.mesh import make_production_mesh
    from repro.sharding.ctx import use_mesh

    def _write(rec):
        out_dir.mkdir(parents=True, exist_ok=True)
        fname = out_dir / f"{arch.replace('.', '_')}__{shape}__{mesh_kind}.json"
        fname.write_text(json.dumps(rec, indent=2, default=str))
        return rec

    cfg = get_config(arch)
    if shape not in supported_shapes(cfg):
        return _write({
            "arch": arch, "shape": shape, "mesh": mesh_kind,
            "status": "skipped",
            "reason": "shape unsupported for this family (DESIGN.md §4.2)",
        })

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(mesh.devices.shape))
    t0 = time.perf_counter()
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "chips": n_chips}
    try:
        with use_mesh(mesh):
            spec = build(arch, shape, mesh)
            jitted = jax.jit(
                spec.fn,
                in_shardings=spec.in_shardings,
                out_shardings=spec.out_shardings,
                donate_argnums=spec.donate_argnums if donate else (),
            )
            lowered = jitted.lower(*spec.args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

            from repro.compat import cost_analysis

            mem = compiled.memory_analysis()
            cost = cost_analysis(compiled)
            hlo = compiled.as_text()
        coll = collective_stats(hlo)

        flops_total = float(cost.get("flops", 0.0))
        # cost_analysis flops are per-device under SPMD
        bytes_total = float(cost.get("bytes accessed", 0.0))
        coll_bytes_per_dev = coll["total_bytes"]

        compute_s = flops_total / PEAK_FLOPS
        memory_s = bytes_total / HBM_BW
        collective_s = coll_bytes_per_dev / ICI_BW
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": collective_s}
        dominant = max(terms, key=terms.get)

        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "n_params": spec.n_params,
            "n_active_params": spec.n_active_params,
            "model_flops_global": spec.model_flops,
            "model_flops_per_chip": spec.model_flops / n_chips,
            "hlo_flops_per_chip": flops_total,
            "hlo_bytes_per_chip": bytes_total,
            "collective_bytes_per_chip": coll_bytes_per_dev,
            "collectives": coll["per_kind"],
            "roofline": {
                **{k: float(v) for k, v in terms.items()},
                "dominant": dominant,
                "useful_flops_ratio": (
                    spec.model_flops / n_chips / flops_total
                    if flops_total else None
                ),
            },
            "memory": {
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
                "peak_bytes_estimate": int(
                    getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "output_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)
                    - getattr(mem, "alias_size_in_bytes", 0)
                ),
            },
            "top_ops": op_histogram(hlo, top=15),
        })
    except Exception as e:  # noqa: BLE001 — a failed combo is a bug to record
        rec.update({
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        })
    return _write(rec)


def main() -> None:
    from repro.configs import _ALIASES, INPUT_SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    archs = list(_ALIASES) if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                t0 = time.perf_counter()
                rec = run_one(arch, shape, mesh_kind, out_dir)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']}"
                             f" comp={r['compute_s']:.3e}s"
                             f" mem={r['memory_s']:.3e}s"
                             f" coll={r['collective_s']:.3e}s")
                elif status == "error":
                    extra = " " + rec["error"][:160]
                print(f"[{time.perf_counter()-t0:7.1f}s] {arch:22s} {shape:12s} "
                      f"{mesh_kind:6s} {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
