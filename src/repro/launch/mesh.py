"""Mesh factories (functions, not module constants — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Production TPU v5e meshes: 16x16 per pod; 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *, pods: int = 0):
    """Small forced-host-device mesh for sharding tests."""
    if pods:
        return jax.make_mesh((pods, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
