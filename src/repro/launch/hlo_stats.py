"""Parse compiled HLO text for roofline inputs.

``collective_bytes(hlo_text)`` sums the result-shape bytes of every
communication op (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute) — the quantity the ICI roofline term divides by link
bandwidth. ``op_histogram`` supports the §Perf iteration loop (spotting
redundant collectives / remat recompute).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %ag = bf16[16,1024,512]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*((?:[a-z0-9]+\[[0-9,]*\][^ ]*\s*,?\s*)+)\s*"
    r"((?:%?[\w-]+)?(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)[\w-]*)\("
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-kind {count, bytes} + total bytes for collective ops."""
    stats = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result shapes appear before '=' ... find 'kind(' occurrence
        m = None
        for kind in _COLLECTIVES:
            # match ops like "all-reduce(", "all-gather-start(", fusions excluded
            if re.search(rf"\b{kind}(?:-start|-done)?\(", stripped):
                m = kind
                break
        if m is None:
            continue
        if f"{m}-done" in stripped:
            continue  # bytes counted at -start
        lhs = stripped.split("=", 1)
        if len(lhs) != 2:
            continue
        # result shape(s) precede the op name on the rhs
        rhs = lhs[1]
        op_pos = rhs.find(m)
        shape_part = rhs[:op_pos]
        nbytes = _shape_bytes(shape_part)
        stats[m]["count"] += 1
        stats[m]["bytes"] += nbytes
    total = sum(v["bytes"] for v in stats.values())
    return {"per_kind": dict(stats), "total_bytes": total}


def op_histogram(hlo_text: str, top: int = 25) -> list[tuple[str, int]]:
    """Histogram of HLO opcode occurrences (debug aid for §Perf)."""
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1]
        m = re.search(r"\b([a-z][a-z0-9-]*)\(", rhs)
        if m:
            counts[m.group(1)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
