import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
).strip()

"""Dry-run of the PAPER'S OWN communication pattern on the production mesh.

Clients are data-parallel mesh slices (one shard of the global dataset per
(pod, data) slice); one FLeNS round is lowered with pjit so the uplink
aggregation appears as an explicit cross-client collective in the HLO:

  * flens      — all-reduce of the k x k sketched Hessian + k-dim sketched
                 gradient  (the O(k^2) wire cost of the paper's Table I)
  * fedns      — all-reduce of the (k x M) sketched sqrt-Hessian + M-dim
                 gradient  (O(kM))
  * fednewton  — all-reduce of the full M x M Hessian + M-dim gradient
                 (O(M^2))

The measured collective bytes per round reproduce Table I's communication
column structurally — on the compiled production topology rather than on
paper. Results land in results/dryrun_flens/.

  PYTHONPATH=src python -m repro.launch.dryrun_flens --dim 4096 --k 256
"""
import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import cost_analysis, shard_map
from repro.core.base import root_key


def build_round(method: str, dim: int, k: int, n_per_client: int, lam: float):
    """Returns fn(X, y, w, seed_signs, rows) -> w_next for one round."""

    def hess_sqrt(X, y, w):
        margins = y * (X @ w)
        pr = jax.nn.sigmoid(margins)
        d = pr * (1 - pr)
        return X * jnp.sqrt(d / X.shape[0])[:, None]

    def grad(X, y, w):
        margins = y * (X @ w)
        s = jax.nn.sigmoid(-margins)
        return -(X.T @ (s * y)) / X.shape[0] + lam * w

    def flens_round(X, y, w, signs, rows):
        # per-client (= per data shard) quantities; mean over the client
        # axis IS the server aggregation (psum emitted by pjit).
        # The SRHT is the shared repro.core.sketch operator (dim a power
        # of two here, so the padded domain is the native one): the
        # roofline dry-run lowers the SAME srht_apply/srht_apply_t code
        # path — repro.kernels.ops dispatch included — that the bench
        # gate times, instead of a private inline copy.
        from repro.core.sketch import SrhtSketch

        s = SrhtSketch(k=k, dim=dim, signs=signs, rows=rows)
        a = hess_sqrt(X, y, w)  # (n, dim)
        b = s.apply(a)  # (n, k)
        h_sk = b.T @ b  # (k, k)  <- k^2 floats on the wire
        g_sk = s.apply(grad(X, y, w))  # (k,)
        h_sk = jax.lax.pmean(h_sk, ("pod", "data"))
        g_sk = jax.lax.pmean(g_sk, ("pod", "data"))
        sst = s.apply(s.apply_t(jnp.eye(k, dtype=w.dtype)))
        delta_k = jnp.linalg.solve(h_sk + lam * sst + 1e-8 * jnp.eye(k), g_sk)
        return w - s.apply_t(delta_k)

    return flens_round


def lower_method(method: str, mesh, dim: int, k: int, n_per_client: int,
                 lam: float = 1e-3):
    from repro.launch.hlo_stats import collective_stats

    n_clients = int(np.prod(mesh.devices.shape))
    if method == "flens":
        fn = build_round("flens", dim, k, n_per_client, lam)
        wire = k * k + k
    elif method == "fednewton":
        def fn(X, y, w, signs, rows):
            margins = y * (X @ w)
            pr = jax.nn.sigmoid(margins)
            d = pr * (1 - pr)
            h = (X.T * d) @ X / X.shape[0] + lam * jnp.eye(dim, dtype=w.dtype)
            s = jax.nn.sigmoid(-margins)
            g = -(X.T @ (s * y)) / X.shape[0] + lam * w
            h = jax.lax.pmean(h, ("pod", "data"))  # M x M on the wire
            g = jax.lax.pmean(g, ("pod", "data"))
            return w - jnp.linalg.solve(h, g)
        wire = dim * dim + dim
    elif method == "fedns":
        def fn(X, y, w, signs, rows):
            margins = y * (X @ w)
            pr = jax.nn.sigmoid(margins)
            d = pr * (1 - pr)
            a = X * jnp.sqrt(d / X.shape[0])[:, None]
            # per-client gaussian data-axis sketch (k x n) @ (n, dim):
            # every client shares one FIXED sketch seed (the FedNS wire
            # contract — the server must re-materialize the same S)
            key = root_key(0)
            s_mat = jax.random.normal(key, (k, X.shape[0]), w.dtype) / jnp.sqrt(
                jnp.asarray(k, w.dtype))
            sa = s_mat @ a  # (k, dim) on the wire per client
            s = jax.nn.sigmoid(-margins)
            g = -(X.T @ (s * y)) / X.shape[0] + lam * w
            # FedNS semantics: the server receives every client's (k, M)
            # sketch and sums the outer products — on the mesh this is an
            # all-gather over the client axis (a star-topology uplink has
            # no cheaper collective equivalent on a torus; see EXPERIMENTS)
            sa_all = jax.lax.all_gather(sa, "data")  # (n_data, k, dim)
            sa_all = jax.lax.all_gather(sa_all, "pod")  # (n_pod, n_data, k, dim)
            sa_flat = sa_all.reshape(-1, dim)
            h = (jnp.einsum("ka,kb->ab", sa_flat, sa_flat)
                 / (sa_all.shape[0] * sa_all.shape[1])
                 + lam * jnp.eye(dim, dtype=w.dtype))
            g = jax.lax.pmean(g, ("pod", "data"))
            return w - jnp.linalg.solve(h, g)
        wire = k * dim + dim
    else:
        raise ValueError(method)

    n2 = dim  # power-of-two dim assumed
    X = jax.ShapeDtypeStruct((n_clients * n_per_client, dim), jnp.float32)
    yv = jax.ShapeDtypeStruct((n_clients * n_per_client,), jnp.float32)
    w = jax.ShapeDtypeStruct((dim,), jnp.float32)
    signs = jax.ShapeDtypeStruct((n2,), jnp.float32)
    rows = jax.ShapeDtypeStruct((k,), jnp.int32)

    data_axes = P(("pod", "data"), None)
    shardings = (
        NamedSharding(mesh, data_axes),
        NamedSharding(mesh, P(("pod", "data"))),
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P()),
        NamedSharding(mesh, P()),
    )

    wrapped = shard_map(
        lambda X, y, w, signs, rows: fn(X, y, w[0], signs[0], rows[0])[None],
        mesh=mesh,
        in_specs=(P(("pod", "data"), None), P(("pod", "data")), P(None),
                  P(None), P(None)),
        out_specs=P(None),
        check_vma=False,
    )
    # broadcast-shaped w/signs/rows so shard_map replicates them
    args = (X, yv,
            jax.ShapeDtypeStruct((1, dim), jnp.float32),
            jax.ShapeDtypeStruct((1, n2), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.int32))
    lowered = jax.jit(wrapped).lower(*args)
    compiled = lowered.compile()
    coll = collective_stats(compiled.as_text())
    return {
        "method": method,
        "theory_wire_floats_per_client": wire,
        "collective_bytes_per_device": coll["total_bytes"],
        "collectives": coll["per_kind"],
        "flops_per_device": float(cost_analysis(compiled).get("flops", 0.0)),
    }


def main() -> None:
    from repro.launch.mesh import make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=4096)
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--n-per-client", type=int, default=2048)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun_flens")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=True)  # clients = pod x data = 32
    out = []
    for method in ("flens", "fedns", "fednewton"):
        rec = lower_method(method, mesh, args.dim, args.k, args.n_per_client)
        out.append(rec)
        print(f"{method:>10}: theory={rec['theory_wire_floats_per_client']:,} "
              f"floats/client; measured collective "
              f"{rec['collective_bytes_per_device']/1e6:.2f} MB/device",
              flush=True)
    pathlib.Path(args.out).mkdir(parents=True, exist_ok=True)
    (pathlib.Path(args.out) / "comm_rounds.json").write_text(
        json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
