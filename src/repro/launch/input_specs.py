"""ShapeDtypeStruct stand-ins + sharding trees per (arch x input shape).

``build(arch, shape, mesh)`` returns a ``LoweringSpec``:
  * ``fn``            — the step function to lower (train/prefill/serve)
  * ``args``          — ShapeDtypeStruct pytrees (no device allocation)
  * ``in_shardings`` / ``out_shardings`` — NamedSharding pytrees
plus bookkeeping (param count, model-FLOPs estimate) for §Roofline.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config
from repro.core.base import root_key
from repro.models.lm import LM
from repro.optim.adamw import adamw_init, adamw_update
from repro.sharding import rules
from repro.sharding.ctx import use_mesh


@dataclasses.dataclass
class LoweringSpec:
    arch: str
    shape: str
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    n_params: int
    n_active_params: int
    model_flops: float  # 6*N*D per step (MoE: active params)
    donate_argnums: tuple = ()


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def _count_params(shapes_tree) -> int:
    return int(sum(int(np.prod(leaf.shape))
                   for leaf in jax.tree.leaves(shapes_tree)))


def _active_params(cfg, params_shape) -> int:
    """Params touched per token (MoE: top_k of n_experts + the rest)."""
    total = _count_params(params_shape)
    if not cfg.n_experts:
        return total
    expert_total = 0
    gi = 1 if cfg.first_k_dense else 0
    for key, sub in params_shape.items():
        if not key.startswith("group"):
            continue
        if isinstance(sub, dict) and "moe" in sub:
            for nm in ("w_gate", "w_up", "w_down"):
                expert_total += int(np.prod(sub["moe"][nm].shape))
    active_frac = cfg.top_k / cfg.n_experts
    return int(total - expert_total + expert_total * active_frac)


def _batch_struct(cfg, b, t, *, train: bool):
    batch = {
        "inputs": jax.ShapeDtypeStruct((b, t), jnp.int32),
    }
    if train:
        batch["labels"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
    if cfg.family == "vlm":
        batch["vision"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_tokens, cfg.vision_dim), jnp.float32
        )
    if cfg.family == "audio":
        batch["audio_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.audio_frames, cfg.d_model), jnp.float32
        )
    return batch


def _serving_param_shardings(mesh, params_shape, param_sh, n_params):
    """Serving layout policy (§Perf hillclimb 2).

    At decode/prefill there is no optimizer state and weights are reused
    every step, so FSDP ("data"-axis) weight sharding only buys per-step
    all-gathers. If the model-parallel-only footprint fits comfortably
    (< 4 GB/chip), strip the fsdp axis (weight-stationary serving). The
    vocab table additionally drops its d_model sharding always — the
    unembed of a single token otherwise all-gathers the whole table.
    """

    # Measured (§Perf): stripping FSDP from *all* weights at decode trades
    # per-step all-gathers for 16x more per-device HBM weight reads — a net
    # regression for small-weight archs (mamba2 decode 0.8ms -> 1.7ms).
    # Only the vocab table (whose d_model-sharded contraction makes XLA
    # gather the whole table per step) keeps the replicated-D layout.
    strip_fsdp = False

    def fix(path, leaf, sh):
        names = rules._path_names(path)
        spec = list(sh.spec)
        # expert weights flip to the F-sharded decode layout so the MoE
        # decode path (activation-gather, moe.py) sees zero weight movement:
        # (E, D, F): (model, None, data);  (E, F, D): (model, data, None)
        if (len(names) >= 2 and names[-2] == "moe"
                and names[-1] in ("w_gate", "w_up", "w_down")):
            lead = [None] * (len(leaf.shape) - 3)
            if names[-1] == "w_down":
                return NamedSharding(mesh, rules._guard(
                    mesh, leaf.shape, tuple(lead) + ("model", "data", None)))
            return NamedSharding(mesh, rules._guard(
                mesh, leaf.shape, tuple(lead) + ("model", None, "data")))
        is_table = names and names[-1] in ("table", "lm_head")
        # replicate the table's d_model dim only when the vocab dim IS
        # model-sharded (otherwise the baseline D-sharded layout already
        # psums small logit partials and replication just adds HBM reads)
        vocab_sharded = any(ax == "model" or (isinstance(ax, tuple) and
                                              "model" in ax) for ax in spec)
        if (is_table and vocab_sharded) or strip_fsdp:
            spec = [
                (None if ax == "data" or (isinstance(ax, tuple) and "data" in ax)
                 else ax)
                for ax in spec
            ]
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(fix, params_shape, param_sh)


def build(arch: str, shape_name: str, mesh: Mesh, *,
          lr: float = 3e-4, opt_state_dtype=None) -> LoweringSpec:
    cfg = get_config(arch)
    shp = INPUT_SHAPES[shape_name]
    model = LM(cfg)

    params_shape = jax.eval_shape(model.init, root_key(0))
    n_params = _count_params(params_shape)
    n_active = _active_params(cfg, params_shape)
    param_sh = rules.tree_shardings(mesh, params_shape, rules.param_spec)
    if shp.kind == "decode":
        # prefill keeps FSDP (it is train-like: weight reads amortize over
        # the whole sequence — measured regression when stripped, §Perf)
        param_sh = _serving_param_shardings(mesh, params_shape, param_sh,
                                            n_params)

    if opt_state_dtype is None:
        # fp32 moments unless the model cannot fit them (1T-class MoE)
        opt_state_dtype = jnp.bfloat16 if n_params > 3e11 else jnp.float32

    if shp.kind == "train":
        import os as _os

        b, t = shp.global_batch, shp.seq_len
        micro = int(_os.environ.get("REPRO_MICROBATCH", "1"))
        zero_pod = _os.environ.get("REPRO_ZERO_POD", "0") == "1"
        batch = _batch_struct(cfg, b, t, train=True)
        batch_sh = jax.tree_util.tree_map_with_path(
            lambda p, leaf: NamedSharding(
                mesh, rules.batch_spec(mesh, p, leaf)), batch
        )
        opt_shape = jax.eval_shape(
            lambda p: adamw_init(p, state_dtype=opt_state_dtype), params_shape
        )
        moments_sh = param_sh
        if zero_pod and "pod" in mesh.axis_names:
            # ZeRO-1 over the pod axis: optimizer moments sharded one level
            # deeper than the params (update gathers them implicitly)
            def pod_spec(path, leaf):
                base = rules.param_spec(mesh, path, leaf)
                spec = list(base) + [None] * (len(leaf.shape) - len(base))
                for i, ax in enumerate(spec):
                    if ax is None and leaf.shape[i] % mesh.shape["pod"] == 0:
                        spec[i] = "pod"
                        break
                    if isinstance(ax, str) and ax != "pod":
                        cand = (ax, "pod")
                        if leaf.shape[i] % (
                            mesh.shape[ax] * mesh.shape["pod"]) == 0:
                            spec[i] = cand
                            break
                while spec and spec[-1] is None:
                    spec.pop()
                return P(*spec)

            moments_sh = jax.tree_util.tree_map_with_path(
                lambda p, leaf: NamedSharding(mesh, pod_spec(p, leaf)),
                params_shape
            )
        opt_sh = {
            "m": moments_sh,
            "v": moments_sh,
            "step": NamedSharding(mesh, P()),
        }

        def train_step(params, opt_state, batch):
            def loss_fn(p, mb):
                return model.loss(p, mb)

            if micro == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                # gradient accumulation: scan over microbatches, grads
                # accumulated in the param dtype (memory-bound regime)
                def split(x):
                    return x.reshape((micro, x.shape[0] // micro) + x.shape[1:])

                mbs = jax.tree.map(split, batch)

                def micro_step(acc, mb):
                    (lv, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, mb)
                    acc = jax.tree.map(
                        lambda a, gg: a + gg.astype(a.dtype), acc, g)
                    return acc, (lv, m["ce"], m["aux"])

                acc0 = jax.tree.map(jnp.zeros_like, params)
                grads, (ls, ces, auxs) = jax.lax.scan(micro_step, acc0, mbs)
                grads = jax.tree.map(lambda g: g / micro, grads)
                loss = jnp.mean(ls)
                metrics = {"ce": jnp.mean(ces), "aux": jnp.mean(auxs)}
            new_params, new_opt, gnorm = adamw_update(
                params, grads, opt_state, lr=lr
            )
            out_metrics = {
                "loss": loss, "ce": metrics["ce"], "aux": metrics["aux"],
                "grad_norm": gnorm,
            }
            return new_params, new_opt, out_metrics

        rep = NamedSharding(mesh, P())
        metrics_sh = {"loss": rep, "ce": rep, "aux": rep, "grad_norm": rep}
        # model fwd+bwd flops: ~6 * active params * tokens
        flops = 6.0 * n_active * b * t
        return LoweringSpec(
            arch, shape_name, train_step,
            (params_shape, opt_shape, batch),
            (param_sh, opt_sh, batch_sh),
            (param_sh, opt_sh, metrics_sh),
            n_params, n_active, flops,
            donate_argnums=(0, 1),
        )

    if shp.kind == "prefill":
        b, t = shp.global_batch, shp.seq_len
        batch = _batch_struct(cfg, b, t, train=False)
        batch_sh = jax.tree_util.tree_map_with_path(
            lambda p, leaf: NamedSharding(
                mesh, rules.batch_spec(mesh, p, leaf)), batch
        )

        def prefill(params, batch):
            return model.prefill(params, batch)

        with use_mesh(mesh):
            out_shape = jax.eval_shape(prefill, params_shape, batch)
        logits_sh = NamedSharding(mesh, rules._guard(
            mesh, out_shape[0].shape, ("data", "model"))
        )
        state_sh = rules.tree_shardings(
            mesh, out_shape[1], rules.state_spec, batch=b
        )
        flops = 2.0 * n_active * b * t  # forward only
        return LoweringSpec(
            arch, shape_name, prefill,
            (params_shape, batch),
            (param_sh, batch_sh),
            (logits_sh, state_sh),
            n_params, n_active, flops,
        )

    # decode
    b, s = shp.global_batch, shp.seq_len
    state_shape = jax.eval_shape(
        lambda: model.init_decode_state(b, s, index=s - 1)
    )
    state_sh = rules.tree_shardings(mesh, state_shape, rules.state_spec, batch=b)
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tokens_sh = NamedSharding(mesh, rules._guard(mesh, (b, 1), ("data", None)))

    def serve_step(params, state, tokens):
        return model.decode_step(params, state, tokens)

    with use_mesh(mesh):
        out_shape = jax.eval_shape(serve_step, params_shape, state_shape, tokens)
    logits_sh = NamedSharding(
        mesh, rules._guard(mesh, out_shape[0].shape, ("data", "model"))
    )
    flops = 2.0 * n_active * b * 1
    return LoweringSpec(
        arch, shape_name, serve_step,
        (params_shape, state_shape, tokens),
        (param_sh, state_sh, tokens_sh),
        (logits_sh, state_sh),
        n_params, n_active, flops,
        donate_argnums=(1,),
    )
