"""Serving launcher: batched prefill + greedy decode (CPU-runnable reduced).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.base import root_key
from repro.models.lm import LM


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = LM(cfg)
    params = model.init(root_key(args.seed))

    # one key per synthetic payload: the init stream stays disjoint from
    # the batch stream, and no key is drawn from twice
    k_inputs, k_vision, k_audio = jax.random.split(root_key(args.seed, 1), 3)
    batch = {"inputs": jax.random.randint(
        k_inputs, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            k_vision, (args.batch, cfg.vision_tokens, cfg.vision_dim),
            jnp.float32)
    if cfg.family == "audio":
        batch["audio_frames"] = jax.random.normal(
            k_audio, (args.batch, cfg.audio_frames, cfg.d_model), jnp.float32)

    cache_len = args.prompt_len + args.gen
    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cache_len))
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    logits, state = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = jnp.argmax(logits, axis=-1)[:, None]
    generated = [toks]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, state = decode(params, state, toks)
        toks = jnp.argmax(logits, axis=-1)[:, None]
        generated.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.perf_counter() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"arch={cfg.arch_id} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:,.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms for {args.gen-1} steps "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):,.0f} tok/s)")
    print("sample tokens:", np.asarray(out[0, :16]))


if __name__ == "__main__":
    main()
