"""Training launcher (CPU-runnable end-to-end; mesh-aware when available).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_config
from repro.core.base import root_key
from repro.data.lm_stream import FastLMStream
from repro.models.lm import LM
from repro.optim import adamw_init, adamw_update, linear_warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        overrides = {}
        if args.d_model:
            overrides["d_model"] = args.d_model
        if args.n_layers:
            overrides["n_layers"] = args.n_layers
        if args.vocab:
            overrides["vocab"] = args.vocab
        cfg = cfg.reduced(**overrides)
    model = LM(cfg)

    params = model.init(root_key(args.seed))
    opt_state = adamw_init(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.arch_id} params={n_params/1e6:.1f}M "
          f"layers={cfg.n_layers} d={cfg.d_model} vocab={cfg.vocab}")

    start = 0
    if args.ckpt_dir:
        st = latest_step(args.ckpt_dir)
        if st is not None:
            params = restore(args.ckpt_dir, st, params)
            opt_state = restore(args.ckpt_dir + "/opt", st, opt_state)
            start = st
            print(f"restored step {st}")

    @jax.jit
    def train_step(params, opt_state, batch, step):
        def loss_fn(p):
            return model.loss(p, batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        lr = linear_warmup_cosine(step, base_lr=args.lr, warmup_steps=20,
                                  total_steps=args.steps)
        new_params, new_opt, gnorm = adamw_update(params, grads, opt_state, lr=lr)
        return new_params, new_opt, loss, metrics["ce"], gnorm

    stream = FastLMStream(cfg.vocab, args.seq, args.batch, seed=args.seed)
    t0 = time.perf_counter()
    losses = []
    for step, batch in enumerate(stream.batches(args.steps - start), start=start):
        params, opt_state, loss, ce, gnorm = train_step(
            params, opt_state, batch, jnp.asarray(step, jnp.float32)
        )
        losses.append(float(ce))
        if step % args.log_every == 0 or step == args.steps - 1:
            tps = (step - start + 1) * args.batch * args.seq / (
                time.perf_counter() - t0
            )
            print(f"step {step:5d}  ce={float(ce):.4f}  "
                  f"gnorm={float(gnorm):.3f}  tok/s={tps:,.0f}", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, step + 1, params)
            save(args.ckpt_dir + "/opt", step + 1, opt_state)

    if args.ckpt_dir:
        save(args.ckpt_dir, args.steps, params)
        save(args.ckpt_dir + "/opt", args.steps, opt_state)
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"ce first10={first:.4f} last10={last:.4f} "
          f"improvement={(first - last):.4f}")


if __name__ == "__main__":
    main()
