"""Continuous-batching serving engine.

Slot-based scheduler over a fixed decode batch: every sequence in the
batch sits at its own position (per-slot ``index`` vector — see
``attention.attn_decode``), so new requests are admitted into free slots
while others are mid-generation; no generation "waves", no head-of-line
blocking by the longest sequence.

  * admit: single-request prefill (prompt bucketed to powers of two to
    bound compile count), state inserted into the batch state at the free
    slot (batch-dim discovered structurally per leaf);
  * step: one jitted batched decode for all active slots;
  * complete: slots free as sequences hit max_new_tokens (or EOS).

Correctness contract (tests/test_serving_engine.py): every request's
continuous-batched output equals its isolated prefill+greedy-decode
output exactly.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LM


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list  # token ids
    max_new_tokens: int
    eos_id: Optional[int] = None
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int, minimum: int = 16) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


class ServingEngine:
    def __init__(self, model: LM, params, *, max_batch: int = 4,
                 cache_len: int = 512):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * max_batch

        self.state = model.init_decode_state(max_batch, cache_len, index=0)
        self.state["index"] = jnp.zeros((max_batch,), jnp.int32)
        self.active = np.zeros(max_batch, dtype=bool)
        self.last_tokens = np.zeros(max_batch, dtype=np.int32)

        # structural batch-dim discovery per state leaf
        s1 = jax.eval_shape(lambda: self._mk_state(1))
        s2 = jax.eval_shape(lambda: self._mk_state(2))

        def bdim(a, b):
            for i, (x, y) in enumerate(zip(a.shape, b.shape)):
                if x != y:
                    return i
            return None

        self._batch_dims = jax.tree.map(bdim, s1, s2)

        self._decode = jax.jit(model.decode_step)
        self._insert = jax.jit(self._insert_impl, static_argnums=())
        self._prefill_cache = {}

    def _mk_state(self, b):
        st = self.model.init_decode_state(b, self.cache_len, index=0)
        st["index"] = jnp.zeros((b,), jnp.int32)
        return st

    # -- state surgery ---------------------------------------------------------
    def _insert_impl(self, batch_state, single_state, slot):
        def ins(big, small, bd):
            if bd is None:
                return big
            return jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), slot, axis=bd)

        return jax.tree.map(ins, batch_state, single_state, self._batch_dims)

    @staticmethod
    def _mask_padded_positions(state, true_len: int):
        """Invalidate cache slots written by right-padding garbage."""
        def walk(node):
            if isinstance(node, dict):
                return {k: (jnp.where(v >= true_len, -1, v) if k == "pos"
                            else walk(v))
                        for k, v in node.items()}
            if isinstance(node, list):
                return [walk(v) for v in node]
            return node

        return walk(state)

    # -- admission ----------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _prefill_fn(self, lpad: int):
        if lpad not in self._prefill_cache:
            self._prefill_cache[lpad] = jax.jit(
                lambda p, b: self.model.prefill(p, b, cache_len=self.cache_len)
            )
        return self._prefill_cache[lpad]

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            ltrue = len(req.prompt)
            lpad = min(_bucket(ltrue), self.cache_len)
            toks = np.zeros((1, lpad), np.int32)
            toks[0, :ltrue] = req.prompt
            logits, sstate = self._prefill_fn(lpad)(
                self.params, {"inputs": jnp.asarray(toks)})
            # prefill ran over lpad tokens; logits must come from the LAST
            # REAL token. Re-run decode-style? Cheaper: if padded, the next
            # token comes from a one-step decode at position ltrue-1 using
            # the (masked) cache — handled by taking logits only when
            # lpad == ltrue, else bootstrapping with the last real token.
            sstate = self._mask_padded_positions(sstate, ltrue)
            sstate["index"] = jnp.full((1,), ltrue, jnp.int32)
            self.state = self._insert(self.state, sstate,
                                      jnp.asarray(slot, jnp.int32))
            if lpad == ltrue:
                first = int(jnp.argmax(logits[0]))
                self.last_tokens[slot] = first
                req.generated.append(first)
            else:
                # replay the last real token through one decode step
                self.state["index"] = self.state["index"].at[slot].set(ltrue - 1)
                self.last_tokens[slot] = req.prompt[-1]
            self.slots[slot] = req
            self.active[slot] = True

    # -- one engine iteration --------------------------------------------------------
    def step(self) -> int:
        """Admit + one batched decode. Returns number of active slots."""
        self._admit()
        if not self.active.any():
            return 0
        toks = jnp.asarray(self.last_tokens[:, None])
        logits, self.state = self._decode(self.params, self.state, toks)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for slot in range(self.max_batch):
            req = self.slots[slot]
            if req is None:
                continue
            tok = int(nxt[slot])
            req.generated.append(tok)
            self.last_tokens[slot] = tok
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if len(req.generated) >= req.max_new_tokens or hit_eos:
                req.done = True
                self.slots[slot] = None
                self.active[slot] = False
        return int(self.active.sum())

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and not self.active.any():
                return
            self.step()
        raise RuntimeError("serving run() exceeded max_steps")
