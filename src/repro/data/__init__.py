"""Data pipeline: synthetic LIBSVM twins, federated partitioners, LM streams."""
from repro.data.libsvm_like import PAPER_DATASETS, DatasetSpec, load, make_classification
