"""Synthetic LM token streams for training examples/benchmarks.

A deterministic Zipf-ish Markov token source: fast, seedable, and with
enough local structure that a small LM's loss visibly drops within a few
hundred steps (unlike uniform noise). Also provides the federated
variant: per-client streams with distinct transition matrices (non-iid).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class LMStream:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    n_states: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # hidden-state Markov chain emitting zipf-distributed tokens
        self.trans = rng.dirichlet(np.ones(self.n_states) * 0.2,
                                   size=self.n_states)
        ranks = np.arange(1, self.vocab + 1)
        base = 1.0 / ranks**1.1
        self.emit = np.stack([
            np.roll(base, rng.integers(0, self.vocab)) for _ in range(self.n_states)
        ])
        self.emit /= self.emit.sum(axis=1, keepdims=True)

    def batches(self, n_steps: int):
        rng = np.random.default_rng(self.seed + 1)
        for _ in range(n_steps):
            toks = np.empty((self.batch, self.seq_len + 1), np.int32)
            state = rng.integers(0, self.n_states, size=self.batch)
            for t in range(self.seq_len + 1):
                for b in range(self.batch):
                    toks[b, t] = rng.choice(self.vocab, p=self.emit[state[b]])
                    state[b] = rng.choice(self.n_states, p=self.trans[state[b]])
            yield {
                "inputs": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:]),
            }


class FastLMStream:
    """Vectorized variant (no per-token python loop) for larger batches.

    Sacrifices the Markov hidden state for a bigram-mixture structure:
    token_{t+1} ~ mix(bigram[token_t], zipf). Fully vectorized in numpy.
    """

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 bigram_weight: float = 0.7):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, batch
        self.seed = seed
        rng = np.random.default_rng(seed)
        self.shift = rng.integers(1, vocab, size=vocab)  # deterministic bigram
        ranks = np.arange(1, vocab + 1)
        self.zipf = (1.0 / ranks**1.1)
        self.zipf /= self.zipf.sum()
        self.w = bigram_weight

    def batches(self, n_steps: int):
        rng = np.random.default_rng(self.seed + 1)
        for _ in range(n_steps):
            toks = np.empty((self.batch, self.seq_len + 1), np.int64)
            toks[:, 0] = rng.choice(self.vocab, p=self.zipf, size=self.batch)
            for t in range(self.seq_len):
                follow = (toks[:, t] + self.shift[toks[:, t]]) % self.vocab
                rand = rng.choice(self.vocab, p=self.zipf, size=self.batch)
                use_bigram = rng.random(self.batch) < self.w
                toks[:, t + 1] = np.where(use_bigram, follow, rand)
            yield {
                "inputs": jnp.asarray(toks[:, :-1], jnp.int32),
                "labels": jnp.asarray(toks[:, 1:], jnp.int32),
            }
