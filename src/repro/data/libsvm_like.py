"""Seeded synthetic stand-ins for the paper's LIBSVM datasets.

The container is offline, so we generate classification data with the
same (n, M) statistics as Table II of the paper and a controllable
*effective dimension* — the quantity FLeNS's sketch-size theory keys on.

Generator: features x ~ N(0, Sigma) with power-law spectrum
``lambda_i = i^{-decay}`` (small decay -> heavy spectrum -> large d_lam),
labels from a ground-truth logistic model with margin noise. All draws
are jax.random with fixed seeds — runs are exactly reproducible.
"""
from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n: int
    dim: int  # feature dimension M
    m_clients: int  # paper Table II's m
    sketch_k: int  # paper Table II's k
    spectrum_decay: float = 1.0
    label_noise: float = 0.05


# Paper Table II (n reduced for covtype/SUSY to CPU-tractable sizes; the
# (M, k, m) columns — the quantities that drive communication — match).
# spectrum_decay is calibrated so the effective dimension d_lam of each
# twin is at-or-below the paper's sketch size k — the regime the paper's
# sketch-size theory (k = O(d_lam)) targets; real LIBSVM features are
# highly correlated (binary / standardized physics features), which the
# power-law covariance mimics.
PAPER_DATASETS = {
    "phishing": DatasetSpec("phishing", 11_055, 68, 40, 17, spectrum_decay=2.0),
    "covtype": DatasetSpec("covtype", 58_101, 54, 200, 20, spectrum_decay=1.8),
    "susy": DatasetSpec("susy", 100_000, 18, 1000, 10, spectrum_decay=1.5),
}


def make_classification(
    key: jax.Array,
    n: int,
    dim: int,
    *,
    spectrum_decay: float = 1.0,
    label_noise: float = 0.05,
    dtype=jnp.float64,  # noqa: RA005 — paper-dataset fidelity: the source tables are double precision
):
    """Logistic-model data with power-law feature covariance.

    Returns X (n, dim), y (n,) in {-1, +1}.
    """
    kx, kw, kn = jax.random.split(key, 3)
    evals = jnp.arange(1, dim + 1, dtype=dtype) ** (-spectrum_decay)
    X = jax.random.normal(kx, (n, dim), dtype) * jnp.sqrt(evals)[None, :]
    w_true = jax.random.normal(kw, (dim,), dtype)
    w_true = w_true / jnp.linalg.norm(w_true) * 4.0
    logits = X @ w_true
    p = jax.nn.sigmoid(logits)
    u = jax.random.uniform(kn, (n,), dtype)
    y = jnp.where(u < p, 1.0, -1.0).astype(dtype)
    # flip a small fraction for label noise
    kf = jax.random.fold_in(kn, 1)
    flip = jax.random.uniform(kf, (n,), dtype) < label_noise
    y = jnp.where(flip, -y, y)
    return X, y


def load(name: str, *, dtype=jnp.float64, seed: int = 0):  # noqa: RA005 — paper-dataset fidelity: the source tables are double precision
    """Load one of the paper's datasets (synthetic twin). Returns spec, X, y."""
    spec = PAPER_DATASETS[name]
    # deterministic name hash: builtin hash() is salted per process
    # (PYTHONHASHSEED), which silently broke cross-run reproducibility
    name_h = zlib.crc32(name.encode()) % (2**31)
    key = jax.random.PRNGKey(name_h + seed)  # noqa: RA001 — documented (crc32(name), seed) salt: dataset twins are pure in the name
    X, y = make_classification(
        key,
        spec.n,
        spec.dim,
        spectrum_decay=spec.spectrum_decay,
        label_noise=spec.label_noise,
        dtype=dtype,
    )
    return spec, X, y
