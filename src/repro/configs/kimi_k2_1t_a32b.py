"""Kimi K2 — trillion-param MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840,
MoE 384 experts top-8, 1 shared expert, first layer dense.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=112,
    d_ff=16384,  # first dense layer / dense-equivalent width (8 active experts)
    vocab=163_840,
    rope_theta=50_000.0,
    tied_embeddings=False,
    n_experts=384,
    top_k=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    first_k_dense=1,
    capacity_factor=1.25,
    source="arXiv:2501.kimi2 (Kimi K2 model table)",
)
