"""TinyLlama-1.1B — llama2-arch small [arXiv:2401.02385].

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=64,
    d_ff=5632,
    vocab=32_000,
    rope_theta=10_000.0,
    tied_embeddings=False,
    source="arXiv:2401.02385",
)
