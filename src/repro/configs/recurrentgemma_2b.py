"""RecurrentGemma-2B — RG-LRU + local attention, 2 recurrent : 1 attn
[arXiv:2402.19427 (Griffin)].

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000, window 2048.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    vocab=256_000,
    window=2048,
    rope_theta=10_000.0,
    act="gelu",
    tied_embeddings=True,
    block_pattern=("rec", "rec", "attn"),
    rglru_conv=4,
    source="arXiv:2402.19427",
)
