"""Llama-3.2-Vision-90B — cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision, 90B scaling].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; 20 cross-attn
layers (1 per 4 self layers); vision frontend stubbed (precomputed patch
embeddings, 1601 tokens x 1280).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=28_672,
    vocab=128_256,
    rope_theta=500_000.0,
    tied_embeddings=False,
    cross_attn_every=4,
    vision_tokens=1601,
    vision_dim=1280,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
