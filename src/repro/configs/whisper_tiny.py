"""Whisper-tiny — encoder-decoder, conv frontend stubbed [arXiv:2212.04356].

4L (decoder) + 4L encoder, d_model=384 6H (kv=6) d_ff=1536 vocab=51865;
audio frontend is a stub: input_specs provide precomputed frame embeddings
(1500 x 384).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_head=64,
    d_ff=1536,
    vocab=51_865,
    act="gelu",
    tied_embeddings=True,
    encoder_layers=4,
    audio_frames=1500,
    source="arXiv:2212.04356",
)
