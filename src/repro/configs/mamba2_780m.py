"""Mamba2-780m — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1536 (attention-free) vocab=50280, ssm_state=128.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50_280,
    tied_embeddings=True,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    source="arXiv:2405.21060",
)
