"""Gemma3-4B — 5 local : 1 global attention, 128k ctx [hf:google/gemma-3-1b-pt
family card; 4B config].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, window 1024,
rope 10k local / 1M global, qk-norm, geglu.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=10_240,
    vocab=262_144,
    window=1024,
    local_per_global=5,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    act="gelu",
    tied_embeddings=True,
    source="hf:google/gemma-3-1b-pt (family card)",
)
