"""Gemma3-1B — 5 local : 1 global, 128k [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, window 512.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab=262_144,
    window=512,
    local_per_global=5,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    qk_norm=True,
    act="gelu",
    tied_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
