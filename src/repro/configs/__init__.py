"""Architecture registry: the 10 assigned configs + input-shape registry."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.common import ModelConfig

ARCH_IDS = (
    "kimi_k2_1t_a32b",
    "recurrentgemma_2b",
    "mamba2_780m",
    "gemma3_4b",
    "llama32_vision_90b",
    "tinyllama_1_1b",
    "qwen15_110b",
    "gemma3_1b",
    "whisper_tiny",
    "arctic_480b",
)

# public ids as assigned (hyphens) -> module names
_ALIASES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mamba2-780m": "mamba2_780m",
    "gemma3-4b": "gemma3_4b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "qwen1.5-110b": "qwen15_110b",
    "gemma3-1b": "gemma3_1b",
    "whisper-tiny": "whisper_tiny",
    "arctic-480b": "arctic_480b",
}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    """Arch id, optionally with a variant suffix: "gemma3-4b@rightsized"."""
    variant = None
    if "@" in arch:
        arch, variant = arch.split("@", 1)
    mod_name = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.CONFIG
    if variant == "rightsized":
        cfg = dataclasses.replace(cfg, cache_mode="rightsized")
    elif variant:
        raise ValueError(f"unknown variant {variant!r}")
    return cfg


def sub_quadratic(cfg: ModelConfig) -> bool:
    """Can this arch serve a 500k context (per DESIGN.md skip matrix)?"""
    if cfg.family in ("ssm", "hybrid"):
        return True
    if cfg.local_per_global and cfg.window:  # sliding-window dense (gemma3)
        return True
    return False


def supported_shapes(cfg: ModelConfig) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if sub_quadratic(cfg):
        out.append("long_500k")
    if cfg.family == "audio":
        # whisper decoder context is architecturally tiny; 500k skipped
        pass
    return out
