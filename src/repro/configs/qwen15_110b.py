"""Qwen1.5-110B — QKV bias [hf:Qwen/Qwen1.5-0.5B family card, 110B config].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=49_152,
    vocab=152_064,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tied_embeddings=False,
    source="hf:Qwen/Qwen1.5-0.5B (family card)",
)
