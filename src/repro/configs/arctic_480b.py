"""Snowflake Arctic 480B — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2,
dense FFN residual in parallel with the MoE in every layer.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,
    vocab=32_000,
    rope_theta=10_000.0,
    tied_embeddings=False,
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    moe_dense_residual=True,
    capacity_factor=1.25,
    source="hf:Snowflake/snowflake-arctic-base",
)
