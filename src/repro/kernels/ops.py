"""Kernel dispatch: a backend registry with explicit impl selection.

Every compute hot spot in the stack is exposed here as a named *op* with
interchangeable implementations registered per backend:

  * ``"ref"``       — pure-jnp oracle from ``repro.kernels.ref``
                      (portable; the path every golden trajectory and
                      committed baseline is pinned to; alias
                      ``"reference"``)
  * ``"pallas"``    — compiled Pallas TPU kernel (TPU only; forcing it
                      off-TPU raises)
  * ``"interpret"`` — the Pallas kernel body interpreted on CPU (the
                      parity-test path: same body, no TPU)
  * ``"auto"``      — ``"pallas"`` on TPU, ``"ref"`` everywhere else

Selection precedence, most local wins:

  1. the per-call ``impl=`` argument,
  2. the process default set by ``set_default_impl`` / the ``use_impl``
     context manager,
  3. the ``REPRO_KERNEL_IMPL`` environment variable (CI job legs force
     ``REPRO_KERNEL_IMPL=ref`` to prove the reference path stays green),
  4. ``"auto"``.

Ops: ``fwht``, ``srht_apply``, ``srht_apply_t`` (the fused sketch hot
loop consumed by ``repro.core.sketch``), ``topk_mask``,
``qint8_roundtrip`` (the transport codec inner loops consumed by
``repro.comm.codecs``), and ``flash_attention``. Implementations are
registered lazily — Pallas modules import only when a pallas/interpret
impl is actually selected.

NOTE: resolution happens at Python trace time. Inside an already-traced
jit cache entry the choice is baked in; set the env var / default before
the first call (CI does).
"""
from __future__ import annotations

import contextlib
import functools
import os
from typing import Callable

import jax

from repro.kernels import ref

ENV_VAR = "REPRO_KERNEL_IMPL"
IMPLS = ("auto", "pallas", "interpret", "ref")
_ALIASES = {"reference": "ref"}

_default_impl: str | None = None


def _canonical(impl: str) -> str:
    impl = _ALIASES.get(impl, impl)
    if impl not in IMPLS:
        raise ValueError(
            f"unknown kernel impl {impl!r}; expected one of {IMPLS} "
            f"(or alias {tuple(_ALIASES)})")
    return impl


def set_default_impl(impl: str | None) -> None:
    """Set the process-wide implementation default (``None`` clears it,
    falling back to ``REPRO_KERNEL_IMPL`` / ``"auto"``)."""
    global _default_impl
    _default_impl = None if impl is None else _canonical(impl)


@contextlib.contextmanager
def use_impl(impl: str | None):
    """Scoped ``set_default_impl`` — the config hook for tests and
    experiment drivers."""
    prev = _default_impl
    set_default_impl(impl)
    try:
        yield
    finally:
        set_default_impl(prev)


def resolve_impl(impl: str | None = None) -> str:
    """Resolve per-call > config > env > auto down to a concrete impl."""
    choice = impl or _default_impl or os.environ.get(ENV_VAR) or "auto"
    choice = _canonical(choice)
    if choice == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return choice


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

# op -> impl -> loader() -> callable. Loaders keep Pallas imports lazy;
# resolved callables are cached on first use.
_REGISTRY: dict[str, dict[str, Callable[[], Callable]]] = {}


def register_impl(op: str, impl: str):
    """Register ``loader() -> callable`` as ``op``'s ``impl`` backend."""
    def deco(loader: Callable[[], Callable]):
        _REGISTRY.setdefault(op, {})[_canonical(impl)] = loader
        return loader
    return deco


def available_impls(op: str) -> tuple[str, ...]:
    if op not in _REGISTRY:
        raise KeyError(f"unknown kernel op {op!r}; have {sorted(_REGISTRY)}")
    return tuple(sorted(_REGISTRY[op]))


@functools.lru_cache(maxsize=None)
def get_impl(op: str, impl: str) -> Callable:
    """The concrete callable for (op, impl); raises with the available
    backends when the combination is not registered."""
    impls = _REGISTRY.get(op)
    if impls is None:
        raise KeyError(f"unknown kernel op {op!r}; have {sorted(_REGISTRY)}")
    if impl == "pallas" and jax.default_backend() != "tpu":
        raise RuntimeError(
            f"impl='pallas' for op {op!r} requires a TPU backend (running "
            f"on {jax.default_backend()!r}); use impl='interpret' to run "
            "the kernel body here, or impl='ref' for the oracle")
    loader = impls.get(impl)
    if loader is None:
        raise KeyError(
            f"op {op!r} has no {impl!r} implementation; "
            f"available: {available_impls(op)}")
    return loader()


def _dispatch(op: str, impl: str | None) -> Callable:
    return get_impl(op, resolve_impl(impl))


# ---------------------------------------------------------------------------
# public ops
# ---------------------------------------------------------------------------

def fwht(x: jax.Array, *, normalize: bool = False,
         impl: str | None = None) -> jax.Array:
    """Walsh-Hadamard transform along the last axis."""
    return _dispatch("fwht", impl)(x, normalize=normalize)


def srht_apply(x: jax.Array, signs: jax.Array, rows: jax.Array, *,
               impl: str | None = None) -> jax.Array:
    """Fused SRHT forward: sign-flip -> FWHT -> row-subsample.
    x (..., dim) -> (..., k); n = signs.shape[-1], k = rows.shape[-1]."""
    return _dispatch("srht_apply", impl)(x, signs, rows)


def srht_apply_t(y: jax.Array, signs: jax.Array, rows: jax.Array,
                 dim: int, *, impl: str | None = None) -> jax.Array:
    """Fused SRHT transpose: scatter -> FWHT -> sign-flip -> restrict.
    y (..., k) -> (..., dim)."""
    return _dispatch("srht_apply_t", impl)(y, signs, rows, dim)


def topk_mask(x: jax.Array, kept: int, *,
              impl: str | None = None) -> jax.Array:
    """Keep the ``kept`` largest-|.| entries of ``x`` (dense mask; ties
    broken by lowest flat index, as ``jax.lax.top_k``)."""
    return _dispatch("topk_mask", impl)(x, kept)


def qint8_roundtrip(x: jax.Array, u: jax.Array, *,
                    impl: str | None = None) -> jax.Array:
    """Per-tensor symmetric int8 quantize->dequantize; ``u ~ U[0,1)``
    (x's shape) is the caller-supplied stochastic-rounding noise."""
    return _dispatch("qint8_roundtrip", impl)(x, u)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,  # None | int | traced scalar (per-layer metadata)
    q_offset: int = 0,
    impl: str | None = None,
    block_q: int = 512,
    block_k: int = 1024,
) -> jax.Array:
    """Grouped-query flash attention, (B, T, H, D) layout.

    Not jitted here (callers jit the whole step); ``window`` may be a
    traced scalar so it cannot be a static argument.
    """
    return _dispatch("flash_attention", impl)(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k)


# ---------------------------------------------------------------------------
# implementation registrations
# ---------------------------------------------------------------------------

@register_impl("fwht", "ref")
def _fwht_ref():
    return jax.jit(ref.fwht, static_argnames=("normalize",))


@register_impl("fwht", "pallas")
def _fwht_pallas():
    from repro.kernels.fwht import fwht_pallas
    return fwht_pallas


@register_impl("fwht", "interpret")
def _fwht_interpret():
    from repro.kernels.fwht import fwht_pallas
    return functools.partial(fwht_pallas, interpret=True)


@register_impl("srht_apply", "ref")
def _srht_apply_ref():
    return jax.jit(ref.srht_apply)


@register_impl("srht_apply", "pallas")
def _srht_apply_pallas():
    from repro.kernels.srht import srht_apply_pallas
    return srht_apply_pallas


@register_impl("srht_apply", "interpret")
def _srht_apply_interpret():
    from repro.kernels.srht import srht_apply_pallas
    return functools.partial(srht_apply_pallas, interpret=True)


@register_impl("srht_apply_t", "ref")
def _srht_apply_t_ref():
    return jax.jit(ref.srht_apply_t, static_argnames=("dim",))


@register_impl("srht_apply_t", "pallas")
def _srht_apply_t_pallas():
    from repro.kernels.srht import srht_apply_t_pallas
    return srht_apply_t_pallas


@register_impl("srht_apply_t", "interpret")
def _srht_apply_t_interpret():
    from repro.kernels.srht import srht_apply_t_pallas
    return functools.partial(srht_apply_t_pallas, interpret=True)


@register_impl("topk_mask", "ref")
def _topk_mask_ref():
    return jax.jit(ref.topk_mask, static_argnames=("kept",))


@register_impl("topk_mask", "pallas")
def _topk_mask_pallas():
    from repro.kernels.codec_kernels import topk_mask_pallas
    return topk_mask_pallas


@register_impl("topk_mask", "interpret")
def _topk_mask_interpret():
    from repro.kernels.codec_kernels import topk_mask_pallas
    return functools.partial(topk_mask_pallas, interpret=True)


@register_impl("qint8_roundtrip", "ref")
def _qint8_ref():
    return jax.jit(ref.qint8_roundtrip)


@register_impl("qint8_roundtrip", "pallas")
def _qint8_pallas():
    from repro.kernels.codec_kernels import qint8_roundtrip_pallas
    return qint8_roundtrip_pallas


@register_impl("qint8_roundtrip", "interpret")
def _qint8_interpret():
    from repro.kernels.codec_kernels import qint8_roundtrip_pallas
    return functools.partial(qint8_roundtrip_pallas, interpret=True)


@register_impl("flash_attention", "ref")
def _flash_attention_ref():
    return ref.mha_blocked


@register_impl("flash_attention", "pallas")
def _flash_attention_pallas():
    from repro.kernels import flash_attention as fa

    def run(q, k, v, *, causal, window, q_offset, block_q, block_k):
        return fa.flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            block_q=min(block_q, 128), block_k=min(block_k, 128))
    return run


@register_impl("flash_attention", "interpret")
def _flash_attention_interpret():
    from repro.kernels import flash_attention as fa

    def run(q, k, v, *, causal, window, q_offset, block_q, block_k):
        return fa.flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            block_q=min(block_q, 128), block_k=min(block_k, 128),
            interpret=True)
    return run
