"""Jit'd public wrappers around the Pallas kernels with reference fallback.

``impl`` selection:
  * "auto"      — Pallas on TPU, reference elsewhere (CPU container → ref)
  * "pallas"    — force the Pallas kernel (compiled; TPU only)
  * "interpret" — Pallas kernel body interpreted on CPU (used by tests)
  * "reference" — pure-jnp oracle from ``repro.kernels.ref``
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("normalize", "impl"))
def fwht(x: jax.Array, *, normalize: bool = False, impl: str = "auto") -> jax.Array:
    """Walsh-Hadamard transform along the last axis."""
    if impl == "reference" or (impl == "auto" and not _on_tpu()):
        return ref.fwht(x, normalize=normalize)
    from repro.kernels import fwht as fwht_kernel

    return fwht_kernel.fwht_pallas(
        x, normalize=normalize, interpret=(impl == "interpret")
    )


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,  # None | int | traced scalar (per-layer metadata)
    q_offset: int = 0,
    impl: str = "auto",
    block_q: int = 512,
    block_k: int = 1024,
) -> jax.Array:
    """Grouped-query flash attention, (B, T, H, D) layout.

    Not jitted here (callers jit the whole step); ``window`` may be a
    traced scalar so it cannot be a static argument.
    """
    if impl == "reference" or (impl == "auto" and not _on_tpu()):
        return ref.mha_blocked(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            block_q=block_q, block_k=block_k,
        )
    from repro.kernels import flash_attention as fa

    return fa.flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=min(block_q, 128),
        block_k=min(block_k, 128),
        interpret=(impl == "interpret"),
    )
