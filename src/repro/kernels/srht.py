"""Pallas TPU kernel: the fused SRHT hot loop (forward and transpose).

The SRHT sketch ``S = sqrt(n/k) * P * H_n * D`` is the per-round compute
hot spot of every sketched optimizer (FLeNS/FLeNS+, FedNS, FedNDES,
DistributedFLeNS). The reference path traces it as a jit-graph of four
primitives — pad, sign multiply, ``fwht``, ``take`` (and a scatter for
the transpose) — each of which round-trips the full padded row through
memory. This kernel fuses the whole pipeline into one VMEM-resident
Pallas body:

  forward   : out = (x * D) H  P^T * (1/sqrt(k))          (rows, k)
  transpose : out = ((y * sqrt(n/k)) P) H * (1/sqrt(n)) D (rows, dim)

Structure (same TPU adaptation as ``repro.kernels.fwht``): the length-n
Hadamard factorizes as ``H_n = (H_A (x) I_B) . (I_A (x) H_B)``, so the
transform is two dense MXU matmuls against tiny Hadamard factors. The
row-subsample ``P`` (a gather in the reference path) and its transpose
(a scatter) both become matmuls against a one-hot selection matrix built
in-kernel from a ``broadcasted_iota`` comparison — the transpose's
scatter is therefore an in-kernel masked write: lanes whose iota matches
no sampled row receive exactly zero. The two normalizations (orthonormal
FWHT's 1/sqrt(n) and the SRHT's sqrt(n/k)) fold into a single 1/sqrt(k)
applied once at the output.

Validated against ``repro.kernels.ref.srht_apply``/``srht_apply_t`` in
interpret mode (CPU) by ``tests/test_kernels_srht.py``; the compiled
path targets TPU. Dispatch via ``repro.kernels.ops.srht_apply``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fwht import _factor
from repro.kernels.ref import hadamard_matrix


def _fwht_body(x, ha, hb, rows: int, a: int, b: int):
    """Two-matmul length-(a*b) Walsh-Hadamard transform of (rows, a*b)."""
    y = x.reshape(rows, a, b)
    y = jax.lax.dot_general(y, hb, (((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = jnp.einsum("rab,ca->rcb", y, ha)
    return y.reshape(rows, a * b)


def _srht_fwd_kernel(x_ref, signs_ref, rows_ref, ha_ref, hb_ref, o_ref,
                     *, a: int, b: int, k: int, out_scale: float):
    n = a * b
    rows = x_ref.shape[0]
    x = x_ref[...].astype(jnp.float32) * signs_ref[...].astype(jnp.float32)
    h = _fwht_body(x, ha_ref[...].astype(jnp.float32),
                   hb_ref[...].astype(jnp.float32), rows, a, b)
    # row subsample as a one-hot matmul (MXU-shaped gather):
    # sel[i, j] = 1 iff lane i is the j-th sampled row
    lane = jax.lax.broadcasted_iota(jnp.int32, (n, k), 0)
    sel = (lane == rows_ref[...]).astype(jnp.float32)  # rows_ref (1, k)
    out = jax.lax.dot_general(h, sel, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] = (out * out_scale).astype(o_ref.dtype)


def _srht_t_kernel(y_ref, signs_ref, rows_ref, ha_ref, hb_ref, o_ref,
                   *, a: int, b: int, k: int, out_scale: float):
    n = a * b
    rows = y_ref.shape[0]
    y = y_ref[...].astype(jnp.float32)
    # transpose subsample: scatter the k entries into the n-wide padded
    # domain as an in-kernel masked write — sel_t[j, i] is one-hot per
    # sampled row j, so lanes no row maps to are written exactly zero
    lane = jax.lax.broadcasted_iota(jnp.int32, (k, n), 1)
    sel_t = (lane == rows_ref[...].reshape(k, 1)).astype(jnp.float32)
    z = jax.lax.dot_general(y, sel_t, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = _fwht_body(z, ha_ref[...].astype(jnp.float32),
                   hb_ref[...].astype(jnp.float32), rows, a, b)
    out = h * out_scale * signs_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


def _flatten_rows(x: jax.Array, last: int, block_rows: int):
    """(..., last) -> ((rows_padded, last), rows) for row-tiled grids."""
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    xm = x.reshape(rows, last)
    pad = (-rows) % block_rows
    if pad:
        xm = jnp.pad(xm, ((0, pad), (0, 0)))
    return xm, rows


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret"))
def srht_apply_pallas(x: jax.Array, signs: jax.Array, rows: jax.Array, *,
                      block_rows: int = 8, interpret: bool = False
                      ) -> jax.Array:
    """Fused S @ x: x (..., dim) -> (..., k); n = signs.shape[-1]."""
    n = signs.shape[-1]
    k = rows.shape[-1]
    dim = x.shape[-1]
    a, b = _factor(n)
    pad = n - dim
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
    xm, nrows = _flatten_rows(xp, n, block_rows)
    out = pl.pallas_call(
        functools.partial(_srht_fwd_kernel, a=a, b=b, k=k,
                          out_scale=1.0 / k ** 0.5),
        grid=(xm.shape[0] // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xm.shape[0], k), x.dtype),
        interpret=interpret,
    )(xm, signs.reshape(1, n), rows.reshape(1, k).astype(jnp.int32),
      hadamard_matrix(a), hadamard_matrix(b))
    return out[:nrows].reshape(x.shape[:-1] + (k,))


@functools.partial(jax.jit,
                   static_argnames=("dim", "block_rows", "interpret"))
def srht_apply_t_pallas(y: jax.Array, signs: jax.Array, rows: jax.Array,
                        dim: int, *, block_rows: int = 8,
                        interpret: bool = False) -> jax.Array:
    """Fused S^T @ y: y (..., k) -> (..., dim)."""
    n = signs.shape[-1]
    k = rows.shape[-1]
    a, b = _factor(n)
    ym, nrows = _flatten_rows(y, k, block_rows)
    out = pl.pallas_call(
        functools.partial(_srht_t_kernel, a=a, b=b, k=k,
                          out_scale=1.0 / k ** 0.5),
        grid=(ym.shape[0] // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ym.shape[0], n), y.dtype),
        interpret=interpret,
    )(ym, signs.reshape(1, n), rows.reshape(1, k).astype(jnp.int32),
      hadamard_matrix(a), hadamard_matrix(b))
    return out[:nrows, :dim].reshape(y.shape[:-1] + (dim,))
