"""Pallas TPU kernel: grouped-query flash attention (online softmax).

Blocking: grid = (batch, q_heads, nQ, nK); the KV loop is the innermost
(sequential) grid dimension, accumulating into VMEM scratch
(acc (bq, d) f32, running max / denom (bq,)). GQA is handled in the
BlockSpec index maps (kv head = q head // group) — no KV expansion in
HBM. Causal + sliding-window masks are applied from absolute block
positions; out-of-range KV blocks contribute zero via the mask (TPU grid
cannot skip blocks — the §Perf log quantifies what block-skipping would
save).

Mirrors ``repro.kernels.ref.mha_blocked`` (the oracle used in tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0**30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               bq: int, bk: int, nk: int, tk_valid: int, causal: bool,
               window: int | None, q_offset: int, scale: float):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
    qpos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < tk_valid
    if causal:
        mask &= kpos <= qpos
    if window is not None and window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k",
                     "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, Tq, H, D)
    k: jax.Array,  # (B, Tk, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    group = h // hkv
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    pad_q = (-tq) % block_q
    pad_k = (-tk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        # padded keys are masked in-kernel via kpos < tk_valid
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_k

    kernel = functools.partial(
        _fa_kernel, bq=block_q, bk=block_k, nk=nk, tk_valid=tk,
        causal=causal, window=window, q_offset=q_offset, scale=1.0 / d**0.5,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b_, h_, iq, ik: (b_, iq, h_, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, iq, ik, g=group: (b_, ik, h_ // g, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda b_, h_, iq, ik, g=group: (b_, ik, h_ // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda b_, h_, iq, ik: (b_, iq, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :tq]
    return out
