"""Pallas TPU kernel: Fast Walsh-Hadamard Transform (the SRHT hot loop).

TPU adaptation (DESIGN.md §3): instead of emulating the GPU butterfly
(warp shuffles) on the VPU, the length-N transform is factored as

    H_N = (H_A (x) I_B) . (I_A (x) H_B),      N = A * B

so a row reshaped to (A, B) is transformed by two *dense matmuls* with
small Hadamard matrices:  Y = H_A @ X @ H_B. Both factors are <=128 wide,
i.e. exactly MXU-shaped. Rows are tiled into VMEM blocks; the Hadamard
factors ride along as (tiny) kernel inputs.

Validated against ``repro.kernels.ref.fwht`` in interpret mode (CPU) by
``tests/test_kernels_fwht.py``; compiled path targets TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import hadamard_matrix


def _factor(n: int) -> tuple[int, int]:
    """n = a * b with both <= 128 when possible (n a power of two)."""
    if n <= 0 or n & (n - 1) != 0:
        raise ValueError(f"FWHT length must be a power of two, got {n}")
    b = min(n, 128)
    a = n // b
    while a > 128:  # n > 16384: grow b beyond 128 (still a power of 2)
        b *= 2
        a = n // b
    return a, b


def _fwht_kernel(x_ref, ha_ref, hb_ref, o_ref, *, a: int, b: int, norm: float):
    rows = x_ref.shape[0]
    x = x_ref[...].astype(jnp.float32).reshape(rows, a, b)
    ha = ha_ref[...].astype(jnp.float32)  # (a, a)
    hb = hb_ref[...].astype(jnp.float32)  # (b, b)
    # Y = H_A @ X @ H_B per row: einsum over the two small factors
    y = jax.lax.dot_general(x, hb, (((2,), (0,)), ((), ())))  # (rows, a, b)
    y = jnp.einsum("rab,ca->rcb", y, ha)
    o_ref[...] = (y.reshape(rows, a * b) * norm).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("normalize", "block_rows", "interpret"))
def fwht_pallas(x: jax.Array, *, normalize: bool = False, block_rows: int = 8,
                interpret: bool = False) -> jax.Array:
    """WHT along the last axis. x (..., N), N a power of two."""
    orig_shape = x.shape
    n = orig_shape[-1]
    a, b = _factor(n)
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    xm = x.reshape(rows, n)
    pad = (-rows) % block_rows
    if pad:
        xm = jnp.pad(xm, ((0, pad), (0, 0)))
    ha = hadamard_matrix(a, jnp.float32)
    hb = hadamard_matrix(b, jnp.float32)
    norm = (1.0 / n**0.5) if normalize else 1.0

    out = pl.pallas_call(
        functools.partial(_fwht_kernel, a=a, b=b, norm=norm),
        grid=(xm.shape[0] // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xm.shape, x.dtype),
        interpret=interpret,
    )(xm, ha, hb)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
