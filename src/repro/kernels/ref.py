"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here is the ground-truth implementation used by
``tests/test_kernels_*.py`` to validate the Pallas kernels (run with
``interpret=True`` on CPU) and as the portable fallback selected by
``repro.kernels.ops`` when not running on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Fast Walsh-Hadamard transform (the SRHT hot loop)
# ---------------------------------------------------------------------------

def fwht(x: jax.Array, *, normalize: bool = False) -> jax.Array:
    """Walsh-Hadamard transform along the last axis (length power of two).

    Iterative butterfly: log2(n) stages of pairwise add/sub. ``normalize``
    scales by 1/sqrt(n) so the transform is orthonormal.
    """
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"FWHT length must be a power of two, got {n}")
    orig_shape = x.shape
    y = x.reshape((-1, n))
    h = 1
    while h < n:
        y = y.reshape((y.shape[0], n // (2 * h), 2, h))
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    y = y.reshape(orig_shape)
    if normalize:
        y = y * (1.0 / jnp.sqrt(jnp.asarray(n, dtype=x.dtype)))
    return y


def hadamard_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Dense (unnormalized) Hadamard matrix of size n (power of two)."""
    if n & (n - 1):
        raise ValueError(f"Hadamard size must be a power of two, got {n}")
    h = jnp.array([[1.0]], dtype=dtype)
    while h.shape[0] < n:
        h = jnp.block([[h, h], [h, -h]])
    return h


# ---------------------------------------------------------------------------
# SRHT: sign-flip -> FWHT -> row-subsample (and its transpose)
# ---------------------------------------------------------------------------
#
# These are the unfused reference paths for the fused Pallas kernels in
# ``repro.kernels.srht``. The primitive sequence here is EXACTLY the one
# the pre-kernel ``Sketch.apply``/``apply_t`` traced (pad -> multiply ->
# fwht -> take / scatter -> fwht -> multiply -> slice), so routing the
# sketch through ``repro.kernels.ops`` with ``impl="ref"`` keeps every
# golden trajectory bit-identical.

def srht_apply(x: jax.Array, signs: jax.Array, rows: jax.Array) -> jax.Array:
    """sqrt(n/k) * P * H_n * D restricted to the first dim coordinates.

    x (..., dim) -> (..., k) with n = signs.shape[-1] (a power of two,
    >= dim) and k = rows.shape[-1].
    """
    n = signs.shape[-1]
    k = rows.shape[-1]
    pad = n - x.shape[-1]
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
    xp = xp * signs
    h = fwht(xp, normalize=True)
    scale = jnp.sqrt(jnp.asarray(n / k, h.dtype))
    return jnp.take(h, rows, axis=-1) * scale


def srht_apply_t(y: jax.Array, signs: jax.Array, rows: jax.Array,
                 dim: int) -> jax.Array:
    """Transpose SRHT: y (..., k) -> (..., dim). The scatter writes the
    scaled k entries into the padded domain, the inverse ordering of
    ``srht_apply``."""
    n = signs.shape[-1]
    k = rows.shape[-1]
    scale = jnp.sqrt(jnp.asarray(n / k, y.dtype))
    z = jnp.zeros(y.shape[:-1] + (n,), y.dtype)
    z = z.at[..., rows].set(y * scale)
    h = fwht(z, normalize=True)
    h = h * signs
    return h[..., :dim]


# ---------------------------------------------------------------------------
# Transport codec inner loops (the comm hot path)
# ---------------------------------------------------------------------------
#
# Oracles for ``repro.kernels.codec_kernels``; the op order matches the
# pre-kernel ``repro.comm.codecs`` bodies bit-for-bit.

def topk_mask(x: jax.Array, kept: int) -> jax.Array:
    """Magnitude top-k selection as a dense mask: all but the ``kept``
    largest-|.| entries (ties broken by lowest flat index, as
    ``jax.lax.top_k``) are zeroed. Same shape/dtype as ``x``."""
    flat = x.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), kept)
    return jnp.zeros_like(flat).at[idx].set(flat[idx]).reshape(x.shape)


def qint8_roundtrip(x: jax.Array, u: jax.Array) -> jax.Array:
    """Per-tensor symmetric int8 quantize -> dequantize with stochastic
    rounding noise ``u ~ U[0,1)`` supplied by the caller (so every impl
    consumes identical random bits). scale = max|x|/127, clamped away
    from the subnormal range (XLA flushes subnormals to zero on CPU,
    which would turn an all-zero payload into 0/0 = NaN)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0,
                        jnp.finfo(x.dtype).tiny)
    q = jnp.clip(jnp.floor(x / scale + u), -127, 127)
    return (q * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash attention (blocked online-softmax) oracle
# ---------------------------------------------------------------------------

def mha(
    q: jax.Array,  # (B, Tq, H, D)
    k: jax.Array,  # (B, Tk, Hkv, D)
    v: jax.Array,  # (B, Tk, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    scale: float | None = None,
) -> jax.Array:
    """Reference grouped-query attention.

    ``window`` limits attention to the last ``window`` keys (sliding
    window); ``q_offset`` is the absolute position of q[0] (for decode).
    """
    b, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    if h % hkv != 0:
        raise ValueError(f"q heads ({h}) must be a multiple of kv heads "
                         f"({hkv}) for grouped-query attention")
    group = h // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads
    kf = jnp.repeat(kf, group, axis=2)
    vf = jnp.repeat(vf, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    qpos = jnp.arange(tq)[:, None] + q_offset
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # rows that are fully masked produce NaN from softmax(-inf); zero them
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


def mha_blocked(
    q: jax.Array,  # (B, Tq, H, D)
    k: jax.Array,  # (B, Tk, Hkv, D)
    v: jax.Array,  # (B, Tk, Hkv, D)
    *,
    causal: bool = True,
    window=None,  # None | int | traced scalar (<=0 means "no window")
    q_offset: int = 0,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 1024,
) -> jax.Array:
    """Online-softmax blocked attention: O(T) memory, flash-attention math.

    This is both (a) the memory-sane attention used by every model at
    train/prefill time and (b) the structural mirror of the Pallas TPU
    kernel in ``repro.kernels.flash_attention`` (same two-level blocking).
    """
    b, tq, h, d = q.shape
    _, tk, hkv, _ = k.shape
    group = h // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    pad_q = (-tq) % block_q
    pad_k = (-tk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_k

    qf = (qp.astype(jnp.float32) * scale).reshape(b, nq, block_q, hkv, group, d)
    kf = kp.astype(jnp.float32).reshape(b, nk, block_k, hkv, d)
    vf = vp.astype(jnp.float32).reshape(b, nk, block_k, hkv, d)
    q_valid = jnp.arange(nq * block_q) < tq
    k_valid = jnp.arange(nk * block_k) < tk

    def attend_batch(qb, kb, vb):
        # qb (nq, bq, hkv, g, d); kb/vb (nk, bk, hkv, d)
        def per_q(qi, qblk):
            qpos = q_offset + qi * block_q + jnp.arange(block_q)

            def kv_step(carry, xs):
                acc, mx, denom = carry
                kblk, vblk, ki = xs
                kpos = ki * block_k + jnp.arange(block_k)
                logits = jnp.einsum("qhgd,shd->hgqs", qblk, kblk)
                msk = jnp.broadcast_to(
                    k_valid[ki * block_k + jnp.arange(block_k)][None, :],
                    (block_q, block_k),
                )
                if causal:
                    msk = msk & (kpos[None, :] <= qpos[:, None])
                if window is not None:
                    w = jnp.asarray(window)
                    msk = msk & jnp.where(
                        w > 0, kpos[None, :] > qpos[:, None] - w, True
                    )
                logits = jnp.where(msk[None, None], logits, -2.0**30)
                new_mx = jnp.maximum(mx, jnp.max(logits, axis=-1))
                alpha = jnp.exp(mx - new_mx)
                p = jnp.exp(logits - new_mx[..., None])
                denom = denom * alpha + jnp.sum(p, axis=-1)
                acc = acc * alpha[..., None] + jnp.einsum(
                    "hgqs,shd->hgqd", p, vblk
                )
                return (acc, new_mx, denom), None

            acc0 = jnp.zeros((hkv, group, block_q, d), jnp.float32)
            mx0 = jnp.full((hkv, group, block_q), -jnp.inf)
            d0 = jnp.zeros((hkv, group, block_q), jnp.float32)
            (acc, mx, denom), _ = jax.lax.scan(
                kv_step, (acc0, mx0, d0), (kb, vb, jnp.arange(nk))
            )
            return acc / jnp.maximum(denom[..., None], 1e-30)

        return jax.vmap(per_q)(jnp.arange(nq), qb)

    out = jax.vmap(attend_batch)(qf, kf, vf)  # (b, nq, hkv, g, bq, d)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(b, nq * block_q, h, d)
    out = out[:, :tq]
    return out.astype(q.dtype)
