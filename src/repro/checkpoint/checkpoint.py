"""Minimal production-shaped checkpointing: atomic, step-managed, pytree-safe.

Format: one directory per step (``step_000042/``) holding
  * ``tree.msgpack`` — the pytree structure + array metadata
  * ``arrays.npz``   — the tensor payloads (host-gathered)
Writes go to a temp dir + atomic rename, so a killed run never leaves a
half-written "latest" checkpoint. Restore rebuilds the exact pytree
(dtypes preserved, bf16 round-trips via a uint16 view).
"""
from __future__ import annotations

import pathlib
import shutil

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir, step: int, tree) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:09d}"
    tmp = ckpt_dir / f".tmp_step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    arrays = {}
    meta = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = arr.dtype.name
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            dtype_name = "bfloat16"
        arrays[f"a{i}"] = arr
        meta.append({"dtype": dtype_name, "shape": list(arr.shape)})

    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "tree.msgpack").write_bytes(
        msgpack.packb({"treedef": str(treedef), "meta": meta, "step": step})
    )
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(
        int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*") if p.is_dir()
    )
    return steps[-1] if steps else None


def restore(ckpt_dir, step: int, like):
    """Restore into the structure of ``like`` (validates leaf count/shape)."""
    path = pathlib.Path(ckpt_dir) / f"step_{step:09d}"
    blob = msgpack.unpackb((path / "tree.msgpack").read_bytes())
    data = np.load(path / "arrays.npz")
    leaves, treedef = _flatten(like)
    if len(leaves) != len(blob["meta"]):
        raise ValueError(
            f"checkpoint has {len(blob['meta'])} leaves, expected {len(leaves)}"
        )
    out = []
    for i, (leaf, m) in enumerate(zip(leaves, blob["meta"])):
        arr = data[f"a{i}"]
        if m["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != expected {np.shape(leaf)}"
            )
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
