"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, base_lr, total_steps, min_frac=0.1):
    frac = jnp.clip(step / total_steps, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return base_lr * (min_frac + (1.0 - min_frac) * cos)


def linear_warmup_cosine(step, *, base_lr, warmup_steps, total_steps,
                         min_frac=0.1):
    warm = base_lr * jnp.minimum(1.0, step / jnp.maximum(warmup_steps, 1))
    decay = cosine_schedule(
        jnp.maximum(step - warmup_steps, 0),
        base_lr=base_lr,
        total_steps=jnp.maximum(total_steps - warmup_steps, 1),
        min_frac=min_frac,
    )
    return jnp.where(step < warmup_steps, warm, decay)
