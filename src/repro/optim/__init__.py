"""Deep-net optimizers (the convex federated optimizers live in core/)."""
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.flens_head import (
    extract_features,
    flens_head_init,
    flens_head_update,
    head_problem,
)
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine
