"""FLeNS-head: the paper's optimizer as a first-class trainer feature.

The sound transplant of FLeNS (a convex second-order federated method) to
deep networks is second-order on the *convex-given-features* head block:
a logistic readout on frozen/slow backbone features is exactly the
paper's problem with X := features (DESIGN.md §4.1).

Usage (see examples/federated_llm.py): per round, every client (= data
mesh slice) extracts features with the shared backbone, forms its local
gradient + two-sided sketched Hessian of the head objective, and the
server performs the FLeNS update. This module provides the glue from an
LM backbone to a ``repro.core`` FederatedProblem.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import FLeNS, logistic, make_problem
from repro.core.federated import FederatedProblem


def extract_features(model, params, tokens, *, pool: str = "mean"):
    """Backbone features for a token batch (no LM head). (B, D) float."""
    from repro.models.common import embed

    cfg = model.cfg
    x = embed(params["embed"], tokens, cfg)
    feats, _, _ = model._backbone(params, x)
    if pool == "mean":
        return jnp.mean(feats.astype(jnp.float32), axis=1)
    if pool == "last":
        return feats[:, -1].astype(jnp.float32)
    raise ValueError(pool)


def head_problem(features: jax.Array, labels: jax.Array, m_clients: int,
                 lam: float = 1e-3, heterogeneity: str = "iid",
                 key=None) -> FederatedProblem:
    """Build the convex head objective as a federated problem.

    features (N, D) float; labels (N,) in {-1, +1}.
    """
    feats = features.astype(jnp.float64)
    return make_problem(
        feats, labels.astype(jnp.float64), m=m_clients, lam=lam,
        objective=logistic, heterogeneity=heterogeneity, key=key,
    )


def flens_head_init(problem: FederatedProblem, *, k: int, **flens_kw):
    opt = FLeNS(k=k, **flens_kw)
    w0 = jnp.zeros((problem.dim,), problem.X.dtype)
    return opt, opt.init(problem, w0)


def flens_head_update(opt: FLeNS, problem: FederatedProblem, state, key):
    return opt.round(problem, state, key)
