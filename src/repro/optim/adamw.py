"""AdamW with configurable state dtype (fp32 default; bf16 for the 1T MoE,
where fp32 moments cannot fit 512 x 16 GB — see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params, *, state_dtype=jnp.float32):
    def zeros(p):
        return jnp.zeros(p.shape, state_dtype)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params,
    grads,
    opt_state,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float | None = 1.0,
):
    """One AdamW step. lr may be a scalar or a schedule value."""
    step = opt_state["step"] + 1

    if grad_clip is not None:
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
    else:
        gnorm = jnp.float32(0.0)
        scale = 1.0

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = (g.astype(jnp.float32) * scale).astype(m.dtype)
        m2 = b1 * m + (1.0 - b1) * gf
        v2 = b2 * v + (1.0 - b2) * jnp.square(gf)
        mhat = m2.astype(jnp.float32) / bc1
        vhat = v2.astype(jnp.float32) / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
