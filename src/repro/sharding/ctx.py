"""Mesh context + activation sharding-constraint helpers.

Models call ``shard(x, *axes)`` with *physical* mesh axis names; when no
mesh is active (single-device smoke tests) every call is a no-op, so the
model code is mesh-agnostic. Axis entries that name axes absent from the
active mesh are dropped, which lets the same model run on the single-pod
("data","model") and multi-pod ("pod","data","model") meshes unchanged.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Mesh | None = None

# Logical batch axis: sharded over every data-parallel mesh axis present.
BATCH = ("pod", "data")
MODEL = "model"
FSDP = "data"  # weight-shard axis for fully-sharded data parallelism


def set_mesh(mesh: Mesh | None) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Mesh | None:
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None):
    prev = _MESH
    set_mesh(mesh)
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        set_mesh(prev)


def _filter_axes(mesh: Mesh, axes):
    out = []
    for a in axes:
        if a is None:
            out.append(None)
        elif isinstance(a, (tuple, list)):
            sub = tuple(x for x in a if x in mesh.axis_names)
            out.append(sub if sub else None)
        else:
            out.append(a if a in mesh.axis_names else None)
    # drop trailing Nones (cosmetic)
    while out and out[-1] is None:
        out.pop()
    return tuple(out)


def pspec(*axes) -> P:
    """PartitionSpec with axes filtered to the active mesh (P() if none)."""
    mesh = get_mesh()
    if mesh is None:
        return P()
    return P(*_filter_axes(mesh, axes))


def shard(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint against the active mesh (no-op without one)."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec = P(*_filter_axes(mesh, axes))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(*axes) -> NamedSharding | None:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, P(*_filter_axes(mesh, axes)))


def axis_size(name: str) -> int:
    mesh = get_mesh()
    if mesh is None or name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
