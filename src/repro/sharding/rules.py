"""Logical sharding rules: param/state/batch pytrees -> PartitionSpecs.

Strategy (baseline; alternatives measured in EXPERIMENTS.md §Perf):
  * batch dims            -> ("pod", "data")
  * vocab / heads / d_ff / experts (parallelizable width) -> "model"
  * weight d_model dims   -> "data"   (FSDP: all-gather on use,
                                       reduce-scatter on grad)
  * KV-cache sequence     -> "model"  (sequence-parallel decode attention);
                             batch=1 long-context shards seq over
                             ("data", "model") as well
  * every assignment is divisibility-guarded: a dim that does not divide
    by the mesh axis product falls back to replication (e.g. 8 KV heads
    on a 16-way model axis).

The rules are name-based over the param tree paths produced by the model
zoo — the single place where layout policy lives.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, GetAttrKey, SequenceKey


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return n


def _guard(mesh: Mesh, shape, spec_axes) -> P:
    """Drop axis assignments that don't divide or aren't in the mesh."""
    out = []
    for dim, axes in zip(shape, spec_axes):
        if axes is None:
            out.append(None)
            continue
        cand = (axes,) if isinstance(axes, str) else tuple(axes)
        cand = tuple(a for a in cand if a in mesh.axis_names)
        # progressively drop trailing axes until divisible
        while cand and dim % _axsize(mesh, cand) != 0:
            cand = cand[:-1]
        if not cand:
            out.append(None)
        elif len(cand) == 1:
            out.append(cand[0])
        else:
            out.append(cand)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, SequenceKey):
            names.append(str(k.idx))
        elif isinstance(k, GetAttrKey):
            names.append(k.name)
    return names


# -- parameter rules ----------------------------------------------------------

def param_spec(mesh: Mesh, path, leaf) -> P:
    names = _path_names(path)
    name = names[-1]
    shape = leaf.shape
    nd = len(shape)
    fsdp, mdl = "data", "model"

    if nd <= 1:
        return P()
    if name == "table":  # (V, D)
        return _guard(mesh, shape, (mdl, fsdp))
    if name == "lm_head":  # (D, V)
        return _guard(mesh, shape, (fsdp, mdl))
    if name in ("wq",):  # (L?, D, H, Dh)
        base = (fsdp, mdl, None)
        return _guard(mesh, shape, (None,) * (nd - 3) + base)
    if name in ("wk", "wv"):  # (L?, Dkv_in, Hkv, Dh)
        base = (fsdp, mdl, None)
        return _guard(mesh, shape, (None,) * (nd - 3) + base)
    if name == "wo":  # (L?, H, Dh, D)
        base = (mdl, None, fsdp)
        return _guard(mesh, shape, (None,) * (nd - 3) + base)
    if name in ("w_gate", "w_up"):
        # expert tensors are direct children of "moe": (L?, E, D, F);
        # plain mlp (incl. the moe *shared* expert) is (L?, D, F)
        if nd >= 3 and len(names) >= 2 and names[-2] == "moe":
            base = (mdl, fsdp, None)  # (E, D, F)
            return _guard(mesh, shape, (None,) * (nd - 3) + base)
        base = (fsdp, mdl)  # (D, F)
        return _guard(mesh, shape, (None,) * (nd - 2) + base)
    if name == "w_down":
        if nd >= 3 and len(names) >= 2 and names[-2] == "moe":
            base = (mdl, None, fsdp)  # (E, F, D)
            return _guard(mesh, shape, (None,) * (nd - 3) + base)
        base = (mdl, fsdp)  # (F, D)
        return _guard(mesh, shape, (None,) * (nd - 2) + base)
    if name == "router":
        return P()
    if name == "w_in":  # ssd (L?, D, X)
        base = (fsdp, mdl)
        return _guard(mesh, shape, (None,) * (nd - 2) + base)
    if name in ("w_x", "w_gate2", "w_a", "w_i"):  # rglru (L?, D, D)
        base = (fsdp, mdl)
        return _guard(mesh, shape, (None,) * (nd - 2) + base)
    if name == "w_out":  # (L?, Din, D)
        base = (mdl, fsdp)
        return _guard(mesh, shape, (None,) * (nd - 2) + base)
    if name == "conv_w":
        return P()
    if name == "vision_proj":  # (Dv, D)
        return _guard(mesh, shape, (None, fsdp))
    # default: replicate trailing structure, fsdp on the largest dim if big
    if nd >= 2 and int(np.prod(shape)) > 1_000_000:
        base = [None] * nd
        base[-2] = fsdp
        base[-1] = mdl
        return _guard(mesh, shape, tuple(base))
    return P()


# -- decode-state rules ---------------------------------------------------------

def state_spec(mesh: Mesh, path, leaf, *, batch: int) -> P:
    names = _path_names(path)
    name = names[-1]
    shape = leaf.shape
    nd = len(shape)
    batch_axes = "data" if batch > 1 else None
    seq_axes = ("model",) if batch > 1 else ("data", "model")

    if name in ("k", "v"):  # (G, [per,] B, S, Hkv, Dh)
        lead = nd - 4  # layer (and vlm per-layer) dims stay replicated
        spec = (None,) * lead + (batch_axes, seq_axes, None, None)
        return _guard(mesh, shape, spec)
    if name in ("cross_k", "cross_v"):  # (G, B, Sv, Hkv, Dh)
        return _guard(mesh, shape, (None, batch_axes, None, None, None))
    if name == "pos":  # (G, [per,] B, S) — follows the cache sharding
        return _guard(mesh, shape,
                      (None,) * (nd - 2) + (batch_axes, seq_axes))
    if name == "ssm":  # (G, B, H, N, P)
        return _guard(mesh, shape, (None, batch_axes, "model", None, None))
    if name == "conv":  # (G, B, K-1, C)
        return _guard(mesh, shape, (None, batch_axes, None, None))
    if name in ("h", "h0", "h1"):  # (G, B, D)
        return _guard(mesh, shape, (None, batch_axes, None))
    if name in ("conv0", "conv1"):
        return _guard(mesh, shape, (None, batch_axes, None, None))
    if name == "index":
        return P()
    return P()


# -- batch rules -----------------------------------------------------------------

def batch_spec(mesh: Mesh, path, leaf) -> P:
    shape = leaf.shape
    return _guard(mesh, shape, (("pod", "data"),) + (None,) * (len(shape) - 1))


def tree_shardings(mesh: Mesh, tree, rule, **kw):
    """Map a spec rule over a pytree -> NamedSharding pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, rule(mesh, path, leaf, **kw)),
        tree,
    )


# -- federated cohort rules ---------------------------------------------------

def cohort_spec(mesh: Mesh, leaf) -> P:
    """PartitionSpec for one cohort-stacked array: shard the leading
    (client) axis over the first available client-capable mesh axis,
    replicate everything else. Divisibility-guarded like every other
    rule — a cohort that doesn't divide the mesh falls back to
    replication rather than erroring."""
    axes = tuple(a for a in ("clients", "data") if a in mesh.axis_names)[:1]
    if not axes or leaf.ndim == 0:
        return P()
    return _guard(mesh, leaf.shape,
                  (axes[0],) + (None,) * (leaf.ndim - 1))


def shard_cohort(mesh: Mesh, cohort):
    """Place a cohort pytree (``FederatedProblem`` of one sampled
    cohort) with its client axis sharded over ``mesh``.

    This is how a population-mode round spreads over devices: the jitted
    round is vmapped over the client axis, so GSPMD partitions every
    per-client computation along the mesh and the server aggregation
    becomes a cross-device reduction — no shard_map rewrite of the round
    needed. A 1-device mesh is the identity placement.
    """
    return jax.tree_util.tree_map(
        lambda leaf: jax.device_put(
            leaf, NamedSharding(mesh, cohort_spec(mesh, leaf))),
        cohort,
    )
