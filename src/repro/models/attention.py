"""Grouped-query attention with RoPE, sliding windows, KV caches, cross-attn.

Three entry points:
  * ``attn_full``   — full-sequence self-attention (train / prefill)
  * ``attn_decode`` — one-token step against a (possibly ring-buffer) cache
  * ``attn_cross``  — cross-attention over precomputed memory (VLM/whisper)

Caches store absolute positions per slot (``pos``, -1 = empty), which
uniformly supports full-length caches and right-sized ring buffers for
sliding-window layers (cache_mode="rightsized").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.common import ModelConfig, dense_init, residual_out_init, rmsnorm
from repro.sharding.ctx import BATCH, MODEL, shard

NEG_INF = -2.0**30  # large-negative instead of -inf: keeps masked softmax NaN-free


def attention_init(key, cfg: ModelConfig, *, d_kv_in: int | None = None):
    """QKV + output projection params. d_kv_in: cross-attn memory width."""
    d_kv_in = d_kv_in or cfg.d_model
    h, hkv, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * dh, cfg, shape=(d, h, dh)),
        "wk": dense_init(ks[1], d_kv_in, hkv * dh, cfg, shape=(d_kv_in, hkv, dh), fan_in=d_kv_in),
        "wv": dense_init(ks[2], d_kv_in, hkv * dh, cfg, shape=(d_kv_in, hkv, dh), fan_in=d_kv_in),
        "wo": residual_out_init(ks[3], h * dh, d, cfg, shape=(h, dh, d), fan_in=h * dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), cfg.param_dtype)
        p["bk"] = jnp.zeros((hkv, dh), cfg.param_dtype)
        p["bv"] = jnp.zeros((hkv, dh), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.zeros((dh,), cfg.param_dtype)}
        p["k_norm"] = {"scale": jnp.zeros((dh,), cfg.param_dtype)}
    return p


def rope(x: jax.Array, positions: jax.Array, theta) -> jax.Array:
    """Rotary embedding. x (..., T, H, Dh), positions (T,) or (B, T)."""
    dh = x.shape[-1]
    half = dh // 2
    freq_exp = jnp.arange(0, half, dtype=jnp.float32) / half
    inv_freq = theta ** (-freq_exp)  # (half,) ; theta may be traced
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (..., T, half)
    if angles.ndim == 2:  # (T, half) -> broadcast over batch later
        angles = angles[None]
    cos = jnp.cos(angles)[..., None, :]  # (B?, T, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _qkv(params, x, kv_x, cfg):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", kv_x, params["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", kv_x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    return q, k, v


def _out(params, o, dtype):
    return jnp.einsum("bthk,hkd->btd", o, params["wo"].astype(dtype))


def attn_full(
    params,
    x: jax.Array,  # (B, T, D)
    cfg: ModelConfig,
    *,
    causal: bool = True,
    window=None,  # None | int | traced scalar (per-layer meta)
    theta=None,
    positions: jax.Array | None = None,
) -> jax.Array:
    """Full-sequence self-attention (training / prefill)."""
    b, t, d = x.shape
    theta = cfg.rope_theta if theta is None else theta
    if positions is None:
        positions = jnp.arange(t)
    q, k, v = _qkv(params, x, x, cfg)
    if theta is not None:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    q = shard(q, BATCH, None, MODEL, None)
    k = shard(k, BATCH, None, MODEL, None)
    v = shard(v, BATCH, None, MODEL, None)

    # Blocked online-softmax attention: O(T) memory (flash-attention math;
    # Pallas kernel on TPU, pure-jnp blocked reference elsewhere).
    o = kops.flash_attention(q, k, v, causal=causal, window=window)
    o = shard(o, BATCH, None, MODEL, None)
    return _out(params, o, x.dtype)


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def make_cache(cfg: ModelConfig, n_layers: int, batch: int, length: int,
               dtype=None):
    """Stacked (per-layer) attention cache with per-slot absolute positions.

    ``pos`` is per batch row ((L, B, S)) so every sequence in the batch may
    sit at a different decode index — the contract continuous batching
    (serving/engine.py) relies on.
    """
    dtype = dtype or cfg.dtype
    return {
        "k": jnp.zeros((n_layers, batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((n_layers, batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((n_layers, batch, length), -1, jnp.int32),
    }


def attn_decode(
    params,
    x: jax.Array,  # (B, 1, D)
    layer_cache,  # {"k": (B,S,Hkv,Dh), "v": ..., "pos": (B,S)} — one layer
    index,  # int32 scalar OR (B,): per-sequence absolute position
    cfg: ModelConfig,
    *,
    window=None,
    theta=None,
):
    """One decode step. Returns (out (B,1,D), updated layer_cache).

    ``index`` may differ per batch row (continuous batching).
    """
    b = x.shape[0]
    theta = cfg.rope_theta if theta is None else theta
    idx = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(index, jnp.int32)), (b,))
    pos = idx[:, None]  # (B, 1) positions for rope
    q, k_new, v_new = _qkv(params, x, x, cfg)
    if theta is not None:
        q = rope(q, pos, theta)
        k_new = rope(k_new, pos, theta)

    s_cache = layer_cache["k"].shape[1]
    slot = jnp.mod(idx, s_cache)  # (B,)
    # One-hot (elementwise) cache write instead of dynamic_update_slice:
    # DUS at a dynamic index on a sharded sequence dim forces XLA SPMD to
    # re-materialize the cache through cache-sized collectives every step;
    # a where() with a local iota mask partitions with ZERO collectives
    # (§Perf hillclimb 1 — collective term 3.84s -> ms-scale on qwen
    # decode_32k). The extra full-cache write is fused by XLA.
    hot = (jnp.arange(s_cache, dtype=jnp.int32)[None, :] == slot[:, None])  # (B,S)
    k = jnp.where(hot[:, :, None, None], k_new.astype(layer_cache["k"].dtype),
                  layer_cache["k"])
    v = jnp.where(hot[:, :, None, None], v_new.astype(layer_cache["v"].dtype),
                  layer_cache["v"])
    pos_arr = jnp.where(hot, idx[:, None], layer_cache["pos"])  # (B,S)

    # Sequence-parallel decode attention: everything downstream of the
    # cache follows the cache's SEQ sharding (batch -> data when b > 1;
    # seq -> model, or (data, model) for batch=1 long-context). Without
    # these constraints XLA reshards the (B, H, 1, S) logits between the
    # two einsums — cache-sized collectives per layer (§Perf hillclimb 1).
    from repro.sharding.ctx import axis_size

    batch_ax = BATCH if b >= max(axis_size("data"), 2) else None
    seq_ax = MODEL if batch_ax is not None else ("data", MODEL)
    group = cfg.n_heads // cfg.n_kv_heads
    q = shard(q, batch_ax, None, None, None)  # replicated over model
    qg = q.reshape(b, 1, cfg.n_kv_heads, group, cfg.head_dim)
    scale = cfg.head_dim**-0.5
    # keep the (huge) cache operands in their storage dtype and accumulate
    # in f32 via preferred_element_type — an explicit .astype(f32) makes
    # XLA hoist a convert of the ENTIRE stacked cache out of the layer
    # loop (2 x 86 GB material on qwen decode_32k; §Perf hillclimb 1)
    logits = jnp.einsum("bqhgk,bshk->bhgqs",
                        (qg * scale).astype(k.dtype), k,
                        preferred_element_type=jnp.float32)
    logits = shard(logits, batch_ax, None, None, None, seq_ax)
    valid = (pos_arr >= 0) & (pos_arr <= idx[:, None])  # (B, S)
    if window is not None:
        w = jnp.asarray(window)
        valid &= jnp.where(w > 0, pos_arr > (idx[:, None] - w), True)
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = shard(probs, batch_ax, None, None, None, seq_ax)
    o = jnp.einsum("bhgqs,bshk->bqhgk", probs.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, cfg.n_heads, cfg.head_dim).astype(x.dtype)
    o = shard(o, batch_ax, None, None, None)
    out = _out(params, o, x.dtype)
    return out, {"k": k, "v": v, "pos": pos_arr}


def attn_cross(
    params,
    x: jax.Array,  # (B, T, D) queries
    memory_kv,  # precomputed {"k": (B,S,Hkv,Dh), "v": ...} or raw memory (B,S,Dm)
    cfg: ModelConfig,
) -> jax.Array:
    """Cross-attention over encoder/vision memory (non-causal, no rope)."""
    b, t, d = x.shape
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(x.dtype))
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
    k, v = memory_kv["k"], memory_kv["v"]
    group = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, t, cfg.n_kv_heads, group, cfg.head_dim)
    scale = cfg.head_dim**-0.5
    logits = jnp.einsum("bqhgk,bshk->bhgqs",
                        (qg * scale).astype(jnp.float32), k.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqs,bshk->bqhgk", probs, v.astype(jnp.float32))
    o = o.reshape(b, t, cfg.n_heads, cfg.head_dim).astype(x.dtype)
    return _out(params, o, x.dtype)


def cross_kv(params, memory: jax.Array, cfg: ModelConfig):
    """Precompute cross-attention K/V from memory (B, S, Dm)."""
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"].astype(memory.dtype))
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"].astype(memory.dtype))
    if "bk" in params:
        k = k + params["bk"].astype(memory.dtype)
        v = v + params["bv"].astype(memory.dtype)
    if "k_norm" in params:
        k = rmsnorm(params["k_norm"], k)
    return {"k": k, "v": v}
