"""Mamba-2 SSD (state-space duality) block — arXiv:2405.21060.

Chunked training algorithm (quadratic intra-chunk + linear inter-chunk
recurrence) and the O(1)-state decode step. Layout follows the paper's
reference: after the input projection the block carries

  x  (B, T, H, P)   value heads          (P = head dim)
  dt (B, T, H)      softplus step sizes
  A  (H,)           negative decay rates
  B_ (B, T, N)      input maps  (n_groups = 1)
  C_ (B, T, N)      output maps
  D  (H,)           skip connection

TPU adaptation: the intra-chunk quadratic term is an MXU-friendly batched
matmul over (chunk x chunk) tiles; the inter-chunk scan runs over
T/chunk steps of (H, N, P) states (tiny), so the sequential depth is
T/chunk instead of T.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, residual_out_init, rmsnorm
from repro.sharding.ctx import BATCH, MODEL, shard


def ssd_init(key, cfg: ModelConfig):
    d, din = cfg.d_model, cfg.ssm_d_inner
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = din + 2 * n  # conv over [x, B, C]
    ks = jax.random.split(key, 6)
    dt_bias = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(ks[4], (h,), jnp.float32) *
                (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    ))  # inverse-softplus of dt in [1e-3, 1e-1]
    return {
        # in_proj -> [z (din), x (din), B (n), C (n), dt (h)]
        "w_in": dense_init(ks[0], d, 2 * din + 2 * n + h, cfg),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32)
                   * (3.0 / cfg.ssm_conv) ** 0.5).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.param_dtype),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": {"scale": jnp.zeros((din,), cfg.param_dtype)},
        "w_out": residual_out_init(ks[5], din, d, cfg, fan_in=din),
    }


def _split_proj(params, u, cfg: ModelConfig):
    din, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = u @ params["w_in"]
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din : 2 * din + 2 * n]
    dt_raw = zxbcdt[..., 2 * din + 2 * n :]
    return z, xbc, dt_raw


def _post_conv(xbc, cfg: ModelConfig):
    din, n = cfg.ssm_d_inner, cfg.ssm_state
    xbc = jax.nn.silu(xbc)
    x = xbc[..., :din]
    b_ = xbc[..., din : din + n]
    c_ = xbc[..., din + n :]
    return x, b_, c_


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv over time. xbc (B, T, C), conv_w (K, C).

    conv_state (B, K-1, C): trailing inputs from the previous segment
    (decode). Returns (out (B,T,C), new_state).
    """
    k = conv_w.shape[0]
    b, t, c = xbc.shape
    if conv_state is None:
        conv_state = jnp.zeros((b, k - 1, c), xbc.dtype)
    ext = jnp.concatenate([conv_state, xbc], axis=1)  # (B, T+K-1, C)
    out = jnp.zeros((b, t, c), xbc.dtype)
    for i in range(k):
        out = out + ext[:, i : i + t, :] * conv_w[i][None, None, :]
    out = out + conv_b[None, None, :]
    new_state = ext[:, t:, :] if t >= 1 else conv_state
    new_state = jax.lax.dynamic_slice_in_dim(ext, ext.shape[1] - (k - 1), k - 1, axis=1)
    return out, new_state


def _segsum_decay(dA):  # (..., Q) -> (..., Q, Q) lower-tri decay logs
    q = dA.shape[-1]
    cum = jnp.cumsum(dA, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # log decay j -> i
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x, dt, a_neg, b_, c_, d_skip, *, chunk: int, init_state=None):
    """Chunked SSD. x (B,T,H,P), dt (B,T,H), a_neg (H,), b_/c_ (B,T,N).

    Returns (y (B,T,H,P), final_state (B,H,N,P)).
    """
    bsz, t, h, p = x.shape
    n = b_.shape[-1]
    if t % chunk != 0:
        raise ValueError(f"sequence length {t} must be divisible by the "
                         f"SSD scan chunk {chunk}")
    nc = t // chunk
    xf = x.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    bf = b_.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cf = c_.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    a_neg = a_neg.astype(jnp.float32)
    d_skip = d_skip.astype(jnp.float32)
    if init_state is not None:
        init_state = init_state.astype(jnp.float32)

    dA = dtf * a_neg[None, None, None, :]  # (B,nc,Q,H) log-decay per step
    dA_hq = dA.transpose(0, 1, 3, 2)  # (B,nc,H,Q)
    cum = jnp.cumsum(dA_hq, axis=-1)  # (B,nc,H,Q)
    decay_mat = jnp.exp(_segsum_decay(dA_hq))  # (B,nc,H,Q,Q), lower-tri

    # intra-chunk (diagonal) term
    scores = jnp.einsum("bcin,bcjn->bcij", cf, bf)  # (B,nc,Q,Q)
    y_diag = jnp.einsum(
        "bcij,bchij,bcjh,bcjhp->bcihp", scores, decay_mat, dtf, xf
    )

    # per-chunk end states: S_c = sum_j exp(cum_end - cum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # (B,nc,H,Q)
    s_chunk = jnp.einsum(
        "bchj,bcjh,bcjn,bcjhp->bchnp", decay_to_end, dtf, bf, xf
    )  # (B,nc,H,N,P)

    # inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(cum[..., -1])  # (B,nc,H) total decay per chunk
    if init_state is None:
        init_state = jnp.zeros((bsz, h, n, p), jnp.float32)

    def body(carry, xs):
        s_in, dec, s_new = carry, xs[0], xs[1]
        out = s_in  # state BEFORE this chunk
        s_next = s_in * dec[:, :, None, None] + s_new
        return s_next, out

    dec_t = chunk_decay.transpose(1, 0, 2)  # (nc, B, H)
    s_t = s_chunk.transpose(1, 0, 2, 3, 4)  # (nc, B, H, N, P)
    final_state, states_before = jax.lax.scan(body, init_state, (dec_t, s_t))
    states_before = states_before.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P)

    # off-diagonal (inter-chunk) contribution
    in_decay = jnp.exp(cum)  # decay from chunk start to position i
    y_off = jnp.einsum(
        "bcin,bchi,bchnp->bcihp", cf, in_decay.transpose(0, 1, 2, 3), states_before
    )
    y = y_diag + y_off + d_skip[None, None, None, :, None] * xf
    return y.reshape(bsz, t, h, p), final_state


def ssd_block_apply(params, u, cfg: ModelConfig, *, ssm_state=None,
                    conv_state=None, return_state: bool = False):
    """Full mamba2 block over a sequence. u (B, T, D)."""
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xbc_raw, dt_raw = _split_proj(params, u, cfg)
    xbc, new_conv_state = _causal_conv(
        xbc_raw, params["conv_w"].astype(u.dtype), params["conv_b"].astype(u.dtype),
        conv_state,
    )
    x, b_, c_ = _post_conv(xbc, cfg)
    bsz, t, _ = u.shape
    xh = x.reshape(bsz, t, h, p)
    xh = shard(xh, BATCH, None, MODEL, None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a_neg = -jnp.exp(params["a_log"])
    y, final_state = ssd_scan(
        xh, dt, a_neg, b_, c_, params["d_skip"], chunk=min(cfg.ssm_chunk, t),
        init_state=ssm_state,
    )
    y = y.reshape(bsz, t, h * p).astype(u.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["w_out"]
    if return_state:
        return out, final_state, new_conv_state
    return out


def ssd_decode_step(params, u, cfg: ModelConfig, *, ssm_state, conv_state):
    """One-token step. u (B, 1, D); states from make_ssd_state/prefill."""
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xbc_raw, dt_raw = _split_proj(params, u, cfg)
    # conv: use the stored K-1 trailing inputs
    xbc, new_conv_state = _causal_conv(
        xbc_raw, params["conv_w"].astype(u.dtype), params["conv_b"].astype(u.dtype),
        conv_state,
    )
    x, b_, c_ = _post_conv(xbc, cfg)
    bsz = u.shape[0]
    xh = x.reshape(bsz, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a_neg = -jnp.exp(params["a_log"])
    dec = jnp.exp(dt * a_neg[None, :])  # (B,H)
    bf = b_[:, 0].astype(jnp.float32)  # (B,N)
    cf = c_[:, 0].astype(jnp.float32)
    new_state = (ssm_state * dec[:, :, None, None]
                 + jnp.einsum("bh,bn,bhp->bhnp", dt, bf, xh))
    y = jnp.einsum("bn,bhnp->bhp", cf, new_state) + params["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, h * p).astype(u.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return y @ params["w_out"], new_state, new_conv_state


def make_ssd_state(cfg: ModelConfig, n_layers: int, batch: int):
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.ssm_d_inner + 2 * n
    return {
        "ssm": jnp.zeros((n_layers, batch, h, n, p), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_dim), cfg.dtype),
    }
