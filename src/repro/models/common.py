"""Model configuration + shared building blocks (norms, MLPs, embeddings).

All models are pure-functional JAX: params are nested dicts of arrays,
every module is an ``init(key, cfg) -> params`` plus an
``apply(params, x, ...) -> y`` pair. No flax/haiku — the framework owns
its substrate end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.ctx import BATCH, MODEL, shard


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config describes every architecture family in the zoo."""

    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None  # default d_model // n_heads

    # attention
    rope_theta: float = 10_000.0
    rope_theta_global: float | None = None  # gemma3 global layers use 1e6
    window: int | None = None  # sliding-window size for local layers
    global_every: int | None = None  # gemma3: 1 global per `global_every+1`? see groups
    local_per_global: int | None = None  # gemma3: 5 local then 1 global
    qkv_bias: bool = False  # qwen1.5
    qk_norm: bool = False  # gemma3
    act: str = "silu"  # silu (swiglu) | gelu (geglu)
    tied_embeddings: bool = True

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0  # kimi: 1 shared expert
    first_k_dense: int = 0  # kimi: first layer(s) dense
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (recurrentgemma): layer pattern within a super-block
    block_pattern: tuple = ()  # e.g. ("rec", "rec", "attn")
    rglru_conv: int = 4

    # VLM
    cross_attn_every: int = 0  # one cross-attn layer per N self layers
    vision_tokens: int = 0
    vision_dim: int = 0

    # audio (whisper): encoder spec; n_layers is the decoder depth
    encoder_layers: int = 0
    audio_frames: int = 0

    # numerics / memory
    dtype: Any = jnp.bfloat16  # activations
    param_dtype: Any = jnp.bfloat16
    remat: bool = True
    logits_chunk: int = 0  # 0 = full logits; else chunked CE over seq
    cache_mode: str = "uniform"  # uniform | rightsized (local layers)

    # source citation (model card / paper)
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        base = dict(
            n_layers=2,
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_head=64,
            d_ff=min(self.d_ff, 512) or 0,
            vocab=min(self.vocab, 512),
            remat=False,
            dtype=jnp.float32,
            param_dtype=jnp.float32,
        )
        if self.n_experts:
            base.update(
                n_experts=4,
                top_k=min(self.top_k, 2),
                moe_d_ff=min(self.moe_d_ff, 256),
                first_k_dense=min(self.first_k_dense, 1),
            )
        if self.ssm_state:
            base.update(ssm_state=32, ssm_head_dim=32, ssm_chunk=32)
        if self.window:
            base.update(window=min(self.window, 32))
        if self.local_per_global:
            base.update(local_per_global=min(self.local_per_global, 2))
        if self.cross_attn_every:
            # vlm group structure needs n_layers % (per+1) == 0
            base.update(cross_attn_every=2, vision_tokens=16, vision_dim=64,
                        n_layers=3)
        if self.encoder_layers:
            base.update(encoder_layers=2, audio_frames=32)
        if self.block_pattern:
            # one full (rec, rec, attn) super-block
            base.update(window=min(self.window or 32, 32), n_layers=3)
        base.update(overrides)
        return dataclasses.replace(self, **base)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def truncated_normal_init(key, shape, scale, dtype):
    stddev = scale / max(1.0, (shape[-2] if len(shape) > 1 else shape[-1])) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


def dense_init(key, d_in, d_out, cfg, *, shape=None, fan_in=None, scale=1.0):
    shape = shape or (d_in, d_out)
    fan_in = fan_in or d_in
    stddev = scale / fan_in**0.5
    w = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * stddev
    return w.astype(cfg.param_dtype)


def residual_out_init(key, d_in, d_out, cfg, *, shape=None, fan_in=None):
    """GPT-2-style scaled init for projections feeding the residual stream."""
    scale = 1.0 / (2.0 * max(cfg.n_layers, 1)) ** 0.5
    return dense_init(key, d_in, d_out, cfg, shape=shape, fan_in=fan_in,
                      scale=scale)


def rmsnorm_init(dim, cfg):
    return {"scale": jnp.zeros((dim,), cfg.param_dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    out = normed * (1.0 + params["scale"].astype(jnp.float32))
    return out.astype(x.dtype)


def mlp_init(key, cfg, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, cfg.d_model, d_ff, cfg),
        "w_up": dense_init(k2, cfg.d_model, d_ff, cfg),
        "w_down": residual_out_init(k3, d_ff, cfg.d_model, cfg, fan_in=d_ff),
    }


def mlp_apply(params, x, cfg):
    """Gated MLP (swiglu/geglu). x: (..., d_model)."""
    gate = x @ params["w_gate"]
    up = x @ params["w_up"]
    act = jax.nn.silu(gate) if cfg.act == "silu" else jax.nn.gelu(gate)
    h = act * up
    h = shard(h, BATCH, None, MODEL)
    return h @ params["w_down"]


def embedding_init(key, cfg):
    emb = (jax.random.normal(key, (cfg.vocab, cfg.d_model), jnp.float32)
           * cfg.d_model**-0.5).astype(cfg.param_dtype)
    return {"table": emb}


def embed(params, tokens, cfg):
    x = jnp.take(params["table"], tokens, axis=0).astype(cfg.dtype)
    if cfg.family in ("dense", "moe", "vlm", "hybrid"):
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)  # gemma-style scale
    return shard(x, BATCH, None, None)


def unembed(params, x, cfg):
    table = params["table"]
    logits = x @ table.T.astype(x.dtype)
    return shard(logits, BATCH, None, MODEL)


def cross_entropy(logits, labels, mask=None):
    """Mean next-token CE in float32. logits (B,T,V), labels (B,T)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_cross_entropy(feats, table, labels, mask=None):
    """CE from features without gathering along the (sharded) vocab dim.

    gold logit = <feats, table[labels]> — a row gather from the embedding
    table (cheap under SPMD) instead of a take_along_axis on the full
    (B, T, V) logits tensor (which forces an all-gather of f32 logits).
    logsumexp still runs over the vocab-sharded logits (one small
    all-reduce of (B, T) partials).
    """
    logits = feats @ table.T.astype(feats.dtype)
    logits = shard(logits, BATCH, None, MODEL)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold_rows = jnp.take(table, labels, axis=0).astype(jnp.float32)  # (B,T,D)
    gold = jnp.einsum("btd,btd->bt", feats.astype(jnp.float32), gold_rows)
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_cross_entropy(features, emb_table, labels, chunk, mask=None):
    """CE without materializing full (B,T,V) logits: scan over T chunks.

    features (B,T,D) -> per-chunk logits (B,c,V) -> nll, accumulated.
    """
    b, t, d = features.shape
    if t % chunk != 0:
        raise ValueError(f"sequence length {t} must be divisible by the "
                         f"cross-entropy chunk {chunk}")
    n_chunks = t // chunk
    feats = features.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    labs = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    msk = mask.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xs):
        f, lab, mk = xs
        logits = (f @ emb_table.T.astype(f.dtype)).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mk
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mk)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (feats, labs, msk))
    return tot / jnp.maximum(cnt, 1.0)
