"""Unified language-model assembly for every architecture family.

A model is a sequence of *layer groups*; each group is a homogeneous
stack of units scanned with ``jax.lax.scan`` (compact HLO even at 100
layers). Heterogeneous patterns become either per-layer metadata arrays
(gemma3's 5 local : 1 global windows — same params, different mask) or
super-block units (griffin's (rec, rec, attn); llama-vision's
(4 self + 1 cross)).

Entry points (all pure):
  * ``init(key)``                                  -> params
  * ``loss(params, batch)``                        -> (scalar, metrics)
  * ``prefill(params, batch)``                     -> (last_logits, state)
  * ``decode_step(params, state, tokens)``         -> (logits, state)
  * ``init_decode_state(batch, cache_len)``        -> zeroed state pytree
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rg
from repro.models import ssd as ssd_mod
from repro.models.common import (
    ModelConfig,
    chunked_cross_entropy,
    lm_cross_entropy,
    dense_init,
    embed,
    embedding_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.sharding.ctx import BATCH, MODEL, shard


def _maybe_seq_shard(x):
    """EXPERIMENTS §Perf: optional sequence-parallel residual carries.

    Gated by REPRO_SEQ_PARALLEL=1 (measurement flag, off by default):
    shards the between-layer activations over the model axis so the saved
    scan carries shrink 16x, at the cost of per-layer all-gathers. The
    napkin math predicts a net loss on this baseline (no Megatron-style
    TP gathers to piggyback on) — the dry-run measurement decides.
    """
    import os as _os

    if _os.environ.get("REPRO_SEQ_PARALLEL", "0") == "1":
        return shard(x, BATCH, MODEL, None)
    return x


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    kind: str  # dense | moe | ssd | rec | griffin | vlm | enc | dec
    n: int  # scanned units
    windows: Any = None  # (n,) int32 per-unit window (0 = full attention)
    thetas: Any = None  # (n,) float32 per-unit rope theta


# ---------------------------------------------------------------------------
# group-plan construction per family
# ---------------------------------------------------------------------------

def build_groups(cfg: ModelConfig) -> list[GroupSpec]:
    L = cfg.n_layers
    if cfg.family == "ssm":
        return [GroupSpec("ssd", L)]
    if cfg.family == "hybrid":
        # griffin pattern (rec, rec, attn) repeated; remainder rec-only
        n_super = L // 3
        rem = L - 3 * n_super
        gs = [GroupSpec("griffin", n_super)]
        if rem:
            gs.append(GroupSpec("rec", rem))
        return gs
    if cfg.family == "vlm":
        per = cfg.cross_attn_every  # self layers per cross layer
        if L % (per + 1) != 0:
            raise ValueError(
                f"vlm layer count {L} must be a multiple of "
                f"cross_attn_every+1 ({per + 1})")
        return [GroupSpec("vlm", L // (per + 1))]
    if cfg.family == "audio":
        return [GroupSpec("dec", L)]  # decoder; encoder handled separately
    # dense with local:global pattern + right-sized caches: scan over
    # (local x per + global) super-blocks so local layers can carry
    # window-length ring buffers instead of full-context caches
    # (§Perf hillclimb 2; identical layer order to the meta-array path)
    if (cfg.local_per_global and cfg.cache_mode == "rightsized"
            and cfg.family == "dense"):
        per = cfg.local_per_global + 1
        n_super = L // per
        rem = L - n_super * per
        gs = [GroupSpec("dense_sb", n_super)]
        if rem:
            gs.append(GroupSpec(
                "dense", rem,
                jnp.full((rem,), cfg.window, jnp.int32),
                jnp.full((rem,), cfg.rope_theta, jnp.float32),
            ))
        return gs
    # dense / moe with optional local:global window pattern
    if cfg.local_per_global:
        pat = cfg.local_per_global
        win, th = [], []
        for i in range(L):
            is_global = (i % (pat + 1)) == pat
            win.append(0 if is_global else cfg.window)
            th.append(cfg.rope_theta_global if is_global else cfg.rope_theta)
        windows = jnp.asarray(win, jnp.int32)
        thetas = jnp.asarray(th, jnp.float32)
    else:
        windows = jnp.full((L,), cfg.window or 0, jnp.int32)
        thetas = jnp.full((L,), cfg.rope_theta, jnp.float32)
    if cfg.family == "moe":
        gs = []
        if cfg.first_k_dense:
            k = cfg.first_k_dense
            gs.append(GroupSpec("dense", k, windows[:k], thetas[:k]))
        gs.append(
            GroupSpec("moe", L - cfg.first_k_dense,
                      windows[cfg.first_k_dense:], thetas[cfg.first_k_dense:])
        )
        return gs
    return [GroupSpec("dense", L, windows, thetas)]


# ---------------------------------------------------------------------------
# per-unit init / apply
# ---------------------------------------------------------------------------

def _dense_unit_init(key, cfg):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, cfg),
        "attn": attn.attention_init(k1, cfg),
        "ln2": rmsnorm_init(cfg.d_model, cfg),
        "mlp": mlp_init(k2, cfg),
    }
    if cfg.qk_norm:  # gemma3 sandwich norms
        p["ln1_post"] = rmsnorm_init(cfg.d_model, cfg)
        p["ln2_post"] = rmsnorm_init(cfg.d_model, cfg)
    return p


def _dense_unit_apply(p, x, cfg, *, window, theta, causal=True):
    h = attn.attn_full(p["attn"], rmsnorm(p["ln1"], x), cfg,
                       causal=causal, window=window, theta=theta)
    if "ln1_post" in p:
        h = rmsnorm(p["ln1_post"], h)
    x = x + h
    h = mlp_apply(p["mlp"], rmsnorm(p["ln2"], x), cfg)
    if "ln2_post" in p:
        h = rmsnorm(p["ln2_post"], h)
    return x + h


def _dense_unit_decode(p, x, cache, index, cfg, *, window, theta):
    h, cache = attn.attn_decode(p["attn"], rmsnorm(p["ln1"], x), cache, index,
                                cfg, window=window, theta=theta)
    if "ln1_post" in p:
        h = rmsnorm(p["ln1_post"], h)
    x = x + h
    h = mlp_apply(p["mlp"], rmsnorm(p["ln2"], x), cfg)
    if "ln2_post" in p:
        h = rmsnorm(p["ln2_post"], h)
    return x + h, cache


def _moe_unit_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, cfg),
        "attn": attn.attention_init(k1, cfg),
        "ln2": rmsnorm_init(cfg.d_model, cfg),
        "moe": moe_mod.moe_init(k2, cfg),
    }
    if cfg.moe_dense_residual:  # arctic: dense FFN in parallel with MoE
        p["dense_mlp"] = mlp_init(k3, cfg)
    return p


def _moe_unit_apply(p, x, cfg, *, window, theta):
    h = attn.attn_full(p["attn"], rmsnorm(p["ln1"], x), cfg,
                       window=window, theta=theta)
    x = x + h
    normed = rmsnorm(p["ln2"], x)
    mo, aux, drop = moe_mod.moe_apply(p["moe"], normed, cfg)
    if "dense_mlp" in p:
        mo = mo + mlp_apply(p["dense_mlp"], normed, cfg)
    return x + mo, aux, drop


def _moe_unit_decode(p, x, cache, index, cfg, *, window, theta):
    h, cache = attn.attn_decode(p["attn"], rmsnorm(p["ln1"], x), cache, index,
                                cfg, window=window, theta=theta)
    x = x + h
    normed = rmsnorm(p["ln2"], x)
    mo, aux, drop = moe_mod.moe_apply(p["moe"], normed, cfg)
    if "dense_mlp" in p:
        mo = mo + mlp_apply(p["dense_mlp"], normed, cfg)
    return x + mo, cache


def _ssd_unit_init(key, cfg):
    return {"ln1": rmsnorm_init(cfg.d_model, cfg),
            "ssd": ssd_mod.ssd_init(key, cfg)}


def _rec_unit_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, cfg),
        "rec": rg.rglru_init(k1, cfg),
        "ln2": rmsnorm_init(cfg.d_model, cfg),
        "mlp": mlp_init(k2, cfg),
    }


def _rec_unit_apply(p, x, cfg, *, state=None, conv=None, want_state=False):
    if want_state:
        h, s, c = rg.rglru_block_apply(p["rec"], rmsnorm(p["ln1"], x), cfg,
                                       state=state, conv_state=conv,
                                       return_state=True)
    else:
        h = rg.rglru_block_apply(p["rec"], rmsnorm(p["ln1"], x), cfg)
        s = c = None
    x = x + h
    x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x), cfg)
    return (x, s, c) if want_state else x


def _dense_sb_init(key, cfg):
    """Super-block: cfg.local_per_global local layers + 1 global layer."""
    per = cfg.local_per_global
    ks = jax.random.split(key, per + 1)
    return {
        "loc": jax.vmap(lambda k: _dense_unit_init(k, cfg))(ks[:per]),
        "glob": _dense_unit_init(ks[per], cfg),
    }


def _griffin_unit_init(key, cfg):
    k0, k1, k2 = jax.random.split(key, 3)
    return {
        "rec0": _rec_unit_init(k0, cfg),
        "rec1": _rec_unit_init(k1, cfg),
        "attn": _dense_unit_init(k2, cfg),
    }


def _vlm_unit_init(key, cfg):
    per = cfg.cross_attn_every
    ks = jax.random.split(key, per + 2)
    self_params = jax.vmap(lambda k: _dense_unit_init(k, cfg))(ks[:per])
    kc1, kc2 = ks[per], ks[per + 1]
    cross = {
        "ln": rmsnorm_init(cfg.d_model, cfg),
        "attn": attn.attention_init(kc1, cfg, d_kv_in=cfg.d_model),
        "gate": jnp.zeros((), cfg.param_dtype),  # tanh-gated cross-attn
        "ln2": rmsnorm_init(cfg.d_model, cfg),
        "mlp": mlp_init(kc2, cfg),
        "gate_mlp": jnp.zeros((), cfg.param_dtype),
    }
    return {"self": self_params, "cross": cross}


def _enc_unit_init(key, cfg):
    return _dense_unit_init(key, cfg)


def _dec_unit_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, cfg),
        "self_attn": attn.attention_init(k1, cfg),
        "ln2": rmsnorm_init(cfg.d_model, cfg),
        "cross_attn": attn.attention_init(k2, cfg, d_kv_in=cfg.d_model),
        "ln3": rmsnorm_init(cfg.d_model, cfg),
        "mlp": mlp_init(k3, cfg),
    }


_UNIT_INIT = {
    "dense": _dense_unit_init,
    "dense_sb": _dense_sb_init,
    "moe": _moe_unit_init,
    "ssd": _ssd_unit_init,
    "rec": _rec_unit_init,
    "griffin": _griffin_unit_init,
    "vlm": _vlm_unit_init,
    "enc": _enc_unit_init,
    "dec": _dec_unit_init,
}


def sinusoidal_positions(t: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((t, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (d + 1) // 2]))
    return pe.astype(dtype)


class LM:
    """Unified model wrapper for one ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.groups = build_groups(cfg)

    # -- init ----------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, len(self.groups) + 6)
        params: dict = {"embed": embedding_init(keys[0], cfg)}
        params["final_norm"] = rmsnorm_init(cfg.d_model, cfg)
        if not cfg.tied_embeddings:
            params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab, cfg)
        if cfg.family == "vlm":
            params["vision_proj"] = dense_init(
                keys[2], cfg.vision_dim, cfg.d_model, cfg, fan_in=cfg.vision_dim
            )
        if cfg.family == "audio":
            enc_keys = jax.random.split(keys[3], cfg.encoder_layers)
            params["encoder"] = jax.vmap(
                lambda k: _enc_unit_init(k, cfg)
            )(enc_keys)
            params["enc_final_norm"] = rmsnorm_init(cfg.d_model, cfg)
        for gi, g in enumerate(self.groups):
            gkeys = jax.random.split(keys[4 + gi], g.n)
            params[f"group{gi}"] = jax.vmap(
                lambda k: _UNIT_INIT[g.kind](k, cfg)
            )(gkeys)
        return params

    # -- shared forward over the groups (training / prefill) ------------------
    def _backbone(self, params, x, *, memory_kv_builder=None, collect_cache=False,
                  cache_len: int | None = None):
        """Run all groups over full sequences.

        memory_kv_builder(unit_params_slice) -> memory KV for cross-attn
        (already precomputed per group outside the scan).
        Returns (features, aux_losses, caches_per_group or None).
        """
        cfg = self.cfg
        aux_total = jnp.float32(0.0)
        caches = []
        for gi, g in enumerate(self.groups):
            gp = params[f"group{gi}"]
            x, aux, cache = self._run_group_full(
                g, gp, x, params, collect_cache=collect_cache, cache_len=cache_len
            )
            aux_total = aux_total + aux
            caches.append(cache)
        x = rmsnorm(params["final_norm"], x)
        return x, aux_total, caches

    def _run_group_full(self, g: GroupSpec, gp, x, params, *,
                        collect_cache: bool, cache_len):
        cfg = self.cfg
        b, t, _ = x.shape
        s_cache = cache_len or t

        def pad_cache_kv(k_seq, v_seq):
            """(B,T,Hkv,Dh) -> padded (B,S,Hkv,Dh) + per-row pos (B,S)."""
            pad = s_cache - t
            kk = jnp.pad(k_seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vv = jnp.pad(v_seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
            pos = jnp.concatenate([
                jnp.arange(t, dtype=jnp.int32),
                jnp.full((pad,), -1, jnp.int32),
            ])
            pos = jnp.tile(pos[None], (b, 1))
            return kk, vv, pos

        def attn_cache_from(p_attn, xin, theta):
            """Recompute K/V for caching at prefill (cheap vs attention)."""
            q, k, v = attn._qkv(p_attn, xin, xin, cfg)
            if theta is not None:
                k = attn.rope(k, jnp.arange(t), theta)
            return k, v

        if g.kind in ("dense", "moe"):
            def body(carry, xs):
                xc, aux = carry
                xc = _maybe_seq_shard(xc)
                if g.kind == "dense":
                    p, window, theta = xs
                    xin = rmsnorm(p["ln1"], xc)
                    xo = _dense_unit_apply(p, xc, cfg, window=window, theta=theta)
                    daux = jnp.float32(0.0)
                else:
                    p, window, theta = xs
                    xin = rmsnorm(p["ln1"], xc)
                    xo, daux, _ = _moe_unit_apply(p, xc, cfg, window=window, theta=theta)
                ys = None
                if collect_cache:
                    k, v = attn_cache_from(p["attn"], xin, theta)
                    ys = pad_cache_kv(k, v)
                return (xo, aux + daux), ys

            if cfg.remat:
                body = jax.checkpoint(body)
            (x, aux), ys = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                        (gp, g.windows, g.thetas))
            cache = None
            if collect_cache:
                cache = {"k": ys[0], "v": ys[1], "pos": ys[2]}
            return x, aux, cache

        if g.kind == "dense_sb":
            per = cfg.local_per_global
            w_len = min(cfg.window, s_cache)
            n_keep = min(t, w_len)

            def ring_cache(k_seq, v_seq):
                """Keep the last n_keep positions in a w_len ring buffer."""
                pos_keep = jnp.arange(t - n_keep, t, dtype=jnp.int32)
                slots = jnp.mod(pos_keep, w_len)
                kk = jnp.zeros((b, w_len) + k_seq.shape[2:], k_seq.dtype)
                vv = jnp.zeros_like(kk)
                kk = kk.at[:, slots].set(k_seq[:, t - n_keep:])
                vv = vv.at[:, slots].set(v_seq[:, t - n_keep:])
                pos = jnp.full((w_len,), -1, jnp.int32).at[slots].set(pos_keep)
                pos = jnp.tile(pos[None], (b, 1))
                return kk, vv, pos

            def body(carry, p):
                xc = carry
                loc_ys = []
                for i in range(per):
                    pi = jax.tree.map(lambda a: a[i], p["loc"])
                    xin = rmsnorm(pi["ln1"], xc)
                    xc = _dense_unit_apply(pi, xc, cfg, window=cfg.window,
                                           theta=cfg.rope_theta)
                    if collect_cache:
                        k, v = attn_cache_from(pi["attn"], xin, cfg.rope_theta)
                        loc_ys.append(ring_cache(k, v))
                pg = p["glob"]
                xin = rmsnorm(pg["ln1"], xc)
                theta_g = cfg.rope_theta_global or cfg.rope_theta
                xc = _dense_unit_apply(pg, xc, cfg, window=None, theta=theta_g)
                ys = None
                if collect_cache:
                    k, v = attn_cache_from(pg["attn"], xin, theta_g)
                    gk, gv, gpos = pad_cache_kv(k, v)
                    lk = jnp.stack([y[0] for y in loc_ys])
                    lv = jnp.stack([y[1] for y in loc_ys])
                    lpos = jnp.stack([y[2] for y in loc_ys])
                    ys = (lk, lv, lpos, gk, gv, gpos)
                return xc, ys

            if cfg.remat:
                body = jax.checkpoint(body)
            x, ys = jax.lax.scan(body, x, gp)
            cache = None
            if collect_cache:
                cache = {"loc": {"k": ys[0], "v": ys[1], "pos": ys[2]},
                         "glob": {"k": ys[3], "v": ys[4], "pos": ys[5]}}
            return x, jnp.float32(0.0), cache

        if g.kind == "ssd":
            def body(carry, p):
                xc = carry
                h, s_fin, conv = ssd_mod.ssd_block_apply(
                    p["ssd"], rmsnorm(p["ln1"], xc), cfg, return_state=True
                )
                xo = xc + h
                ys = (s_fin, conv) if collect_cache else None
                return xo, ys

            if cfg.remat:
                body = jax.checkpoint(body)
            x, ys = jax.lax.scan(body, x, gp)
            cache = {"ssm": ys[0], "conv": ys[1]} if collect_cache else None
            return x, jnp.float32(0.0), cache

        if g.kind == "rec":
            def body(carry, p):
                xc = carry
                xo, s, c = _rec_unit_apply(p, xc, cfg, want_state=True)
                ys = (s, c) if collect_cache else None
                return xo, ys

            if cfg.remat:
                body = jax.checkpoint(body)
            x, ys = jax.lax.scan(body, x, gp)
            cache = {"h": ys[0], "conv": ys[1]} if collect_cache else None
            return x, jnp.float32(0.0), cache

        if g.kind == "griffin":
            def body(carry, p):
                xc = carry
                x1, s0, c0 = _rec_unit_apply(p["rec0"], xc, cfg, want_state=True)
                x2, s1, c1 = _rec_unit_apply(p["rec1"], x1, cfg, want_state=True)
                xin = rmsnorm(p["attn"]["ln1"], x2)
                x3 = _dense_unit_apply(p["attn"], x2, cfg,
                                       window=cfg.window, theta=cfg.rope_theta)
                ys = None
                if collect_cache:
                    k, v = attn_cache_from(p["attn"]["attn"], xin, cfg.rope_theta)
                    kk, vv, pos = pad_cache_kv(k, v)
                    ys = (s0, c0, s1, c1, kk, vv, pos)
                return x3, ys

            if cfg.remat:
                body = jax.checkpoint(body)
            x, ys = jax.lax.scan(body, x, gp)
            cache = None
            if collect_cache:
                cache = {
                    "h0": ys[0], "conv0": ys[1], "h1": ys[2], "conv1": ys[3],
                    "k": ys[4], "v": ys[5], "pos": ys[6],
                }
            return x, jnp.float32(0.0), cache

        if g.kind == "vlm":
            memory = params["_vision_memory"]  # injected by loss/prefill

            def body(carry, p):
                xc = carry

                def self_body(c2, ps):
                    xin = rmsnorm(ps["ln1"], c2)
                    out = _dense_unit_apply(ps, c2, cfg, window=None,
                                            theta=cfg.rope_theta)
                    ys = None
                    if collect_cache:
                        k, v = attn_cache_from(ps["attn"], xin, cfg.rope_theta)
                        ys = pad_cache_kv(k, v)
                    return out, ys

                xc, self_ys = jax.lax.scan(self_body, xc, p["self"])
                cr = p["cross"]
                mkv = attn.cross_kv(cr["attn"], memory, cfg)
                h = attn.attn_cross(cr["attn"], rmsnorm(cr["ln"], xc), mkv, cfg)
                xc = xc + jnp.tanh(cr["gate"].astype(jnp.float32)).astype(x.dtype) * h
                h = mlp_apply(cr["mlp"], rmsnorm(cr["ln2"], xc), cfg)
                xc = xc + jnp.tanh(cr["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * h
                ys = (self_ys, mkv["k"], mkv["v"]) if collect_cache else None
                return xc, ys

            if cfg.remat:
                body = jax.checkpoint(body)
            x, ys = jax.lax.scan(body, x, gp)
            cache = None
            if collect_cache:
                self_ys, ck, cv = ys
                cache = {
                    "k": self_ys[0], "v": self_ys[1], "pos": self_ys[2],
                    "cross_k": ck, "cross_v": cv,
                }
            return x, jnp.float32(0.0), cache

        if g.kind == "dec":
            memory = params["_encoder_memory"]

            def body(carry, p):
                xc = carry
                xin = rmsnorm(p["ln1"], xc)
                h = attn.attn_full(p["self_attn"], xin, cfg, causal=True,
                                   theta=cfg.rope_theta)
                xc = xc + h
                mkv = attn.cross_kv(p["cross_attn"], memory, cfg)
                h = attn.attn_cross(p["cross_attn"], rmsnorm(p["ln2"], xc), mkv, cfg)
                xc = xc + h
                xc = xc + mlp_apply(p["mlp"], rmsnorm(p["ln3"], xc), cfg)
                ys = None
                if collect_cache:
                    k, v = attn_cache_from(p["self_attn"], xin, cfg.rope_theta)
                    kk, vv, pos = pad_cache_kv(k, v)
                    ys = (kk, vv, pos, mkv["k"], mkv["v"])
                return xc, ys

            if cfg.remat:
                body = jax.checkpoint(body)
            x, ys = jax.lax.scan(body, x, gp)
            cache = None
            if collect_cache:
                cache = {"k": ys[0], "v": ys[1], "pos": ys[2],
                         "cross_k": ys[3], "cross_v": ys[4]}
            return x, jnp.float32(0.0), cache

        raise ValueError(g.kind)

    # -- encoder (whisper) -----------------------------------------------------
    def _encode_audio(self, params, frames):
        cfg = self.cfg
        x = frames + sinusoidal_positions(frames.shape[1], cfg.d_model, frames.dtype)

        def body(carry, p):
            return _dense_unit_apply(p, carry, cfg, window=None, theta=None,
                                     causal=False), None

        x, _ = jax.lax.scan(body, x, params["encoder"])
        return rmsnorm(params["enc_final_norm"], x)

    # -- embeddings of the non-token modality ----------------------------------
    def _inject_memory(self, params, batch):
        cfg = self.cfg
        params = dict(params)
        if cfg.family == "vlm":
            vis = batch["vision"].astype(cfg.dtype) @ params["vision_proj"]
            params["_vision_memory"] = vis
        if cfg.family == "audio":
            params["_encoder_memory"] = self._encode_audio(
                params, batch["audio_frames"].astype(cfg.dtype)
            )
        return params

    # -- training loss ----------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        params = self._inject_memory(params, batch)
        x = embed(params["embed"], batch["inputs"], cfg)
        feats, aux, _ = self._backbone(params, x)
        labels = batch["labels"]
        mask = batch.get("mask")
        table = (params["lm_head"].T if "lm_head" in params
                 else params["embed"]["table"])
        if cfg.logits_chunk:
            ce = chunked_cross_entropy(feats, table, labels, cfg.logits_chunk, mask)
        else:
            ce = lm_cross_entropy(feats, table, labels, mask)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    # -- prefill ------------------------------------------------------------------
    def prefill(self, params, batch, *, cache_len: int | None = None):
        cfg = self.cfg
        params = self._inject_memory(params, batch)
        tokens = batch["inputs"]
        b, t = tokens.shape
        x = embed(params["embed"], tokens, cfg)
        feats, _, caches = self._backbone(
            params, x, collect_cache=True, cache_len=cache_len or t
        )
        table = (params["lm_head"].T if "lm_head" in params
                 else params["embed"]["table"])
        last = feats[:, -1:, :]
        logits = last @ table.T.astype(feats.dtype)
        state = {"groups": caches, "index": jnp.asarray(t, jnp.int32)}
        return logits[:, 0], state

    # -- zeroed decode state (dry-run decode shapes) ------------------------------
    def init_decode_state(self, batch: int, cache_len: int, *, index=None):
        cfg = self.cfg
        states = []
        for g in self.groups:
            n = g.n
            if g.kind == "dense_sb":
                per = cfg.local_per_global
                w_len = min(cfg.window, cache_len)
                states.append({
                    "loc": {
                        "k": jnp.zeros((n, per, batch, w_len, cfg.n_kv_heads,
                                        cfg.head_dim), cfg.dtype),
                        "v": jnp.zeros((n, per, batch, w_len, cfg.n_kv_heads,
                                        cfg.head_dim), cfg.dtype),
                        "pos": jnp.full((n, per, batch, w_len), -1, jnp.int32),
                    },
                    "glob": attn.make_cache(cfg, n, batch, cache_len),
                })
            elif g.kind in ("dense", "moe"):
                length = cache_len
                if (cfg.cache_mode == "rightsized" and cfg.window
                        and g.windows is not None):
                    import numpy as _np
                    if bool((_np.asarray(g.windows) > 0).all()):
                        length = min(cfg.window, cache_len)
                states.append(attn.make_cache(cfg, n, batch, length))
            elif g.kind == "ssd":
                states.append({
                    "ssm": jnp.zeros((n, batch, cfg.ssm_heads, cfg.ssm_state,
                                      cfg.ssm_head_dim), jnp.float32),
                    "conv": jnp.zeros((n, batch, cfg.ssm_conv - 1,
                                       cfg.ssm_d_inner + 2 * cfg.ssm_state),
                                      cfg.dtype),
                })
            elif g.kind == "rec":
                states.append({
                    "h": jnp.zeros((n, batch, cfg.d_model), jnp.float32),
                    "conv": jnp.zeros((n, batch, cfg.rglru_conv - 1, cfg.d_model),
                                      cfg.dtype),
                })
            elif g.kind == "griffin":
                attn_len = (min(cache_len, cfg.window)
                            if (cfg.cache_mode == "rightsized" and cfg.window)
                            else cache_len)
                c = attn.make_cache(cfg, n, batch, attn_len)
                states.append({
                    "h0": jnp.zeros((n, batch, cfg.d_model), jnp.float32),
                    "conv0": jnp.zeros((n, batch, cfg.rglru_conv - 1, cfg.d_model),
                                       cfg.dtype),
                    "h1": jnp.zeros((n, batch, cfg.d_model), jnp.float32),
                    "conv1": jnp.zeros((n, batch, cfg.rglru_conv - 1, cfg.d_model),
                                       cfg.dtype),
                    "k": c["k"], "v": c["v"], "pos": c["pos"],
                })
            elif g.kind == "vlm":
                per = cfg.cross_attn_every
                c = attn.make_cache(cfg, n, batch, cache_len)
                states.append({
                    "k": jnp.zeros((n, per) + c["k"].shape[1:], cfg.dtype),
                    "v": jnp.zeros((n, per) + c["v"].shape[1:], cfg.dtype),
                    "pos": jnp.full((n, per, batch, cache_len), -1, jnp.int32),
                    "cross_k": jnp.zeros((n, batch, cfg.vision_tokens,
                                          cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
                    "cross_v": jnp.zeros((n, batch, cfg.vision_tokens,
                                          cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
                })
            elif g.kind == "dec":
                c = attn.make_cache(cfg, n, batch, cache_len)
                states.append({
                    "k": c["k"], "v": c["v"], "pos": c["pos"],
                    "cross_k": jnp.zeros((n, batch, cfg.audio_frames,
                                          cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
                    "cross_v": jnp.zeros((n, batch, cfg.audio_frames,
                                          cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
                })
            else:
                raise ValueError(g.kind)
        if index is None:
            index = jnp.asarray(cache_len, jnp.int32)
        return {"groups": states, "index": jnp.asarray(index, jnp.int32)}

    # -- decode step ----------------------------------------------------------------
    def decode_step(self, params, state, tokens):
        """tokens (B, 1) int32 -> (logits (B, vocab), new state)."""
        cfg = self.cfg
        index = state["index"]
        x = embed(params["embed"], tokens, cfg)
        new_groups = []
        for gi, g in enumerate(self.groups):
            gp = params[f"group{gi}"]
            gc = state["groups"][gi]
            x, gc_new = self._decode_group(g, gp, gc, x, index)
            new_groups.append(gc_new)
        x = rmsnorm(params["final_norm"], x)
        table = (params["lm_head"].T if "lm_head" in params
                 else params["embed"]["table"])
        logits = (x @ table.T.astype(x.dtype))[:, 0]
        return logits, {"groups": new_groups, "index": index + 1}

    def _decode_group(self, g: GroupSpec, gp, gc, x, index):
        cfg = self.cfg

        if g.kind in ("dense", "moe"):
            def body(carry, xs):
                xc = carry
                p, window, theta, ck, cv, cpos = xs
                cache = {"k": ck, "v": cv, "pos": cpos}
                if g.kind == "dense":
                    xo, cache = _dense_unit_decode(p, xc, cache, index, cfg,
                                                   window=window, theta=theta)
                else:
                    xo, cache = _moe_unit_decode(p, xc, cache, index, cfg,
                                                 window=window, theta=theta)
                return xo, (cache["k"], cache["v"], cache["pos"])

            x, ys = jax.lax.scan(body, x, (gp, g.windows, g.thetas,
                                           gc["k"], gc["v"], gc["pos"]))
            return x, {"k": ys[0], "v": ys[1], "pos": ys[2]}

        if g.kind == "dense_sb":
            per = cfg.local_per_global
            theta_g = cfg.rope_theta_global or cfg.rope_theta

            def body(carry, xs):
                xc = carry
                p, lk, lv, lpos, gk, gv, gpos = xs
                lk_o, lv_o, lpos_o = [], [], []
                for i in range(per):
                    pi = jax.tree.map(lambda a: a[i], p["loc"])
                    cache = {"k": lk[i], "v": lv[i], "pos": lpos[i]}
                    xc, cache = _dense_unit_decode(
                        pi, xc, cache, index, cfg,
                        window=cfg.window, theta=cfg.rope_theta,
                    )
                    lk_o.append(cache["k"])
                    lv_o.append(cache["v"])
                    lpos_o.append(cache["pos"])
                gcache = {"k": gk, "v": gv, "pos": gpos}
                xc, gcache = _dense_unit_decode(
                    p["glob"], xc, gcache, index, cfg,
                    window=None, theta=theta_g,
                )
                ys = (jnp.stack(lk_o), jnp.stack(lv_o), jnp.stack(lpos_o),
                      gcache["k"], gcache["v"], gcache["pos"])
                return xc, ys

            x, ys = jax.lax.scan(body, x, (
                gp, gc["loc"]["k"], gc["loc"]["v"], gc["loc"]["pos"],
                gc["glob"]["k"], gc["glob"]["v"], gc["glob"]["pos"]))
            return x, {"loc": {"k": ys[0], "v": ys[1], "pos": ys[2]},
                       "glob": {"k": ys[3], "v": ys[4], "pos": ys[5]}}

        if g.kind == "ssd":
            def body(carry, xs):
                xc = carry
                p, s, c = xs
                h, s2, c2 = ssd_mod.ssd_decode_step(
                    p["ssd"], rmsnorm(p["ln1"], xc), cfg, ssm_state=s, conv_state=c
                )
                return xc + h, (s2, c2)

            x, ys = jax.lax.scan(body, x, (gp, gc["ssm"], gc["conv"]))
            return x, {"ssm": ys[0], "conv": ys[1]}

        if g.kind == "rec":
            def body(carry, xs):
                xc = carry
                p, h0, c0 = xs
                h, h2, c2 = rg.rglru_decode_step(
                    p["rec"], rmsnorm(p["ln1"], xc), cfg, state=h0, conv_state=c0
                )
                xc = xc + h
                xc = xc + mlp_apply(p["mlp"], rmsnorm(p["ln2"], xc), cfg)
                return xc, (h2, c2)

            x, ys = jax.lax.scan(body, x, (gp, gc["h"], gc["conv"]))
            return x, {"h": ys[0], "conv": ys[1]}

        if g.kind == "griffin":
            def one_rec(p, xc, h0, c0):
                h, h2, c2 = rg.rglru_decode_step(
                    p["rec"], rmsnorm(p["ln1"], xc), cfg, state=h0, conv_state=c0
                )
                xc = xc + h
                xc = xc + mlp_apply(p["mlp"], rmsnorm(p["ln2"], xc), cfg)
                return xc, h2, c2

            def body(carry, xs):
                xc = carry
                p, h0, c0, h1, c1, ck, cv, cpos = xs
                xc, h0n, c0n = one_rec(p["rec0"], xc, h0, c0)
                xc, h1n, c1n = one_rec(p["rec1"], xc, h1, c1)
                cache = {"k": ck, "v": cv, "pos": cpos}
                xc, cache = _dense_unit_decode(
                    p["attn"], xc, cache, index, cfg,
                    window=cfg.window, theta=cfg.rope_theta,
                )
                return xc, (h0n, c0n, h1n, c1n, cache["k"], cache["v"], cache["pos"])

            x, ys = jax.lax.scan(body, x, (gp, gc["h0"], gc["conv0"],
                                           gc["h1"], gc["conv1"],
                                           gc["k"], gc["v"], gc["pos"]))
            return x, {"h0": ys[0], "conv0": ys[1], "h1": ys[2], "conv1": ys[3],
                       "k": ys[4], "v": ys[5], "pos": ys[6]}

        if g.kind == "vlm":
            def body(carry, xs):
                xc = carry
                p, ck, cv, cpos, crk, crv = xs

                def self_body(c2, xs2):
                    ps, k1, v1, p1 = xs2
                    cache = {"k": k1, "v": v1, "pos": p1}
                    out, cache = _dense_unit_decode(ps, c2, cache, index, cfg,
                                                    window=None,
                                                    theta=cfg.rope_theta)
                    return out, (cache["k"], cache["v"], cache["pos"])

                xc, sys_ = jax.lax.scan(self_body, xc, (p["self"], ck, cv, cpos))
                cr = p["cross"]
                mkv = {"k": crk, "v": crv}
                h = attn.attn_cross(cr["attn"], rmsnorm(cr["ln"], xc), mkv, cfg)
                xc = xc + jnp.tanh(cr["gate"].astype(jnp.float32)).astype(xc.dtype) * h
                h = mlp_apply(cr["mlp"], rmsnorm(cr["ln2"], xc), cfg)
                xc = xc + jnp.tanh(cr["gate_mlp"].astype(jnp.float32)).astype(xc.dtype) * h
                return xc, (sys_[0], sys_[1], sys_[2], crk, crv)

            x, ys = jax.lax.scan(body, x, (gp, gc["k"], gc["v"], gc["pos"],
                                           gc["cross_k"], gc["cross_v"]))
            return x, {"k": ys[0], "v": ys[1], "pos": ys[2],
                       "cross_k": ys[3], "cross_v": ys[4]}

        if g.kind == "dec":
            def body(carry, xs):
                xc = carry
                p, ck, cv, cpos, crk, crv = xs
                cache = {"k": ck, "v": cv, "pos": cpos}
                h, cache = attn.attn_decode(
                    p["self_attn"], rmsnorm(p["ln1"], xc), cache, index, cfg,
                    theta=cfg.rope_theta,
                )
                xc = xc + h
                mkv = {"k": crk, "v": crv}
                h = attn.attn_cross(p["cross_attn"], rmsnorm(p["ln2"], xc), mkv, cfg)
                xc = xc + h
                xc = xc + mlp_apply(p["mlp"], rmsnorm(p["ln3"], xc), cfg)
                return xc, (cache["k"], cache["v"], cache["pos"], crk, crv)

            x, ys = jax.lax.scan(body, x, (gp, gc["k"], gc["v"], gc["pos"],
                                           gc["cross_k"], gc["cross_v"]))
            return x, {"k": ys[0], "v": ys[1], "pos": ys[2],
                       "cross_k": ys[3], "cross_v": ys[4]}

        raise ValueError(g.kind)
