"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(c * softplus(Lambda) * (-r_t))   in (0,1),  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses an associative scan over T (depth log T); decode is O(1).
The full residual block is: linear -> causal conv(4) -> RG-LRU on one
branch, gelu gate on the other, merged by an output projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, residual_out_init
from repro.sharding.ctx import BATCH, MODEL, shard

_C = 8.0


def rglru_init(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], d, d, cfg),  # input branch
        "w_gate": dense_init(ks[1], d, d, cfg),  # gelu gate branch
        "conv_w": (jax.random.normal(ks[2], (cfg.rglru_conv, d), jnp.float32)
                   * (3.0 / cfg.rglru_conv) ** 0.5).astype(cfg.param_dtype),
        "conv_b": jnp.zeros((d,), cfg.param_dtype),
        "w_a": dense_init(ks[3], d, d, cfg),
        "b_a": jnp.zeros((d,), cfg.param_dtype),
        "w_i": dense_init(ks[4], d, d, cfg),
        "b_i": jnp.zeros((d,), cfg.param_dtype),
        # Lambda init so a^c is roughly in [0.9, 0.999] at r=1
        "lam": jnp.linspace(0.3, 1.5, d).astype(jnp.float32),
        "w_out": residual_out_init(ks[5], d, d, cfg),
    }


def _gates(params, x):
    """a_log (decay log) and gated input for each step. x (B,T,D)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32)
                       + params["b_i"].astype(jnp.float32))
    lam = jax.nn.softplus(params["lam"])  # (D,)
    a_log = -_C * lam[None, None, :] * r  # log a_t  (B,T,D)
    a = jnp.exp(a_log)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, gated


def rglru_scan(params, x, h0=None):
    """Linear recurrence via associative scan. x (B,T,D) -> (y, h_T)."""
    a, b = _gates(params, x)  # (B,T,D) each, float32
    if h0 is not None:
        # fold the initial state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    ac, bc = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = bc  # h_t for every t
    return y.astype(x.dtype), y[:, -1]


def rglru_step(params, x, h):
    """One-token recurrence. x (B,1,D), h (B,D) float32."""
    a, b = _gates(params, x)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new[:, None, :].astype(x.dtype), h_new


def _causal_conv(x, conv_w, conv_b, conv_state=None):
    k = conv_w.shape[0]
    bsz, t, c = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((bsz, k - 1, c), x.dtype)
    ext = jnp.concatenate([conv_state, x], axis=1)
    out = jnp.zeros((bsz, t, c), x.dtype)
    for i in range(k):
        out = out + ext[:, i : i + t, :] * conv_w[i][None, None, :]
    out = out + conv_b[None, None, :]
    new_state = jax.lax.dynamic_slice_in_dim(ext, ext.shape[1] - (k - 1), k - 1, axis=1)
    return out, new_state


def rglru_block_apply(params, u, cfg: ModelConfig, *, state=None,
                      conv_state=None, return_state: bool = False):
    """Full Griffin recurrent block. u (B,T,D)."""
    gate = jax.nn.gelu(u @ params["w_gate"])
    x = u @ params["w_x"]
    x = shard(x, BATCH, None, MODEL)
    x, new_conv = _causal_conv(
        x, params["conv_w"].astype(u.dtype), params["conv_b"].astype(u.dtype),
        conv_state,
    )
    y, h_last = rglru_scan(params, x, h0=state)
    out = (gate * y) @ params["w_out"]
    if return_state:
        return out, h_last, new_conv
    return out


def rglru_decode_step(params, u, cfg: ModelConfig, *, state, conv_state):
    gate = jax.nn.gelu(u @ params["w_gate"])
    x = u @ params["w_x"]
    x, new_conv = _causal_conv(
        x, params["conv_w"].astype(u.dtype), params["conv_b"].astype(u.dtype),
        conv_state,
    )
    y, h_new = rglru_step(params, x, state)
    out = (gate * y) @ params["w_out"]
    return out, h_new, new_conv
