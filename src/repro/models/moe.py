"""Mixture-of-Experts FFN with expert-parallel sharding.

Baseline distribution strategy (see DESIGN.md §5 and EXPERIMENTS.md §Perf
for the measured alternatives):

  * tokens enter replicated across the ``model`` axis (the residual
    stream is sharded over batch only);
  * expert weights are sharded E -> ``model`` (and D -> ``data`` FSDP on
    the big configs, all-gathered per layer inside the block);
  * every model-shard routes all of its data-shard's tokens, keeps the
    assignments that belong to its local experts, computes them with a
    capacity-bounded gather -> grouped-matmul -> scatter-add, and the
    partial outputs are ``psum``'d over ``model``.

Routing is top-k softmax with a Switch-style load-balance auxiliary loss
and capacity-factor token dropping (drop fraction returned for tests /
telemetry). A dense fallback path (no mesh) runs the identical math on
one shard so smoke tests exercise the same code.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.common import ModelConfig, dense_init, residual_out_init
from repro.sharding.ctx import get_mesh


def moe_init(key, cfg: ModelConfig):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(ks[0], d, e, cfg),
        "w_gate": dense_init(ks[1], d, f, cfg, shape=(e, d, f)),
        "w_up": dense_init(ks[2], d, f, cfg, shape=(e, d, f)),
        "w_down": residual_out_init(ks[3], f, d, cfg, shape=(e, f, d), fan_in=f),
    }
    if cfg.n_shared_experts:
        from repro.models.common import mlp_init

        params["shared"] = mlp_init(ks[4], cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return params


def _route(router_w, x_flat, cfg: ModelConfig):
    """Top-k routing. Returns (ids (T,k), weights (T,k), aux_loss, probs)."""
    logits = (x_flat.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)  # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch load-balance loss: E * sum_e f_e * p_e
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=1), axis=0
    )  # fraction of tokens routed to e
    aux = e * jnp.sum(me * ce)
    return ids, weights, aux


def _expert_compute(w_gate, w_up, w_down, xs, cfg: ModelConfig):
    """Grouped gated-MLP over per-expert capacity buffers.

    xs: (E_loc, C, D) -> (E_loc, C, D)
    """
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", xs, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", xs, w_up
    )
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_shard_body(x_flat, router_w, w_gate, w_up, w_down, *,
                    cfg: ModelConfig, n_exp_shards: int, shard_idx,
                    capacity: int, model_axis: str | None):
    """Per-(data, model)-shard MoE. x_flat (T, D) replicated over model."""
    t, d = x_flat.shape
    e = cfg.n_experts
    e_loc = e // n_exp_shards
    ids, weights, aux = _route(router_w, x_flat, cfg)  # (T,k)

    flat_ids = ids.reshape(-1)  # (T*k,)
    flat_w = weights.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(t), cfg.top_k)  # (T*k,)

    local_e = flat_ids - shard_idx * e_loc  # local expert index or OOB
    is_local = (local_e >= 0) & (local_e < e_loc)
    # position within each local expert: cumsum over one-hot assignment
    onehot = jax.nn.one_hot(jnp.where(is_local, local_e, e_loc), e_loc + 1,
                            dtype=jnp.int32)[:, :e_loc]  # (T*k, E_loc)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot  # exclusive prefix count
    pos = jnp.sum(pos_in_e * onehot, axis=1)  # (T*k,)
    keep = is_local & (pos < capacity)

    # scatter token rows into (E_loc, C, D)
    slot = jnp.where(keep, local_e * capacity + pos, e_loc * capacity)
    buf = jnp.zeros((e_loc * capacity + 1, d), x_flat.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], x_flat[tok_of], 0.0))
    xs = buf[:-1].reshape(e_loc, capacity, d)

    ys = _expert_compute(w_gate, w_up, w_down, xs, cfg)  # (E_loc, C, D)

    # combine: weighted scatter-add back to tokens
    ys_flat = ys.reshape(e_loc * capacity, d)
    contrib = jnp.where(
        keep[:, None], ys_flat[jnp.minimum(slot, e_loc * capacity - 1)], 0.0
    ) * flat_w[:, None].astype(x_flat.dtype)
    out = jnp.zeros_like(x_flat).at[tok_of].add(contrib)

    drop_frac = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (
        jnp.sum(is_local.astype(jnp.float32)) + 1e-9
    )
    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)
        aux = aux  # identical on every model shard (same tokens)
        drop_frac = jax.lax.pmean(drop_frac, model_axis)
    return out, aux, drop_frac


def moe_apply(params, x, cfg: ModelConfig, *, capacity: int | None = None):
    """MoE FFN. x (B, T, D) -> (out (B,T,D), aux_loss, drop_frac)."""
    b, t, d = x.shape
    mesh = get_mesh()
    n_exp_shards = (
        mesh.shape["model"] if (mesh is not None and "model" in mesh.axis_names) else 1
    )
    # per-shard token count (tokens replicated over model; sharded over data/pod)
    n_data_shards = 1
    if mesh is not None:
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                n_data_shards *= mesh.shape[ax]
    t_shard = (b // n_data_shards) * t
    if capacity is None:
        capacity = max(
            4,
            int(cfg.capacity_factor * cfg.top_k * t_shard
                / max(cfg.n_experts, 1)),
        )
    capacity = min(capacity, t_shard * cfg.top_k)

    x_flat_shape_batch = x.reshape(b * t, d)

    if mesh is None or n_exp_shards == 1 and n_data_shards == 1:
        out, aux, drop = _moe_shard_body(
            x_flat_shape_batch, params["router"], params["w_gate"],
            params["w_up"], params["w_down"], cfg=cfg, n_exp_shards=1,
            shard_idx=0, capacity=capacity, model_axis=None,
        )
        out = out.reshape(b, t, d)
    elif b * t <= 4096 and "data" in mesh.axis_names:
        # DECODE path (EXPERIMENTS.md SS-Perf extra iteration): tokens are
        # tiny (B x 1) while the fsdp-sharded expert weights are huge, so
        # gather the ACTIVATIONS over the fsdp axis (KBs) instead of the
        # weights (GBs per layer): every data shard computes all tokens
        # against its F-slice of the local experts (the gated MLP is
        # elementwise in F), partial outputs psum over ("data", "model"),
        # and each shard keeps its own token rows again. Expert weights
        # must arrive F-sharded over "data" (serving layout,
        # input_specs._serving_param_shardings).
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        n_data = mesh.shape["data"]
        cap_dec = max(4, int(cfg.capacity_factor * cfg.top_k * b * t
                             / max(cfg.n_experts, 1)))
        cap_dec = min(cap_dec, b * t * cfg.top_k)

        def body(xb, router_w, wg, wu, wd):
            bl, tl, dl = xb.shape
            midx = jax.lax.axis_index("model")
            didx = jax.lax.axis_index("data")
            x_all = jax.lax.all_gather(xb.reshape(bl * tl, dl), "data",
                                       tiled=True)  # (n_data*bl*tl, D)
            o, aux, drop = _moe_shard_body(
                x_all, router_w, wg, wu, wd, cfg=cfg,
                n_exp_shards=n_exp_shards, shard_idx=midx,
                capacity=cap_dec, model_axis=None,
            )
            # o is partial over BOTH the F-slice ("data") and the local
            # experts ("model")
            o = jax.lax.psum(o, ("data", "model"))
            o_mine = jax.lax.dynamic_slice_in_dim(
                o, didx * bl * tl, bl * tl, axis=0)
            aux = jax.lax.pmean(aux, ("data", "model"))
            drop = jax.lax.pmean(drop, ("data", "model"))
            if "pod" in mesh.axis_names:
                aux = jax.lax.pmean(aux, "pod")
                drop = jax.lax.pmean(drop, "pod")
            return o_mine.reshape(bl, tl, dl), aux, drop

        out, aux, drop = shard_map(
            body,
            mesh=mesh,
            in_specs=(
                P(batch_axes, None, None),  # x
                P(None, None),  # router
                P("model", None, "data"),  # w_gate (E, D, F): F fsdp-sharded
                P("model", None, "data"),  # w_up
                P("model", "data", None),  # w_down (E, F, D)
            ),
            out_specs=(P(batch_axes, None, None), P(), P()),
            check_vma=False,
        )(x, params["router"], params["w_gate"], params["w_up"],
          params["w_down"])
    else:
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

        def body(xb, router_w, wg, wu, wd):
            bl, tl, dl = xb.shape
            idx = jax.lax.axis_index("model")
            o, aux, drop = _moe_shard_body(
                xb.reshape(bl * tl, dl), router_w, wg, wu, wd, cfg=cfg,
                n_exp_shards=n_exp_shards, shard_idx=idx,
                capacity=capacity, model_axis="model",
            )
            # aux/drop: average over data shards for logging
            for ax in batch_axes:
                aux = jax.lax.pmean(aux, ax)
                drop = jax.lax.pmean(drop, ax)
            return o.reshape(bl, tl, dl), aux, drop

        out, aux, drop = shard_map(
            body,
            mesh=mesh,
            in_specs=(
                P(batch_axes, None, None),  # x
                P(None, None),  # router
                P("model", None, None),  # w_gate (E, D, F)
                P("model", None, None),  # w_up
                P("model", None, None),  # w_down
            ),
            out_specs=(P(batch_axes, None, None), P(), P()),
            check_vma=False,
        )(x, params["router"], params["w_gate"], params["w_up"],
          params["w_down"])

    if cfg.n_shared_experts:
        from repro.models.common import mlp_apply

        out = out + mlp_apply(params["shared"], x, cfg)
    return out, aux, drop
