"""Event-driven asynchronous federated round driver.

The synchronous driver (``CommSession``) makes the server wait for the
slowest delivering client every round, so a single straggler inflates
``sim_time_s`` for everyone — exactly the device-heterogeneity problem
FedNL (Safaryan et al., 2021) and FLECS (Agafonov et al., 2022) motivate
second-order FL with. This module replaces the lock-step clock with an
event simulation built on the per-client delivery times the channel
model already produces (``ChannelModel.client_times``):

  * every client runs its own download -> compute -> upload cycle on a
    persistent clock, computing on the model *version it last received*;
  * uploads arrive at the server when the client's simulated link
    finishes; dropped uploads trigger a deterministic re-dispatch (the
    client re-fetches the current model and retries);
  * the server commits an aggregation step as soon as a quorum of
    uploads has buffered — a FedBuff-style buffer of ``K = buffer_size``
    arrivals, or ``ceil(async_quantile * m)`` when no buffer size is
    set — instead of waiting for the full cohort;
  * contributions based on version ``v`` at server version ``t`` carry
    staleness ``tau = t - v`` and are weighted by a pluggable staleness
    rule (``constant``, ``inverse`` = 1/(1+tau), ``poly:a`` =
    (1+tau)^-a) on top of the existing participation weights.

Aggregation semantics
---------------------
Buffered arrivals are grouped by base model version. Each group re-runs
the optimizer's (jitted) round from the snapshot of its base version
with the group's delivery mask — so partial cohorts perturb the
optimization through the exact machinery the sync driver uses
(``CommRound.weights`` / ``where_delivered``) — and contributes the
model *delta* it would have produced. The server combines deltas:

    w_{t+1} = w_t + eta_s * sum_g c_g (w'_g - w_{v_g}),
    c_g  =  staleness(tau_g) * P_g / sum_h P_h

(P_g = group participation mass, eta_s = ``CommConfig.server_lr`` — the
FedBuff-style global server learning rate, 1.0 by default and then
bit-identical to not having the knob). Participation is renormalized over the
commit — the same renormalization the sync driver applies to partial
cohorts — while the staleness factor *damps* the applied step, so a
fully-stale commit under ``inverse`` moves the model by 1/(1+tau) of its
delta instead of being silently renormalized back to a full step.

Auxiliary optimizer state (momentum, guards, duals) advances along the
*freshest* group's round; stale groups contribute model deltas only.
When a commit consists of a single group based on the current version
(always the case in lock-step-equivalent configs), the combined state
IS that round's output — no delta arithmetic — which is what makes the
``async_quantile=1.0`` / full-participation path bit-identical to the
synchronous driver: same key schedule, same jaxpr, same floats.

Error-feedback memory (``repro.comm.feedback``) is threaded through
every group round and gated by that group's delivery mask, so memory
rows advance exactly when a client's payload is actually consumed by a
server commit — delivery-keyed updates that now span server steps.

Determinism: channel randomness for the cohort dispatched after commit
``t`` comes from the same ``(seed, t)`` key schedule the sync driver
uses; retries after a dropped upload fold the retry count in. A
trajectory is exactly reproducible from ``CommConfig.seed``.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from collections import defaultdict
from typing import Any, Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import feedback
from repro.comm.metrics import RoundTrace
from repro.obs import NULL_TELEMETRY
from repro.obs import log as obs_log

# a dropped upload is retried with fresh channel coins; after this many
# consecutive drops the delivery is forced so the simulation cannot spin
# forever under dropout_prob -> 1.0
MAX_RETRIES = 8

# begin_variant sentinel: "no variant announced yet" (None is a valid
# round signature — the default single-trace trajectory)
_NO_VARIANT = object()


def make_staleness(spec: "str | Callable[[float], float]"):
    """Resolve a staleness-weighting spec to a ``tau -> weight`` callable.

    ``"constant"`` — every contribution weighs 1 regardless of lag;
    ``"inverse"`` — 1/(1+tau), the FedAsync polynomial special case;
    ``"poly:a"`` — (1+tau)^-a (``a`` defaults to 0.5).
    A callable is passed through unchanged.
    """
    if callable(spec):
        return spec
    if spec == "constant":
        return lambda tau: 1.0
    if spec == "inverse":
        return lambda tau: 1.0 / (1.0 + tau)
    kind, _, arg = str(spec).partition(":")
    if kind in ("poly", "polynomial"):
        a = float(arg or 0.5)
        return lambda tau: (1.0 + tau) ** (-a)
    raise ValueError(
        f"unknown staleness spec {spec!r}; want 'constant', 'inverse', "
        f"'poly:<a>', or a callable")


@dataclasses.dataclass
class _Flight:
    """One client upload cycle in the air."""

    client: int
    version: int  # model version the client computed on
    straggler: bool
    dropped: bool  # upload lost in transit: re-dispatch on landing
    retry: int = 0


class AsyncSession:
    """Host-side event-driven driver state for one trajectory.

    Owns the per-client clocks, the arrival event heap, the server
    buffer, per-version state snapshots, the EF memory pytree, and the
    per-commit ``RoundTrace`` records. The jitted round function is
    injected per step so the session stays optimizer-agnostic — it has
    the same ``(state, memory, key, mask, codec_key)`` signature the
    synchronous driver jits.
    """

    def __init__(
        self,
        config,
        m: int,
        client_weights: np.ndarray,
        keys: jax.Array,  # (rounds, 2) per-version optimizer round keys
        state0: Any = None,
        mask_dtype=jnp.float64,  # noqa: RA005 — caller passes the problem dtype; the default only names the widest mask the goldens were recorded with
        obs=NULL_TELEMETRY,
    ):
        self.config = config
        self.m = m
        self.obs = obs
        self.client_weights = np.asarray(client_weights, dtype=np.float64)
        self.keys = keys
        self._state0 = state0
        self.plan: Dict[str, int] = {}
        self.traces: List[RoundTrace] = []
        self.ef_memory: Dict[str, jax.Array] = {}
        self._mask_dtype = mask_dtype
        self._root = jax.random.PRNGKey(config.seed)  # noqa: RA001 — the transport root stream; repro.comm cannot import repro.core.base (cycle)
        self._staleness = make_staleness(config.staleness)
        if config.buffer_size is not None:
            self.quorum = min(m, int(config.buffer_size))
        else:
            self.quorum = max(1, min(m, int(math.ceil(
                config.async_quantile * m))))
        # lock-step-equivalent: full scheduler, no dropout, full quorum.
        # Every commit then aggregates exactly the fresh full cohort, so
        # the round runs with mask=None — the identical jaxpr (and key
        # schedule) the sync driver uses, hence bit-identical. Churn and
        # correlated outages (dynamics.forces_mask) break the static
        # full-cohort guarantee, so they force the masked path.
        dyn = config.dynamics
        self.lockstep = (config.scheduler.is_full
                         and config.channel.dropout_prob == 0.0
                         and self.quorum == m
                         and (dyn is None or not dyn.forces_mask))
        # dynamics bookkeeping (inert when dynamics is None)
        self._elig_prev = None
        self._attacker_arr = None
        self.robust_stats: Dict[str, float] = {}

        self.version = 0
        self.server_clock = 0.0
        self._snapshots: Dict[int, Any] = {}
        self._heap: list = []  # (time, seq, _Flight)
        self._seq = 0
        self._buffer: List[tuple] = []  # (client, version, straggler, t_arr)
        self._idle: set = set()
        self._quorum_capped = False
        self._pending_down = np.zeros(m, dtype=np.float64)
        self._pending_dropped = np.zeros(m, dtype=bool)
        self._variant_sig: Any = _NO_VARIANT

    # -- key schedule (matches CommSession.begin_round exactly) -------------
    def _round_keys(self, version: int):
        k = jax.random.fold_in(self._root, version)
        return jax.random.split(k, 3)  # k_sched, k_chan, k_codec

    @property
    def bytes_up_per_client(self) -> int:
        from repro.comm.config import plan_bytes

        return plan_bytes(self.plan, down=False)

    @property
    def bytes_down_per_client(self) -> int:
        """Exact encoded broadcast bytes per dispatched client (the
        ``down:*`` plan entries the prepare-time probe filled)."""
        from repro.comm.config import plan_bytes

        return plan_bytes(self.plan, down=True)

    # -- Session protocol: trace-time discovery -----------------------------
    def prepare(self, trace_round) -> None:
        """One abstract probe of the round (nothing executes): fills the
        payload byte plan — the async clock needs encoded bytes in BOTH
        directions *before* the first round runs, unlike the sync driver
        which reads them after — discovers the EF memory shapes along
        the way, then snapshots the initial state and launches every
        client's first cycle."""
        from repro.comm.config import probe_round

        spec = probe_round(self.config, self.m, self._mask_dtype, self.plan,
                           trace_round, full_cohort=self.lockstep)
        self.ef_memory = feedback.init_memory(spec)
        if self._state0 is not None:
            self.start(self._state0)

    def begin_variant(self, sig, trace_round) -> None:
        """The async clock prices in-flight uploads at dispatch time, so
        the payload plan must stay constant for the whole trajectory:
        the first announced variant is accepted (its plan was already
        probed by ``prepare``), any later change — an adaptive-k policy
        resizing payloads mid-run — is rejected."""
        if self._variant_sig is _NO_VARIANT:
            self._variant_sig = sig
        elif sig != self._variant_sig:
            raise NotImplementedError(
                "round-varying payload plans (adaptive-k sketch policies) "
                "are not supported by the asynchronous driver: uploads "
                "already in flight were priced at dispatch time; use the "
                "synchronous driver")

    def comm_round(self, memory, mask, codec_key):
        """In-jit transport view for the driver's round builder."""
        from repro.comm.config import CommRound

        return CommRound(self.config, self.plan, mask, codec_key,
                         memory=memory)

    def finalize(self):
        from repro.comm.metrics import transport_from_traces

        if self.obs.enabled:
            ef_bytes = sum(
                int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
                for a in jax.tree_util.tree_leaves(self.ef_memory))
            self.obs.metrics.gauge("ef_memory_bytes").set(float(ef_bytes))
        return transport_from_traces(
            self.traces,
            staleness=np.array([tr.mean_staleness for tr in self.traces]),
            ef_residuals=self.ef_residual_norms(),
        )

    # -- event machinery ----------------------------------------------------
    def start(self, state) -> None:
        """Snapshot the initial model and put every client in the air."""
        self._snapshots[0] = state
        self._dispatch_cohort(range(self.m), now=0.0)

    def _dispatch_cohort(self, clients, now: float) -> None:
        """Send the current model to ``clients`` that the scheduler picks
        this version; the rest idle until the next commit."""
        from repro.comm.config import apply_churn

        clients = list(clients)
        if not clients:
            return
        k_sched, k_chan, _ = self._round_keys(self.version)
        eligible = apply_churn(self, self.version)
        chan = self.config.channel_at(self.version)
        scheduled = self.config.scheduler.participants(
            k_sched, self.version, self.m, chan, eligible=eligible)
        cohort = [j for j in clients if scheduled[j]]
        if not cohort and not self._heap and not self._buffer:
            # nothing else in flight: avoid a stall (alive clients only;
            # a fully-departed landed set falls back to everyone — the
            # empty-eligibility warning in apply_churn covers that case)
            cohort = [j for j in clients if self._alive(j)] or clients
        self._idle.update(j for j in clients if j not in cohort)
        draw = chan.draw(k_chan, self.m)
        times = self._flight_times(draw)
        for j in cohort:
            self._idle.discard(j)
            self._launch(j, now, times[j], bool(draw.straggler[j]),
                         bool(draw.dropout[j]), retry=0)

    def _alive(self, j: int) -> bool:
        """Is client ``j`` churn-eligible as of the last dispatch?"""
        return self._elig_prev is None or bool(self._elig_prev[j])

    def _retire_ef(self, departed: np.ndarray) -> None:
        """Zero newly-departed clients' EF memory rows (dense layout)."""
        if self.ef_memory:
            z = jnp.asarray(departed)
            self.ef_memory = {k: v.at[z].set(0)
                              for k, v in self.ef_memory.items()}

    def _retire_flight(self, flight: _Flight, now: float) -> None:
        """A departed client's upload landed: it is retired, never
        buffered — the client leaves the simulation until it returns."""
        self._pending_dropped[flight.client] = True
        self._idle.add(flight.client)

    def _consume_stats(self, stats: Dict[str, Any]) -> None:
        """Drain a group round's traced robust-aggregation counters."""
        for stat_name, val in stats.items():
            v = float(val)
            self.robust_stats[stat_name] = \
                self.robust_stats.get(stat_name, 0.0) + v
            self.obs.metrics.counter(stat_name).inc(v)

    def _pack_threat(self, mask, ids=None):
        """Bundle the attacker indicator next to the delivery mask when
        a threat is active (matches ``CommSession._pack_threat``)."""
        dyn = self.config.dynamics
        if dyn is None or dyn.threat is None:
            return mask
        if ids is None:
            if self._attacker_arr is None:
                self._attacker_arr = jnp.asarray(
                    dyn.threat.attacker_mask(np.arange(self.m)),
                    dtype=self._mask_dtype)
            return (mask, self._attacker_arr)
        return (mask, jnp.asarray(dyn.threat.attacker_mask(ids),
                                  dtype=self._mask_dtype))

    def _count_corrupted(self, delivered: np.ndarray,
                         ids: "np.ndarray | None") -> None:
        """Host-side tally of corrupted uploads the server consumed."""
        dyn = self.config.dynamics
        if dyn is None or dyn.threat is None:
            return
        att = dyn.threat.attacker_mask(
            np.arange(self.m) if ids is None else ids)
        n_bad = float((att & delivered).sum())
        self.robust_stats["uploads_corrupted"] = \
            self.robust_stats.get("uploads_corrupted", 0.0) + n_bad
        self.obs.metrics.counter("uploads_corrupted").inc(n_bad)

    def _redispatch(self, j: int, now: float, retry: int) -> None:
        """A dropped upload landed: the client re-fetches the current
        model and retries with fresh (deterministic) channel coins."""
        if not self._alive(j):
            self._idle.add(j)  # departed mid-flight: no retry
            return
        _, k_chan, _ = self._round_keys(self.version)
        chan = self.config.channel_at(self.version)
        draw = chan.draw(jax.random.fold_in(k_chan, retry), self.m)
        dropped = bool(draw.dropout[j]) and retry < MAX_RETRIES
        times = self._flight_times(draw)
        self._launch(j, now, times[j], bool(draw.straggler[j]), dropped,
                     retry=retry)

    def _flight_times(self, draw) -> np.ndarray:
        """Per-client cycle times for a full (m,) dispatch draw — both
        directions priced at their exact encoded sizes."""
        bytes_up = np.full(self.m, float(self.bytes_up_per_client))
        bytes_down = np.full(self.m, float(self.bytes_down_per_client))
        return self.config.channel_at(self.version).client_times(
            draw, bytes_up, bytes_down)

    def _launch(self, j: int, now: float, dt: float, straggler: bool,
                dropped: bool, retry: int) -> None:
        self._pending_down[j] += self.bytes_down_per_client
        self._seq += 1
        flight = _Flight(client=j, version=self.version,
                         straggler=straggler, dropped=dropped, retry=retry)
        heapq.heappush(self._heap, (now + dt, self._seq, flight))
        self.obs.flight.record(
            "dispatch", now, client=j, version=self.version,
            eta=now + dt, straggler=straggler, retry=retry)
        if retry:
            self.obs.metrics.counter("upload_retries").inc()

    def _pump(self) -> float:
        """Advance the event clock until the commit quorum buffers;
        returns the commit time (the quorum-th arrival's landing).

        The quorum is capped at the number of uploads that can still
        arrive (buffered + in flight): a partial-participation scheduler
        may idle more clients than ``buffer_size`` expects, and waiting
        for uploads nobody will send would deadlock the clock. The cap
        is announced once per trajectory; the per-commit cohort is
        always visible in ``RoundTrace.delivered``."""
        t = self.server_clock
        while True:
            need = max(1, min(self.quorum, len(self._buffer) + len(self._heap)))
            if need < self.quorum and not self._quorum_capped:
                self._quorum_capped = True
                obs_log.warn_with_context(
                    f"async commit quorum capped at {need} (< configured "
                    f"{self.quorum}): the scheduler keeps fewer clients in "
                    f"flight than the quorum asks for",
                    server_version=self.version, quorum=self.quorum,
                    capped_to=need)
            if len(self._buffer) >= need:
                return t
            if not self._heap:
                # everything idled out (pathological scheduler draw):
                # force-dispatch so the trajectory can make progress
                self._dispatch_cohort(sorted(self._idle), now=t)
                continue
            t, _, flight = heapq.heappop(self._heap)
            if not self._alive(flight.client):
                # the client churned out while its upload was in the
                # air: deterministic retirement (never buffered)
                self._retire_flight(flight, t)
                self.obs.flight.record(
                    "retire", t, client=flight.client,
                    version=flight.version)
                self.obs.metrics.counter("uploads_retired").inc()
                continue
            if flight.dropped:
                self._pending_dropped[flight.client] = True
                self.obs.flight.record(
                    "drop", t, client=flight.client, version=flight.version,
                    retry=flight.retry)
                self._redispatch(flight.client, t, flight.retry + 1)
            else:
                self._buffer.append(
                    (flight.client, flight.version, flight.straggler, t))
                self.obs.flight.record(
                    "arrival", t, client=flight.client,
                    version=flight.version,
                    server_version=self.version,
                    buffered=len(self._buffer))

    # -- one server commit --------------------------------------------------
    def step(self, round_fn) -> Any:
        """Run the event simulation up to the next server commit and
        return the committed state. ``round_fn(state, memory, key, mask,
        codec_key) -> (state, memory)`` is the jitted optimizer round."""
        commit_time = self._pump()
        committed, self._buffer = self._buffer, []
        if self.obs.enabled:
            self._observe_commit(committed, commit_time)

        # group arrivals by the model version they computed on
        groups: Dict[int, List[tuple]] = {}
        for client, version, straggler, _ in committed:
            groups.setdefault(version, []).append((client, straggler))
        order = sorted(groups, reverse=True)  # freshest first

        outputs: Dict[int, Any] = {}
        for v in order:
            members = [c for c, _ in groups[v]]
            if self.lockstep:
                mask = None
            else:
                mvec = np.zeros(self.m)
                mvec[members] = 1.0
                mask = jnp.asarray(mvec, self._mask_dtype)
            _, _, k_codec = self._round_keys(v)
            outputs[v], self.ef_memory, stats = round_fn(
                self._snapshots[v], self.ef_memory, self.keys[v],
                self._pack_threat(mask), k_codec)
            self._consume_stats(stats)

        fresh = order[0]
        eta = float(self.config.server_lr)
        if len(order) == 1 and fresh == self.version and eta == 1.0:
            # single fresh group at unit server lr: the round output IS
            # the next state (no delta arithmetic — preserves sync
            # bit-exactness; the staleness weight is 1 at tau=0 by
            # convention)
            state_new = outputs[fresh]
        else:
            # c_g = eta_s * staleness(tau_g) * P_g / sum_h P_h:
            # participation mass is renormalized over the commit (as the
            # sync driver renormalizes partial cohorts) but staleness
            # DAMPS the step rather than being renormalized away — an
            # all-stale commit under "inverse" moves the model by
            # 1/(1+tau) of its delta, and a weight of exactly 0
            # contributes exactly nothing. The FedBuff-style global
            # server learning rate eta_s scales every committed delta on
            # top (eta_s = 1 is bit-identical to not having the knob).
            p_mass = {
                v: float(self.client_weights[[c for c, _ in groups[v]]].sum())
                for v in order
            }
            p_total = sum(p_mass.values())
            w_cur = self._snapshots[self.version]["w"]
            w_new = w_cur
            for v in order:
                c = (eta * self._staleness(float(self.version - v))
                     * p_mass[v] / p_total)
                delta = outputs[v]["w"] - self._snapshots[v]["w"]
                w_new = w_new + c * delta
            # auxiliary state rides the freshest cohort's round when that
            # cohort is current; otherwise the current state is kept and
            # only the model moves (stale aux must not overwrite fresher)
            base = (outputs[fresh] if fresh == self.version
                    else self._snapshots[self.version])
            state_new = dict(base)
            state_new["w"] = w_new

        self._record_trace(committed, commit_time)
        self.version += 1
        self.server_clock = commit_time
        self._snapshots[self.version] = state_new
        self._gc_snapshots()
        self._dispatch_cohort(
            sorted({c for c, _, _, _ in committed} | self._idle),
            now=commit_time)
        return state_new

    def _observe_commit(self, committed, commit_time: float) -> None:
        """Populate commit-time telemetry (host-side, before aggregation;
        only called when telemetry is enabled)."""
        mt = self.obs.metrics
        mt.histogram("commit_buffer_depth").observe(len(committed))
        mt.histogram("inflight_depth").observe(len(self._heap))
        mt.histogram("staleness").observe_many(
            float(self.version - v) for _, v, _, _ in committed)
        mt.histogram("buffered_upload_age_s").observe_many(
            commit_time - t_arr for _, _, _, t_arr in committed)
        self.obs.flight.record(
            "commit", commit_time, version=self.version + 1,
            server_version=self.version,
            clients=sorted(c for c, _, _, _ in committed),
            inflight=len(self._heap))

    def _record_trace(self, committed, commit_time: float) -> None:
        mask = np.zeros(self.m, dtype=bool)
        straggler = np.zeros(self.m, dtype=bool)
        stale = np.full(self.m, np.nan)
        for client, version, was_straggler, _ in committed:
            mask[client] = True
            straggler[client] = was_straggler
            stale[client] = float(self.version - version)
        bytes_up = float(self.bytes_up_per_client) * mask.astype(np.float64)
        # scheduled \ delivered = clients whose upload was lost in this
        # commit window and who did not land a retry before the commit —
        # keeps summarize()'s dropped_client_rounds honest in async mode
        self.traces.append(RoundTrace(
            round=self.version,
            scheduled=mask | self._pending_dropped,
            delivered=mask,
            straggler=straggler,
            bytes_up=bytes_up,
            bytes_down=self._pending_down,
            sim_time_s=commit_time - self.server_clock,
            staleness=stale,
            version=self.version + 1,
        ))
        self._count_corrupted(mask, None)
        if self.obs.enabled:
            tr = self.traces[-1]
            mt = self.obs.metrics
            mt.counter("bytes_up").inc(float(tr.bytes_up.sum()))
            mt.counter("bytes_down").inc(float(tr.bytes_down.sum()))
            mt.counter("delivered_client_rounds").inc(float(mask.sum()))
            mt.counter("dropped_client_rounds").inc(
                float(self._pending_dropped.sum()))
            mt.counter("straggler_client_rounds").inc(float(straggler.sum()))
            self.obs.annotate(
                bytes_up=float(tr.bytes_up.sum()),
                bytes_down=float(tr.bytes_down.sum()),
                delivered=int(mask.sum()),
                version=self.version + 1,
                mean_staleness=tr.mean_staleness,
                sim_time_s=float(tr.sim_time_s))
        self._pending_down = np.zeros(self.m, dtype=np.float64)
        self._pending_dropped = np.zeros(self.m, dtype=bool)

    def _gc_snapshots(self) -> None:
        """Drop model snapshots no in-flight or buffered cycle references."""
        alive = {self.version}
        alive.update(f.version for _, _, f in self._heap if not f.dropped)
        alive.update(v for _, v, _, _ in self._buffer)
        for v in [v for v in self._snapshots if v not in alive]:
            del self._snapshots[v]

    def ef_residual_norms(self) -> Dict[str, float]:
        """Per-payload Frobenius norm of the current EF residuals."""
        return feedback.residual_norms(self.ef_memory)


class PopulationAsyncSession(AsyncSession):
    """Event-driven driver over a lazy ``ClientPopulation``.

    Same event machinery as ``AsyncSession`` (heap, buffer, versioned
    snapshots, staleness-weighted delta commits) with the client axis
    replaced by sampled cohorts:

      * each new model version samples its cohort ids from the
        population (``Scheduler.sample_ids`` on the SAME
        ``fold_in(seed, version)`` stream the sync population driver
        uses, so both drivers schedule identical cohorts) and dispatches
        the ids not already in flight; landed clients return to the
        anonymous pool instead of being tracked per id;
      * dropped uploads are *replaced*, not retried: the client goes
        back to the pool and the next version's draw samples fresh ids —
        the realistic cross-device semantic (FedBuff-style systems
        replace failed clients). If every in-flight upload drops, the
        current version's cohort redraws its channel coins with a
        folded attempt counter (forced delivery after ``MAX_RETRIES``
        attempts) so the clock always advances;
      * each commit group materializes its members' shards on demand,
        padded to the scheduler's fixed cohort size (pad rows duplicate
        the first member under a zero delivery mask), so every group of
        every round reuses one jaxpr;
      * EF memory lives in the bounded LRU hot-set store
        (``feedback.BoundedMemory``): rows are gathered for the group,
        gated by the group's delivery mask inside the round, and
        scattered back for the real members only.

    Lock-step configs (full scheduler, no dropout, full quorum) sample
    the whole population as one cohort with ``mask=None`` — the
    identical jaxpr and key schedule as ``PopulationCommSession``, hence
    bit-identical across the drivers.
    """

    def __init__(self, config, population, *, keys, state0=None,
                 mask_dtype=jnp.float64, obs=NULL_TELEMETRY,  # noqa: RA005 — caller passes the problem dtype; default matches the recorded goldens
                 client_mesh=None):
        super().__init__(config, m=population.m,
                         client_weights=population.client_weights,
                         keys=keys, state0=None, mask_dtype=mask_dtype,
                         obs=obs)
        self.population = population
        self.client_mesh = client_mesh
        self.cohort_size = config.scheduler.cohort_size(population.m)
        self.ef_store: "feedback.BoundedMemory | None" = None
        # quorum counts against what can actually be in flight — one
        # cohort — not against the population
        if config.buffer_size is not None:
            self.quorum = min(self.cohort_size, int(config.buffer_size))
        else:
            self.quorum = max(1, min(self.cohort_size, int(math.ceil(
                config.async_quantile * self.cohort_size))))
        dyn = config.dynamics
        self.lockstep = (config.scheduler.is_full
                         and config.channel.dropout_prob == 0.0
                         and self.quorum == self.m
                         and (dyn is None or not dyn.forces_mask))
        # population-mode event bookkeeping: O(in-flight), never O(m)
        self._in_flight: set = set()
        # client id -> dispatched broadcast bytes (defaultdict: the
        # inherited _launch accumulates with `+=`)
        self._pending_down = defaultdict(float)
        self._pending_dropped = {}  # client id -> True (lost this window)
        self._attempt = 0  # channel redraws of the current version's cohort
        self._state0 = state0

    # -- trace-time discovery ------------------------------------------------
    def prepare(self, trace_round) -> None:
        from repro.comm.config import probe_round

        spec = probe_round(self.config, self.cohort_size, self._mask_dtype,
                           self.plan, trace_round, full_cohort=self.lockstep)
        if spec:
            capacity = self.config.ef_capacity
            if capacity is None:
                capacity = min(self.m, 8 * self.cohort_size)
            self.ef_store = feedback.BoundedMemory(
                spec, max(capacity, self.cohort_size))
        self.ef_memory = {}
        if self._state0 is not None:
            self.start(self._state0)

    # -- event machinery -----------------------------------------------------
    def start(self, state) -> None:
        self._snapshots[0] = state
        self._dispatch_cohort((), now=0.0)

    def _dispatch_cohort(self, clients, now: float) -> None:
        """Sample the current version's cohort and replenish the flight
        pool up to the cohort size. ``clients`` (the dense driver's
        landed set) is ignored: population clients are anonymous between
        cycles.

        The concurrency cap mirrors the dense driver, where only landed
        clients are re-dispatched so at most one cohort is ever in the
        air: without it every commit would add a full cohort while
        consuming only a quorum, the backlog would grow without bound,
        and staleness would diverge linearly in the round count."""
        from repro.comm.config import apply_churn

        budget = self.cohort_size - len(self._in_flight)
        if budget <= 0:
            return
        k_sched, k_chan, _ = self._round_keys(self.version)
        eligible = apply_churn(self, self.version)
        chan = self.config.channel_at(self.version)
        ids = self.config.scheduler.sample_ids(
            k_sched, self.version, self.m, chan, eligible=eligible)
        cohort = np.asarray(
            [j for j in ids if int(j) not in self._in_flight][:budget],
            dtype=np.int64)
        if cohort.size == 0:
            return
        attempt = self._attempt
        self._attempt += 1
        if attempt:
            # the whole previous dispatch of this version dropped:
            # redraw the coins deterministically, forcing delivery once
            # the attempt budget is spent so the clock cannot stall
            k_chan = jax.random.fold_in(k_chan, attempt)
        draw = chan.draw_for(k_chan, cohort)
        if attempt >= MAX_RETRIES:
            draw = dataclasses.replace(
                draw, dropout=np.zeros_like(draw.dropout))
        per_up = float(self.bytes_up_per_client)
        per_down = float(self.bytes_down_per_client)
        times = chan.client_times_for(
            cohort, self.m, draw,
            np.full(cohort.size, per_up), np.full(cohort.size, per_down))
        for i, j in enumerate(cohort):
            j = int(j)
            self._in_flight.add(j)
            self._launch(j, now, float(times[i]), bool(draw.straggler[i]),
                         bool(draw.dropout[i]), retry=attempt)

    def _redispatch(self, j: int, now: float, retry: int) -> None:
        """A dropped upload landed: the client returns to the pool (the
        scheduler replaces it from the population at the next version).
        ``_pump`` already marked it in ``_pending_dropped``."""
        self._in_flight.discard(j)
        if not self._heap and not self._buffer:
            # every in-flight upload dropped: redraw this version's
            # cohort (attempt counter folded into the coins)
            self._dispatch_cohort((), now=now)

    def _retire_flight(self, flight: _Flight, now: float) -> None:
        """A departed client's upload landed: back to the anonymous pool
        (the next dispatch samples a replacement from the survivors)."""
        self._pending_dropped[flight.client] = True
        self._in_flight.discard(flight.client)
        if not self._heap and not self._buffer:
            self._dispatch_cohort((), now=now)

    def _retire_ef(self, departed: np.ndarray) -> None:
        """Departed clients leave the EF hot set deterministically."""
        if self.ef_store is not None:
            self.ef_store.retire(departed)

    # -- one server commit ---------------------------------------------------
    def step(self, round_fn) -> Any:
        """Population-mode commit: groups materialize their members'
        shards on demand. ``round_fn(cohort, state, memory, key, mask,
        codec_key) -> (state, memory)`` is the jitted cohort round."""
        commit_time = self._pump()
        committed, self._buffer = self._buffer, []
        if self.obs.enabled:
            self._observe_commit(committed, commit_time)

        groups: Dict[int, List[tuple]] = {}
        for client, version, straggler, _ in committed:
            groups.setdefault(version, []).append((client, straggler))
        order = sorted(groups, reverse=True)  # freshest first

        outputs: Dict[int, Any] = {}
        for v in order:
            members = [c for c, _ in groups[v]]
            n_real = len(members)
            # fixed-width cohort: pad with the first member's id under a
            # zero delivery mask, so every group reuses one jaxpr
            padded = members + [members[0]] * (self.cohort_size - n_real)
            cohort = self.population.materialize(np.asarray(padded))
            if self.client_mesh is not None:
                from repro.sharding.rules import shard_cohort

                cohort = shard_cohort(self.client_mesh, cohort)
            if self.lockstep:
                mask = None
            else:
                mvec = np.zeros(self.cohort_size)
                mvec[:n_real] = 1.0
                mask = jnp.asarray(mvec, self._mask_dtype)
            memory = self.ef_store.gather(padded) if self.ef_store else {}
            _, _, k_codec = self._round_keys(v)
            outputs[v], mem_out, stats = round_fn(
                cohort, self._snapshots[v], memory, self.keys[v],
                self._pack_threat(mask, np.asarray(padded)), k_codec)
            self._consume_stats(stats)
            if self.ef_store is not None:
                # real members only: pad rows are frozen duplicates
                self.ef_store.scatter(members, mem_out)

        fresh = order[0]
        eta = float(self.config.server_lr)
        if len(order) == 1 and fresh == self.version and eta == 1.0:
            state_new = outputs[fresh]
        else:
            # same commit combination as the dense driver: staleness
            # damps, participation mass renormalizes over the commit
            p_mass = {
                v: float(self.client_weights[[c for c, _ in groups[v]]].sum())
                for v in order
            }
            p_total = sum(p_mass.values())
            w_cur = self._snapshots[self.version]["w"]
            w_new = w_cur
            for v in order:
                c = (eta * self._staleness(float(self.version - v))
                     * p_mass[v] / p_total)
                delta = outputs[v]["w"] - self._snapshots[v]["w"]
                w_new = w_new + c * delta
            base = (outputs[fresh] if fresh == self.version
                    else self._snapshots[self.version])
            state_new = dict(base)
            state_new["w"] = w_new

        self._record_trace(committed, commit_time)
        for client, _, _, _ in committed:
            self._in_flight.discard(client)
        self.version += 1
        self._attempt = 0
        self.server_clock = commit_time
        self._snapshots[self.version] = state_new
        self._gc_snapshots()
        self._dispatch_cohort((), now=commit_time)
        return state_new

    def _record_trace(self, committed, commit_time: float) -> None:
        down = dict(self._pending_down)
        dropped = set(self._pending_dropped)
        ids = sorted({c for c, _, _, _ in committed} | dropped | set(down))
        index = {cid: i for i, cid in enumerate(ids)}
        n = len(ids)
        delivered = np.zeros(n, dtype=bool)
        straggler = np.zeros(n, dtype=bool)
        stale = np.full(n, np.nan)
        for client, version, was_straggler, _ in committed:
            i = index[client]
            delivered[i] = True
            straggler[i] = was_straggler
            stale[i] = float(self.version - version)
        scheduled = delivered.copy()
        for cid in dropped:
            scheduled[index[cid]] = True
        bytes_up = (float(self.bytes_up_per_client)
                    * delivered.astype(np.float64))
        bytes_down = np.asarray([down.get(cid, 0.0) for cid in ids])
        tr = RoundTrace(
            round=self.version,
            scheduled=scheduled,
            delivered=delivered,
            straggler=straggler,
            bytes_up=bytes_up,
            bytes_down=bytes_down,
            sim_time_s=commit_time - self.server_clock,
            staleness=stale,
            version=self.version + 1,
            ids=np.asarray(ids, dtype=np.int64),
            population=self.m,
        )
        self.traces.append(tr)
        self._count_corrupted(delivered, tr.ids)
        if self.obs.enabled:
            mt = self.obs.metrics
            mt.counter("bytes_up").inc(float(tr.bytes_up.sum()))
            mt.counter("bytes_down").inc(float(tr.bytes_down.sum()))
            mt.counter("delivered_client_rounds").inc(float(delivered.sum()))
            mt.counter("dropped_client_rounds").inc(float(len(dropped)))
            mt.counter("straggler_client_rounds").inc(float(straggler.sum()))
            self.obs.annotate(
                bytes_up=float(tr.bytes_up.sum()),
                bytes_down=float(tr.bytes_down.sum()),
                delivered=int(delivered.sum()),
                version=self.version + 1,
                mean_staleness=tr.mean_staleness,
                sim_time_s=float(tr.sim_time_s))
        self._pending_down = defaultdict(float)
        self._pending_dropped = {}

    def finalize(self):
        from repro.comm.metrics import transport_from_traces

        if self.obs.enabled:
            ef_bytes = self.ef_store.nbytes if self.ef_store else 0
            self.obs.metrics.gauge("ef_memory_bytes").set(float(ef_bytes))
            if self.ef_store is not None:
                self.obs.metrics.gauge("ef_hot_set_evictions").set(
                    float(self.ef_store.evictions))
        return transport_from_traces(
            self.traces,
            staleness=np.array([tr.mean_staleness for tr in self.traces]),
            ef_residuals=self.ef_residual_norms(),
        )

    def ef_residual_norms(self) -> Dict[str, float]:
        if self.ef_store is not None:
            return self.ef_store.residual_norms()
        return {}
