"""Byte-accurate per-round communication accounting.

``RoundTrace`` is the unit record the round driver accumulates: who was
scheduled, who delivered, exactly how many encoded bytes moved in each
direction, and the simulated wall-clock the round cost. ``summarize``
folds a trajectory of traces into the cumulative curves benchmarks plot
(loss vs transmitted bytes, loss vs simulated time). ``Transport`` is
the bundle of those curves a ``Session`` hands back to the round driver
for ``History`` assembly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class RoundTrace:
    """One communication round, as observed on the (simulated) wire.

    Synchronous rounds leave the async-only fields at their defaults;
    asynchronous server steps (``repro.comm.async_driver``) additionally
    record which model ``version`` the step produced and the per-client
    ``staleness`` — for each committed client, how many server steps its
    base model lagged the server (NaN for clients not in the commit).
    ``sim_time_s`` is then the *server-clock increment* between commits,
    so ``cumulative_time`` yields the server-clock axis in both modes.

    Async field semantics differ per client: ``scheduled`` is the
    committed cohort plus clients whose upload was LOST in this commit
    window (so ``scheduled & ~delivered`` still counts drops), while
    ``bytes_down`` bills model broadcasts when they are *dispatched* —
    a client still in flight can carry ``bytes_down > 0`` in a trace
    whose ``scheduled`` row is False. Per-trace totals and cumulative
    curves are conserved in both modes; only the per-client pairing of
    ``bytes_down`` with ``scheduled`` is sync-specific.

    Population-mode (cohort) traces set ``ids`` to the cohort's client
    ids and ``population`` to the population size m: every per-client
    array is then cohort-length (``len(ids)``), never ``(m,)`` — at
    m ~ 10⁵ with q ~ 10⁻³ a trace stores ~100 rows instead of 100 000.
    Dense traces leave ``ids=None`` / ``population=0``; all aggregate
    properties work identically on both forms.
    """

    round: int
    scheduled: np.ndarray  # (m,) bool — asked to participate
    delivered: np.ndarray  # (m,) bool — scheduled and not dropped
    straggler: np.ndarray  # (m,) bool — delivered late (slowdown applied)
    bytes_up: np.ndarray  # (m,) encoded uplink bytes (0 if not delivered)
    bytes_down: np.ndarray  # (m,) broadcast bytes (0 if not scheduled)
    sim_time_s: float  # round wall-clock (sync) / server-clock delta (async)
    staleness: "np.ndarray | None" = None  # (m,) server steps of lag, NaN = absent
    version: int = -1  # model version this commit produced (-1 for sync)
    ids: "np.ndarray | None" = None  # cohort client ids (population mode)
    population: int = 0  # population size m (0 = dense trace)

    @property
    def clients(self) -> int:
        """Denominator for participation: population m, or the dense
        per-client axis length."""
        return self.population if self.population else len(self.delivered)

    @property
    def total_bytes(self) -> int:
        return int(self.bytes_up.sum() + self.bytes_down.sum())

    @property
    def mean_staleness(self) -> float:
        """Mean staleness over committed clients (0.0 for sync rounds).

        All-NaN rows (a commit that delivered nobody — only possible in
        degenerate configs, but representable) are defined as 0.0, not
        NaN: the mean is over committed clients and an empty cohort has
        no lag to report.
        """
        if self.staleness is None:
            return 0.0
        hit = ~np.isnan(self.staleness)
        return float(self.staleness[hit].mean()) if hit.any() else 0.0

    def to_dict(self) -> dict:
        """JSON-able record of this trace (``History.to_jsonl`` line).

        Per-client NaN staleness (clients absent from the commit) is
        encoded as ``null`` — strict JSON has no NaN token.
        """
        return {
            "round": int(self.round),
            "scheduled": [bool(v) for v in self.scheduled],
            "delivered": [bool(v) for v in self.delivered],
            "straggler": [bool(v) for v in self.straggler],
            "bytes_up": [float(v) for v in self.bytes_up],
            "bytes_down": [float(v) for v in self.bytes_down],
            "sim_time_s": float(self.sim_time_s),
            "staleness": (None if self.staleness is None else
                          [None if np.isnan(v) else float(v)
                           for v in self.staleness]),
            "version": int(self.version),
            **({} if self.ids is None else
               {"ids": [int(v) for v in self.ids],
                "population": int(self.population)}),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "RoundTrace":
        stale = d.get("staleness")
        return cls(
            round=int(d["round"]),
            scheduled=np.asarray(d["scheduled"], dtype=bool),
            delivered=np.asarray(d["delivered"], dtype=bool),
            straggler=np.asarray(d["straggler"], dtype=bool),
            bytes_up=np.asarray(d["bytes_up"], dtype=np.float64),
            bytes_down=np.asarray(d["bytes_down"], dtype=np.float64),
            sim_time_s=float(d["sim_time_s"]),
            staleness=(None if stale is None else np.asarray(
                [np.nan if v is None else v for v in stale],
                dtype=np.float64)),
            version=int(d.get("version", -1)),
            ids=(None if d.get("ids") is None
                 else np.asarray(d["ids"], dtype=np.int64)),
            population=int(d.get("population", 0)),
        )


def summarize(traces: "list[RoundTrace]") -> dict:
    """Aggregate totals for reports / JSON artifacts."""
    if not traces:
        return {"rounds": 0, "total_bytes_up": 0, "total_bytes_down": 0,
                "sim_time_s": 0.0, "mean_participation": 0.0,
                "dropped_client_rounds": 0, "mean_staleness": 0.0}
    up = sum(int(t.bytes_up.sum()) for t in traces)
    down = sum(int(t.bytes_down.sum()) for t in traces)
    part = float(np.mean([t.delivered.sum() / t.clients for t in traces]))
    dropped = sum(int((t.scheduled & ~t.delivered).sum()) for t in traces)
    return {
        "rounds": len(traces),
        "total_bytes_up": up,
        "total_bytes_down": down,
        "sim_time_s": float(sum(t.sim_time_s for t in traces)),
        "mean_participation": part,
        "dropped_client_rounds": dropped,
        "mean_staleness": float(np.mean([t.mean_staleness for t in traces])),
    }


def cumulative_bytes(traces: "list[RoundTrace]") -> np.ndarray:
    """(T+1,) cumulative up+down bytes after each round (0 at round 0)."""
    per_round = np.array([t.total_bytes for t in traces], dtype=np.float64)
    return np.concatenate([[0.0], np.cumsum(per_round)])


def cumulative_bytes_up(traces: "list[RoundTrace]") -> np.ndarray:
    """(T+1,) cumulative uplink bytes (all clients) after each round."""
    per_round = np.array([float(t.bytes_up.sum()) for t in traces])
    return np.concatenate([[0.0], np.cumsum(per_round)])


def cumulative_bytes_down(traces: "list[RoundTrace]") -> np.ndarray:
    """(T+1,) cumulative downlink (broadcast) bytes after each round."""
    per_round = np.array([float(t.bytes_down.sum()) for t in traces])
    return np.concatenate([[0.0], np.cumsum(per_round)])


def cumulative_time(traces: "list[RoundTrace]") -> np.ndarray:
    """(T+1,) cumulative simulated seconds after each round."""
    per_round = np.array([t.sim_time_s for t in traces], dtype=np.float64)
    return np.concatenate([[0.0], np.cumsum(per_round)])


@dataclasses.dataclass(frozen=True)
class Transport:
    """Transport axes one ``Session`` produces for ``History`` assembly.

    ``traces``/``staleness``/``ef_residuals`` are None on the
    no-transport path (``run_rounds(..., comm=None)``), where the bytes
    curve is derived from the per-optimizer float formulas instead of
    encoded wire sizes and simulated time is identically zero.
    """

    cumulative_bytes: np.ndarray  # (T+1,) up+down, all clients
    sim_time_s: np.ndarray  # (T+1,) cumulative simulated seconds
    traces: Optional[list] = None  # per-round RoundTrace records
    staleness: Optional[np.ndarray] = None  # (T,) mean commit staleness
    ef_residuals: Optional[dict] = None  # final EF memory norms


def transport_from_traces(
    traces: "list[RoundTrace]",
    staleness: "np.ndarray | None" = None,
    ef_residuals: "dict | None" = None,
) -> Transport:
    """Fold a trace trajectory into the ``Transport`` axes — the one
    assembly both transport drivers share, so a new axis cannot be added
    to one driver's ``History`` and silently missed in the other's."""
    return Transport(
        cumulative_bytes=cumulative_bytes(traces),
        sim_time_s=cumulative_time(traces),
        traces=traces,
        staleness=staleness,
        ef_residuals=ef_residuals,
    )
