"""Byte-accurate per-round communication accounting.

``RoundTrace`` is the unit record the round driver accumulates: who was
scheduled, who delivered, exactly how many encoded bytes moved in each
direction, and the simulated wall-clock the round cost. ``summarize``
folds a trajectory of traces into the cumulative curves benchmarks plot
(loss vs transmitted bytes, loss vs simulated time).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class RoundTrace:
    """One communication round, as observed on the (simulated) wire."""

    round: int
    scheduled: np.ndarray  # (m,) bool — asked to participate
    delivered: np.ndarray  # (m,) bool — scheduled and not dropped
    straggler: np.ndarray  # (m,) bool — delivered late (slowdown applied)
    bytes_up: np.ndarray  # (m,) encoded uplink bytes (0 if not delivered)
    bytes_down: np.ndarray  # (m,) broadcast bytes (0 if not scheduled)
    sim_time_s: float  # synchronous round wall-clock

    @property
    def total_bytes(self) -> int:
        return int(self.bytes_up.sum() + self.bytes_down.sum())


def summarize(traces: "list[RoundTrace]") -> dict:
    """Aggregate totals for reports / JSON artifacts."""
    if not traces:
        return {"rounds": 0, "total_bytes_up": 0, "total_bytes_down": 0,
                "sim_time_s": 0.0, "mean_participation": 0.0,
                "dropped_client_rounds": 0}
    up = sum(int(t.bytes_up.sum()) for t in traces)
    down = sum(int(t.bytes_down.sum()) for t in traces)
    part = float(np.mean([t.delivered.mean() for t in traces]))
    dropped = sum(int((t.scheduled & ~t.delivered).sum()) for t in traces)
    return {
        "rounds": len(traces),
        "total_bytes_up": up,
        "total_bytes_down": down,
        "sim_time_s": float(sum(t.sim_time_s for t in traces)),
        "mean_participation": part,
        "dropped_client_rounds": dropped,
    }


def cumulative_bytes(traces: "list[RoundTrace]") -> np.ndarray:
    """(T+1,) cumulative up+down bytes after each round (0 at round 0)."""
    per_round = np.array([t.total_bytes for t in traces], dtype=np.float64)
    return np.concatenate([[0.0], np.cumsum(per_round)])


def cumulative_time(traces: "list[RoundTrace]") -> np.ndarray:
    """(T+1,) cumulative simulated seconds after each round."""
    per_round = np.array([t.sim_time_s for t in traces], dtype=np.float64)
    return np.concatenate([[0.0], np.cumsum(per_round)])
