"""The ``Session`` driver protocol: one round loop, three clocks.

``run_rounds`` used to special-case its three modes (no transport,
synchronous transport, asynchronous transport) with an isinstance
ladder. Instead, every mode now implements one small protocol and the
driver is a single protocol-driven loop:

  * ``prepare(trace_round)`` — trace-time discovery before the first
      round executes: the async driver probes the payload byte plan
      (its clock needs encoded sizes up front) and launches the initial
      cohort; the sync driver probes EF memory shapes when error
      feedback is on; the null session does nothing.
  * ``begin_variant(sig, trace_round)`` — announce the static round
      variant about to execute (adaptive-k sketch policies change
      payload sizes mid-trajectory; ``sig`` comes from
      ``FederatedOptimizer.round_signature``). Sessions probe each new
      variant's payload byte plan once (``jax.eval_shape`` — nothing
      executes) and install it, so per-round accounting bills the true
      round-varying sizes: the null session derives its formula bytes
      from an identity-codec plan, the sync session swaps its live plan
      per variant, and the async session rejects mid-run variant
      changes (its clock prices in-flight uploads at dispatch time).
  * ``comm_round(memory, mask, codec_key)`` — build the in-jit
      transport view the optimizer's round receives (``CommRound``, or
      the no-op ``NULL_COMM`` on the no-transport path). Called at
      trace time by the driver's uniform round builder.
  * ``step(round_fn)`` — advance one server round/commit and return the
      new optimizer state. ``round_fn(state, memory, key, mask,
      codec_key) -> (state, memory)`` is the one jitted round function
      shared by every mode.
  * ``finalize() -> Transport`` — the transport axes (cumulative bytes,
      simulated time, traces, staleness, EF residuals) for ``History``.

Sessions own the host-side trajectory state (optimizer state between
rounds, per-round keys, EF memory, clocks); the jitted round function
stays pure. Adding a fourth driver mode means implementing this
protocol — not deepening a branch in ``run_rounds``.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.comm.async_driver import AsyncSession, PopulationAsyncSession
from repro.comm.config import (
    NULL_COMM,
    CommConfig,
    CommSession,
    PopulationCommSession,
    plan_bytes,
    probe_round,
)
from repro.comm.metrics import Transport
from repro.obs import NULL_TELEMETRY
from repro.obs import log as obs_log


class Session:
    """Protocol base for round drivers (see module docstring)."""

    def prepare(self, trace_round) -> None:
        raise NotImplementedError

    def begin_variant(self, sig, trace_round) -> None:
        raise NotImplementedError

    def comm_round(self, memory, mask, codec_key):
        raise NotImplementedError

    def step(self, round_fn) -> Any:
        raise NotImplementedError

    def finalize(self) -> Transport:
        raise NotImplementedError


class NullSession(Session):
    """No-transport driver: rounds execute back to back with the no-op
    ``NULL_COMM`` view — the exact legacy jaxpr. The byte axis is
    derived from an identity-codec probe of the round's payload plan
    (the measured wire: every payload occurrence at its raw encoded
    size, both directions), falling back to the per-optimizer
    float-count formulas when no probe context is available; adaptive-k
    variants re-probe, so the formula axis is round-varying too."""

    def __init__(self, keys, state0, formula_bytes_per_round: float,
                 m: "int | None" = None, mask_dtype=None,
                 obs=NULL_TELEMETRY):
        self.keys = keys
        self._state = state0
        self._formula = float(formula_bytes_per_round)
        self.m = m
        self._mask_dtype = mask_dtype
        self._plans: dict = {}
        self._per_round: "list[float]" = []
        self._t = 0
        self.obs = obs

    def prepare(self, trace_round) -> None:
        pass

    def begin_variant(self, sig, trace_round) -> None:
        if self.m is None:
            return  # no probe context: keep the float-formula fallback
        if sig not in self._plans:
            plan: dict = {}
            try:
                with self.obs.trace.span("probe_plan"):
                    probe_round(CommConfig(), self.m, self._mask_dtype, plan,
                                trace_round, full_cohort=True)
            except Exception as e:  # un-traceable round: formula fallback
                plan = None
                obs_log.warn_with_context(
                    f"payload-plan probe failed ({e!r}); the no-comm byte "
                    f"axis falls back to the per-optimizer float-count "
                    f"formulas for this run (these can undercount the "
                    f"wire)", round=self._t, variant=sig)
                self.obs.metrics.counter("plan_probe_fallbacks").inc()
            self._plans[sig] = plan
        plan = self._plans[sig]
        if plan is not None:
            per_client = (plan_bytes(plan, down=False)
                          + plan_bytes(plan, down=True))
            self._formula = float(per_client * self.m)

    def comm_round(self, memory, mask, codec_key):
        return NULL_COMM

    def step(self, round_fn) -> Any:
        self._state, _, _ = round_fn(self._state, {}, self.keys[self._t],
                                     None, None)
        self._per_round.append(self._formula)
        self._t += 1
        if self.obs.enabled:
            self.obs.metrics.counter("formula_bytes").inc(self._formula)
            self.obs.annotate(formula_bytes=self._formula)
        return self._state

    def finalize(self) -> Transport:
        per_round = np.asarray(self._per_round, dtype=np.float64)
        return Transport(
            cumulative_bytes=np.concatenate([[0.0], np.cumsum(per_round)]),
            sim_time_s=np.zeros(self._t + 1),
        )


def make_session(
    comm: Optional[CommConfig],
    *,
    m: int,
    mask_dtype,
    client_weights: np.ndarray,
    keys,
    state0,
    formula_bytes_per_round: float,
    obs=NULL_TELEMETRY,
    population=None,
    client_mesh=None,
) -> Session:
    """Resolve a ``CommConfig`` (or None) to its driver session — the
    single place mode dispatch happens. ``obs`` is the live telemetry
    runtime (``repro.obs.Telemetry``) or the shared no-op.

    ``population`` (a ``repro.core.federated.ClientPopulation``) selects
    the lazy cohort-materialization drivers; it requires a transport
    (``comm`` must not be None — a population has no dense legacy path
    to fall back to). ``client_mesh`` optionally shards each
    materialized cohort's client axis over a device mesh
    (``repro.sharding.rules.shard_cohort``).
    """
    if population is not None:
        if comm is None:
            raise ValueError(
                "population-mode runs need a CommConfig: pass "
                "run_rounds(..., comm=CommConfig(scheduler='uniform:q')) "
                "(materializing all clients of a population is exactly "
                "what populations exist to avoid — use "
                "population.materialize_all() explicitly if you really "
                "want the dense problem)")
        if comm.async_mode:
            return PopulationAsyncSession(
                comm, population, keys=keys, state0=state0,
                mask_dtype=mask_dtype, obs=obs, client_mesh=client_mesh)
        return PopulationCommSession(
            comm, population, mask_dtype=mask_dtype, keys=keys,
            state0=state0, obs=obs, client_mesh=client_mesh)
    if comm is None:
        return NullSession(keys, state0, formula_bytes_per_round,
                           m=m, mask_dtype=mask_dtype, obs=obs)
    if comm.async_mode:
        return AsyncSession(comm, m=m, client_weights=client_weights,
                            keys=keys, state0=state0, mask_dtype=mask_dtype,
                            obs=obs)
    return CommSession(comm, m=m, mask_dtype=mask_dtype, keys=keys,
                       state0=state0, obs=obs)
