"""The ``Session`` driver protocol: one round loop, three clocks.

``run_rounds`` used to special-case its three modes (no transport,
synchronous transport, asynchronous transport) with an isinstance
ladder. Instead, every mode now implements one small protocol and the
driver is a single protocol-driven loop:

  * ``prepare(trace_round)`` — trace-time discovery before the first
      round executes: the async driver probes the payload byte plan
      (its clock needs encoded sizes up front) and launches the initial
      cohort; the sync driver probes EF memory shapes when error
      feedback is on; the null session does nothing.
  * ``comm_round(memory, mask, codec_key)`` — build the in-jit
      transport view the optimizer's round receives (``CommRound``, or
      the no-op ``NULL_COMM`` on the no-transport path). Called at
      trace time by the driver's uniform round builder.
  * ``step(round_fn)`` — advance one server round/commit and return the
      new optimizer state. ``round_fn(state, memory, key, mask,
      codec_key) -> (state, memory)`` is the one jitted round function
      shared by every mode.
  * ``finalize() -> Transport`` — the transport axes (cumulative bytes,
      simulated time, traces, staleness, EF residuals) for ``History``.

Sessions own the host-side trajectory state (optimizer state between
rounds, per-round keys, EF memory, clocks); the jitted round function
stays pure. Adding a fourth driver mode means implementing this
protocol — not deepening a branch in ``run_rounds``.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.comm.async_driver import AsyncSession
from repro.comm.config import NULL_COMM, CommConfig, CommSession
from repro.comm.metrics import Transport


class Session:
    """Protocol base for round drivers (see module docstring)."""

    def prepare(self, trace_round) -> None:
        raise NotImplementedError

    def comm_round(self, memory, mask, codec_key):
        raise NotImplementedError

    def step(self, round_fn) -> Any:
        raise NotImplementedError

    def finalize(self) -> Transport:
        raise NotImplementedError


class NullSession(Session):
    """No-transport driver: rounds execute back to back with the no-op
    ``NULL_COMM`` view — the exact legacy jaxpr — and the byte axis is
    derived from the per-optimizer float-count formulas."""

    def __init__(self, keys, state0, formula_bytes_per_round: float):
        self.keys = keys
        self._state = state0
        self._formula = float(formula_bytes_per_round)
        self._t = 0

    def prepare(self, trace_round) -> None:
        pass

    def comm_round(self, memory, mask, codec_key):
        return NULL_COMM

    def step(self, round_fn) -> Any:
        self._state, _ = round_fn(self._state, {}, self.keys[self._t],
                                  None, None)
        self._t += 1
        return self._state

    def finalize(self) -> Transport:
        t = self._t
        return Transport(
            cumulative_bytes=np.arange(t + 1, dtype=np.float64)
            * self._formula,
            sim_time_s=np.zeros(t + 1),
        )


def make_session(
    comm: Optional[CommConfig],
    *,
    m: int,
    mask_dtype,
    client_weights: np.ndarray,
    keys,
    state0,
    formula_bytes_per_round: float,
) -> Session:
    """Resolve a ``CommConfig`` (or None) to its driver session — the
    single place mode dispatch happens."""
    if comm is None:
        return NullSession(keys, state0, formula_bytes_per_round)
    if comm.async_mode:
        return AsyncSession(comm, m=m, client_weights=client_weights,
                            keys=keys, state0=state0, mask_dtype=mask_dtype)
    return CommSession(comm, m=m, mask_dtype=mask_dtype, keys=keys,
                       state0=state0)
