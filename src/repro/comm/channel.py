"""Per-client link model: bandwidth, latency, compute, stragglers, dropout.

The channel is a *driver-side* (host, numpy) model: per round it draws
which scheduled clients straggle (slowed by ``straggler_slowdown``) and
which drop out entirely (their payload never reaches the server), then
converts per-client byte counts into per-client cycle times
(``client_times`` = latency + broadcast download + local compute +
upload). The synchronous driver reduces those to a single round
wall-clock — the server waits for the slowest delivering client
(``round_time``) — while the asynchronous driver
(``repro.comm.async_driver``) keeps the full per-client vector and
advances a persistent per-client clock from it, so fast clients lap
slow ones instead of waiting.

``compute_s`` models per-client local computation time explicitly
(scalar or per-client ``(m,)`` — heterogeneous devices), instead of
folding compute into link latency; stragglers slow the whole cycle,
compute included.

All draws are deterministic functions of a PRNG key, so a trajectory is
exactly reproducible from ``(CommConfig.seed, round index)``.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


def _per_client(x, m: int) -> np.ndarray:
    """Broadcast a scalar or (m,) array-like to a float64 (m,) vector."""
    arr = np.asarray(x, dtype=np.float64)
    if arr.ndim == 0:
        return np.full((m,), float(arr))
    if arr.shape != (m,):
        raise ValueError(f"per-client value has shape {arr.shape}, want ({m},)")
    return arr


@dataclasses.dataclass(frozen=True)
class ChannelDraw:
    """One round's channel randomness for the scheduled cohort."""

    straggler: np.ndarray  # (m,) bool
    dropout: np.ndarray  # (m,) bool


@dataclasses.dataclass(frozen=True)
class ChannelModel:
    """Synchronous-round link model.

    ``uplink_bytes_per_s`` / ``downlink_bytes_per_s`` may be scalars or
    per-client (m,) arrays (heterogeneous edge links).
    """

    uplink_bytes_per_s: "float | np.ndarray" = 1.25e6  # ~10 Mbit/s edge uplink
    downlink_bytes_per_s: "float | np.ndarray" = 1.25e7  # ~100 Mbit/s down
    latency_s: float = 0.05
    compute_s: "float | np.ndarray" = 0.0  # per-client local compute time
    straggler_prob: float = 0.0
    straggler_slowdown: float = 10.0
    dropout_prob: float = 0.0

    def uplink_rates(self, m: int) -> np.ndarray:
        return _per_client(self.uplink_bytes_per_s, m)

    def downlink_rates(self, m: int) -> np.ndarray:
        return _per_client(self.downlink_bytes_per_s, m)

    def compute_times(self, m: int) -> np.ndarray:
        return _per_client(self.compute_s, m)

    def draw(self, key: jax.Array, m: int) -> ChannelDraw:
        """Deterministic straggler/dropout coin flips for one round."""
        k_straggle, k_drop = jax.random.split(key)
        straggler = np.asarray(
            jax.random.bernoulli(k_straggle, self.straggler_prob, (m,)))
        dropout = np.asarray(
            jax.random.bernoulli(k_drop, self.dropout_prob, (m,)))
        return ChannelDraw(straggler=straggler, dropout=dropout)

    def client_times(
        self,
        draw: ChannelDraw,
        bytes_up: np.ndarray,  # (m,) uplink bytes per client
        bytes_down: np.ndarray,  # (m,) broadcast bytes per client
    ) -> np.ndarray:
        """(m,) per-client cycle times: latency + downlink + compute +
        uplink, straggler-scaled. This is the quantity the async driver
        consumes directly; the sync driver takes its max over delivering
        clients."""
        m = draw.straggler.shape[0]
        up = self.uplink_rates(m)
        down = self.downlink_rates(m)
        t = (self.latency_s + bytes_down / down + self.compute_times(m)
             + bytes_up / up)
        return np.where(draw.straggler, t * self.straggler_slowdown, t)

    def round_time(
        self,
        draw: ChannelDraw,
        delivered: np.ndarray,  # (m,) bool — scheduled & not dropped
        bytes_up: np.ndarray,  # (m,) uplink bytes for delivering clients
        bytes_down: np.ndarray,  # (m,) broadcast bytes per client
    ) -> float:
        """Simulated wall-clock: slowest delivering client closes the round."""
        t = self.client_times(draw, bytes_up, bytes_down)
        if not delivered.any():
            return float(self.latency_s)
        return float(np.max(t[delivered]))
