"""Per-client link model: bandwidth, latency, compute, stragglers, dropout.

The channel is a *driver-side* (host, numpy) model: per round it draws
which scheduled clients straggle (slowed by ``straggler_slowdown``) and
which drop out entirely (their payload never reaches the server), then
converts per-client byte counts into per-client cycle times
(``client_times`` = latency + broadcast download + local compute +
upload). The synchronous driver reduces those to a single round
wall-clock — the server waits for the slowest delivering client
(``round_time``) — while the asynchronous driver
(``repro.comm.async_driver``) keeps the full per-client vector and
advances a persistent per-client clock from it, so fast clients lap
slow ones instead of waiting.

Per-client fields (``uplink_bytes_per_s`` / ``downlink_bytes_per_s`` /
``latency_s`` / ``compute_s``) accept, uniformly:

* a scalar — every client identical;
* an ``(m,)`` array — explicit per-client values (workstation-scale
  populations only; wrong lengths raise a field-named error);
* a distribution spec string — ``"loguniform:lo,hi"``,
  ``"lognormal:median,sigma"``, ``"uniform:lo,hi"``, ``"const:v"`` —
  drawn *per client id* from a field-keyed PRNG stream
  (``attr_seed`` + a stable hash of the field name), so client ``j``'s
  bandwidth is a pure function of the spec and ``j``: populations of
  10⁴–10⁶ clients never store an ``(m,)`` array, and a client keeps the
  same link no matter which cohort samples it.

All draws are deterministic functions of a PRNG key (round coins) or of
the client id (static attributes), so a trajectory is exactly
reproducible from ``(CommConfig.seed, round index)`` and per-client
attributes are reproducible across runs and drivers.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib

import jax
import jax.numpy as jnp
import numpy as np

FIELD_DISTRIBUTIONS = ("loguniform", "lognormal", "uniform", "const")


def _parse_spec(spec: str) -> "tuple[str, tuple[float, ...]]":
    kind, _, rest = spec.partition(":")
    if kind not in FIELD_DISTRIBUTIONS:
        raise ValueError(
            f"unknown channel distribution {spec!r}; expected one of "
            f"{', '.join(k + ':...' for k in FIELD_DISTRIBUTIONS)}")
    try:
        params = tuple(float(p) for p in rest.split(",") if p != "")
    except ValueError:
        raise ValueError(f"bad parameters in channel distribution {spec!r}")
    want = 1 if kind == "const" else 2
    if len(params) != want:
        raise ValueError(
            f"channel distribution {spec!r} wants {want} parameter(s), "
            f"got {len(params)}")
    return kind, params


@functools.lru_cache(maxsize=None)
def _spec_sampler(spec: str, salt: int):
    """Compiled per-id sampler for one (distribution spec, field salt).

    Client ``j``'s value is a pure function of ``(spec, salt, j)`` —
    independent of cohort composition, round, and driver.
    """
    kind, params = _parse_spec(spec)
    key0 = jax.random.PRNGKey(np.uint32(salt))  # noqa: RA001 — documented (seed, field) salt: per-id draws must be pure in (spec, salt, id)

    def one(cid):
        k = jax.random.fold_in(key0, cid)
        if kind == "const":
            return jnp.float64(params[0]) if jax.config.jax_enable_x64 \
                else jnp.float32(params[0])
        if kind == "uniform":
            lo, hi = params
            return lo + (hi - lo) * jax.random.uniform(k)
        if kind == "loguniform":
            lo, hi = params
            u = jax.random.uniform(k)
            return jnp.exp(jnp.log(lo) + u * (jnp.log(hi) - jnp.log(lo)))
        median, sigma = params
        return median * jnp.exp(sigma * jax.random.normal(k))

    return jax.jit(jax.vmap(one))


def _draw_spec(spec: str, ids: np.ndarray, field: str, seed: int) -> np.ndarray:
    salt = (zlib.crc32(field.encode()) ^ (seed & 0xFFFFFFFF)) & 0xFFFFFFFF
    vals = _spec_sampler(spec, salt)(jnp.asarray(ids, jnp.uint32))
    return np.asarray(vals, dtype=np.float64)


def _per_client(x, m: int, field: str = "per-client value",
                seed: int = 0) -> np.ndarray:
    """Resolve a channel field to a float64 ``(m,)`` vector.

    Scalars broadcast; distribution specs draw per client id; arrays
    must already be ``(m,)`` — anything else raises naming the field.
    """
    if isinstance(x, str):
        return _draw_spec(x, np.arange(m), field, seed)
    arr = np.asarray(x, dtype=np.float64)
    if arr.ndim == 0:
        return np.full((m,), float(arr))
    if arr.shape != (m,):
        raise ValueError(
            f"channel field {field!r} has shape {arr.shape}, want ({m},) "
            f"— pass a scalar, an (m,) array, or a distribution spec "
            f"like 'loguniform:lo,hi'")
    return arr


@dataclasses.dataclass(frozen=True)
class ChannelDraw:
    """One round's channel randomness for the scheduled cohort."""

    straggler: np.ndarray  # (m,) bool
    dropout: np.ndarray  # (m,) bool


@dataclasses.dataclass(frozen=True)
class ChannelModel:
    """Synchronous-round link model.

    ``uplink_bytes_per_s`` / ``downlink_bytes_per_s`` / ``latency_s`` /
    ``compute_s`` uniformly accept scalars, per-client ``(m,)`` arrays
    (heterogeneous edge links), or distribution spec strings drawn per
    client id (population-scale heterogeneity without ``(m,)`` storage).
    """

    uplink_bytes_per_s: "float | np.ndarray | str" = 1.25e6  # ~10 Mbit/s up
    downlink_bytes_per_s: "float | np.ndarray | str" = 1.25e7  # ~100 Mbit/s
    latency_s: "float | np.ndarray | str" = 0.05
    compute_s: "float | np.ndarray | str" = 0.0  # per-client local compute
    straggler_prob: float = 0.0
    straggler_slowdown: float = 10.0
    dropout_prob: float = 0.0
    attr_seed: int = 0  # stream seed for distribution-spec fields

    # -- dense (m,) views ----------------------------------------------------
    def _field(self, name: str, ids: "np.ndarray | None", m: int) -> np.ndarray:
        """Values of one field for ``ids`` (default: all m clients)."""
        x = getattr(self, name)
        if ids is None:
            return _per_client(x, m, field=name, seed=self.attr_seed)
        ids = np.asarray(ids, dtype=np.int64)
        if isinstance(x, str):
            return _draw_spec(x, ids, name, self.attr_seed)
        arr = np.asarray(x, dtype=np.float64)
        if arr.ndim == 0:
            return np.full((len(ids),), float(arr))
        if arr.shape != (m,):
            raise ValueError(
                f"channel field {name!r} has shape {arr.shape}, want ({m},) "
                f"— pass a scalar, an (m,) array over the population, or a "
                f"distribution spec like 'loguniform:lo,hi'")
        return arr[ids]

    def uplink_rates(self, m: int) -> np.ndarray:
        return self._field("uplink_bytes_per_s", None, m)

    def downlink_rates(self, m: int) -> np.ndarray:
        return self._field("downlink_bytes_per_s", None, m)

    def compute_times(self, m: int) -> np.ndarray:
        return self._field("compute_s", None, m)

    def latencies(self, m: int) -> np.ndarray:
        return self._field("latency_s", None, m)

    # -- cohort views (population mode) -------------------------------------
    def uplink_rates_for(self, ids, m: int) -> np.ndarray:
        """(c,) uplink rates of the cohort ``ids`` from an m-client
        population — per-id deterministic for spec fields."""
        return self._field("uplink_bytes_per_s", ids, m)

    def downlink_rates_for(self, ids, m: int) -> np.ndarray:
        return self._field("downlink_bytes_per_s", ids, m)

    def compute_times_for(self, ids, m: int) -> np.ndarray:
        return self._field("compute_s", ids, m)

    def latencies_for(self, ids, m: int) -> np.ndarray:
        return self._field("latency_s", ids, m)

    def draw(self, key: jax.Array, m: int) -> ChannelDraw:
        """Deterministic straggler/dropout coin flips for one round."""
        k_straggle, k_drop = jax.random.split(key)
        straggler = np.asarray(
            jax.random.bernoulli(k_straggle, self.straggler_prob, (m,)))
        dropout = np.asarray(
            jax.random.bernoulli(k_drop, self.dropout_prob, (m,)))
        return ChannelDraw(straggler=straggler, dropout=dropout)

    def draw_for(self, key: jax.Array, ids) -> ChannelDraw:
        """Cohort coin flips, keyed per client id: client ``j``'s coins
        this round depend on ``(key, j)`` only, never on which other
        clients ride the cohort — so sync and async drivers sampling the
        same cohort from the same round key see identical coins."""
        ids_j = jnp.asarray(np.asarray(ids, dtype=np.int64), jnp.uint32)

        def one(cid):
            ks, kd = jax.random.split(jax.random.fold_in(key, cid))
            return (jax.random.bernoulli(ks, self.straggler_prob),
                    jax.random.bernoulli(kd, self.dropout_prob))

        straggler, dropout = jax.vmap(one)(ids_j)
        return ChannelDraw(straggler=np.asarray(straggler),
                           dropout=np.asarray(dropout))

    def client_times(
        self,
        draw: ChannelDraw,
        bytes_up: np.ndarray,  # (m,) uplink bytes per client
        bytes_down: np.ndarray,  # (m,) broadcast bytes per client
    ) -> np.ndarray:
        """(m,) per-client cycle times: latency + downlink + compute +
        uplink, straggler-scaled. This is the quantity the async driver
        consumes directly; the sync driver takes its max over delivering
        clients."""
        m = draw.straggler.shape[0]
        up = self.uplink_rates(m)
        down = self.downlink_rates(m)
        t = (self.latencies(m) + bytes_down / down + self.compute_times(m)
             + bytes_up / up)
        return np.where(draw.straggler, t * self.straggler_slowdown, t)

    def client_times_for(
        self,
        ids,
        m: int,
        draw: ChannelDraw,  # cohort-length coins (from draw_for)
        bytes_up: np.ndarray,  # (c,) uplink bytes
        bytes_down: np.ndarray,  # (c,) broadcast bytes
    ) -> np.ndarray:
        """(c,) cycle times of one cohort from an m-client population."""
        up = self.uplink_rates_for(ids, m)
        down = self.downlink_rates_for(ids, m)
        t = (self.latencies_for(ids, m) + bytes_down / down
             + self.compute_times_for(ids, m) + bytes_up / up)
        return np.where(draw.straggler, t * self.straggler_slowdown, t)

    def round_time(
        self,
        draw: ChannelDraw,
        delivered: np.ndarray,  # (m,) bool — scheduled & not dropped
        bytes_up: np.ndarray,  # (m,) uplink bytes for delivering clients
        bytes_down: np.ndarray,  # (m,) broadcast bytes per client
    ) -> float:
        """Simulated wall-clock: slowest delivering client closes the round."""
        t = self.client_times(draw, bytes_up, bytes_down)
        if not delivered.any():
            # empty round still costs a propagation delay
            return float(np.mean(self.latencies(draw.straggler.shape[0])))
        return float(np.max(t[delivered]))

    def round_time_for(
        self,
        ids,
        m: int,
        draw: ChannelDraw,
        delivered: np.ndarray,  # (c,) bool
        bytes_up: np.ndarray,
        bytes_down: np.ndarray,
    ) -> float:
        """Cohort round wall-clock (population mode)."""
        if not delivered.any():
            lat = self.latencies_for(ids, m)
            return float(np.mean(lat)) if len(lat) else 0.0
        t = self.client_times_for(ids, m, draw, bytes_up, bytes_down)
        return float(np.max(t[delivered]))
