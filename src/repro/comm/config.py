"""CommConfig + the per-round runtime objects the driver threads through.

Three layers:

  * ``CommConfig``  — user-facing description: which codec per payload
      name, which participation scheduler, which channel model, seed.
  * ``CommSession`` — driver-side (host) state for one trajectory: draws
      cohorts/channel randomness per round, accumulates ``RoundTrace``s,
      and owns the *payload plan* (exact encoded bytes per payload name,
      recorded once at jit-trace time — payload shapes are static).
  * ``CommRound``   — the view optimizers see *inside* the jitted round:
      ``uplink(name, x)`` routes a stacked per-client payload through its
      codec (so compression error perturbs the optimization), and
      ``weights(p)`` masks + renormalizes aggregation weights for the
      delivering cohort.

With ``CommConfig(error_feedback=...)`` lossy payloads additionally
carry client-side error-feedback memory (``repro.comm.feedback``): the
driver threads the memory pytree through the jitted round and ``uplink``
emits the updated memory via ``CommRound.memory_out``. Under the default
``ef_variant="ef21"`` the memory is the payload *estimate* ``g`` — the
wire carries the compressed innovation ``C(x - g)`` and the server
consumes the advanced estimate ``g + C(x - g)``; under ``"ef14"`` it is
the accumulated residual ``e`` and the wire carries the compensated
payload ``C(x + e)``.

Bit-exactness contract: with the identity codec and full participation
(no dropout), ``CommRound.uplink`` returns its input object unchanged
and ``weights`` returns ``p`` unchanged — the round's jaxpr is identical
to the no-comm path, so trajectories match today's bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import feedback
from repro.comm.channel import ChannelModel
from repro.comm.codecs import Codec, IdentityCodec, make_codec
from repro.comm.metrics import RoundTrace
from repro.comm.scheduler import Scheduler, make_scheduler

# control-plane payloads default to lossless regardless of the default
# codec (compressing a 1-scalar guard loss saves nothing and can poison
# the accept/reject logic)
_LOSSLESS_BY_DEFAULT = ("loss",)


@dataclasses.dataclass
class CommConfig:
    """Transport description for one federated run.

    ``codecs`` maps payload names (``"h_sk"``, ``"sg"``, ``"grad"``,
    ``"w_local"``, ...) to codec specs; the ``"default"`` entry covers
    unnamed payloads. A bare string/Codec is shorthand for
    ``{"default": ...}``.

    ``error_feedback`` gates client-side error-feedback memory per
    payload (see ``repro.comm.feedback``): ``True`` enables it for every
    *eligible* payload with a *lossy* codec, a collection of names
    enables those payloads only, and a ``{name: bool}`` dict (optional
    ``"default"`` entry) gives full control. Lossless payloads never
    allocate memory regardless, and call sites can opt a payload out
    entirely with ``uplink(..., ef_eligible=False)`` (per-round random
    sketch bases). ``ef_variant`` picks the recursion: ``"ef21"``
    (compressed-estimate tracking, default) or ``"ef14"`` (classic
    residual compensation).

    ``async_mode=True`` swaps the synchronous lock-step driver for the
    event-driven async driver (``repro.comm.async_driver``): each client
    computes on the model version it last received and the server
    commits once a quorum of uploads has arrived — ``buffer_size`` (a
    FedBuff-style K) when set, else ``ceil(async_quantile * m)``.
    ``staleness`` weights stale contributions on top of participation
    weights: ``"constant"``, ``"inverse"`` (1/(1+tau)), or
    ``"poly:a"`` ((1+tau)^-a); see ``make_staleness``. With the full
    scheduler, no dropout, and a full quorum (``async_quantile=1.0``,
    ``buffer_size`` unset) the async driver is lock-step-equivalent and
    reproduces the synchronous trajectory bit-identically.
    """

    codecs: "Dict[str, Any] | str | Codec" = "identity"
    scheduler: "str | Scheduler" = "full"
    channel: ChannelModel = dataclasses.field(default_factory=ChannelModel)
    seed: int = 0
    error_feedback: "bool | str | Dict[str, bool] | tuple | frozenset" = False
    ef_variant: str = "ef21"
    async_mode: bool = False
    buffer_size: "int | None" = None
    async_quantile: float = 1.0
    staleness: "str | Any" = "constant"

    def __post_init__(self):
        if not isinstance(self.codecs, dict):
            self.codecs = {"default": self.codecs}
        if self.ef_variant not in feedback.EF_VARIANTS:
            raise ValueError(
                f"unknown ef_variant {self.ef_variant!r}; "
                f"want one of {feedback.EF_VARIANTS}")
        if self.buffer_size is not None and self.buffer_size < 1:
            raise ValueError(
                f"buffer_size must be >= 1, got {self.buffer_size}")
        if not 0.0 < self.async_quantile <= 1.0:
            raise ValueError(
                f"async_quantile must be in (0, 1], got {self.async_quantile}")
        # validate the staleness spec eagerly (bad specs fail at config
        # time, not mid-trajectory); AsyncSession resolves it for real
        from repro.comm.async_driver import make_staleness

        make_staleness(self.staleness)
        self._codec_cache: Dict[str, Codec] = {}
        self.scheduler = make_scheduler(self.scheduler)

    def codec_for(self, payload: str) -> Codec:
        if payload not in self._codec_cache:
            if payload in self.codecs:
                spec = self.codecs[payload]
            elif payload in _LOSSLESS_BY_DEFAULT:
                spec = "identity"
            else:
                spec = self.codecs.get("default", "identity")
            self._codec_cache[payload] = make_codec(spec)
        return self._codec_cache[payload]

    def ef_for(self, payload: str) -> bool:
        """EF is folded in only where it can matter: requested AND lossy."""
        return (feedback.ef_requested(self.error_feedback, payload)
                and not self.codec_for(payload).lossless)

    @property
    def has_error_feedback(self) -> bool:
        return feedback.any_ef_requested(self.error_feedback)


class CommRound:
    """In-jit view of one round's transport. Constructed inside the
    traced round function; ``mask``/``key``/``memory`` are traced
    arrays, the codec table and byte plan are static Python closed over
    by the trace.

    ``memory`` is the EF21 residual pytree threaded through the jitted
    round by the driver (``{payload_key: (m, ...)}``); ``uplink`` folds
    the matching residual into EF-enabled lossy payloads and writes the
    updated residual to ``memory_out``. ``ef_record`` switches the
    object into the shape-discovery mode ``CommSession.
    init_error_feedback`` uses under ``jax.eval_shape``.
    """

    def __init__(
        self,
        config: CommConfig,
        plan: Dict[str, int],
        mask: "jax.Array | None",
        key: "jax.Array | None",
        memory: "Dict[str, jax.Array] | None" = None,
        ef_record: "Dict[str, jax.ShapeDtypeStruct] | None" = None,
    ):
        self._config = config
        self._plan = plan
        self.mask = mask
        self._key = key
        self._n_payloads = 0
        self._occurrences: Dict[str, int] = {}
        self._ef_record = ef_record
        # memory_out starts as a same-structure copy so payloads a round
        # happens to skip still thread their residual through unchanged
        self.memory_out: Dict[str, jax.Array] = dict(memory or {})

    def _payload_key(self, name: str) -> str:
        """Stable per-round key for the i-th uplink of ``name`` — a round
        calling ``uplink("g", ...)`` twice bills (and remembers) both."""
        occ = self._occurrences.get(name, 0)
        self._occurrences[name] = occ + 1
        return name if occ == 0 else f"{name}#{occ}"

    def uplink(self, name: str, x: jax.Array,
               wire_shape: "tuple | None" = None,
               ef_eligible: bool = True) -> jax.Array:
        """Route a stacked per-client payload ``x: (m, ...)`` through its
        codec's simulated encode→decode; records exact encoded bytes.

        ``wire_shape`` overrides the shape billed for payloads whose
        algorithm already defines a native wire format (e.g. FedNL
        transmits a rank-1 ``(M+1,)`` eigenpair, not the materialized
        (M, M) difference); the codec still prices that shape, so codec
        compression stays reflected in the byte accounting.

        ``ef_eligible=False`` declares that this payload's coordinate
        system is redrawn every round (two-sided sketches): cross-round
        error-feedback memory would mix incompatible bases, so EF is
        skipped for it even when ``CommConfig.error_feedback`` asks."""
        codec = self._config.codec_for(name)
        pkey = self._payload_key(name)
        self._plan[pkey] = codec.nbytes(
            tuple(wire_shape) if wire_shape is not None
            else tuple(x.shape[1:]), x.dtype)
        self._n_payloads += 1
        if isinstance(codec, IdentityCodec):
            return x  # same object: zero jaxpr change
        ef = ef_eligible and self._config.ef_for(name)
        if ef and self._ef_record is not None:
            self._ef_record[pkey] = jax.ShapeDtypeStruct(x.shape, x.dtype)
        if codec.deterministic:
            keys = jnp.zeros((x.shape[0], 2), jnp.uint32)  # unused by codec
        else:
            base = jax.random.fold_in(self._key, self._n_payloads)
            keys = jax.random.split(base, x.shape[0])
        if ef and pkey in self.memory_out:
            decoded, mem_new = feedback.compensate(
                codec, keys, x, self.memory_out[pkey],
                variant=self._config.ef_variant)
            # dropped clients never ran the round: freeze their memory
            # rows with the same gate that protects optimizer state
            self.memory_out[pkey] = self.where_delivered(
                mem_new, self.memory_out[pkey])
            return decoded
        return jax.vmap(codec.roundtrip)(keys, x)

    def weights(self, p: jax.Array) -> jax.Array:
        """Aggregation weights restricted to the delivering cohort."""
        if self.mask is None:
            return p
        pm = p * self.mask
        return pm / jnp.sum(pm)

    def where_delivered(self, new: jax.Array, old: jax.Array) -> jax.Array:
        """Per-client state update gate: non-delivering clients keep
        ``old`` (e.g. FedNew duals). Leading axis must be the client axis."""
        if self.mask is None:
            return new
        shape = (-1,) + (1,) * (new.ndim - 1)
        return jnp.where(self.mask.reshape(shape) > 0, new, old)


class _NullComm:
    """No-transport stand-in: every optimizer routes through this when
    ``comm=None`` so the comm-aware code path is the only code path."""

    mask = None

    def uplink(self, name, x, wire_shape=None, ef_eligible=True):
        return x

    def weights(self, p):
        return p

    def where_delivered(self, new, old):
        return new


NULL_COMM = _NullComm()


def probe_round(config: CommConfig, m: int, mask_dtype, plan: Dict[str, int],
                trace_round, *, full_cohort: bool):
    """One ``jax.eval_shape`` pass of the optimizer's round with a
    recording ``CommRound`` — nothing executes. Fills ``plan`` with the
    exact encoded bytes of every payload occurrence and returns the
    ``{payload_key: ShapeDtypeStruct}`` spec of EF-enabled lossy
    payloads (empty when error feedback is off). Shared by both round
    drivers: the sync session probes for EF shapes only, the async
    session also needs the byte plan before the first round runs.

    ``full_cohort`` selects the mask the real driver will pass
    (``None`` on the statically-full / lock-step path, a traced (m,)
    array otherwise) so the probe traces the same jaxpr structure.
    """
    spec: Dict[str, jax.ShapeDtypeStruct] = {}
    mask = None if full_cohort else jnp.zeros((m,), mask_dtype)
    ck = jax.random.PRNGKey(0)

    def probe(mask, ck):
        cr = CommRound(config, plan, mask, ck, ef_record=spec)
        return trace_round(cr)

    jax.eval_shape(probe, mask, ck)
    return spec


class CommSession:
    """Host-side per-trajectory comm state (cohorts, randomness, traces)."""

    def __init__(
        self,
        config: CommConfig,
        m: int,
        downlink_bytes: int,
        mask_dtype=jnp.float64,
    ):
        self.config = config
        self.m = m
        self.downlink_bytes = int(downlink_bytes)
        # keyed by payload occurrence (``name`` / ``name#i``): a round
        # uplinking the same name twice accumulates both, it does not
        # overwrite the first entry
        self.plan: Dict[str, int] = {}
        self.traces: "list[RoundTrace]" = []
        self.ef_memory: Dict[str, jax.Array] = {}
        self._root = jax.random.PRNGKey(config.seed)
        self._mask_dtype = mask_dtype
        # static decision: identical jit trace structure for every round
        self._always_full = (
            config.scheduler.is_full and config.channel.dropout_prob == 0.0)
        self._pending = None

    @property
    def bytes_up_per_client(self) -> int:
        """Exact encoded uplink bytes per delivering client per round,
        summed over every payload occurrence (valid after the first
        round has been traced)."""
        return int(sum(self.plan.values()))

    def init_error_feedback(self, trace_round) -> "Dict[str, jax.Array]":
        """Discover EF payload shapes and zero-init the memory pytree.

        ``trace_round(comm_round)`` must invoke the optimizer's round
        exactly as the driver will; it is traced abstractly once (via
        ``probe_round`` — nothing executes), which notes the shape/dtype
        of every EF-enabled lossy payload. Payload shapes are static, so
        one probe suffices. With no EF-eligible payloads the memory
        stays an empty pytree and the jitted round's jaxpr is unchanged.
        """
        spec = probe_round(self.config, self.m, self._mask_dtype, {},
                           trace_round, full_cohort=self._always_full)
        self.ef_memory = feedback.init_memory(spec)
        return self.ef_memory

    def ef_residual_norms(self) -> "Dict[str, float]":
        """Per-payload Frobenius norm of the current EF residuals."""
        return feedback.residual_norms(self.ef_memory)

    def begin_round(self, t: int):
        """Draw this round's cohort + channel randomness.

        Returns ``(mask, codec_key)`` to pass into the jitted round:
        ``mask`` is None on the statically-full path (bit-exactness) or a
        float (m,) delivery mask otherwise.
        """
        k = jax.random.fold_in(self._root, t)
        k_sched, k_chan, k_codec = jax.random.split(k, 3)
        scheduled = self.config.scheduler.participants(
            k_sched, t, self.m, self.config.channel)
        draw = self.config.channel.draw(k_chan, self.m)
        delivered = scheduled & ~draw.dropout
        if scheduled.any() and not delivered.any():
            # every scheduled client dropped: the server re-polls one
            # (deterministically the lowest-index scheduled client) so
            # aggregation weights stay well-defined
            delivered = np.zeros_like(scheduled)
            delivered[int(np.argmax(scheduled))] = True
        self._pending = (t, scheduled, delivered, draw)
        if self._always_full:
            return None, k_codec
        return jnp.asarray(delivered, dtype=self._mask_dtype), k_codec

    def end_round(self) -> RoundTrace:
        """Account the round just executed (reads the traced byte plan)."""
        t, scheduled, delivered, draw = self._pending
        per_client = float(self.bytes_up_per_client)
        bytes_up = per_client * delivered.astype(np.float64)
        bytes_down = float(self.downlink_bytes) * scheduled.astype(np.float64)
        sim = self.config.channel.round_time(
            draw, delivered, bytes_up, bytes_down)
        trace = RoundTrace(
            round=t,
            scheduled=scheduled,
            delivered=delivered,
            straggler=draw.straggler & delivered,
            bytes_up=bytes_up,
            bytes_down=bytes_down,
            sim_time_s=sim,
        )
        self.traces.append(trace)
        self._pending = None
        return trace
