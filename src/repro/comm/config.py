"""CommConfig + the per-round runtime objects the driver threads through.

Three layers:

  * ``CommConfig``  — user-facing description: which codec per payload
      name *and direction*, which participation scheduler, which channel
      model, seed.
  * ``CommSession`` — driver-side (host) state for one trajectory: draws
      cohorts/channel randomness per round, accumulates ``RoundTrace``s,
      and owns the *payload plan* (exact encoded bytes per payload name,
      recorded once at jit-trace time — payload shapes are static).
      Implements the ``Session`` protocol (``prepare`` / ``step`` /
      ``finalize``, see ``repro.comm.session``) for the synchronous
      lock-step clock.
  * ``CommRound``   — the view optimizers see *inside* the jitted round:
      ``uplink(name, x)`` routes a stacked per-client payload through its
      codec (so compression error perturbs the optimization),
      ``downlink(name, x)`` routes a server broadcast through its
      direction-aware codec (encoded once, received by every scheduled
      client), and ``weights(p)`` masks + renormalizes aggregation
      weights for the delivering cohort.

The wire API is symmetric: downlink payloads resolve codecs under the
``"down:"``-prefixed name (``codecs={"down:w": "bf16"}`` or the
``downlink_codecs={"w": "bf16"}`` shorthand) and are billed at their
exact encoded size per receiving client — the broadcast is no longer a
``downlink_floats * itemsize`` formula.

With ``CommConfig(error_feedback=...)`` lossy payloads additionally
carry client-side error-feedback memory (``repro.comm.feedback``): the
driver threads the memory pytree through the jitted round and ``uplink``
emits the updated memory via ``CommRound.memory_out``. Under the default
``ef_variant="ef21"`` the memory is the payload *estimate* ``g`` — the
wire carries the compressed innovation ``C(x - g)`` and the server
consumes the advanced estimate ``g + C(x - g)``; under ``"ef14"`` it is
the accumulated residual ``e`` and the wire carries the compensated
payload ``C(x + e)``.

Bit-exactness contract: with identity codecs and full participation
(no dropout), ``CommRound.uplink`` AND ``CommRound.downlink`` return
their input objects unchanged and ``weights`` returns ``p`` unchanged —
the round's jaxpr is identical to the no-comm path, so trajectories
match today's bit-for-bit, in both wire directions.

Scenario dynamics (``CommConfig(dynamics=DynamicsConfig(...))``, see
``repro.dynamics``) compose on top: churn filters the eligible id set
the scheduler samples from (departed clients' EF rows are retired
deterministically), a ``ChannelProcess`` modulates the channel per
round, a ``ThreatModel`` corrupts a seeded subset of uplinks inside
the traced round (before the codec — attackers craft their wire
payload), and a robust aggregator transforms the decoded payload
before the optimizer's weighted aggregation. Every layer defaults off,
and with ``dynamics=None`` every code path here is literally the
pre-dynamics one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import feedback
from repro.comm.channel import ChannelModel
from repro.comm.codecs import Codec, IdentityCodec, make_codec
from repro.comm.metrics import RoundTrace, Transport, transport_from_traces
from repro.comm.scheduler import Scheduler, make_scheduler
from repro.obs import NULL_TELEMETRY
from repro.obs import log as obs_log

# payload-name prefix that selects the downlink (server -> client)
# direction in codec specs and in the byte plan
DOWN = "down:"

# control-plane payloads default to lossless regardless of the default
# codec (compressing a 1-scalar guard loss or an O(1) sketch seed saves
# nothing and can poison the accept/reject logic / the shared basis)
_LOSSLESS_BY_DEFAULT = ("loss", "down:seed")

# fold_in stream offset separating downlink codec keys from the uplink
# payload counter (keeps uplink key schedules unchanged by the presence
# of downlink payloads)
_DOWNLINK_KEY_STREAM = 1 << 20

# fold_in stream offset for threat-model corruption keys (disjoint from
# both the uplink payload counter and the downlink stream, so turning a
# threat on never perturbs codec randomness)
_THREAT_KEY_STREAM = 1 << 21

# begin_variant sentinel: "no variant announced yet" (None is a valid
# round signature — the default single-trace trajectory)
_NO_VARIANT = object()


def plan_bytes(plan: "Dict[str, int]", *, down: bool) -> int:
    """Sum one direction of a payload byte plan (keys are payload
    occurrences; downlink occurrences carry the ``"down:"`` prefix)."""
    return int(sum(v for k, v in plan.items()
                   if k.startswith(DOWN) == down))


@dataclasses.dataclass
class CommConfig:
    """Transport description for one federated run.

    ``codecs`` maps payload names (``"h_sk"``, ``"sg"``, ``"grad"``,
    ``"w_local"``, ...) to codec specs; the ``"default"`` entry covers
    unnamed payloads. A bare string/Codec is shorthand for
    ``{"default": ...}``. Downlink (server -> client broadcast) payloads
    resolve under the ``"down:"``-prefixed name — ``"down:w"`` for the
    model broadcast — falling back to ``"down:default"`` and then to
    identity, NEVER to the uplink ``"default"``: turning on uplink
    compression must not silently degrade the broadcast.
    ``downlink_codecs`` is a shorthand that merges into ``codecs`` with
    the prefix applied: ``downlink_codecs="bf16"`` ==
    ``codecs["down:default"] = "bf16"``, ``downlink_codecs={"w": ...}``
    == ``codecs["down:w"] = ...`` (explicit ``down:`` entries in
    ``codecs`` win on conflict).

    ``error_feedback`` gates client-side error-feedback memory per
    payload (see ``repro.comm.feedback``): ``True`` enables it for every
    *eligible* payload with a *lossy* codec, a collection of names
    enables those payloads only, and a ``{name: bool}`` dict (optional
    ``"default"`` entry) gives full control. Lossless payloads never
    allocate memory regardless, and call sites can opt a payload out
    entirely with ``uplink(..., ef_eligible=False)`` (per-round random
    sketch bases). ``ef_variant`` picks the recursion: ``"ef21"``
    (compressed-estimate tracking, default) or ``"ef14"`` (classic
    residual compensation). ``ef_capacity`` bounds EF state in
    population mode (``run_rounds`` over a ``ClientPopulation``): dense
    memory rows are kept only for an LRU hot set of that many client
    ids, the long tail re-entering with a zero row (on-sample reset);
    default is ``min(m, 8 × cohort size)``. Dense-``m`` runs ignore it.

    ``async_mode=True`` swaps the synchronous lock-step driver for the
    event-driven async driver (``repro.comm.async_driver``): each client
    computes on the model version it last received and the server
    commits once a quorum of uploads has arrived — ``buffer_size`` (a
    FedBuff-style K) when set, else ``ceil(async_quantile * m)``.
    ``staleness`` weights stale contributions on top of participation
    weights: ``"constant"``, ``"inverse"`` (1/(1+tau)), or
    ``"poly:a"`` ((1+tau)^-a); see ``make_staleness``. ``server_lr`` is
    the FedBuff-style global server learning rate: every committed model
    delta is additionally scaled by it *after* staleness weighting
    (default 1.0 is bit-identical to not having the knob). It is an
    async-driver control — configuring it with ``async_mode=False``
    raises. With the full scheduler, no dropout, a full quorum
    (``async_quantile=1.0``, ``buffer_size`` unset) and ``server_lr=1``
    the async driver is lock-step-equivalent and reproduces the
    synchronous trajectory bit-identically.
    """

    codecs: "Dict[str, Any] | str | Codec" = "identity"
    downlink_codecs: "Dict[str, Any] | str | Codec | None" = None
    scheduler: "str | Scheduler" = "full"
    channel: ChannelModel = dataclasses.field(default_factory=ChannelModel)
    seed: int = 0
    error_feedback: "bool | str | Dict[str, bool] | tuple | frozenset" = False
    ef_variant: str = "ef21"
    ef_capacity: "int | None" = None  # EF hot-set size (population mode)
    async_mode: bool = False
    buffer_size: "int | None" = None
    async_quantile: float = 1.0
    staleness: "str | Any" = "constant"
    server_lr: float = 1.0
    dynamics: "Any | None" = None  # repro.dynamics.DynamicsConfig

    def __post_init__(self):
        if self.dynamics is not None:
            from repro.dynamics import DynamicsConfig

            if not isinstance(self.dynamics, DynamicsConfig):
                raise ValueError(
                    f"CommConfig.dynamics wants a "
                    f"repro.dynamics.DynamicsConfig, got {self.dynamics!r}")
            if self.dynamics.is_null:
                # all layers off: normalize away so every `dynamics is
                # None` fast path (and the bit-exactness gates) holds
                self.dynamics = None
        # always own a private copy: the downlink_codecs merge below must
        # never mutate a caller's dict (configs often share one spec)
        self.codecs = (dict(self.codecs) if isinstance(self.codecs, dict)
                       else {"default": self.codecs})
        if self.downlink_codecs is not None:
            shorthand = (self.downlink_codecs
                         if isinstance(self.downlink_codecs, dict)
                         else {"default": self.downlink_codecs})
            for name, spec in shorthand.items():
                self.codecs.setdefault(f"{DOWN}{name}", spec)
        if self.server_lr <= 0.0:
            raise ValueError(f"server_lr must be > 0, got {self.server_lr}")
        if self.server_lr != 1.0 and not self.async_mode:
            raise ValueError(
                "server_lr scales asynchronous commit deltas; it requires "
                "async_mode=True (the synchronous driver applies rounds "
                "verbatim)")
        if self.ef_variant not in feedback.EF_VARIANTS:
            raise ValueError(
                f"unknown ef_variant {self.ef_variant!r}; "
                f"want one of {feedback.EF_VARIANTS}")
        if self.buffer_size is not None and self.buffer_size < 1:
            raise ValueError(
                f"buffer_size must be >= 1, got {self.buffer_size}")
        if self.ef_capacity is not None and self.ef_capacity < 1:
            raise ValueError(
                f"ef_capacity must be >= 1, got {self.ef_capacity}")
        if not 0.0 < self.async_quantile <= 1.0:
            raise ValueError(
                f"async_quantile must be in (0, 1], got {self.async_quantile}")
        # validate the staleness spec eagerly (bad specs fail at config
        # time, not mid-trajectory); AsyncSession resolves it for real
        from repro.comm.async_driver import make_staleness

        make_staleness(self.staleness)
        self._codec_cache: Dict[str, Codec] = {}
        self.scheduler = make_scheduler(self.scheduler)

    def codec_for(self, payload: str) -> Codec:
        """Resolve a payload (``"name"`` uplink / ``"down:name"``
        downlink) to its codec. Each direction has its own default."""
        if payload not in self._codec_cache:
            if payload in self.codecs:
                spec = self.codecs[payload]
            elif payload in _LOSSLESS_BY_DEFAULT:
                spec = "identity"
            elif payload.startswith(DOWN):
                spec = self.codecs.get(f"{DOWN}default", "identity")
            else:
                spec = self.codecs.get("default", "identity")
            self._codec_cache[payload] = make_codec(spec)
        return self._codec_cache[payload]

    def ef_for(self, payload: str) -> bool:
        """EF is folded in only where it can matter: requested AND lossy."""
        return (feedback.ef_requested(self.error_feedback, payload)
                and not self.codec_for(payload).lossless)

    @property
    def has_error_feedback(self) -> bool:
        return feedback.any_ef_requested(self.error_feedback)

    def channel_at(self, t: int):
        """The channel as seen at round ``t``: the static model itself
        when no ``ChannelProcess`` is configured (the literal same
        object — zero change to the default path), else a per-round
        modulated view with the same method signatures."""
        dyn = self.dynamics
        if dyn is None or dyn.channel is None:
            return self.channel
        return dyn.channel.at(self.channel, t)


def apply_churn(session, t: int) -> "np.ndarray | None":
    """Shared churn bookkeeping for every comm session at round/version
    ``t``: returns the eligible id array (or ``None`` without churn),
    retires newly-departed clients' EF rows via the session's
    ``_retire_ef`` hook, and publishes the ``active_population`` gauge.

    Idempotent within one ``t`` (the async driver may dispatch the same
    version more than once). If churn empties the population entirely,
    the full id set is restored with a one-time warning — a trajectory
    cannot run over zero clients.
    """
    dyn = session.config.dynamics
    if dyn is None or dyn.churn is None:
        return None
    elig = dyn.churn.eligible_mask(t, session.m)
    if not elig.any():
        if not getattr(session, "_churn_warned", False):
            session._churn_warned = True
            obs_log.warn_with_context(
                "churn left zero eligible clients; treating the full "
                "population as eligible so the trajectory can proceed",
                round=t, m=session.m)
        elig = np.ones(session.m, dtype=bool)
    prev = session._elig_prev
    session._elig_prev = elig
    if prev is not None:
        departed = np.nonzero(prev & ~elig)[0]
        if departed.size:
            session._retire_ef(departed)
            session.obs.metrics.counter("clients_departed").inc(
                float(departed.size))
    if session.obs.enabled:
        session.obs.metrics.gauge("active_population").set(float(elig.sum()))
    return np.nonzero(elig)[0].astype(np.int64)


class CommRound:
    """In-jit view of one round's transport. Constructed inside the
    traced round function; ``mask``/``key``/``memory`` are traced
    arrays, the codec table and byte plan are static Python closed over
    by the trace.

    ``memory`` is the EF21 residual pytree threaded through the jitted
    round by the driver (``{payload_key: (m, ...)}``); ``uplink`` folds
    the matching residual into EF-enabled lossy payloads and writes the
    updated residual to ``memory_out``. ``ef_record`` switches the
    object into the shape-discovery mode ``CommSession.
    init_error_feedback`` uses under ``jax.eval_shape``.
    """

    def __init__(
        self,
        config: CommConfig,
        plan: Dict[str, int],
        mask: "jax.Array | None",
        key: "jax.Array | None",
        memory: "Dict[str, jax.Array] | None" = None,
        ef_record: "Dict[str, jax.ShapeDtypeStruct] | None" = None,
    ):
        self._config = config
        self._plan = plan
        # with a ThreatModel active the sessions pack the per-client
        # attacker indicator next to the delivery mask as a 2-tuple
        # (both traced; jit flattens the pytree) — unpack it here so the
        # rest of the round sees the plain delivery mask
        self.attackers = None
        if isinstance(mask, tuple):
            mask, self.attackers = mask
        self.mask = mask
        self._key = key
        self._n_payloads = 0
        self._n_down = 0
        self._occurrences: Dict[str, int] = {}
        self._ef_record = ef_record
        # memory_out starts as a same-structure copy so payloads a round
        # happens to skip still thread their residual through unchanged
        self.memory_out: Dict[str, jax.Array] = dict(memory or {})
        # traced robust-aggregation counters (uploads_clipped, ...);
        # empty without dynamics — zero extra jaxpr outputs
        self.stats_out: Dict[str, jax.Array] = {}

    def _payload_key(self, name: str) -> str:
        """Stable per-round key for the i-th uplink of ``name`` — a round
        calling ``uplink("g", ...)`` twice bills (and remembers) both."""
        occ = self._occurrences.get(name, 0)
        self._occurrences[name] = occ + 1
        return name if occ == 0 else f"{name}#{occ}"

    def uplink(self, name: str, x: jax.Array,
               wire_shape: "tuple | None" = None,
               ef_eligible: bool = True,
               ef_reset=None) -> jax.Array:
        """Route a stacked per-client payload ``x: (m, ...)`` through its
        codec's simulated encode→decode; records exact encoded bytes.

        ``wire_shape`` overrides the shape billed for payloads whose
        algorithm already defines a native wire format (e.g. FedNL
        transmits a rank-1 ``(M+1,)`` eigenpair, not the materialized
        (M, M) difference); the codec still prices that shape, so codec
        compression stays reflected in the byte accounting.

        ``ef_eligible=False`` declares that this payload's coordinate
        system is redrawn every round (two-sided sketches): cross-round
        error-feedback memory would mix incompatible bases, so EF is
        skipped for it even when ``CommConfig.error_feedback`` asks.

        ``ef_reset`` (a traced 0/1 scalar, or None) zeroes the EF memory
        BEFORE compensating: rotating sketch schedules pass
        ``SketchPolicy.ef_reset(t)`` so the residual accumulated in the
        previous epoch's basis is discarded the round the basis
        rotates, instead of being injected into the new basis. The
        reset is a pure function of the round index and the declared
        schedule, so the server's estimate resets in lock-step."""
        codec = self._config.codec_for(name)
        pkey = self._payload_key(name)
        self._plan[pkey] = codec.nbytes(
            tuple(wire_shape) if wire_shape is not None
            else tuple(x.shape[1:]), x.dtype)
        self._n_payloads += 1
        dyn = self._config.dynamics
        threat = dyn.threat if dyn is not None else None
        robust = dyn.robust if dyn is not None else None
        if (threat is not None and self.attackers is not None
                and threat.applies(name)):
            # corruption happens BEFORE the codec: the attacker crafts
            # its wire payload, so compression and EF operate on the
            # corrupted upload exactly as on an honest one. The key
            # stream is disjoint from codec/downlink streams.
            x = threat.corrupt(
                jax.random.fold_in(
                    self._key, _THREAT_KEY_STREAM + self._n_payloads),
                x, self.attackers)
        if isinstance(codec, IdentityCodec):
            if robust is None:
                return x  # same object: zero jaxpr change
            decoded = x
        else:
            decoded = self._roundtrip(codec, name, pkey, x, ef_eligible,
                                      ef_reset)
        if robust is not None:
            # server-side defense on what was received (post-decode);
            # EF memory above tracks the *wire* payload — the client
            # cannot observe the server's clipping/trimming
            decoded = robust(decoded, self.mask, self.stats_out)
        return decoded

    def _roundtrip(self, codec, name, pkey, x, ef_eligible, ef_reset):
        """Simulated encode->decode of one lossy payload (+ EF memory)."""
        ef = ef_eligible and self._config.ef_for(name)
        if ef and self._ef_record is not None:
            self._ef_record[pkey] = jax.ShapeDtypeStruct(x.shape, x.dtype)
        if codec.deterministic:
            keys = jnp.zeros((x.shape[0], 2), jnp.uint32)  # unused by codec
        else:
            base = jax.random.fold_in(self._key, self._n_payloads)
            keys = jax.random.split(base, x.shape[0])
        if ef and pkey in self.memory_out:
            mem = self.memory_out[pkey]
            if ef_reset is not None:
                # basis rotated: the residual's coordinate system is
                # stale — compensate from a zeroed memory this round
                mem = mem * (1 - jnp.asarray(ef_reset, mem.dtype))
            decoded, mem_new = feedback.compensate(
                codec, keys, x, mem,
                variant=self._config.ef_variant)
            # dropped clients never ran the round: freeze their memory
            # rows with the same gate that protects optimizer state.
            # The frozen fallback is the post-reset ``mem``: the basis
            # rotation is schedule knowledge, not computation — a client
            # absent on the boundary round must still drop its old-epoch
            # residual, or it would compensate into the new basis later.
            self.memory_out[pkey] = self.where_delivered(mem_new, mem)
            return decoded
        return jax.vmap(codec.roundtrip)(keys, x)

    def downlink(self, name: str, x: jax.Array,
                 wire_shape: "tuple | None" = None) -> jax.Array:
        """Route a server->client broadcast through its downlink codec's
        simulated encode->decode; records exact encoded bytes.

        The server encodes ONCE and every scheduled client decodes the
        same bytes, so ``x`` is the unstacked server-side array (no
        client axis) and the plan bills ``nbytes`` per receiving client
        (each client pulls the broadcast over its own link). Codecs
        resolve under ``"down:<name>"`` — see ``CommConfig.codecs`` —
        and the identity codec returns ``x`` unchanged, preserving the
        bit-exactness contract in the downlink direction too.

        No error feedback applies: EF memory is a per-client *uplink*
        construct; a broadcast has one sender whose compression error is
        common knowledge.
        """
        codec = self._config.codec_for(f"{DOWN}{name}")
        pkey = self._payload_key(f"{DOWN}{name}")
        self._plan[pkey] = codec.nbytes(
            tuple(wire_shape) if wire_shape is not None
            else tuple(x.shape), x.dtype)
        self._n_down += 1
        if isinstance(codec, IdentityCodec):
            return x  # same object: zero jaxpr change
        if codec.deterministic:
            key = jnp.zeros((2,), jnp.uint32)  # unused by codec
        else:
            key = jax.random.fold_in(
                self._key, _DOWNLINK_KEY_STREAM + self._n_down)
        return codec.roundtrip(key, x)

    def weights(self, p: jax.Array) -> jax.Array:
        """Aggregation weights restricted to the delivering cohort."""
        if self.mask is None:
            return p
        pm = p * self.mask
        return pm / jnp.sum(pm)

    def where_delivered(self, new: jax.Array, old: jax.Array) -> jax.Array:
        """Per-client state update gate: non-delivering clients keep
        ``old`` (e.g. FedNew duals). Leading axis must be the client axis."""
        if self.mask is None:
            return new
        shape = (-1,) + (1,) * (new.ndim - 1)
        return jnp.where(self.mask.reshape(shape) > 0, new, old)


class _NullComm:
    """No-transport stand-in: every optimizer routes through this when
    ``comm=None`` so the comm-aware code path is the only code path."""

    mask = None

    def uplink(self, name, x, wire_shape=None, ef_eligible=True,
               ef_reset=None):
        return x

    def downlink(self, name, x, wire_shape=None):
        return x

    def weights(self, p):
        return p

    def where_delivered(self, new, old):
        return new

    @property
    def memory_out(self):
        return {}

    @property
    def stats_out(self):
        return {}


NULL_COMM = _NullComm()


def probe_round(config: CommConfig, m: int, mask_dtype, plan: Dict[str, int],
                trace_round, *, full_cohort: bool):
    """One ``jax.eval_shape`` pass of the optimizer's round with a
    recording ``CommRound`` — nothing executes. Fills ``plan`` with the
    exact encoded bytes of every payload occurrence and returns the
    ``{payload_key: ShapeDtypeStruct}`` spec of EF-enabled lossy
    payloads (empty when error feedback is off). Shared by both round
    drivers: the sync session probes for EF shapes only, the async
    session also needs the byte plan before the first round runs.

    ``full_cohort`` selects the mask the real driver will pass
    (``None`` on the statically-full / lock-step path, a traced (m,)
    array otherwise) so the probe traces the same jaxpr structure.
    """
    spec: Dict[str, jax.ShapeDtypeStruct] = {}
    mask = None if full_cohort else jnp.zeros((m,), mask_dtype)
    if config.dynamics is not None and config.dynamics.threat is not None:
        # with a threat the sessions pack (delivery, attackers); probe
        # the same pytree structure
        mask = (mask, jnp.zeros((m,), mask_dtype))
    ck = jax.random.PRNGKey(0)  # noqa: RA001 — shape-only eval_shape probe; the key value never executes

    def probe(mask, ck):
        cr = CommRound(config, plan, mask, ck, ef_record=spec)
        return trace_round(cr)

    jax.eval_shape(probe, mask, ck)
    return spec


class CommSession:
    """Host-side per-trajectory comm state (cohorts, randomness, traces).

    Implements the ``Session`` driver protocol (``repro.comm.session``)
    for the synchronous lock-step clock: ``prepare`` runs the EF shape
    probe when error feedback is on, ``step`` draws a cohort, executes
    the jitted round, and accounts it, ``finalize`` folds the traces
    into the ``Transport`` axes ``History`` carries.
    """

    def __init__(
        self,
        config: CommConfig,
        m: int,
        mask_dtype=jnp.float64,  # noqa: RA005 — caller passes the problem dtype; the default only names the widest mask the goldens were recorded with
        keys: "jax.Array | None" = None,
        state0: Any = None,
        obs=NULL_TELEMETRY,
    ):
        self.config = config
        self.m = m
        self.obs = obs
        # keyed by payload occurrence (``name`` / ``name#i``, downlink
        # occurrences under ``down:name``): a round uplinking the same
        # name twice accumulates both, it does not overwrite the first
        # entry. The dict OBJECT is stable for the whole trajectory
        # (traced rounds close over it); ``begin_variant`` swaps its
        # CONTENTS when an adaptive sketch policy changes payload sizes,
        # so per-round accounting follows the active variant.
        self.plan: Dict[str, int] = {}
        self._plans: "Dict[Any, Dict[str, int]]" = {}
        self._variant: Any = _NO_VARIANT
        self.traces: "list[RoundTrace]" = []
        self.ef_memory: Dict[str, jax.Array] = {}
        self.keys = keys
        self._state = state0
        self._t = 0
        self._root = jax.random.PRNGKey(config.seed)  # noqa: RA001 — the transport root stream; repro.comm cannot import repro.core.base (cycle)
        self._mask_dtype = mask_dtype
        # static decision: identical jit trace structure for every round.
        # Churn and correlated outages invalidate the statically-full
        # path — the delivery mask must then be traced every round.
        dyn = config.dynamics
        self._always_full = (
            config.scheduler.is_full and config.channel.dropout_prob == 0.0
            and (dyn is None or not dyn.forces_mask))
        # dynamics bookkeeping (all inert when dynamics is None)
        self._elig_prev = None
        self._attacker_arr = None
        self.robust_stats: Dict[str, float] = {}
        # probe geometry: subclasses with a cohort axis narrower than m
        # (population mode) override these so abstract probes trace the
        # same shapes the real rounds will
        self._probe_m = m
        self._pending = None

    @property
    def _probe_full(self) -> bool:
        return self._always_full

    @property
    def bytes_up_per_client(self) -> int:
        """Exact encoded uplink bytes per delivering client per round,
        summed over every payload occurrence (valid after the first
        round has been traced)."""
        return plan_bytes(self.plan, down=False)

    @property
    def bytes_down_per_client(self) -> int:
        """Exact encoded broadcast bytes per scheduled client per round
        (``down:*`` plan entries; valid after the first trace)."""
        return plan_bytes(self.plan, down=True)

    # -- Session protocol ----------------------------------------------------
    def prepare(self, trace_round) -> None:
        """EF shape discovery (one abstract probe, only when requested —
        without EF the byte plan fills during the first real trace and
        the round's jaxpr stays untouched)."""
        if self.config.has_error_feedback:
            self.init_error_feedback(trace_round)

    def begin_variant(self, sig, trace_round) -> None:
        """Install the payload byte plan of the round variant about to
        run. The first variant keeps the lazy pre-policy behavior (the
        plan fills during the first real jit trace — no extra abstract
        interpretation on the common single-variant path); when a
        SECOND variant appears (adaptive-k changed payload sizes), the
        outgoing plan is snapshotted and the new variant is probed once
        (``jax.eval_shape`` — nothing executes) and cached, so
        ``end_round`` bills round-varying sizes truthfully even when a
        jitted trace is reused."""
        if self._variant is _NO_VARIANT:
            self._variant = sig
            return
        if sig == self._variant:
            return
        self._plans[self._variant] = dict(self.plan)
        plan = self._plans.get(sig)
        if plan is None:
            plan = {}
            probe_round(self.config, self._probe_m, self._mask_dtype, plan,
                        trace_round, full_cohort=self._probe_full)
            self._plans[sig] = plan
        self.plan.clear()
        self.plan.update(plan)
        self._variant = sig

    def comm_round(self, memory, mask, codec_key) -> CommRound:
        """The in-jit transport view ``run_rounds``'s round builder
        hands to the optimizer (called at trace time)."""
        return CommRound(self.config, self.plan, mask, codec_key,
                         memory=memory)

    def step(self, round_fn) -> Any:
        """One lock-step round: draw cohort, execute, account."""
        t = self._t
        mask, ck = self.begin_round(t)
        self._state, self.ef_memory, stats = round_fn(
            self._state, self.ef_memory, self.keys[t], mask, ck)
        self._consume_stats(stats)
        self.end_round()
        self._t += 1
        return self._state

    def _consume_stats(self, stats: Dict[str, Any]) -> None:
        """Drain the round's traced robust-aggregation counters into
        telemetry (empty dict — the no-dynamics case — is free)."""
        for stat_name, val in stats.items():
            v = float(val)
            self.robust_stats[stat_name] = \
                self.robust_stats.get(stat_name, 0.0) + v
            self.obs.metrics.counter(stat_name).inc(v)

    def _retire_ef(self, departed: np.ndarray) -> None:
        """Zero newly-departed clients' EF memory rows (dense layout)."""
        if self.ef_memory:
            z = jnp.asarray(departed)
            self.ef_memory = {k: v.at[z].set(0)
                              for k, v in self.ef_memory.items()}

    def _pack_threat(self, mask, ids=None):
        """Bundle the attacker indicator next to the delivery mask when
        a threat is active (``ids`` selects the cohort rows; dense
        sessions pass None and cache the (m,) indicator)."""
        dyn = self.config.dynamics
        if dyn is None or dyn.threat is None:
            return mask
        if ids is None:
            if self._attacker_arr is None:
                self._attacker_arr = jnp.asarray(
                    dyn.threat.attacker_mask(np.arange(self.m)),
                    dtype=self._mask_dtype)
            return (mask, self._attacker_arr)
        return (mask, jnp.asarray(dyn.threat.attacker_mask(ids),
                                  dtype=self._mask_dtype))

    def finalize(self) -> Transport:
        if self.obs.enabled:
            # final EF memory footprint (bytes held across all clients)
            ef_bytes = sum(
                int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
                for a in jax.tree_util.tree_leaves(self.ef_memory))
            self.obs.metrics.gauge("ef_memory_bytes").set(float(ef_bytes))
        return transport_from_traces(
            self.traces, ef_residuals=self.ef_residual_norms())

    def init_error_feedback(self, trace_round) -> "Dict[str, jax.Array]":
        """Discover EF payload shapes and zero-init the memory pytree.

        ``trace_round(comm_round)`` must invoke the optimizer's round
        exactly as the driver will; it is traced abstractly once (via
        ``probe_round`` — nothing executes), which notes the shape/dtype
        of every EF-enabled lossy payload. Payload shapes are static, so
        one probe suffices. With no EF-eligible payloads the memory
        stays an empty pytree and the jitted round's jaxpr is unchanged.
        """
        spec = probe_round(self.config, self._probe_m, self._mask_dtype, {},
                           trace_round, full_cohort=self._probe_full)
        self.ef_memory = feedback.init_memory(spec)
        return self.ef_memory

    def ef_residual_norms(self) -> "Dict[str, float]":
        """Per-payload Frobenius norm of the current EF residuals."""
        return feedback.residual_norms(self.ef_memory)

    def begin_round(self, t: int):
        """Draw this round's cohort + channel randomness.

        Returns ``(mask, codec_key)`` to pass into the jitted round:
        ``mask`` is None on the statically-full path (bit-exactness) or a
        float (m,) delivery mask otherwise.
        """
        k = jax.random.fold_in(self._root, t)
        k_sched, k_chan, k_codec = jax.random.split(k, 3)
        eligible = apply_churn(self, t)
        chan = self.config.channel_at(t)
        scheduled = self.config.scheduler.participants(
            k_sched, t, self.m, chan, eligible=eligible)
        draw = chan.draw(k_chan, self.m)
        delivered = scheduled & ~draw.dropout
        if scheduled.any() and not delivered.any():
            # every scheduled client dropped: the server re-polls one
            # (deterministically the lowest-index scheduled client) so
            # aggregation weights stay well-defined
            delivered = np.zeros_like(scheduled)
            delivered[int(np.argmax(scheduled))] = True
        self._pending = (t, scheduled, delivered, draw)
        if self._always_full:
            return self._pack_threat(None), k_codec
        mask = jnp.asarray(delivered, dtype=self._mask_dtype)
        return self._pack_threat(mask), k_codec

    def end_round(self) -> RoundTrace:
        """Account the round just executed (reads the traced byte plan —
        both directions carry real encoded sizes, downlink included)."""
        t, scheduled, delivered, draw = self._pending
        per_client = float(self.bytes_up_per_client)
        bytes_up = per_client * delivered.astype(np.float64)
        bytes_down = (float(self.bytes_down_per_client)
                      * scheduled.astype(np.float64))
        sim = self.config.channel_at(t).round_time(
            draw, delivered, bytes_up, bytes_down)
        trace = RoundTrace(
            round=t,
            scheduled=scheduled,
            delivered=delivered,
            straggler=draw.straggler & delivered,
            bytes_up=bytes_up,
            bytes_down=bytes_down,
            sim_time_s=sim,
        )
        self.traces.append(trace)
        self._pending = None
        self._count_corrupted(delivered, None)
        if self.obs.enabled:
            self._observe(trace)
        return trace

    def _count_corrupted(self, delivered: np.ndarray,
                         ids: "np.ndarray | None") -> None:
        """Host-side tally of corrupted uploads that reached the server
        this round (attacker AND delivered client-rounds)."""
        dyn = self.config.dynamics
        if dyn is None or dyn.threat is None:
            return
        att = dyn.threat.attacker_mask(
            np.arange(self.m) if ids is None else ids)
        n_bad = float((att & delivered).sum())
        self.robust_stats["uploads_corrupted"] = \
            self.robust_stats.get("uploads_corrupted", 0.0) + n_bad
        self.obs.metrics.counter("uploads_corrupted").inc(n_bad)

    def _observe(self, trace: RoundTrace) -> None:
        """Populate per-round telemetry (host-side, after the round ran)."""
        mt = self.obs.metrics
        up = float(trace.bytes_up.sum())
        down = float(trace.bytes_down.sum())
        mt.counter("bytes_up").inc(up)
        mt.counter("bytes_down").inc(down)
        mt.counter("scheduled_client_rounds").inc(
            float(trace.scheduled.sum()))
        mt.counter("delivered_client_rounds").inc(
            float(trace.delivered.sum()))
        mt.counter("dropped_client_rounds").inc(
            float((trace.scheduled & ~trace.delivered).sum()))
        mt.counter("straggler_client_rounds").inc(
            float(trace.straggler.sum()))
        self.obs.annotate(
            bytes_up=up, bytes_down=down,
            delivered=int(trace.delivered.sum()),
            dropped=int((trace.scheduled & ~trace.delivered).sum()),
            sim_time_s=float(trace.sim_time_s))


class PopulationCommSession(CommSession):
    """Synchronous driver over a lazy ``ClientPopulation``.

    Per round: sample the cohort's client *ids* from the population
    (``Scheduler.sample_ids`` — same draw, and therefore the same
    cohort, as the dense ``participants`` mask under one seed),
    materialize exactly those ``(c, n_shard, M)`` shards, draw the
    cohort's channel coins *per client id*, gather the cohort's EF rows
    from the bounded hot-set store, run the one jitted cohort round, and
    scatter the updated rows back. Nothing ``(m,)``-shaped is ever
    allocated except O(m) host-side metadata (shard sizes, scheduler
    draws), so m ~ 10⁵ populations with q ~ 10⁻³ participation run in
    cohort-bounded memory.

    The round function signature gains the cohort problem as its first
    (traced pytree) argument; since every cohort of one scheduler has
    the same static size ``c`` and pad width, round 2..T reuse round 1's
    jaxpr — cohort membership changes never retrace.
    """

    def __init__(self, config: CommConfig, population, *,
                 mask_dtype=jnp.float64, keys=None, state0=None,  # noqa: RA005 — caller passes the problem dtype; default matches the recorded goldens
                 obs=NULL_TELEMETRY, client_mesh=None):
        super().__init__(config, population.m, mask_dtype=mask_dtype,
                         keys=keys, state0=state0, obs=obs)
        self.population = population
        self.cohort_size = config.scheduler.cohort_size(population.m)
        self.client_mesh = client_mesh
        self.ef_store: "feedback.BoundedMemory | None" = None
        # probes must trace cohort-shaped rounds, not (m,) ones
        self._probe_m = self.cohort_size
        self._pending_ids = None
        self._pending_real = None

    @property
    def _probe_full(self) -> bool:
        # every cohort member is scheduled by construction; the mask only
        # carries dropout, so no-dropout channels keep the mask=None
        # (bit-exact identity) path even under q < 1 sampling. Churn
        # (cohorts padded below the static size) and outages force it.
        dyn = self.config.dynamics
        return (self.config.channel.dropout_prob == 0.0
                and (dyn is None or not dyn.forces_mask))

    def _materialize(self, ids):
        cohort = self.population.materialize(ids)
        if self.client_mesh is not None:
            from repro.sharding.rules import shard_cohort

            cohort = shard_cohort(self.client_mesh, cohort)
        return cohort

    def init_error_feedback(self, trace_round):
        spec = probe_round(self.config, self._probe_m, self._mask_dtype, {},
                           trace_round, full_cohort=self._probe_full)
        capacity = self.config.ef_capacity
        if capacity is None:
            capacity = min(self.m, 8 * self.cohort_size)
        capacity = max(capacity, self.cohort_size)
        self.ef_store = feedback.BoundedMemory(spec, capacity)
        self.ef_memory = {}
        return self.ef_memory

    def begin_round(self, t: int):
        """Sample cohort ids + per-id channel coins for round ``t``.

        The key schedule is byte-identical to the dense driver's
        (``fold_in(root, t)`` split into sched/chan/codec streams), so a
        population run and a dense run of the same seed schedule the
        same cohorts, and so does the async driver's version stream.
        """
        k = jax.random.fold_in(self._root, t)
        k_sched, k_chan, k_codec = jax.random.split(k, 3)
        eligible = apply_churn(self, t)
        chan = self.config.channel_at(t)
        ids = self.config.scheduler.sample_ids(
            k_sched, t, self.m, chan, eligible=eligible)
        n_real = len(ids)
        if n_real < self.cohort_size:
            # churn shrank the eligible set below the static cohort
            # size: pad with the first sampled id under a zero delivery
            # mask so every round keeps the one traced jaxpr
            ids = np.concatenate([
                ids, np.full(self.cohort_size - n_real, ids[0],
                             dtype=np.int64)])
        draw = chan.draw_for(k_chan, ids)
        delivered = ~draw.dropout
        delivered[n_real:] = False
        if not delivered.any():
            # every sampled client dropped: re-poll the lowest id so
            # aggregation weights stay well-defined (dense-path rule)
            delivered = np.zeros_like(delivered)
            delivered[0] = True
        scheduled = np.ones_like(delivered)
        scheduled[n_real:] = False
        self._pending = (t, scheduled, delivered, draw)
        self._pending_ids = ids
        self._pending_real = n_real
        if self._probe_full:
            return ids, self._pack_threat(None, ids), k_codec
        mask = jnp.asarray(delivered, dtype=self._mask_dtype)
        return ids, self._pack_threat(mask, ids), k_codec

    def step(self, round_fn) -> Any:
        """One cohort round: sample ids, materialize, execute, account.

        ``round_fn(cohort, state, memory, key, mask, codec_key)`` — the
        population-mode round signature (cohort problem is a traced
        pytree argument, so one jaxpr serves every cohort).
        """
        t = self._t
        ids, mask, ck = self.begin_round(t)
        cohort = self._materialize(ids)
        memory = self.ef_store.gather(ids) if self.ef_store else {}
        self._state, mem_out, stats = round_fn(
            cohort, self._state, memory, self.keys[t], mask, ck)
        self._consume_stats(stats)
        if self.ef_store is not None:
            # real ids only: churn-padded rows duplicate ids[0] and must
            # not race its real row on scatter
            self.ef_store.scatter(ids[:self._pending_real], mem_out)
        self.end_round()
        self._t += 1
        return self._state

    def _retire_ef(self, departed: np.ndarray) -> None:
        """Departed clients leave the EF hot set (their slot is freed
        and zeroed — deterministic retirement, not LRU luck)."""
        if self.ef_store is not None:
            self.ef_store.retire(departed)

    def end_round(self) -> RoundTrace:
        t, scheduled, delivered, draw = self._pending
        ids = self._pending_ids
        per_client = float(self.bytes_up_per_client)
        bytes_up = per_client * delivered.astype(np.float64)
        bytes_down = (float(self.bytes_down_per_client)
                      * scheduled.astype(np.float64))
        sim = self.config.channel_at(t).round_time_for(
            ids, self.m, draw, delivered, bytes_up, bytes_down)
        trace = RoundTrace(
            round=t,
            scheduled=scheduled,
            delivered=delivered,
            straggler=draw.straggler & delivered,
            bytes_up=bytes_up,
            bytes_down=bytes_down,
            sim_time_s=sim,
            ids=ids,
            population=self.m,
        )
        self.traces.append(trace)
        self._pending = None
        self._pending_ids = None
        self._pending_real = None
        self._count_corrupted(delivered, ids)
        if self.obs.enabled:
            self._observe(trace)
        return trace

    def finalize(self) -> Transport:
        if self.obs.enabled:
            ef_bytes = self.ef_store.nbytes if self.ef_store else 0
            self.obs.metrics.gauge("ef_memory_bytes").set(float(ef_bytes))
            if self.ef_store is not None:
                self.obs.metrics.gauge("ef_hot_set_evictions").set(
                    float(self.ef_store.evictions))
        return transport_from_traces(
            self.traces, ef_residuals=self.ef_residual_norms())

    def ef_residual_norms(self) -> "Dict[str, float]":
        if self.ef_store is not None:
            return self.ef_store.residual_norms()
        return {}
