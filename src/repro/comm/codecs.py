"""Uplink payload codecs: simulated encode→decode with exact byte counts.

A codec models what a client actually puts on the wire. In simulation we
never materialize the encoded buffer — we need (a) the *decoded* payload
the server would reconstruct (so compression error genuinely perturbs
the optimization, as in FedNL-style error analyses) and (b) the *exact*
number of encoded bytes (so loss-vs-bytes curves are byte-accurate, not
float-count estimates).

Every codec therefore implements

  * ``roundtrip(key, x) -> x_hat``  — pure, jit/vmap-compatible simulated
      encode→decode for ONE client's payload ``x`` (shapes static);
  * ``nbytes(shape, dtype) -> int`` — exact encoded size in bytes,
      computed statically in Python from the payload spec.

Codecs compose: ``TopKCodec``/``SymPackCodec`` wrap an inner codec that
handles their kept values. ``make_codec`` parses ``"+"``-chained specs,
e.g. ``"sympack+qint8"`` (pack the upper triangle of a symmetric k×k
matrix, then int8-quantize the packed vector) or ``"topk0.05+fp16"``.
"""
from __future__ import annotations

import dataclasses
import math
import re

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

_INT32_BYTES = 4  # index width for sparse formats
_SCALE_BYTES = 4  # one fp32 scale per quantized tensor


def _size(shape) -> int:
    return int(math.prod(shape)) if shape else 1


class Codec:
    """Base codec. ``deterministic`` codecs ignore the PRNG key.
    ``lossless`` codecs decode bit-exactly — error feedback skips them
    (their residual is identically zero)."""

    name: str = "codec"
    deterministic: bool = True
    lossless: bool = False

    def roundtrip(self, key: jax.Array, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def nbytes(self, shape: tuple[int, ...], dtype) -> int:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"


class IdentityCodec(Codec):
    """Lossless passthrough — bytes are the raw payload size.

    ``roundtrip`` returns its input object unchanged, so routing a payload
    through the identity codec adds *nothing* to the jaxpr: the comm path
    with this codec is bit-identical to no comm path at all.
    """

    name = "identity"
    lossless = True

    def roundtrip(self, key, x):
        return x

    def nbytes(self, shape, dtype):
        return _size(shape) * jnp.dtype(dtype).itemsize


@dataclasses.dataclass(frozen=True)
class CastCodec(Codec):
    """Lossy dtype cast on the wire (fp16 / bf16), decoded back up."""

    wire_dtype: str = "float16"
    deterministic = True

    @property
    def name(self):
        return {"float16": "fp16", "bfloat16": "bf16"}.get(
            self.wire_dtype, self.wire_dtype)

    def roundtrip(self, key, x):
        return x.astype(self.wire_dtype).astype(x.dtype)

    def nbytes(self, shape, dtype):
        return _size(shape) * jnp.dtype(self.wire_dtype).itemsize


class QInt8Codec(Codec):
    """Per-tensor symmetric int8 quantization with stochastic rounding.

    scale = max|x| / 127;  q = floor(x/scale + u), u ~ U[0,1)  (unbiased:
    E[q * scale] = x).  Wire format: int8 payload + one fp32 scale.
    """

    name = "qint8"
    deterministic = False

    def roundtrip(self, key, x):
        # the PRNG draw stays here (identical random bits for every
        # kernel impl); the fused quantize body dispatches through
        # repro.kernels.ops (ref on CPU, Pallas on TPU)
        u = jax.random.uniform(key, x.shape, x.dtype)
        return kops.qint8_roundtrip(x, u)

    def nbytes(self, shape, dtype):
        return _size(shape) * 1 + _SCALE_BYTES


@dataclasses.dataclass(frozen=True)
class TopKCodec(Codec):
    """Magnitude top-k sparsification: keep a fraction (or count) of
    entries, transmitted as (int32 index, value) pairs; values optionally
    re-encoded by ``inner``."""

    fraction: float | None = None
    k: int | None = None
    inner: Codec = dataclasses.field(default_factory=IdentityCodec)

    def __post_init__(self):
        if (self.fraction is None) == (self.k is None):
            raise ValueError(
                "TopKCodec needs exactly one of fraction= or k=, got "
                f"fraction={self.fraction} k={self.k}")
        if self.fraction is not None and not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"top-k fraction must be in (0, 1], "
                             f"got {self.fraction}")

    @property
    def name(self):
        tag = f"topk{self.fraction}" if self.fraction is not None else f"topk@{self.k}"
        return tag if isinstance(self.inner, IdentityCodec) else f"{tag}+{self.inner.name}"

    @property
    def deterministic(self):
        return self.inner.deterministic

    @property
    def lossless(self):
        # keeping every entry degenerates to the inner codec
        return self.fraction == 1.0 and self.inner.lossless

    def _kept(self, n: int) -> int:
        if self.k is not None:
            return max(1, min(int(self.k), n))
        return max(1, min(n, int(math.ceil(float(self.fraction) * n))))

    def roundtrip(self, key, x):
        kept = self._kept(math.prod(x.shape) if x.shape else 1)
        # fused select+pack body via repro.kernels.ops (exactly `kept`
        # entries survive; ties resolved as jax.lax.top_k)
        sparse = kops.topk_mask(x, kept)
        return self.inner.roundtrip(key, sparse)

    def nbytes(self, shape, dtype):
        kept = self._kept(_size(shape))
        return kept * _INT32_BYTES + self.inner.nbytes((kept,), dtype)


@dataclasses.dataclass(frozen=True)
class SymPackCodec(Codec):
    """Symmetric-matrix packing: transmit only the upper triangle of a
    square symmetric payload (k(k+1)/2 entries instead of k²) — an
    immediate ~2× on FLeNS's dominant ``k×k`` sketched-Hessian uplink.
    The packed vector is re-encoded by ``inner``; decode mirrors it back
    to a full symmetric matrix."""

    inner: Codec = dataclasses.field(default_factory=IdentityCodec)

    @property
    def name(self):
        return ("sympack" if isinstance(self.inner, IdentityCodec)
                else f"sympack+{self.inner.name}")

    @property
    def deterministic(self):
        return self.inner.deterministic

    @property
    def lossless(self):
        return self.inner.lossless

    def roundtrip(self, key, x):
        if x.ndim != 2 or x.shape[0] != x.shape[1]:
            raise ValueError(
                f"sympack requires a square matrix payload, got {x.shape}")
        k = x.shape[0]
        sym = 0.5 * (x + x.T)  # encode-side symmetrization (cheap, exact
        # for already-symmetric payloads like the sketched Hessian)
        iu = jnp.triu_indices(k)
        packed = self.inner.roundtrip(key, sym[iu])
        out = jnp.zeros_like(sym).at[iu].set(packed)
        diag = jnp.diagonal(out)
        return out + out.T - jnp.diag(diag)

    def nbytes(self, shape, dtype):
        if len(shape) != 2 or shape[0] != shape[1]:
            raise ValueError(f"sympack requires a square payload, got {shape}")
        k = shape[0]
        return self.inner.nbytes((k * (k + 1) // 2,), dtype)


# ---------------------------------------------------------------------------
# spec parser
# ---------------------------------------------------------------------------

_TOPK_RE = re.compile(r"^topk(@)?([0-9.]+)$")

CODEC_SPECS = ("identity", "fp16", "bf16", "qint8", "topk<frac>",
               "topk@<k>", "sympack")


def make_codec(spec: "str | Codec") -> Codec:
    """Parse ``"+"``-chained codec specs, outermost stage first.

    ``"identity" | "fp16" | "bf16" | "qint8" | "topk0.1" | "topk@64" |
    "sympack"`` — wrappers (``topk*``, ``sympack``) apply every stage to
    their right to the values they keep: ``"sympack+qint8"`` packs the
    triangle then int8-quantizes it.
    """
    if isinstance(spec, Codec):
        return spec
    stages = [s.strip() for s in spec.split("+") if s.strip()]
    if not stages:
        return IdentityCodec()

    def _contains_sympack(codec: Codec) -> bool:
        while codec is not None:
            if isinstance(codec, SymPackCodec):
                return True
            codec = getattr(codec, "inner", None)
        return False

    def build(parts: list[str]) -> Codec:
        head, rest = parts[0], parts[1:]
        m = _TOPK_RE.match(head)
        if m:
            inner = build(rest) if rest else IdentityCodec()
            if _contains_sympack(inner):
                # top-k flattens to a sparse vector; sympack downstream
                # would see a non-square payload and fail mid-round
                raise ValueError(
                    f"sympack cannot follow top-k in {spec!r}; "
                    "use 'sympack+topk...' to pack first")
            if m.group(1):  # topk@K absolute count
                return TopKCodec(k=int(float(m.group(2))), inner=inner)
            return TopKCodec(fraction=float(m.group(2)), inner=inner)
        if head == "sympack":
            return SymPackCodec(inner=build(rest) if rest else IdentityCodec())
        if rest:
            raise ValueError(
                f"codec {head!r} cannot wrap {'+'.join(rest)!r} (in "
                f"{spec!r}); only topk*/sympack take inner stages")
        if head in ("identity", "none", "raw"):
            return IdentityCodec()
        if head == "fp16":
            return CastCodec("float16")
        if head == "bf16":
            return CastCodec("bfloat16")
        if head == "qint8":
            return QInt8Codec()
        raise ValueError(
            f"unknown codec spec {head!r} (in {spec!r}); expected one of "
            f"{', '.join(CODEC_SPECS)}")

    return build(stages)
