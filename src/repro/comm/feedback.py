"""Client-side error-feedback memory for lossy uplink codecs.

Biased compressors (top-k above all, but also quantizers) break the
fixed point of federated aggregation: every round throws away payload
mass, the discarded part never reaches the server, and the iterates
stall at a compression floor — the convergence gap
``examples/edge_clients.py`` measures under lossy codecs + partial
participation. Error feedback fixes this by making each client
*remember* what the codec dropped and re-offer it in later rounds. Two
standard recursions are implemented, per payload name and per client,
both with zero-initialized memory and identical wire formats (EF never
changes the encoded byte count — only which values ride in it):

``ef21`` (default) — compressed-estimate tracking, Richtárik et al.
(2021); the compressed-Hessian learning of FedNL (Safaryan et al.,
2022) is the same mechanism specialized to Hessians. The memory ``g_t``
is the client's current payload estimate (mirrored by the server in a
real deployment); the wire carries only the compressed *innovation*:

    transmit   c_t     = C(x_t - g_t)
    estimate   g_{t+1} = g_t + c_t          (what the server now holds)

On a fixed payload stream the residual ``x - g_t`` contracts
geometrically under any contractive ``C`` (``g_t -> x``), so the
server-side payload converges to the uncompressed one — and because the
server consumes the smooth estimate ``g_{t+1}`` rather than a raw
compressed payload, per-round noise is far lower than ``ef14``.

``ef14`` — classic error compensation (Seide et al. 2014; Stich et al.
2018), the ``e_{t+1} = e_t + x - C(x + e_t)`` recursion:

    transmit   m_t     = C(x_t + e_t)       (the compensated payload)
    remember   e_{t+1} = (x_t + e_t) - m_t  (what C dropped this time)

The residual stays bounded (not contracting) and the *time-averaged*
transmitted payload converges to the time-averaged true payload; the
per-round decode is spikier than ``ef21``'s, which matters for
Newton-type methods whose guards reject noisy steps.

Traced-memory design
--------------------
``CommRound.uplink`` runs inside the jitted round, so the memories
cannot live on a Python object that mutates per round — they form a
pytree of ``(m, ...)`` arrays (one leaf per EF-active payload
occurrence, stacked over clients) that the round driver threads through
the jitted step alongside the optimizer state:

  * payload shapes are discovered at trace time: ``CommSession.
    init_error_feedback`` runs one ``jax.eval_shape`` probe of the round
    with a recording ``CommRound``, then zero-initializes one ``(m, ...)``
    leaf per EF-active payload;
  * ``CommRound`` receives the memory pytree, ``uplink`` applies the
    selected recursion and writes the new memory into
    ``CommRound.memory_out``; ``run_rounds`` carries the updated pytree
    into the next round;
  * dropped clients never observe the round, so their memory rows are
    frozen via the delivery mask (``CommRound.where_delivered``, the
    same gate that protects per-client optimizer state and zeroes their
    aggregation weight);
  * under the asynchronous driver (``repro.comm.async_driver``) the same
    gate keys memory updates to *actual delivery*: one server commit may
    replay several version-grouped rounds, each advancing only the
    memory rows of the clients whose uploads that commit consumed, so a
    slow client's memory stays put across the server steps its payload
    spends in flight;
  * payloads whose codec is lossless (identity, bare sympack) allocate
    no memory at all, so the identity-codec path keeps a bit-identical
    jaxpr: the memory pytree is empty and ``uplink`` is unchanged.

Eligibility: EF memory only makes sense for payloads expressed in a
coordinate system that persists across rounds. Sketch-basis payloads
(FLeNS's ``h_sk``/``sg``, FedNS's ``sa``) are re-expressed in a fresh
random basis every round — cross-round memory would mix incompatible
bases and actively corrupt the estimate — so those call sites pass
``uplink(..., ef_eligible=False)`` and are skipped, exactly like
``wire_shape`` this is algorithm knowledge declared at the uplink.

Enable with ``CommConfig(error_feedback=True)`` (all eligible lossy
payloads), a collection of payload names, or a ``{name: bool}`` dict
with an optional ``"default"`` entry; pick the recursion with
``CommConfig(ef_variant="ef21"|"ef14")``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.comm.codecs import Codec

EF_VARIANTS = ("ef21", "ef14")


def ef_requested(error_feedback: Any, payload: str) -> bool:
    """Resolve the per-payload gate from a ``CommConfig.error_feedback``
    spec: ``bool`` (all/none), a collection of payload names, or a
    ``{name: bool}`` dict with an optional ``"default"`` fallback."""
    if isinstance(error_feedback, bool):
        return error_feedback
    if isinstance(error_feedback, str):  # one payload name, not chars
        return payload == error_feedback
    if isinstance(error_feedback, dict):
        return bool(error_feedback.get(
            payload, error_feedback.get("default", False)))
    return payload in error_feedback


def any_ef_requested(error_feedback: Any) -> bool:
    """Whether the spec can enable EF for at least one payload name."""
    if isinstance(error_feedback, bool):
        return error_feedback
    if isinstance(error_feedback, str):
        return bool(error_feedback)
    if isinstance(error_feedback, dict):
        return any(bool(v) for v in error_feedback.values())
    return len(tuple(error_feedback)) > 0


def compensate(
    codec: Codec, keys: jax.Array, x: jax.Array, mem: jax.Array,
    variant: str = "ef21",
) -> "tuple[jax.Array, jax.Array]":
    """One error-feedback step on a stacked ``(m, ...)`` payload.

    Returns ``(decoded, new_mem)``: what the server reconstructs this
    round and the client memory to carry into the next round. ``keys``
    is ``(m, 2)`` per-client codec randomness (ignored by deterministic
    codecs).

    * ``ef21``: ``mem`` is the payload estimate ``g``; the wire carries
      ``C(x - g)`` and both sides advance to ``g + C(x - g)`` — decoded
      payload and new memory coincide.
    * ``ef14``: ``mem`` is the residual ``e``; the wire carries
      ``C(x + e)`` and the client keeps ``(x + e) - C(x + e)``.
    """
    if variant == "ef21":
        innovation = jax.vmap(codec.roundtrip)(keys, x - mem)
        estimate = mem + innovation
        return estimate, estimate
    if variant == "ef14":
        compensated = x + mem
        decoded = jax.vmap(codec.roundtrip)(keys, compensated)
        return decoded, compensated - decoded
    raise ValueError(
        f"unknown error-feedback variant {variant!r}; want one of {EF_VARIANTS}")


def init_memory(spec: "Dict[str, jax.ShapeDtypeStruct]") -> "Dict[str, jax.Array]":
    """Zero memories from a discovered ``{payload_key: ShapeDtypeStruct}``."""
    return {name: jnp.zeros(s.shape, s.dtype) for name, s in spec.items()}


def residual_norms(memory: "Dict[str, jax.Array]") -> "Dict[str, float]":
    """Host-side diagnostic: per-payload Frobenius norm of the stacked
    memory (summed over clients). For ``ef21`` this is the estimate
    magnitude; for ``ef14`` the accumulated residual."""
    return {name: float(jnp.linalg.norm(e)) for name, e in memory.items()}


class BoundedMemory:
    """LRU-bounded EF row store for population-scale runs.

    Dense EF keeps one memory row per client per payload — ``O(m)``
    state that is exactly what population mode must not materialize.
    ``BoundedMemory`` keeps dense rows only for a *hot set* of
    ``capacity`` client ids with LRU eviction; a client outside the hot
    set re-enters with a **zero row** (on-sample reset — the FedBuff-
    style tradeoff: long-tail clients participate so rarely that their
    stale residual is worth less than its footprint).

    Per round the session calls ``gather(ids)`` to assemble the
    cohort-stacked ``(c, ...)`` memory pytree the jitted round consumes
    (assigning hot-set slots to new ids, evicting the least recently
    sampled), and ``scatter(ids, memory)`` afterwards to write the
    round's ``memory_out`` rows back into the store. Both are host-side
    O(c); total footprint is ``capacity × Σ row_bytes`` regardless of m,
    reported by the session through the existing ``repro.obs``
    ``ef_memory_bytes`` gauge.
    """

    def __init__(self, spec: "Dict[str, jax.ShapeDtypeStruct]", capacity: int):
        # ``spec`` rows are cohort-stacked (leading axis = cohort); the
        # store keeps ``capacity`` rows of each payload's row shape
        if capacity < 1:
            raise ValueError(f"BoundedMemory capacity must be >= 1, "
                             f"got {capacity}")
        self.capacity = int(capacity)
        self._bufs = {
            name: jnp.zeros((self.capacity,) + tuple(s.shape[1:]), s.dtype)
            for name, s in spec.items()
        }
        self._slot_of: "dict[int, int]" = {}  # client id -> slot (LRU order)
        self._free: "list[int]" = []  # slots released by retire()
        self.evictions = 0  # long-tail resets observed so far
        self.retirements = 0  # churn-departed rows dropped so far

    @property
    def payload_names(self):
        return tuple(self._bufs)

    @property
    def nbytes(self) -> int:
        """Bounded footprint: capacity × Σ per-payload row bytes."""
        return int(sum(b.nbytes for b in self._bufs.values()))

    def _assign(self, ids) -> "tuple[jnp.ndarray, list[int]]":
        """Slots for ``ids`` (LRU-refreshed), plus newly assigned slots."""
        fresh = []
        for cid in ids:
            cid = int(cid)
            if cid in self._slot_of:
                # refresh recency
                self._slot_of[cid] = self._slot_of.pop(cid)
                continue
            if self._free:
                slot = self._free.pop()
            elif len(self._slot_of) < self.capacity:
                # invariant: slots [0, len(_slot_of) + len(_free)) are
                # allocated, and _free holds the retired ones — so the
                # next virgin slot is the allocation high-water mark
                slot = len(self._slot_of) + len(self._free)
            else:
                # evict the least recently sampled id (oldest dict entry)
                victim = next(iter(self._slot_of))
                slot = self._slot_of.pop(victim)
                self.evictions += 1
            self._slot_of[cid] = slot
            fresh.append(slot)
        return (jnp.asarray([self._slot_of[int(c)] for c in ids],
                            dtype=jnp.int32), fresh)

    def gather(self, ids) -> "Dict[str, jax.Array]":
        """Cohort-stacked ``(c, ...)`` memory rows for ``ids``.

        Ids new to the hot set (or evicted since last sampled) read
        zeros — the on-sample reset.
        """
        if len(ids) > self.capacity:
            raise ValueError(
                f"cohort of {len(ids)} exceeds EF hot-set capacity "
                f"{self.capacity}; raise CommConfig.ef_capacity")
        slots, fresh = self._assign(ids)
        if fresh:
            z = jnp.asarray(fresh, dtype=jnp.int32)
            self._bufs = {name: buf.at[z].set(0)
                          for name, buf in self._bufs.items()}
        return {name: buf[slots] for name, buf in self._bufs.items()}

    def scatter(self, ids, memory: "Dict[str, jax.Array]") -> None:
        """Write the round's updated rows back (ids must be unique)."""
        if not self._bufs:
            return
        slots = jnp.asarray([self._slot_of[int(c)] for c in ids],
                            dtype=jnp.int32)
        self._bufs = {name: buf.at[slots].set(memory[name][: len(ids)])
                      for name, buf in self._bufs.items()}

    def retire(self, ids) -> int:
        """Drop hot-set rows for churn-departed clients.

        Zeros the rows (so a recycled slot starts clean even if a later
        ``gather`` misses it) and releases the slots for reuse. Ids not
        in the hot set are ignored. Returns the number retired.
        """
        gone = [int(c) for c in ids if int(c) in self._slot_of]
        if not gone:
            return 0
        slots = [self._slot_of.pop(c) for c in gone]
        z = jnp.asarray(slots, dtype=jnp.int32)
        self._bufs = {name: buf.at[z].set(0)
                      for name, buf in self._bufs.items()}
        self._free.extend(slots)
        self.retirements += len(gone)
        return len(gone)

    def residual_norms(self) -> "Dict[str, float]":
        return residual_norms(self._bufs)
