"""Client participation policies.

A scheduler decides, per round, which of the ``m`` clients are asked to
participate. The mask it returns reweights server aggregation (masked,
renormalized ``client_weights``) — partial participation is therefore an
*optimization* perturbation, not just an accounting one.

Policies:
  * ``FullParticipation``      — every client, every round.
  * ``UniformSampler(q)``      — uniform sample of ceil(q·m) clients
                                 without replacement (FedAvg-style).
  * ``BandwidthAware(q)``      — sample ceil(q·m) clients with probability
                                 proportional to uplink bandwidth (prefer
                                 fast links; Gumbel top-k trick, so the
                                 draw is a pure function of the key).

All draws are deterministic from the PRNG key: the same
``(seed, round)`` always yields the same cohort.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.channel import ChannelModel


class Scheduler:
    name: str = "scheduler"

    def participants(
        self, key: jax.Array, round_idx: int, m: int, channel: ChannelModel
    ) -> np.ndarray:
        """(m,) bool mask of clients scheduled this round."""
        raise NotImplementedError

    @property
    def is_full(self) -> bool:
        return False


class FullParticipation(Scheduler):
    name = "full"

    def participants(self, key, round_idx, m, channel):
        return np.ones((m,), dtype=bool)

    @property
    def is_full(self):
        return True


@dataclasses.dataclass(frozen=True)
class UniformSampler(Scheduler):
    """Uniform-without-replacement sample of a q-fraction each round."""

    q: float = 0.5

    @property
    def name(self):
        return f"uniform:{self.q}"

    def _count(self, m: int) -> int:
        return max(1, min(m, int(math.ceil(self.q * m))))

    def participants(self, key, round_idx, m, channel):
        chosen = jax.random.choice(
            key, m, shape=(self._count(m),), replace=False)
        mask = np.zeros((m,), dtype=bool)
        mask[np.asarray(chosen)] = True
        return mask


@dataclasses.dataclass(frozen=True)
class BandwidthAware(UniformSampler):
    """Bandwidth-proportional sampling: fast uplinks participate more.

    Uses the Gumbel top-k trick over log-bandwidth scores so selection is
    a deterministic function of the key and degrades to uniform when all
    clients share one link speed.
    """

    q: float = 0.5

    @property
    def name(self):
        return f"bandwidth:{self.q}"

    def participants(self, key, round_idx, m, channel):
        rates = channel.uplink_rates(m)
        scores = jnp.log(jnp.asarray(rates)) + jax.random.gumbel(key, (m,))
        _, top = jax.lax.top_k(scores, self._count(m))
        mask = np.zeros((m,), dtype=bool)
        mask[np.asarray(top)] = True
        return mask


def make_scheduler(spec: "str | Scheduler") -> Scheduler:
    """``"full" | "uniform:<q>" | "bandwidth:<q>"`` or a Scheduler."""
    if isinstance(spec, Scheduler):
        return spec
    if spec == "full":
        return FullParticipation()
    kind, _, arg = spec.partition(":")
    if kind == "uniform":
        return UniformSampler(q=float(arg or 0.5))
    if kind == "bandwidth":
        return BandwidthAware(q=float(arg or 0.5))
    raise ValueError(f"unknown scheduler spec {spec!r}")
