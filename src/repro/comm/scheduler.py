"""Client participation policies.

A scheduler decides, per round, which of the ``m`` clients are asked to
participate. The mask it returns reweights server aggregation (masked,
renormalized ``client_weights``) — partial participation is therefore an
*optimization* perturbation, not just an accounting one.

Policies:
  * ``FullParticipation``      — every client, every round.
  * ``UniformSampler(q)``      — uniform sample of ceil(q·m) clients
                                 without replacement (FedAvg-style).
  * ``BandwidthAware(q)``      — sample ceil(q·m) clients with probability
                                 proportional to uplink bandwidth (prefer
                                 fast links; Gumbel top-k trick, so the
                                 draw is a pure function of the key).

All draws are deterministic from the PRNG key: the same
``(seed, round)`` always yields the same cohort.

Population mode: ``sample_ids`` returns the sorted cohort *client ids*
instead of an ``(m,)`` mask — the form the lazy-materialization path
consumes (only the cohort's shards ever exist). ``participants`` and
``sample_ids`` are two views of the SAME draw (same key → the mask is
exactly the indicator of the ids), so dense and population runs of one
seed schedule identical cohorts. ``cohort_size`` exposes the static
per-round cohort cardinality so jitted rounds trace once per size.

Churn (``repro.dynamics``): every policy takes an optional ``eligible``
id array restricting the draw to the clients alive this round. With
``eligible=None`` (the default, and the only call shape without
dynamics) each policy's draw is byte-identical to the pre-churn code:
the restricted path draws *indices into the eligible set*, so it never
perturbs the unrestricted stream.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.channel import ChannelModel

SCHEDULER_SPECS = ("full", "uniform:<q>", "bandwidth:<q>")


class Scheduler:
    name: str = "scheduler"

    def participants(
        self, key: jax.Array, round_idx: int, m: int, channel: ChannelModel,
        eligible: "np.ndarray | None" = None,
    ) -> np.ndarray:
        """(m,) bool mask of clients scheduled this round."""
        mask = np.zeros((m,), dtype=bool)
        mask[self.sample_ids(key, round_idx, m, channel,
                             eligible=eligible)] = True
        return mask

    def sample_ids(
        self, key: jax.Array, round_idx: int, m: int, channel: ChannelModel,
        eligible: "np.ndarray | None" = None,
    ) -> np.ndarray:
        """Sorted int64 client ids of this round's cohort.

        Same draw as ``participants`` (identical key → identical
        cohort); O(cohort) output, never an ``(m,)`` mask, so q ~ 10⁻³
        participation over m ~ 10⁵ populations stays cheap. ``eligible``
        (sorted ids) restricts the draw to churn survivors.
        """
        raise NotImplementedError

    def cohort_size(self, m: int) -> int:
        """Static number of clients sampled per round (an upper bound
        under churn: a shrunken eligible set yields fewer ids)."""
        return m

    @property
    def is_full(self) -> bool:
        return False


class FullParticipation(Scheduler):
    name = "full"

    def participants(self, key, round_idx, m, channel, eligible=None):
        if eligible is None:
            return np.ones((m,), dtype=bool)
        mask = np.zeros((m,), dtype=bool)
        mask[eligible] = True
        return mask

    def sample_ids(self, key, round_idx, m, channel, eligible=None):
        if eligible is None:
            return np.arange(m, dtype=np.int64)
        return np.asarray(eligible, dtype=np.int64)

    @property
    def is_full(self):
        return True


@dataclasses.dataclass(frozen=True)
class UniformSampler(Scheduler):
    """Uniform-without-replacement sample of a q-fraction each round."""

    q: float = 0.5

    @property
    def name(self):
        return f"uniform:{self.q}"

    def _count(self, m: int) -> int:
        return max(1, min(m, int(math.ceil(self.q * m))))

    def sample_ids(self, key, round_idx, m, channel, eligible=None):
        if eligible is None:
            chosen = jax.random.choice(
                key, m, shape=(self._count(m),), replace=False)
            return np.sort(np.asarray(chosen, dtype=np.int64))
        eligible = np.asarray(eligible, dtype=np.int64)
        n = len(eligible)
        count = min(self._count(m), n)
        # draw indices INTO the eligible set: the cohort size follows
        # the shrunken population, the stream stays per-round pure
        chosen = jax.random.choice(key, n, shape=(count,), replace=False)
        return np.sort(eligible[np.asarray(chosen, dtype=np.int64)])

    def cohort_size(self, m: int) -> int:
        return self._count(m)


@dataclasses.dataclass(frozen=True)
class BandwidthAware(UniformSampler):
    """Bandwidth-proportional sampling: fast uplinks participate more.

    Uses the Gumbel top-k trick over log-bandwidth scores so selection is
    a deterministic function of the key and degrades to uniform when all
    clients share one link speed.
    """

    q: float = 0.5

    @property
    def name(self):
        return f"bandwidth:{self.q}"

    def sample_ids(self, key, round_idx, m, channel, eligible=None):
        if eligible is None:
            rates = channel.uplink_rates(m)
            scores = jnp.log(jnp.asarray(rates)) + jax.random.gumbel(key, (m,))
            _, top = jax.lax.top_k(scores, self._count(m))
            return np.sort(np.asarray(top, dtype=np.int64))
        eligible = np.asarray(eligible, dtype=np.int64)
        n = len(eligible)
        count = min(self._count(m), n)
        rates = channel.uplink_rates_for(eligible, m)
        scores = jnp.log(jnp.asarray(rates)) + jax.random.gumbel(key, (n,))
        _, top = jax.lax.top_k(scores, count)
        return np.sort(eligible[np.asarray(top, dtype=np.int64)])


def make_scheduler(spec: "str | Scheduler") -> Scheduler:
    """``"full" | "uniform:<q>" | "bandwidth:<q>"`` or a Scheduler."""
    if isinstance(spec, Scheduler):
        return spec
    if spec == "full":
        return FullParticipation()
    kind, _, arg = str(spec).partition(":")
    known = ", ".join(repr(s) for s in SCHEDULER_SPECS)
    try:
        if kind == "uniform":
            return UniformSampler(q=float(arg or 0.5))
        if kind == "bandwidth":
            return BandwidthAware(q=float(arg or 0.5))
    except ValueError:
        raise ValueError(
            f"bad parameter in scheduler spec {spec!r} (q must be a "
            f"float); expected one of {known}") from None
    raise ValueError(
        f"unknown scheduler spec {spec!r}; expected one of {known}")
