"""Simulated federated transport: codecs, channel, scheduling, accounting.

This package turns the repo's communication story from a float-count
formula into a measurable simulation: every federated round's payloads
flow through pluggable codecs in BOTH directions — per-client uplinks
via ``CommRound.uplink`` and server broadcasts via
``CommRound.downlink`` (direction-aware specs: ``codecs["down:w"]`` or
the ``downlink_codecs`` shorthand) — so compression error perturbs the
optimization, a per-client channel model converts exact encoded bytes
into simulated wall-clock with compute time, stragglers and dropout,
and participation schedulers reweight server aggregation. Lossy uplink
codecs can carry client-side EF21 error-feedback memory
(``repro.comm.feedback``) so biased compression keeps the uncompressed
fixed point.

Rounds are driven through the ``Session`` protocol
(``repro.comm.session``): ``NullSession`` (no transport, legacy jaxpr),
``CommSession`` (synchronous lock-step — the server waits for the
slowest delivering client), or ``AsyncSession``
(``CommConfig(async_mode=True)`` — event-driven per-client clocks with
quorum commits, staleness-weighted aggregation, and a FedBuff-style
``server_lr``, see ``repro.comm.async_driver``).

Scenario dynamics (client churn, time-varying channels, Byzantine
threats + robust aggregation) thread through
``CommConfig(dynamics=repro.dynamics.DynamicsConfig(...))`` and default
entirely off — see ``repro.dynamics``.

Entry point: build a :class:`CommConfig` and pass it to
``repro.core.run_rounds(..., comm=cfg)``. See ``examples/edge_clients.py``
and ``examples/async_edge.py``.
"""
from repro.comm.async_driver import (
    AsyncSession,
    PopulationAsyncSession,
    make_staleness,
)
from repro.comm.channel import ChannelDraw, ChannelModel
from repro.comm.codecs import (
    CastCodec,
    Codec,
    IdentityCodec,
    QInt8Codec,
    SymPackCodec,
    TopKCodec,
    make_codec,
)
from repro.comm.config import (
    NULL_COMM,
    CommConfig,
    CommRound,
    CommSession,
    PopulationCommSession,
    apply_churn,
)
from repro.comm.feedback import (
    BoundedMemory,
    compensate,
    init_memory,
    residual_norms,
)
from repro.comm.metrics import (
    RoundTrace,
    Transport,
    cumulative_bytes,
    cumulative_bytes_down,
    cumulative_bytes_up,
    cumulative_time,
    summarize,
)
from repro.comm.session import NullSession, Session, make_session
from repro.comm.scheduler import (
    BandwidthAware,
    FullParticipation,
    Scheduler,
    UniformSampler,
    make_scheduler,
)

__all__ = [
    "AsyncSession",
    "BandwidthAware",
    "BoundedMemory",
    "CastCodec",
    "ChannelDraw",
    "ChannelModel",
    "Codec",
    "CommConfig",
    "CommRound",
    "CommSession",
    "FullParticipation",
    "IdentityCodec",
    "NULL_COMM",
    "NullSession",
    "PopulationAsyncSession",
    "PopulationCommSession",
    "QInt8Codec",
    "RoundTrace",
    "Scheduler",
    "Session",
    "SymPackCodec",
    "TopKCodec",
    "Transport",
    "UniformSampler",
    "apply_churn",
    "compensate",
    "cumulative_bytes",
    "cumulative_bytes_down",
    "cumulative_bytes_up",
    "cumulative_time",
    "init_memory",
    "make_codec",
    "make_scheduler",
    "make_session",
    "make_staleness",
    "residual_norms",
    "summarize",
]
