"""Simulated federated transport: codecs, channel, scheduling, accounting.

This package turns the repo's communication story from a float-count
formula into a measurable simulation: every federated round's uplink
payloads flow through pluggable codecs (so compression error perturbs
the optimization), a per-client channel model converts exact encoded
bytes into simulated wall-clock with stragglers and dropout, and
participation schedulers reweight server aggregation. Lossy codecs can
carry client-side EF21 error-feedback memory (``repro.comm.feedback``)
so biased compression keeps the uncompressed fixed point.

Rounds are driven either synchronously (lock-step, the server waits for
the slowest delivering client) or asynchronously
(``CommConfig(async_mode=True)`` — event-driven per-client clocks with
quorum commits and staleness-weighted aggregation, see
``repro.comm.async_driver``).

Entry point: build a :class:`CommConfig` and pass it to
``repro.core.run_rounds(..., comm=cfg)``. See ``examples/edge_clients.py``
and ``examples/async_edge.py``.
"""
from repro.comm.async_driver import AsyncSession, make_staleness
from repro.comm.channel import ChannelDraw, ChannelModel
from repro.comm.codecs import (
    CastCodec,
    Codec,
    IdentityCodec,
    QInt8Codec,
    SymPackCodec,
    TopKCodec,
    make_codec,
)
from repro.comm.config import NULL_COMM, CommConfig, CommRound, CommSession
from repro.comm.feedback import (
    compensate,
    init_memory,
    residual_norms,
)
from repro.comm.metrics import (
    RoundTrace,
    cumulative_bytes,
    cumulative_time,
    summarize,
)
from repro.comm.scheduler import (
    BandwidthAware,
    FullParticipation,
    Scheduler,
    UniformSampler,
    make_scheduler,
)

__all__ = [
    "AsyncSession",
    "BandwidthAware",
    "CastCodec",
    "ChannelDraw",
    "ChannelModel",
    "Codec",
    "CommConfig",
    "CommRound",
    "CommSession",
    "FullParticipation",
    "IdentityCodec",
    "NULL_COMM",
    "QInt8Codec",
    "RoundTrace",
    "Scheduler",
    "SymPackCodec",
    "TopKCodec",
    "UniformSampler",
    "compensate",
    "cumulative_bytes",
    "cumulative_time",
    "init_memory",
    "make_codec",
    "make_scheduler",
    "make_staleness",
    "residual_norms",
    "summarize",
]
