"""Simulated federated transport: codecs, channel, scheduling, accounting.

This package turns the repo's communication story from a float-count
formula into a measurable simulation: every federated round's uplink
payloads flow through pluggable codecs (so compression error perturbs
the optimization), a per-client channel model converts exact encoded
bytes into simulated wall-clock with stragglers and dropout, and
participation schedulers reweight server aggregation. Lossy codecs can
carry client-side EF21 error-feedback memory (``repro.comm.feedback``)
so biased compression keeps the uncompressed fixed point.

Entry point: build a :class:`CommConfig` and pass it to
``repro.core.run_rounds(..., comm=cfg)``. See ``examples/edge_clients.py``.
"""
from repro.comm.channel import ChannelDraw, ChannelModel
from repro.comm.codecs import (
    CastCodec,
    Codec,
    IdentityCodec,
    QInt8Codec,
    SymPackCodec,
    TopKCodec,
    make_codec,
)
from repro.comm.config import NULL_COMM, CommConfig, CommRound, CommSession
from repro.comm.feedback import (
    compensate,
    init_memory,
    residual_norms,
)
from repro.comm.metrics import (
    RoundTrace,
    cumulative_bytes,
    cumulative_time,
    summarize,
)
from repro.comm.scheduler import (
    BandwidthAware,
    FullParticipation,
    Scheduler,
    UniformSampler,
    make_scheduler,
)

__all__ = [
    "BandwidthAware",
    "CastCodec",
    "ChannelDraw",
    "ChannelModel",
    "Codec",
    "CommConfig",
    "CommRound",
    "CommSession",
    "FullParticipation",
    "IdentityCodec",
    "NULL_COMM",
    "QInt8Codec",
    "RoundTrace",
    "Scheduler",
    "SymPackCodec",
    "TopKCodec",
    "UniformSampler",
    "compensate",
    "cumulative_bytes",
    "cumulative_time",
    "init_memory",
    "make_codec",
    "make_scheduler",
    "residual_norms",
    "summarize",
]
