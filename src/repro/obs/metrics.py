"""Telemetry metrics registry: counters, gauges, histograms.

Deliberately tiny — these are *host-side* simulation metrics at
federated-round granularity (hundreds to low-thousands of observations
per run), not a wire-format for a metrics backend. Histograms therefore
keep their raw observations and compute exact quantiles at snapshot
time instead of maintaining approximate buckets.

The registry is get-or-create by name so producer sites stay one-liners
(``metrics.counter("bytes_up").inc(n)``) and the consumer (the run
summary / ``repro.obs.report``) discovers whatever was populated.
"""
from __future__ import annotations

from typing import Dict, List


class Counter:
    """Monotonically increasing total."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    """Last-written value (e.g. a final memory footprint)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Exact-quantile histogram over raw observations."""

    __slots__ = ("values",)

    def __init__(self):
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(float(v))

    def observe_many(self, vs) -> None:
        self.values.extend(float(v) for v in vs)

    def snapshot(self) -> dict:
        if not self.values:
            return {"count": 0}
        xs = sorted(self.values)
        n = len(xs)

        def q(p: float) -> float:
            return xs[min(n - 1, int(p * n))]

        return {
            "count": n,
            "sum": sum(xs),
            "mean": sum(xs) / n,
            "min": xs[0],
            "max": xs[-1],
            "p50": q(0.50),
            "p90": q(0.90),
            "p99": q(0.99),
        }


class MetricsRegistry:
    """Named counters/gauges/histograms, get-or-create per kind.

    A name is owned by the kind that first created it; asking for the
    same name as a different kind raises (silent shadowing would split
    one logical metric across two objects).
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls()
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        """JSON-able dump: ``{counters: {...}, gauges: {...},
        histograms: {name: {count, mean, p50, ...}}}``."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.snapshot()
        return out


class _NullMetric:
    __slots__ = ()

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def observe_many(self, vs) -> None:
        pass


_NULL_METRIC = _NullMetric()


class NullMetricsRegistry:
    """No-op registry backing the disabled-telemetry path."""

    __slots__ = ()

    def counter(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def gauge(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def histogram(self, name: str) -> _NullMetric:
        return _NULL_METRIC

    def snapshot(self) -> dict:
        return {"counters": {}, "gauges": {}, "histograms": {}}


NULL_METRICS = NullMetricsRegistry()
