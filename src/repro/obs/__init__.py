"""`repro.obs` — the observability layer.

Host-side telemetry for the federated round drivers: a nestable span
tracer (jit-boundary wall-clock only, never traced code), a metrics
registry (counters / gauges / histograms the Sessions populate), an
async flight recorder (bounded ring of dispatch/arrival/drop/commit
events), pluggable record sinks (``null`` / ``stdout`` /
``jsonl:<path>``), and a structured driver logger.

Entry point: ``run_rounds(..., obs=TelemetryConfig(...))``. The default
(``obs=None``) is the shared ``NULL_TELEMETRY`` no-op — zero overhead
and bit-identical trajectories (tested). Render or schema-check the
emitted artifacts with ``python -m repro.obs.report``.
"""
from repro.obs.flight import (
    EVENT_KINDS,
    NULL_FLIGHT,
    FlightRecorder,
    NullFlightRecorder,
)
from repro.obs.metrics import (
    NULL_METRICS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullMetricsRegistry,
)
from repro.obs.sinks import JsonlSink, NullSink, StdoutSink, make_sink
from repro.obs.telemetry import (
    NULL_TELEMETRY,
    SCHEMA,
    NullTelemetry,
    Telemetry,
    TelemetryConfig,
)
from repro.obs.trace import NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Counter",
    "EVENT_KINDS",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "JsonlSink",
    "MetricsRegistry",
    "NULL_FLIGHT",
    "NULL_METRICS",
    "NULL_TELEMETRY",
    "NULL_TRACER",
    "NullFlightRecorder",
    "NullMetricsRegistry",
    "NullSink",
    "NullTelemetry",
    "NullTracer",
    "SCHEMA",
    "StdoutSink",
    "Telemetry",
    "TelemetryConfig",
    "Tracer",
    "make_sink",
]
