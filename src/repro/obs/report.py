"""Render (and schema-check) telemetry artifacts.

Two artifact kinds are understood:

  * a telemetry JSONL stream (``TelemetryConfig(sink="jsonl:...")``):
    per-round records, flight events, and per-run summaries — rendered
    as one table per run covering phase timings, the compile-vs-exec
    wall-clock split, byte totals, and the staleness distribution;
  * ``BENCH_round_time.json`` (``benchmarks/run.py --only round_time``):
    the per-optimizer perf-trajectory record — rendered as a table.

``--check-schema`` validates the artifact's structure instead of
rendering and exits non-zero on drift: CI's nightly job runs it over
the uploaded artifacts so a silently-changed record shape fails loudly
rather than rotting every downstream consumer.

  PYTHONPATH=src python -m repro.obs.report results/telemetry.jsonl
  PYTHONPATH=src python -m repro.obs.report BENCH_round_time.json --check-schema
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.obs.flight import EVENT_KINDS
from repro.obs.telemetry import SCHEMA

BENCH_SCHEMA = "bench_round_time/v1"

# required record shapes (schema drift = a missing key or unknown type)
_SUMMARY_KEYS = ("rounds", "compile_rounds", "compile_s", "exec_s",
                 "exec_s_per_round", "phase_s", "setup_phase_s", "metrics",
                 "flight")
_ROUND_KEYS = ("round", "wall_s", "compile", "phases")
_BENCH_OPT_KEYS = ("compile_s", "exec_s_per_round", "bytes_total",
                   "loss_final", "loss_at_budget")


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def _fmt_bytes(x: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("kB", 1e3)):
        if x >= div:
            return f"{x / div:.2f} {unit}"
    return f"{x:.0f} B"


def load_records(path: pathlib.Path) -> "list[dict]":
    records = []
    with path.open() as f:
        for i, line in enumerate(f):
            if not line.strip():
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not a JSON record ({e})")
    return records


# ---------------------------------------------------------------------------
# telemetry JSONL
# ---------------------------------------------------------------------------

def check_jsonl_schema(records: "list[dict]") -> "list[str]":
    """Structural validation; returns human-readable violations."""
    problems = []
    summaries = 0
    for i, rec in enumerate(records):
        where = f"record {i + 1}"
        kind = rec.get("type")
        if kind == "summary":
            summaries += 1
            if rec.get("schema") != SCHEMA:
                problems.append(
                    f"{where}: summary schema {rec.get('schema')!r} != "
                    f"{SCHEMA!r}")
            missing = [k for k in _SUMMARY_KEYS if k not in rec]
            if missing:
                problems.append(f"{where}: summary missing keys {missing}")
        elif kind == "round":
            missing = [k for k in _ROUND_KEYS if k not in rec]
            if missing:
                problems.append(f"{where}: round missing keys {missing}")
        elif kind == "flight":
            if rec.get("kind") not in EVENT_KINDS:
                problems.append(
                    f"{where}: unknown flight event kind {rec.get('kind')!r}")
            if "t" not in rec:
                problems.append(f"{where}: flight event missing 't'")
        else:
            problems.append(f"{where}: unknown record type {kind!r}")
    if summaries == 0:
        problems.append("no summary record (incomplete/truncated stream?)")
    return problems


def _render_histogram(name: str, h: dict) -> str:
    if h.get("count", 0) == 0:
        return f"  {name}: (empty)"
    return (f"  {name}: n={h['count']} mean={h['mean']:.2f} "
            f"p50={h['p50']:.0f} p90={h['p90']:.0f} max={h['max']:.0f}")


def render_summary(rec: dict) -> str:
    """One run's summary table (phase timings, compile-vs-exec split,
    byte totals, staleness distribution)."""
    label = rec.get("label") or rec.get("optimizer") or "(unlabelled)"
    lines = [f"== run {label} =="]
    lines.append(
        f"  rounds: {rec['rounds']} ({rec['compile_rounds']} compile)   "
        f"compile {_fmt_s(rec['compile_s'])} | "
        f"exec {_fmt_s(rec['exec_s'])} "
        f"({_fmt_s(rec['exec_s_per_round'])}/round)")
    for title, phases in (("phases", rec.get("phase_s", {})),
                          ("setup", rec.get("setup_phase_s", {}))):
        if phases:
            body = "  ".join(
                f"{name} {_fmt_s(dur)}" for name, dur in
                sorted(phases.items(), key=lambda kv: -kv[1]))
            lines.append(f"  {title}: {body}")
    metrics = rec.get("metrics", {})
    counters = metrics.get("counters", {})
    up = counters.get("bytes_up", rec.get("total_bytes_up"))
    down = counters.get("bytes_down", rec.get("total_bytes_down"))
    if up is not None or down is not None:
        lines.append(
            f"  bytes: up {_fmt_bytes(up or 0.0)}  "
            f"down {_fmt_bytes(down or 0.0)}  "
            f"total {_fmt_bytes((up or 0.0) + (down or 0.0))}")
    elif "total_bytes" in rec:
        lines.append(f"  bytes: total {_fmt_bytes(rec['total_bytes'])}")
    if "sim_time_s" in rec:
        lines.append(f"  sim clock: {rec['sim_time_s']:.3f}s")
    for name in ("staleness", "commit_buffer_depth", "buffered_upload_age_s",
                 "inflight_depth"):
        h = metrics.get("histograms", {}).get(name)
        if h is not None:
            lines.append(_render_histogram(name, h))
    extra_counters = {k: v for k, v in counters.items()
                      if k not in ("bytes_up", "bytes_down")}
    if extra_counters:
        lines.append("  counters: " + "  ".join(
            f"{k}={v:g}" for k, v in sorted(extra_counters.items())))
    gauges = metrics.get("gauges", {})
    if gauges:
        # scenario-dynamics / EF state gauges (active_population,
        # ef_memory_bytes, ...) — last-set values at run end
        lines.append("  gauges: " + "  ".join(
            f"{k}={v:g}" for k, v in sorted(gauges.items())))
    fl = rec.get("flight", {})
    if fl.get("total"):
        lines.append(
            f"  flight: {fl['kept']} events kept of {fl['total']} "
            f"(capacity {fl['capacity']}, {fl['truncated']} truncated)")
    return "\n".join(lines)


def render_jsonl(records: "list[dict]") -> str:
    out = []
    rounds_by_label: "dict[str, int]" = {}
    for rec in records:
        if rec.get("type") == "round":
            label = rec.get("label", "")
            rounds_by_label[label] = rounds_by_label.get(label, 0) + 1
        elif rec.get("type") == "summary":
            out.append(render_summary(rec))
    if not out:
        return "(no summary records)"
    return "\n\n".join(out)


# ---------------------------------------------------------------------------
# BENCH_round_time.json
# ---------------------------------------------------------------------------

def check_bench_schema(doc: dict) -> "list[str]":
    problems = []
    if doc.get("schema") != BENCH_SCHEMA:
        problems.append(
            f"schema {doc.get('schema')!r} != {BENCH_SCHEMA!r}")
    for key in ("dataset", "rounds", "budget_bytes", "optimizers"):
        if key not in doc:
            problems.append(f"missing top-level key {key!r}")
    opts = doc.get("optimizers", {})
    if not opts:
        problems.append("no optimizers recorded")
    for name, row in opts.items():
        missing = [k for k in _BENCH_OPT_KEYS if k not in row]
        if missing:
            problems.append(f"optimizer {name!r} missing keys {missing}")
    return problems


def render_bench(doc: dict) -> str:
    lines = [
        f"== BENCH round_time: {doc.get('dataset')} "
        f"({doc.get('rounds')} rounds, budget "
        f"{_fmt_bytes(float(doc.get('budget_bytes', 0.0)))}) ==",
        f"{'optimizer':>14} {'compile_s':>10} {'exec/round':>11} "
        f"{'bytes':>10} {'loss@budget':>12} {'loss_final':>11}",
    ]
    for name, row in sorted(doc.get("optimizers", {}).items()):
        lines.append(
            f"{name:>14} {row['compile_s']:>10.3f} "
            f"{_fmt_s(row['exec_s_per_round']):>11} "
            f"{_fmt_bytes(row['bytes_total']):>10} "
            f"{row['loss_at_budget']:>12.6f} {row['loss_final']:>11.6f}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        description="Render or schema-check repro.obs telemetry artifacts.")
    ap.add_argument("path", type=pathlib.Path,
                    help="telemetry JSONL or BENCH_round_time.json")
    ap.add_argument("--check-schema", action="store_true",
                    help="validate structure instead of rendering; "
                         "exit 1 on drift")
    args = ap.parse_args(argv)

    text = args.path.read_text()
    doc = None
    try:
        parsed = json.loads(text)
        if isinstance(parsed, dict) and "schema" in parsed:
            doc = parsed  # a single-document BENCH json
    except json.JSONDecodeError:
        pass

    if doc is not None:
        problems = check_bench_schema(doc)
        if args.check_schema:
            if problems:
                print(f"SCHEMA DRIFT in {args.path}:")
                for p in problems:
                    print(f"  - {p}")
                return 1
            print(f"schema OK: {args.path} ({BENCH_SCHEMA}, "
                  f"{len(doc['optimizers'])} optimizers)")
            return 0
        if problems:
            print(f"warning: schema problems in {args.path}: {problems}",
                  file=sys.stderr)
        print(render_bench(doc))
        return 0

    records = load_records(args.path)
    problems = check_jsonl_schema(records)
    if args.check_schema:
        if problems:
            print(f"SCHEMA DRIFT in {args.path}:")
            for p in problems:
                print(f"  - {p}")
            return 1
        n_sum = sum(1 for r in records if r.get("type") == "summary")
        print(f"schema OK: {args.path} ({SCHEMA}, {len(records)} records, "
              f"{n_sum} run summaries)")
        return 0
    print(render_jsonl(records))
    return 0


if __name__ == "__main__":
    sys.exit(main())
