"""Async flight recorder: a bounded ring of structured wire events.

The asynchronous driver's pathologies (staleness spirals, starved
quorums, retry storms) are *sequencing* bugs — the per-commit
``RoundTrace`` aggregates are too coarse to reconstruct who was in
flight when. The flight recorder keeps the last ``capacity`` raw events
(dispatch / arrival / drop / commit, each stamped with client id, model
version, and server clock) so a post-mortem can replay the tail of the
event history exactly.

Truncation semantics: the ring keeps the MOST RECENT ``capacity``
events; ``total`` counts every event ever recorded and ``truncated``
how many old events fell off the front. Dumps are JSONL, one event per
line, oldest surviving event first.
"""
from __future__ import annotations

import collections
import json
import pathlib

# the event vocabulary (report/check-schema validate against this)
EVENT_KINDS = ("dispatch", "arrival", "drop", "commit")


class FlightRecorder:
    """Bounded ring buffer of ``{"kind", "t", ...}`` event dicts."""

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._ring: collections.deque = collections.deque(
            maxlen=self.capacity)
        self.total = 0

    def record(self, kind: str, t: float, **fields) -> None:
        """Append one event; ``t`` is the simulated server clock."""
        if kind not in EVENT_KINDS:
            raise ValueError(
                f"unknown flight event kind {kind!r}; want one of "
                f"{EVENT_KINDS}")
        self.total += 1
        self._ring.append({"kind": kind, "t": float(t), **fields})

    @property
    def truncated(self) -> int:
        """Events that fell off the front of the ring."""
        return self.total - len(self._ring)

    def events(self) -> "list[dict]":
        """Surviving events, oldest first."""
        return list(self._ring)

    def stats(self) -> dict:
        return {"capacity": self.capacity, "total": self.total,
                "kept": len(self._ring), "truncated": self.truncated}

    def to_jsonl(self, path) -> pathlib.Path:
        """Dump the surviving events as JSONL (one event per line)."""
        path = pathlib.Path(path)
        with path.open("w") as f:
            for ev in self._ring:
                f.write(json.dumps(ev) + "\n")
        return path


class NullFlightRecorder:
    """No-op recorder backing the disabled-telemetry path."""

    __slots__ = ()
    capacity = 0
    total = 0
    truncated = 0

    def record(self, kind: str, t: float, **fields) -> None:
        pass

    def events(self) -> "list[dict]":
        return []

    def stats(self) -> dict:
        return {"capacity": 0, "total": 0, "kept": 0, "truncated": 0}


NULL_FLIGHT = NullFlightRecorder()
