"""The telemetry runtime ``run_rounds`` threads through the Session
drivers.

``TelemetryConfig`` is the user-facing declaration (hung off
``run_rounds(..., obs=...)``); ``Telemetry`` is the per-run runtime
bundling the span tracer, the metrics registry, the async flight
recorder, and the record sink. ``NULL_TELEMETRY`` is the shared
disabled instance the driver uses when ``obs=None`` (the default):
every producer call site degrades to a no-op whose cost is an attribute
lookup, and — the load-bearing guarantee — NOTHING telemetry does ever
appears inside a traced/jitted function, so instrumented and
uninstrumented trajectories are bit-identical (tested, null sink and
jsonl sink alike).

Record stream (what a sink sees, one dict per record):

  * per round:  ``{"type": "round", "round": t, "wall_s", "compile",
                  "phases": {name: seconds}, ...session annotations}``
  * flight:     ``{"type": "flight", ...event}`` (async runs, dumped at
                  finalize, ring-truncated to the most recent events)
  * summary:    ``{"type": "summary", "compile_s", "exec_s",
                  "exec_s_per_round", "phase_s", "setup_phase_s",
                  "metrics", "flight", ...driver extras}``

Every record carries the config's ``label`` so several runs can share
one JSONL artifact.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

from repro.obs.flight import NULL_FLIGHT, FlightRecorder
from repro.obs.metrics import NULL_METRICS, MetricsRegistry
from repro.obs.sinks import make_sink
from repro.obs.trace import NULL_TRACER, Tracer

# schema version stamped on summary records; repro.obs.report
# --check-schema fails on records claiming a different major version
SCHEMA = "repro.obs/v1"


@dataclasses.dataclass
class TelemetryConfig:
    """Declarative telemetry switchboard for one ``run_rounds`` call.

    ``sink`` — where records go: ``"null"`` (default — measure nothing
    downstream, still collect the in-process summary), ``"stdout"``, or
    ``"jsonl:<path>"`` (appends; runs are distinguished by ``label``).
    ``flight_capacity`` — ring size of the async flight recorder.
    ``profile_rounds`` — opt-in ``jax.profiler`` trace hook: capture a
    device/host trace around the FIRST N executed rounds (0 = off) into
    ``profile_dir``. This is the only knob that touches jax at all, and
    it wraps rounds from the host — traced code is never modified.
    """

    sink: str = "null"
    label: str = ""
    flight_capacity: int = 1024
    profile_rounds: int = 0
    profile_dir: str = "results/jax_trace"


class Telemetry:
    """Per-run telemetry runtime (see module docstring)."""

    enabled = True

    def __init__(self, config: "TelemetryConfig | None" = None):
        self.config = config if config is not None else TelemetryConfig()
        self.sink = make_sink(self.config.sink)
        self.metrics = MetricsRegistry()
        self.flight = FlightRecorder(self.config.flight_capacity)
        self.trace = Tracer(self._attribute_span)
        self.rounds: "list[dict]" = []
        self._current: "dict | None" = None
        self._setup_phase_s: "dict[str, float]" = {}
        self._finalized: "dict | None" = None

    # -- span attribution ----------------------------------------------------
    def _attribute_span(self, name: str, dur: float, depth: int) -> None:
        """Closed spans aggregate by name into the live round record, or
        into the setup bucket outside any round (prepare, probes)."""
        target = (self._current["phases"] if self._current is not None
                  else self._setup_phase_s)
        target[name] = target.get(name, 0.0) + dur

    # -- round lifecycle -----------------------------------------------------
    @contextlib.contextmanager
    def round(self, t: int, *, compile_expected: bool = False):
        """Time one driver round. ``compile_expected`` marks rounds whose
        ``round_fn`` call will trace+compile (first execution of a jit
        variant): their wall time lands in ``compile_s``, steady-state
        rounds in ``exec_s``."""
        rec = {"type": "round", "round": int(t),
               "compile": bool(compile_expected), "phases": {}}
        self._current = rec
        t0 = time.perf_counter()
        try:
            yield rec
        finally:
            rec["wall_s"] = time.perf_counter() - t0
            self._current = None
            self.rounds.append(rec)
            self.sink.emit({"label": self.config.label, **rec})

    def annotate(self, **fields) -> None:
        """Merge fields into the live round record (sessions report
        per-round bytes / staleness / cohort sizes here); outside a
        round this is a no-op."""
        if self._current is not None:
            self._current.update(fields)

    # -- finalize ------------------------------------------------------------
    def finalize(self, extra: "dict | None" = None) -> dict:
        """Fold the run into one summary record, flush the flight ring
        and the summary to the sink, close the sink, and return the
        summary. Idempotent (drivers call it once; a late second call
        returns the same dict)."""
        if self._finalized is not None:
            return self._finalized
        compile_rounds = [r for r in self.rounds if r["compile"]]
        exec_rounds = [r for r in self.rounds if not r["compile"]]
        compile_s = sum(r["wall_s"] for r in compile_rounds)
        exec_s = sum(r["wall_s"] for r in exec_rounds)
        phase_s: "dict[str, float]" = {}
        for r in self.rounds:
            for name, dur in r["phases"].items():
                phase_s[name] = phase_s.get(name, 0.0) + dur
        summary = {
            "type": "summary",
            "schema": SCHEMA,
            "label": self.config.label,
            "rounds": len(self.rounds),
            "compile_rounds": len(compile_rounds),
            "compile_s": compile_s,
            "exec_s": exec_s,
            "exec_s_per_round": exec_s / max(len(exec_rounds), 1),
            "phase_s": phase_s,
            "setup_phase_s": dict(self._setup_phase_s),
            "metrics": self.metrics.snapshot(),
            "flight": self.flight.stats(),
        }
        if extra:
            summary.update(extra)
        label = self.config.label
        for ev in self.flight.events():
            self.sink.emit({"type": "flight", "label": label, **ev})
        self.sink.emit(summary)
        self.sink.close()
        self._finalized = summary
        return summary


class NullTelemetry:
    """Disabled telemetry: shared singleton, every surface a no-op.

    Producer sites guard expensive derivations with ``if obs.enabled:``;
    plain span/metric/flight calls are cheap enough to leave unguarded.
    """

    enabled = False
    trace = NULL_TRACER
    metrics = NULL_METRICS
    flight = NULL_FLIGHT
    rounds: "list[dict]" = []

    @contextlib.contextmanager
    def round(self, t: int, *, compile_expected: bool = False):
        yield None

    def annotate(self, **fields) -> None:
        pass

    def finalize(self, extra: "dict | None" = None) -> None:
        return None


NULL_TELEMETRY = NullTelemetry()
