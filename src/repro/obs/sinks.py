"""Telemetry record sinks.

A sink consumes JSON-able record dicts (``emit``) and flushes on
``close``. Specs are strings so ``TelemetryConfig`` stays declarative:

  * ``"null"``          — drop everything (the default; zero overhead)
  * ``"stdout"``        — one JSON line per record to stdout
  * ``"jsonl:<path>"``  — append JSON lines to a file (parent dirs are
                          created; the file is APPENDED to, so several
                          runs — e.g. one per benchmark optimizer — can
                          share one artifact, distinguished by their
                          ``label`` field)

NaN/Infinity never reach the wire: non-finite floats are serialized as
``null`` (json.dumps would otherwise emit tokens invalid in strict
JSON parsers, which is exactly what a downstream dashboard would use).
"""
from __future__ import annotations

import json
import math
import pathlib
import sys


def _scrub(obj):
    """Replace non-finite floats with None, recursively."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: _scrub(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_scrub(v) for v in obj]
    return obj


def encode_record(record: dict) -> str:
    """One strict-JSON line for a record (shared by all sinks)."""
    return json.dumps(_scrub(record), allow_nan=False)


class NullSink:
    def emit(self, record: dict) -> None:
        pass

    def close(self) -> None:
        pass


class StdoutSink:
    def emit(self, record: dict) -> None:
        sys.stdout.write(encode_record(record) + "\n")

    def close(self) -> None:
        sys.stdout.flush()


class JsonlSink:
    """Line-buffered append to ``path`` (opened lazily on first emit, so
    configuring a jsonl sink on a run that records nothing creates
    nothing)."""

    def __init__(self, path):
        self.path = pathlib.Path(path)
        self._f = None

    def emit(self, record: dict) -> None:
        if self._f is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._f = self.path.open("a")
        self._f.write(encode_record(record) + "\n")

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


def make_sink(spec: str):
    """Resolve a sink spec string (see module docstring)."""
    if spec == "null":
        return NullSink()
    if spec == "stdout":
        return StdoutSink()
    kind, sep, arg = str(spec).partition(":")
    if kind == "jsonl" and sep and arg:
        return JsonlSink(arg)
    raise ValueError(
        f"unknown telemetry sink {spec!r}; want 'null', 'stdout', or "
        f"'jsonl:<path>'")
