"""Structured logging for driver diagnostics.

The round drivers used ad-hoc ``warnings.warn`` calls for operational
diagnostics (payload-plan probe fallback, async quorum caps, the
rotating+EF staleness caveat). Those now route through one module-level
logger — ``logging.getLogger("repro.obs")`` — with structured context
(round, optimizer, policy spec, ...) appended as ``key=value`` pairs,
so a host application can attach a handler/filter once and see every
driver diagnostic in one stream.

``warn_with_context`` keeps the warning *API-visible*: it emits BOTH
the structured log record and a real ``warnings.warn`` (same category,
caller-relative stacklevel), because the repo's public contract is that
these conditions are observable through the warnings machinery
(``pytest.warns``, ``-W error::UserWarning``) — the logger is an
addition, not a replacement.
"""
from __future__ import annotations

import logging
import warnings

logger = logging.getLogger("repro.obs")
# library default: silent unless the host application configures
# logging (the stdlib "last resort" handler would print WARNINGs twice
# next to the warnings machinery we keep emitting)
logger.addHandler(logging.NullHandler())


def format_context(context: dict) -> str:
    """Render structured context as a stable ``key=value`` suffix."""
    return " ".join(f"{k}={v}" for k, v in sorted(context.items())
                    if v is not None)


def log_with_context(level: int, msg: str, **context) -> None:
    """Emit one structured log record; context rides both in the message
    suffix and machine-readable on ``record.context``."""
    suffix = format_context(context)
    logger.log(level, "%s%s", msg, f" [{suffix}]" if suffix else "",
               extra={"context": context})


def warn_with_context(msg: str, *, category=UserWarning, stacklevel: int = 2,
                      **context) -> None:
    """Structured log record AND an API-visible ``warnings.warn``.

    ``stacklevel`` is relative to the *caller* of this helper (2 points
    the warning at that caller's call site, matching a direct
    ``warnings.warn(..., stacklevel=2)`` there).
    """
    log_with_context(logging.WARNING, msg, **context)
    warnings.warn(msg, category=category, stacklevel=stacklevel + 1)


def debug(msg: str, **context) -> None:
    log_with_context(logging.DEBUG, msg, **context)


def info(msg: str, **context) -> None:
    log_with_context(logging.INFO, msg, **context)
