"""Lightweight host-side span tracer.

Spans time *host* work around jit boundaries — the driver's
``prepare`` / ``begin_variant`` / ``step`` phases, a session's cohort
draw, an async commit's group rounds — never code inside a traced
function (a ``time.perf_counter`` call cannot appear in a jaxpr, and a
span around a dispatch measures dispatch, not device time; that is
exactly the contract here: the wall-clock an end user waits through).

Spans nest (``with trace.span("step"): ... with trace.span("schedule")``)
and every *closed* span reports ``(name, duration, depth)`` to the
telemetry object, which attributes it to the round currently executing
(or to the setup phase outside any round). Aggregation is by name, so
the driver keeps phase names sibling-disjoint where per-phase totals
should partition the round wall-clock.
"""
from __future__ import annotations

import time
from typing import Callable


class _Span:
    """One active span; re-entrant use is not supported (make a new one
    via ``Tracer.span``)."""

    __slots__ = ("_tracer", "name", "_t0")

    def __init__(self, tracer: "Tracer", name: str):
        self._tracer = tracer
        self.name = name
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._tracer._depth += 1
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        dur = time.perf_counter() - self._t0
        self._tracer._depth -= 1
        self._tracer._report(self.name, dur, self._tracer._depth)
        return False


class Tracer:
    """Factory for nestable timing spans.

    ``report(name, duration_s, depth)`` is called once per closed span;
    ``depth`` is 0 for top-level spans. The telemetry runtime installs
    its round-attribution callback here.
    """

    def __init__(self, report: Callable[[str, float, int], None]):
        self._report = report
        self._depth = 0

    def span(self, name: str) -> _Span:
        return _Span(self, name)


class _NullSpan:
    """Shared no-op span: the zero-overhead path when telemetry is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc) -> bool:
        return False


NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracer stand-in whose spans cost one attribute lookup + one
    (shared, stateless) context-manager enter/exit."""

    __slots__ = ()

    def span(self, name: str) -> _NullSpan:
        return NULL_SPAN


NULL_TRACER = NullTracer()
