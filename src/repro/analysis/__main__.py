"""CLI: ``python -m repro.analysis [lint|audit|all] [options]``.

Exit status 0 when every finding is baselined (or none exist), 1 when
NEW findings appear relative to ``--baseline``. ``--update`` rewrites
the baseline to the current finding set instead of failing.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.findings import diff_baseline, load_baseline, save_baseline
from repro.analysis.lint import lint_repo


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("mode", nargs="?", default="all",
                    choices=("lint", "audit", "all"))
    ap.add_argument("--baseline", default="results/analysis_baseline.json")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline to the current findings")
    ap.add_argument("--root", default=".",
                    help="repo root holding src/repro (lint scope)")
    ap.add_argument("--optimizers", default=None,
                    help="comma list restricting the audited optimizers")
    ap.add_argument("--sessions", default=None,
                    help="comma list restricting the audited drivers "
                         "(sync,async,population)")
    ap.add_argument("--codecs", default=None,
                    help="comma list restricting the audited codec legs "
                         "(identity,topk,sympack)")
    ap.add_argument("--no-dynamic", action="store_true",
                    help="skip the instrumented retrace cross-check runs")
    args = ap.parse_args(argv)

    findings = []
    if args.mode in ("lint", "all"):
        findings += lint_repo(args.root)
    if args.mode in ("audit", "all"):
        from repro.analysis.audit import audit_repo

        split = (lambda s: [x for x in s.split(",") if x] if s else None)
        findings += audit_repo(
            optimizers=split(args.optimizers),
            sessions=split(args.sessions),
            codecs=split(args.codecs),
            dynamic=not args.no_dynamic)

    if args.update:
        save_baseline(args.baseline, findings)
        print(f"baseline updated: {len(findings)} finding(s) -> "
              f"{args.baseline}")
        return 0

    diff = diff_baseline(findings, load_baseline(args.baseline))
    for f in diff.new:
        print(f"NEW      {f.render()}")
    for f in diff.accepted:
        print(f"ACCEPTED {f.render()}")
    if diff.resolved:
        print(f"resolved {len(diff.resolved)} baselined finding(s) — "
              f"rerun with --update to record the progress")
    print(f"{args.mode}: {len(diff.new)} new, {len(diff.accepted)} "
          f"accepted, {len(diff.resolved)} resolved")
    return 1 if diff.failed else 0


if __name__ == "__main__":
    sys.exit(main())
