"""Static analysis: repo-specific lint rules + the jaxpr trace auditor.

Two passes behind one CLI (``python -m repro.analysis [lint|audit|all]``):
``repro.analysis.lint`` (AST rules RA000–RA006 over ``src/repro/**``)
and ``repro.analysis.audit`` (traces every optimizer's jitted round
across codecs x session drivers and checks retrace stability, the
dtype census, constant bloat, forbidden primitives, and wire
consistency). Findings diff against ``results/analysis_baseline.json``.
"""
from repro.analysis.findings import Finding, diff_baseline, load_baseline
from repro.analysis.lint import RULES, lint_repo, lint_source

__all__ = [
    "Finding",
    "RULES",
    "diff_baseline",
    "lint_repo",
    "lint_source",
    "load_baseline",
]
