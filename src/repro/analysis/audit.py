"""Layer 2: the jaxpr trace auditor.

Traces every optimizer's jitted round — the EXACT closure ``run_rounds``
jits, via ``repro.core.base.build_round`` — across a combo matrix of
codecs x session drivers, and statically checks the jaxprs for the
invariants the dynamic tests only cover on the paths they execute:

  * **retrace stability** — re-tracing the round with its own output
    avals must reproduce an identical jaxpr fingerprint (shape/dtype/
    weak-type drift in the carried state is exactly what forces the
    one-jaxpr-per-config guarantee to silently retrace every round);
  * **dtype census** — no float64/complex128 avals anywhere in the
    round when x64 is off (run under both settings in the nightly), and
    no weak-type promotion leaking into the carried state;
  * **constant bloat** — closure-captured constants above a size
    threshold baked into the jaxpr (the dense-population regression
    class PR 7 fixed by hand); the dense problem's own shards are the
    one allowlisted capture, population mode is strict;
  * **forbidden primitives** — no ``pure_callback`` / ``io_callback`` /
    ``debug_callback`` / ``debug_print`` inside round bodies
    (host round-trips break the pure-round contract and async replay);
  * **wire consistency** — every ``uplink``/``downlink`` occurrence's
    billed plan bytes equal its codec's ``nbytes`` over the aval shape
    actually traced, the plan filled by the real jit trace matches an
    independent ``eval_shape`` probe, and payloads untargeted by a
    scoped ``ThreatModel`` stay byte-identical to the threat-free round.

A dynamic cross-check (``audit_retraces_dynamic``) additionally runs a
short instrumented trajectory per driver and asserts the ``repro.obs``
``variant_retraces`` counter stayed zero — the runtime witness the
static fingerprint check is cross-checked against.
"""
from __future__ import annotations

import hashlib
from typing import Any, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding
from repro.comm import CommConfig, make_session
from repro.comm.config import CommRound
from repro.core import ALGORITHMS, make_optimizer
from repro.core.base import build_round, root_key
from repro.core.federated import SyntheticPopulation, make_problem
from repro.core.losses import logistic
from repro.dynamics import DynamicsConfig

SESSIONS = ("sync", "async", "population")

# codec legs: lossless identity (the bit-exactness contract), a lossy
# default over every payload, and a payload-scoped spectral codec
CODECS: Dict[str, dict] = {
    "identity": {},
    "topk": {"default": "topk0.25"},
    "sympack": {"h_sk": "sympack"},
}

# jaxpr primitives that must never appear inside a round body
FORBIDDEN_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "debug_print",
    "callback",
})

# constants larger than this many bytes count as bloat at audit scale
# (the toy problems below keep every legitimate capture well under it)
CONST_BLOAT_BYTES = 4096

_AUDIT_SEED = 0
_DIM = 8
_M = 4
_K = 4


def combos(optimizers: Optional[Iterable[str]] = None,
           sessions: Optional[Iterable[str]] = None,
           codecs: Optional[Iterable[str]] = None) -> List[tuple]:
    """The audited (optimizer, session, codec) matrix. FedNew keeps
    dense per-client ADMM duals and is rejected by population mode by
    design, so that one combination is skipped (not silently passed)."""
    opts = tuple(optimizers) if optimizers is not None else ALGORITHMS
    sess = tuple(sessions) if sessions is not None else SESSIONS
    cods = tuple(codecs) if codecs is not None else tuple(CODECS)
    out = []
    for o in opts:
        for s in sess:
            if s == "population" and o == "fednew":
                continue  # per_client_state: rejected by the driver
            for c in cods:
                out.append((o, s, c))
    return out


def _make_optimizer(name: str):
    if name in ("flens", "flens_plus", "fedns"):
        return make_optimizer(name, k=_K)
    return make_optimizer(name)


def _toy_problem(seed: int = _AUDIT_SEED):
    key = root_key(seed, 17)
    kx, ky = jax.random.split(key)
    n = _M * 8
    X = jax.random.normal(kx, (n, _DIM))
    y = jnp.sign(jax.random.normal(ky, (n,)) + 0.1)
    return make_problem(X, y, m=_M, lam=1e-3, objective=logistic)


def _toy_population(seed: int = _AUDIT_SEED):
    return SyntheticPopulation(m=64, dim=_DIM, lam=1e-3, seed=seed,
                               n_per_client=8)


def _comm_config(session: str, codec: str,
                 dynamics: "DynamicsConfig | None" = None) -> CommConfig:
    kw: Dict[str, Any] = {"codecs": dict(CODECS[codec]),
                          "seed": _AUDIT_SEED}
    if dynamics is not None:
        kw["dynamics"] = dynamics
    if session == "async":
        kw["async_mode"] = True
    if session == "population":
        kw["scheduler"] = "uniform:0.25"
    return CommConfig(**kw)


class _AuditTarget:
    """One combo's fully-wired round: session prepared, probe arguments
    shaped exactly as the driver's first ``step`` would pass them."""

    def __init__(self, optimizer, session: str, codec: str,
                 dynamics: "DynamicsConfig | None" = None):
        # tests pass deliberately-broken optimizer INSTANCES; the CLI
        # passes registry names
        opt = self.opt = (_make_optimizer(optimizer)
                          if isinstance(optimizer, str) else optimizer)
        name = optimizer if isinstance(optimizer, str) else opt.name
        self.id = f"{name}/{session}/{codec}"
        self.optimizer, self.session_kind = name, session
        comm = self.comm = _comm_config(session, codec, dynamics)
        population = None
        if session == "population":
            population = _toy_population()
            problem = population.eval_problem()
        else:
            problem = _toy_problem()
        self.problem, self.population = problem, population
        state = opt.init(problem, jnp.zeros((problem.dim,), problem.X.dtype))
        self.keys = jax.random.split(root_key(_AUDIT_SEED), 2)
        m = population.m if population is not None else problem.m
        sess = self.sess = make_session(
            comm, m=m, mask_dtype=problem.X.dtype,
            client_weights=(population.client_weights
                            if population is not None
                            else np.asarray(problem.client_weights)),
            keys=self.keys, state0=state, formula_bytes_per_round=0.0,
            population=population)
        probe_key = root_key(_AUDIT_SEED)
        self._round, self.trace_with = build_round(
            opt, problem, sess, probe_key,
            population=population, comm=comm)
        sess.prepare(self.trace_with(state))
        self.state0 = state
        self.args = self._probe_args(state)

    def _probe_args(self, state) -> tuple:
        """Concrete first-round arguments, built the way the driver's
        ``step`` builds them (``begin_round`` for the sync clocks, the
        lockstep mask + version-0 keys for the async one)."""
        sess = self.sess
        if self.session_kind == "async":
            if sess.lockstep:
                mask = None
            else:
                mask = jnp.asarray(np.ones(sess.m), sess._mask_dtype)
            _, _, k_codec = sess._round_keys(0)
            return (state, sess.ef_memory, self.keys[0],
                    sess._pack_threat(mask), k_codec)
        if self.population is not None:
            ids, mask, ck = sess.begin_round(0)
            cohort = sess._materialize(ids)
            memory = sess.ef_store.gather(ids) if sess.ef_store else {}
            return (cohort, state, memory, self.keys[0], mask, ck)
        mask, ck = sess.begin_round(0)
        return (state, sess.ef_memory, self.keys[0], mask, ck)

    # -- traced artifacts ----------------------------------------------------
    def closed_jaxpr(self, args=None):
        return jax.make_jaxpr(self._round)(*(args or self.args))

    def out_avals(self, args=None):
        return jax.eval_shape(self._round, *(args or self.args))


def _fingerprint(closed) -> str:
    """Stable jaxpr identity: the printed jaxpr (no const values) plus
    every closed-over constant's aval."""
    h = hashlib.sha256()
    h.update(str(closed.jaxpr).encode())
    for c in closed.consts:
        a = jnp.asarray(c)
        h.update(f"{a.shape}:{a.dtype}".encode())
    return h.hexdigest()[:16]


def _walk_jaxprs(jaxpr):
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)  # ClosedJaxpr -> Jaxpr
    yield jaxpr
    is_sub = lambda x: hasattr(x, "eqns") or hasattr(x, "jaxpr")  # noqa: E731 — local predicate, not worth a def
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(v, is_leaf=is_sub):
                if is_sub(sub):
                    yield from _walk_jaxprs(sub)


def _all_avals(jaxpr):
    for j in _walk_jaxprs(jaxpr):
        for v in j.invars + j.constvars + j.outvars:
            if hasattr(v, "aval") and hasattr(v.aval, "dtype"):
                yield v.aval
        for eqn in j.eqns:
            for v in eqn.outvars:
                if hasattr(v, "aval") and hasattr(v.aval, "dtype"):
                    yield v.aval


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(
            jnp.shape(x), jnp.asarray(x).dtype if not hasattr(x, "dtype")
            else x.dtype, weak_type=getattr(x, "weak_type", False)),
        tree)


# -- check families ----------------------------------------------------------

def check_retrace(target: _AuditTarget) -> List[Finding]:
    """Round-2 trace (fed the round-1 output avals) must fingerprint
    identically to the round-1 trace, and the carried state/memory must
    keep shape, dtype and weak-type bit-for-bit."""
    out: List[Finding] = []
    jx1 = target.closed_jaxpr()
    state_out, mem_out, _ = target.out_avals()

    args = target.args
    if target.population is not None:
        cohort, state_in, mem_in, key, mask, ck = args
        args2 = (_sds(cohort), state_out, mem_out, key, mask, ck)
    else:
        state_in, mem_in, key, mask, ck = args
        args2 = (state_out, mem_out, key, mask, ck)

    in_sds = jax.tree_util.tree_map(
        lambda x: (jnp.shape(x), jnp.asarray(x).dtype), (state_in, mem_in))
    out_sds = jax.tree_util.tree_map(
        lambda x: (x.shape, x.dtype), (state_out, mem_out))
    if in_sds != out_sds:
        out.append(Finding(
            code="AUDIT-RETRACE", path=target.id, line=0,
            message=f"carried state avals drift across the round: "
                    f"{in_sds} -> {out_sds}",
            context="carry-aval-drift"))
        return out  # a drifted carry retraces by construction

    weak = [p for p, x in _tree_items(state_out)
            if getattr(x, "weak_type", False)]
    weak += [p for p, x in _tree_items(mem_out)
             if getattr(x, "weak_type", False)]
    if weak:
        out.append(Finding(
            code="AUDIT-WEAKTYPE", path=target.id, line=0,
            message=f"weak-type promotion leaks into the carried state "
                    f"at {weak} (round 2 would retrace)",
            context=f"weak:{sorted(weak)}"))

    jx2 = target.closed_jaxpr(args2)
    f1, f2 = _fingerprint(jx1), _fingerprint(jx2)
    if f1 != f2:
        out.append(Finding(
            code="AUDIT-RETRACE", path=target.id, line=0,
            message=f"jaxpr fingerprint unstable across rounds "
                    f"({f1} != {f2})",
            context="fingerprint-drift"))
    return out


def _tree_items(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), x) for p, x in flat]


def check_dtypes(target: _AuditTarget) -> List[Finding]:
    """No f64/c128 avals anywhere in the round unless x64 is on."""
    if jax.config.jax_enable_x64:
        return []  # f64 is the expected problem dtype under x64
    bad = set()
    for aval in _all_avals(target.closed_jaxpr().jaxpr):
        if aval.dtype in (jnp.dtype("float64"), jnp.dtype("complex128")):
            bad.add(str(aval.dtype))
    if bad:
        return [Finding(
            code="AUDIT-DTYPE", path=target.id, line=0,
            message=f"{sorted(bad)} avals traced with x64 disabled "
                    f"(silent downcast at runtime)",
            context=f"dtypes:{sorted(bad)}")]
    return []


def check_const_bloat(target: _AuditTarget,
                      threshold: int = CONST_BLOAT_BYTES) -> List[Finding]:
    """Closure-captured constants above the threshold. The dense
    problem's own shards are the one legitimate capture (dense mode
    closes over the problem by design); population mode allows none —
    the cohort is a traced argument, a big constant there is exactly
    the regression class PR 7 fixed."""
    closed = target.closed_jaxpr()
    allowed = {id(leaf) for leaf in jax.tree_util.tree_leaves(
        target.problem)} if target.population is None else set()
    allowed_sds = {(jnp.shape(x), str(jnp.asarray(x).dtype))
                   for x in jax.tree_util.tree_leaves(target.problem)
                   } if target.population is None else set()
    out: List[Finding] = []
    for c in closed.consts:
        a = jnp.asarray(c)
        nbytes = int(np.prod(a.shape)) * a.dtype.itemsize
        if nbytes < threshold:
            continue
        if id(c) in allowed or (a.shape, str(a.dtype)) in allowed_sds:
            continue
        out.append(Finding(
            code="AUDIT-CONST", path=target.id, line=0,
            message=f"closure-captured constant {a.shape}:{a.dtype} "
                    f"({nbytes} B) baked into the round jaxpr",
            context=f"const:{a.shape}:{a.dtype}"))
    return out


def check_primitives(target: _AuditTarget) -> List[Finding]:
    out: List[Finding] = []
    seen = set()
    for j in _walk_jaxprs(target.closed_jaxpr().jaxpr):
        for eqn in j.eqns:
            name = eqn.primitive.name
            if name in FORBIDDEN_PRIMITIVES and name not in seen:
                seen.add(name)
                out.append(Finding(
                    code="AUDIT-PRIMITIVE", path=target.id, line=0,
                    message=f"forbidden primitive {name!r} inside the "
                            f"round body (host round-trip in traced code)",
                    context=f"primitive:{name}"))
    return out


class _RecordingRound(CommRound):
    """CommRound that records every payload occurrence's billed shape
    and dtype as traced (uplink payloads drop the leading client axis
    unless a native ``wire_shape`` overrides it)."""

    def __init__(self, *args, record, **kw):
        super().__init__(*args, **kw)
        self._record = record

    def uplink(self, name, x, wire_shape=None, ef_eligible=True,
               ef_reset=None):
        occ = self._occurrences.get(name, 0)
        pkey = name if occ == 0 else f"{name}#{occ}"
        shape = (tuple(wire_shape) if wire_shape is not None
                 else tuple(jnp.shape(x)[1:]))
        self._record[pkey] = (name, shape, jnp.asarray(x).dtype)
        return super().uplink(name, x, wire_shape=wire_shape,
                              ef_eligible=ef_eligible, ef_reset=ef_reset)

    def downlink(self, name, x, wire_shape=None):
        from repro.comm.config import DOWN

        dname = f"{DOWN}{name}"
        occ = self._occurrences.get(dname, 0)
        pkey = dname if occ == 0 else f"{dname}#{occ}"
        shape = (tuple(wire_shape) if wire_shape is not None
                 else tuple(jnp.shape(x)))
        self._record[pkey] = (dname, shape, jnp.asarray(x).dtype)
        return super().downlink(name, x, wire_shape=wire_shape)


def _recorded_probe(target: _AuditTarget):
    """Abstract probe of the round through a recording CommRound:
    returns ``(plan, record)`` filled by one eval_shape pass."""
    sess = target.sess
    plan: Dict[str, int] = {}
    record: Dict[str, tuple] = {}
    trace_round = target.trace_with(target.state0)

    args = target.args
    mask, ck = args[-2], args[-1]

    def probe(mask, ck):
        cr = _RecordingRound(target.comm, plan, mask, ck,
                             memory=dict(args[-4] if target.population
                                         is not None else args[1]),
                             record=record)
        return trace_round(cr)

    jax.eval_shape(probe, mask, ck)
    return plan, record, sess


def check_wire(target: _AuditTarget) -> List[Finding]:
    """Billed plan bytes == codec.nbytes(traced aval shape) for every
    payload occurrence, in both directions; and the plan the real jit
    trace filled agrees with the independent probe."""
    out: List[Finding] = []
    probe_plan, record, sess = _recorded_probe(target)

    for pkey, (name, shape, dtype) in sorted(record.items()):
        codec = target.comm.codec_for(name)
        expect = codec.nbytes(shape, dtype)
        billed = probe_plan.get(pkey)
        if billed != expect:
            out.append(Finding(
                code="AUDIT-WIRE", path=target.id, line=0,
                message=f"payload {pkey!r}: billed {billed} B, codec "
                        f"prices {expect} B for {shape}:{dtype}",
                context=f"wire:{pkey}"))
    missing = set(probe_plan) - set(record)
    if missing:
        out.append(Finding(
            code="AUDIT-WIRE", path=target.id, line=0,
            message=f"plan bills occurrences never traced: "
                    f"{sorted(missing)}",
            context=f"wire-extra:{sorted(missing)}"))

    # the plan the REAL jit trace filled (during closed_jaxpr) must
    # agree with the independent probe — a drift here means accounting
    # and execution see different payload shapes
    target.closed_jaxpr()  # ensure the live plan is filled
    live = dict(sess.plan)
    if live and live != probe_plan:
        out.append(Finding(
            code="AUDIT-WIRE", path=target.id, line=0,
            message=f"live trace plan {live} != probe plan {probe_plan}",
            context="wire-plan-drift"))
    return out


def check_threat_scope(optimizer: str = "fedavg",
                       payload: str = "w_local") -> List[Finding]:
    """Scoped-threat byte identity: with a ``ThreatModel`` restricted
    to ``payloads=(payload,)``, every OTHER uplink of the eager round
    must be byte-identical to the threat-free round, and the targeted
    payload must differ on attacker rows."""
    out: List[Finding] = []
    dyn = DynamicsConfig(threat=f"signflip:0.5@{payload}", seed=3)

    def eager_uplinks(dynamics):
        t = _AuditTarget(optimizer, "sync", "identity", dynamics=dynamics)
        captured: Dict[str, jax.Array] = {}

        class _Capture(_RecordingRound):
            def uplink(self, name, x, **kw):
                y = super().uplink(name, x, **kw)
                captured[name] = y
                return y

        args = t.args
        state, mem, key, mask, ck = args
        cr = _Capture(t.comm, {}, mask, ck, memory=dict(mem), record={})
        t.opt.round(t.problem, state, key, comm=cr)
        attackers = (dynamics.threat.attacker_mask(np.arange(t.sess.m))
                     if dynamics is not None and dynamics.threat is not None
                     else np.zeros(t.sess.m, dtype=bool))
        return captured, attackers

    clean, _ = eager_uplinks(None)
    scoped, attackers = eager_uplinks(dyn)
    if payload not in scoped:
        out.append(Finding(
            code="AUDIT-THREAT", path=f"{optimizer}/threat-scope", line=0,
            message=f"targeted payload {payload!r} never uplinked by "
                    f"{optimizer} — scope check is vacuous",
            context="threat-missing-payload"))
        return out
    for name in clean:
        a, b = np.asarray(clean[name]), np.asarray(scoped[name])
        if name == payload:
            if attackers.any() and np.array_equal(a, b):
                out.append(Finding(
                    code="AUDIT-THREAT", path=f"{optimizer}/threat-scope",
                    line=0,
                    message=f"targeted payload {name!r} unchanged under "
                            f"a scoped threat with live attackers",
                    context=f"threat-not-applied:{name}"))
        elif not np.array_equal(a, b):
            out.append(Finding(
                code="AUDIT-THREAT", path=f"{optimizer}/threat-scope",
                line=0,
                message=f"untargeted payload {name!r} not byte-identical "
                        f"under a threat scoped to {payload!r}",
                context=f"threat-leak:{name}"))
    return out


def audit_combo(optimizer: str, session: str, codec: str) -> List[Finding]:
    target = _AuditTarget(optimizer, session, codec)
    out: List[Finding] = []
    out += check_retrace(target)
    out += check_dtypes(target)
    out += check_const_bloat(target)
    out += check_primitives(target)
    out += check_wire(target)
    return out


def audit_retraces_dynamic(
        optimizers: Iterable[str] = ("flens", "fedavg", "fednl"),
        sessions: Iterable[str] = SESSIONS) -> List[Finding]:
    """Run short instrumented trajectories and assert the ``repro.obs``
    ``variant_retraces`` counter stayed zero — the runtime witness the
    static fingerprint check cross-checks against."""
    from repro.core.base import run_rounds
    from repro.obs import TelemetryConfig

    out: List[Finding] = []
    for o in optimizers:
        for s in sessions:
            if s == "population" and o == "fednew":
                continue
            comm = _comm_config(s, "identity")
            if s == "population":
                problem: Any = _toy_population()
                dim = _DIM
            else:
                problem = _toy_problem()
                dim = problem.dim
            opt = _make_optimizer(o)
            w0 = jnp.zeros((dim,),
                           problem.eval_problem().X.dtype
                           if s == "population" else problem.X.dtype)
            hist = run_rounds(opt, problem, w0, w0, rounds=3,
                              seed=_AUDIT_SEED, comm=comm,
                              obs=TelemetryConfig(sink="null"))
            counters = (hist.telemetry or {}).get(
                "metrics", {}).get("counters", {})
            n = counters.get("variant_retraces", 0)
            if n:
                out.append(Finding(
                    code="AUDIT-RETRACE", path=f"{o}/{s}/identity", line=0,
                    message=f"obs variant_retraces counter hit {n} over a "
                            f"3-round single-variant trajectory",
                    context="dynamic-retrace-counter"))
    return out


def audit_repo(optimizers: Optional[Iterable[str]] = None,
               sessions: Optional[Iterable[str]] = None,
               codecs: Optional[Iterable[str]] = None,
               *, dynamic: bool = True,
               threat_scope: bool = True) -> List[Finding]:
    """The full audit: every combo's static checks, the threat-scope
    byte-identity check, and the dynamic retrace cross-check."""
    out: List[Finding] = []
    for o, s, c in combos(optimizers, sessions, codecs):
        out.extend(audit_combo(o, s, c))
    if threat_scope:
        out.extend(check_threat_scope())
    if dynamic:
        out.extend(audit_retraces_dynamic(
            sessions=tuple(sessions) if sessions is not None else SESSIONS))
    return out
