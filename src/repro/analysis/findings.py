"""Finding records + the committed-baseline diff protocol.

Both analysis passes (``repro.analysis.lint``, ``repro.analysis.audit``)
emit ``Finding`` records. A finding's *fingerprint* deliberately
excludes the line number — it hashes the rule code, the repo-relative
path, and a context snippet (the stripped source line for lint, the
check-specific detail key for audit) — so unrelated edits that shift
line numbers never churn the committed baseline, while a genuinely new
violation always diffs as new.

Baseline workflow (mirrors the benchmark regression gate):

  * ``python -m repro.analysis all`` — findings diff against
    ``results/analysis_baseline.json``; NEW findings fail (exit 1),
    baselined ones are reported as accepted debt, fixed ones as
    resolved.
  * ``--update`` rewrites the baseline to the current finding set (the
    reviewed way to accept debt or record progress).

The committed baseline is empty: every pre-existing violation was
either fixed or given an inline ``# noqa: RAxxx — why`` sanction in the
PR that introduced this layer, so any finding is a regression.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation: a lint rule hit or an audit check failure."""

    code: str  # "RA001" ... (lint) or "AUDIT-*" (trace auditor)
    path: str  # repo-relative file path, or the audited combo id
    line: int  # 1-based line (0 for audit findings — no source span)
    message: str
    context: str = ""  # fingerprint anchor: source line / check detail

    @property
    def fingerprint(self) -> str:
        h = hashlib.sha256()
        h.update(self.code.encode())
        h.update(b"\0")
        h.update(self.path.encode())
        h.update(b"\0")
        h.update(self.context.strip().encode())
        return h.hexdigest()[:16]

    @property
    def span(self) -> str:
        return f"{self.path}:{self.line}" if self.line else self.path

    def render(self) -> str:
        return f"{self.span}: {self.code} {self.message}"

    def to_dict(self) -> dict:
        return {
            "code": self.code,
            "path": self.path,
            "line": self.line,
            "message": self.message,
            "context": self.context,
            "fingerprint": self.fingerprint,
        }


_BASELINE_SCHEMA = "repro.analysis/v1"


def load_baseline(path) -> "set[str]":
    """Accepted-finding fingerprints from a committed baseline JSON
    (missing file = empty baseline: everything is new)."""
    p = pathlib.Path(path)
    if not p.exists():
        return set()
    doc = json.loads(p.read_text())
    if doc.get("schema") != _BASELINE_SCHEMA:
        raise ValueError(
            f"{path}: baseline schema {doc.get('schema')!r} != "
            f"{_BASELINE_SCHEMA!r}")
    return {f["fingerprint"] for f in doc.get("findings", [])}


def save_baseline(path, findings: List[Finding]) -> None:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    doc = {
        "schema": _BASELINE_SCHEMA,
        "findings": sorted((f.to_dict() for f in findings),
                           key=lambda d: (d["path"], d["code"], d["line"])),
    }
    p.write_text(json.dumps(doc, indent=2) + "\n")


@dataclasses.dataclass
class Diff:
    """Current findings split against the baseline fingerprints."""

    new: List[Finding]
    accepted: List[Finding]  # still present, already baselined
    resolved: "set[str]"  # baselined fingerprints no longer found

    @property
    def failed(self) -> bool:
        return bool(self.new)


def diff_baseline(findings: List[Finding],
                  baseline: Optional["set[str]"]) -> Diff:
    baseline = baseline or set()
    new = [f for f in findings if f.fingerprint not in baseline]
    accepted = [f for f in findings if f.fingerprint in baseline]
    current = {f.fingerprint for f in findings}
    return Diff(new=new, accepted=accepted, resolved=baseline - current)
