"""Layer 1: repo-specific AST lint rules (RAxxx).

The invariants every headline claim rests on — seeded determinism, one
PRNG discipline, the x64 boundary, the ``repro.obs`` warning funnel —
are conventions until something checks them. These rules check them at
the AST level over ``src/repro/**``:

  RA000  a ``# noqa: RAxxx`` suppression without a trailing
         justification comment (every sanction must say why)
  RA001  raw ``jax.random.PRNGKey(...)`` outside the sanctioned mint
         helper (``repro.core.base.root_key``): keys must derive from
         the driver key stream (``split`` / ``fold_in``) or from a
         documented ``(seed, id)`` salt site carrying a suppression
  RA002  PRNG key reuse: the same key binding consumed by two or more
         ``jax.random.*`` draws without an intervening reassignment
         (``split`` / ``fold_in`` derive — they do not draw)
  RA003  ``warnings.warn`` outside ``repro.obs.log`` (the structured
         warning funnel; ad-hoc warnings bypass run telemetry)
  RA004  wall-clock / global-RNG nondeterminism in library code:
         ``time.time``, ``datetime.now``/``utcnow``, ``np.random.*``
         (the seeded ``np.random.default_rng`` is allowed only under
         ``repro/data/`` — dataset synthesis owns its generators)
  RA005  ``jnp.float64`` / ``jnp.complex128`` outside the documented
         x64 allowlist (``optim/flens_head.py``); host-side
         ``np.float64`` accounting is always allowed
  RA006  mutable default arguments, and bare ``assert`` statements in
         library code (stripped under ``python -O``)

Suppression syntax (per line): ``# noqa: RA001 — why this is sanctioned``
(multiple codes comma-separated; a bare ``# noqa`` suppresses every RA
rule). RA000 itself enforces the justification text.
"""
from __future__ import annotations

import ast
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.findings import Finding

RULES: Dict[str, str] = {
    "RA000": "suppression without justification",
    "RA001": "raw PRNGKey outside sanctioned sites",
    "RA002": "PRNG key reuse without split/fold_in",
    "RA003": "warnings.warn outside repro.obs.log",
    "RA004": "wall-clock/global-RNG nondeterminism",
    "RA005": "float64 leak outside the x64 allowlist",
    "RA006": "mutable default arg / bare assert",
}

# jax.random.* callees that derive or wrap keys rather than draw from
# them: they neither consume a binding (RA002) nor mint one (RA001)
_KEY_DERIVERS = {"split", "fold_in", "key_data", "wrap_key_data", "clone",
                 "key_impl"}

# RA003: the one module allowed to call warnings.warn (the funnel)
_WARN_FUNNEL = "obs/log.py"
# RA004: seeded numpy generators are a dataset-synthesis tool
_NP_RANDOM_OK_DIR = "repro/data/"
# RA005: the documented x64 allowlist (paper-fidelity float64 paths)
_X64_ALLOWLIST = ("optim/flens_head.py",)

_NOQA_RE = re.compile(
    r"#\s*noqa(?P<codes>:\s*[A-Z]+\d+(?:\s*,\s*[A-Z]+\d+)*)?(?P<rest>.*)",
)
_RA_CODE_RE = re.compile(r"RA\d{3}")


def _parse_noqa(src: str) -> Dict[int, "Set[str] | None"]:
    """Map line number -> suppressed RA codes (None = all RA codes).

    Also returns implicit RA000 targets: handled by ``lint_source``
    (a suppression whose trailing text is empty carries no why).
    """
    out: Dict[int, "Set[str] | None"] = {}
    for i, line in enumerate(src.splitlines(), start=1):
        m = _NOQA_RE.search(line)
        if not m:
            continue
        codes = m.group("codes")
        if codes is None:
            out[i] = None  # bare noqa: everything suppressed
            continue
        ra = set(_RA_CODE_RE.findall(codes))
        if ra:
            out[i] = ra
    return out


def _justified(src_line: str) -> bool:
    """A sanction must carry prose after the codes (``— why``)."""
    m = _NOQA_RE.search(src_line)
    if m is None:
        return True
    rest = (m.group("rest") or "").strip(" -—:\t")
    return bool(rest)


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of a call target / attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


class _Rules(ast.NodeVisitor):
    def __init__(self, path: str, lines: List[str]):
        self.path = path
        self.lines = lines
        self.findings: List[Finding] = []
        # RA002 per-scope key consumption state: name -> True (consumed)
        self._consumed: Dict[str, int] = {}
        self._seen: Set[tuple] = set()

    # -- helpers -------------------------------------------------------------
    def _emit(self, code: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        if (code, line) in self._seen:
            return
        self._seen.add((code, line))
        context = (self.lines[line - 1].strip()
                   if 0 < line <= len(self.lines) else "")
        self.findings.append(Finding(
            code=code, path=self.path, line=line,
            message=f"{message} [{RULES[code]}]", context=context))

    def _in(self, *suffixes: str) -> bool:
        return any(self.path.endswith(s) or f"/{s}" in f"/{self.path}"
                   for s in suffixes)

    # -- function-scope framing (RA002 state, RA006 defaults) ----------------
    def _visit_function(self, node) -> None:
        for default in list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]:
            if isinstance(default, (ast.List, ast.Dict, ast.Set)):
                self._emit("RA006", default,
                           "mutable default argument (shared across calls)")
        outer = self._consumed
        self._consumed = {}
        self.generic_visit(node)
        self._consumed = outer

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    # -- RA006: bare assert --------------------------------------------------
    def visit_Assert(self, node: ast.Assert) -> None:
        self._emit("RA006", node,
                   "bare assert (stripped under -O); raise instead")
        self.generic_visit(node)

    # -- branch merging for RA002 (exclusive branches share a snapshot) ------
    @staticmethod
    def _terminates(body: list) -> bool:
        """Does the branch leave the enclosing flow (so its consumed
        state never reaches the code after the ``if``)?"""
        return bool(body) and isinstance(
            body[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))

    def visit_If(self, node: ast.If) -> None:
        self.visit(node.test)
        snapshot = dict(self._consumed)
        for stmt in node.body:
            self.visit(stmt)
        after_body = (dict(snapshot) if self._terminates(node.body)
                      else self._consumed)
        self._consumed = dict(snapshot)
        for stmt in node.orelse:
            self.visit(stmt)
        if self._terminates(node.orelse):
            self._consumed = dict(snapshot)
        # union: a key consumed on either surviving path stays consumed
        self._consumed.update(after_body)

    def _visit_loop(self, node) -> None:
        # two passes over the body: the second catches draws that reuse
        # a key binding across iterations (no reassignment in between)
        for _ in range(2):
            for stmt in node.body:
                self.visit(stmt)
        for stmt in node.orelse:
            self.visit(stmt)

    def visit_For(self, node: ast.For) -> None:
        self.visit(node.iter)
        self._visit_loop(node)

    def visit_While(self, node: ast.While) -> None:
        self.visit(node.test)
        self._visit_loop(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        self.visit(node.value)
        for tgt in node.targets:
            for name in ast.walk(tgt):
                if isinstance(name, ast.Name):
                    self._consumed.pop(name.id, None)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self.visit(node.value)
        if isinstance(node.target, ast.Name):
            self._consumed.pop(node.target.id, None)

    # -- calls: RA001/RA002/RA003/RA004 --------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        leaf = dotted.rsplit(".", 1)[-1]

        if leaf == "PRNGKey":
            self._emit(
                "RA001", node,
                "raw jax.random.PRNGKey: derive from the driver key "
                "stream or repro.core.base.root_key")

        if dotted.startswith("jax.random.") and leaf != "PRNGKey":
            if leaf not in _KEY_DERIVERS and node.args:
                first = node.args[0]
                if isinstance(first, ast.Name):
                    prev = self._consumed.get(first.id)
                    if prev is not None:
                        self._emit(
                            "RA002", node,
                            f"key {first.id!r} already consumed by a "
                            f"draw on line {prev}")
                    else:
                        self._consumed[first.id] = node.lineno

        if dotted == "warnings.warn" and not self._in(_WARN_FUNNEL):
            self._emit(
                "RA003", node,
                "route through repro.obs.log (warn_with_context)")

        if dotted in ("time.time", "datetime.now", "datetime.datetime.now",
                      "datetime.utcnow", "datetime.datetime.utcnow"):
            self._emit("RA004", node, f"{dotted} in library code")

        self.generic_visit(node)

    # -- attributes: RA004 np.random, RA005 jnp.float64 ----------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        dotted = _dotted(node)
        if (dotted.startswith(("np.random.", "numpy.random."))
                and _NP_RANDOM_OK_DIR not in self.path):
            self._emit("RA004", node,
                       f"{dotted}: global/numpy RNG outside repro/data/")
        if (dotted in ("jnp.float64", "jnp.complex128",
                       "jax.numpy.float64", "jax.numpy.complex128")
                and not self._in(*_X64_ALLOWLIST)):
            # the documented gating idiom — ``jnp.float64 if
            # jax.config.jax_enable_x64 else jnp.float32`` — is allowed
            # when the guard sits on the same source line
            line = (self.lines[node.lineno - 1]
                    if 0 < node.lineno <= len(self.lines) else "")
            if "jax_enable_x64" not in line:
                self._emit(
                    "RA005", node,
                    f"{dotted} outside the x64 allowlist (gate on "
                    f"jax.config.jax_enable_x64 or sanction with a why)")
        self.generic_visit(node)


def lint_source(src: str, path: str = "<memory>") -> List[Finding]:
    """Lint one source blob (the unit the rule tests drive)."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding(code="RA000", path=path, line=e.lineno or 0,
                        message=f"unparsable source: {e.msg}",
                        context="syntax-error")]
    lines = src.splitlines()
    visitor = _Rules(path, lines)
    visitor.visit(tree)
    suppressions = _parse_noqa(src)

    _UNSET = object()
    kept: List[Finding] = []
    for f in visitor.findings:
        codes = suppressions.get(f.line, _UNSET)
        if codes is _UNSET:
            kept.append(f)
        elif codes is None or f.code in codes:
            pass  # suppressed (RA000 still audits the sanction below)
        else:
            kept.append(f)
    # RA000: any RA suppression (used or not) must carry a justification
    for line, codes in suppressions.items():
        src_line = lines[line - 1] if 0 < line <= len(lines) else ""
        if not _justified(src_line):
            kept.append(Finding(
                code="RA000", path=path, line=line,
                message=f"suppression {sorted(codes) if codes else 'noqa'} "
                        f"carries no justification [{RULES['RA000']}]",
                context=src_line.strip()))
    kept.sort(key=lambda f: (f.path, f.line, f.code))
    return kept


def _iter_files(root: pathlib.Path) -> Iterable[pathlib.Path]:
    yield from sorted((root / "src" / "repro").rglob("*.py"))


def lint_repo(root: "pathlib.Path | str" = ".",
              files: Optional[Iterable] = None) -> List[Finding]:
    """Lint the library tree (``src/repro/**``) and return findings."""
    root = pathlib.Path(root)
    paths = ([pathlib.Path(f) for f in files] if files is not None
             else _iter_files(root))
    out: List[Finding] = []
    for p in paths:
        try:
            rel = p.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = p.as_posix()
        out.extend(lint_source(p.read_text(), rel))
    return out
