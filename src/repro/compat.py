"""Version-skew shims for the installed JAX.

``shard_map`` moved from ``jax.experimental.shard_map`` to the ``jax``
top level, and its replication-check kwarg was renamed
(``check_rep`` → ``check_vma``) along the way. Import it from here and
pass either spelling; the shim translates to whatever the installed JAX
accepts.
"""
from __future__ import annotations

import inspect

try:  # new-style top-level export
    from jax import shard_map as _shard_map
except ImportError:  # older JAX
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, **kwargs):
    """``jax.shard_map`` with check_vma/check_rep kwarg translation."""
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    if "check_rep" in kwargs and "check_rep" not in _PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    return _shard_map(f, **kwargs)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every JAX version
    (older releases return a one-element list of per-program dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
